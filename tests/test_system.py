"""End-to-end system behaviour: engines, ARCA-driven serving, emitted-token
accounting — the paper's full pipeline at smoke scale."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import arca
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.data.pipeline import MarkovDataset
from repro.models.api import get_model
from repro.runtime.engine import BatchEngine, SpeculativeEngine, \
    measure_acceptance


def _setup(arch="qwen2-0.5b"):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(7))
    return cfg, model, params, heads


def test_batch_engine_matches_manual_greedy():
    cfg, model, params, _ = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab_size)
    eng = BatchEngine(model, params, max_len=40)
    out, stats = eng.generate({"tokens": toks}, 6)
    assert out.shape == (3, 6)

    # manual reference
    logits, _, cache = model.prefill(params, {"tokens": toks}, max_len=40)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    ref = [np.asarray(cur)]
    for _ in range(5):
        lg, cache = model.decode(params, cache, cur[:, None])
        cur = jnp.argmax(lg[:, 0], -1).astype(jnp.int32)
        ref.append(np.asarray(cur))
    np.testing.assert_array_equal(out, np.stack(ref, 1))


def test_speculative_engine_lossless_and_counts():
    cfg, model, params, heads = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0, cfg.vocab_size)
    spec = T.build_tree(T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 8)

    seq = BatchEngine(model, params, max_len=64)
    ref, _ = seq.generate({"tokens": toks}, 16)
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64)
    out, stats = eng.generate({"tokens": toks}, 16)
    np.testing.assert_array_equal(out[:16], ref[0][:16])
    # accounting: emitted tokens = sum of acceptance lengths (bounded rel err
    # because the last step may be truncated by n_tokens)
    assert stats["steps"] >= 1
    assert 1.0 <= stats["acceptance_length"] <= spec.max_depth


def test_arca_strategy_runs_through_engine():
    cfg, model, params, heads = _setup()
    accs = T.default_accs(cfg.medusa_heads, cfg.medusa_top_k)
    strat = arca.best(arca.choose_strategy(cfg, accs, ctx=64))
    assert strat.width in arca.WIDTHS
    data = MarkovDataset(cfg.vocab_size, seed=3)
    prompts = [{"tokens": jnp.asarray(
        data.sample(1, 8, seed=s)[:, :-1].astype(np.int32))} for s in range(2)]
    al = measure_acceptance(model, heads, params, strat.tree, prompts,
                            n_tokens=12, max_len=64)
    assert 1.0 <= al <= strat.tree.max_depth
