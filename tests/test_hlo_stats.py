"""HLO collective parser unit tests (the roofline's collective term)."""
from repro.launch.hlo_stats import collective_bytes, hlo_op_histogram

HLO = """
ENTRY %main {
  %p0 = bf16[8,128]{1,0} parameter(0)
  %ag = bf16[8,2048]{1,0} all-gather(bf16[8,128]{1,0} %p0), dimensions={1}
  %ar = f32[4096]{0} all-reduce(f32[4096]{0} %x), to_apply=%sum
  %rs = f32[256]{0} reduce-scatter(f32[4096]{0} %ar), dimensions={0}
  %a2a = (s32[16]{0}, s32[16]{0}) all-to-all(s32[16]{0} %a, s32[16]{0} %b)
  %cp = bf16[2,4]{1,0} collective-permute(bf16[2,4]{1,0} %y)
  %ags = (f32[8]{0}, f32[8]{0}) all-gather-start(f32[8]{0} %z)
  %agd = f32[8]{0} all-gather-done((f32[8]{0}, f32[8]{0}) %ags)
  %dot = f32[8,8]{1,0} dot(f32[8,4]{1,0} %l, f32[4,8]{1,0} %r)
}
"""


def test_collective_bytes():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 8 * 2048 * 2 + 8 * 8      # ag + ag-start tuple
    assert out["all-reduce"] == 4096 * 4
    assert out["reduce-scatter"] == 256 * 4
    assert out["all-to-all"] == 2 * 16 * 4
    assert out["collective-permute"] == 2 * 4 * 2
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_histogram():
    h = hlo_op_histogram(HLO)
    assert h.get("all-gather", 0) >= 1
    assert "dot" in h
