"""Sharded lowering tests: reduced configs must lower+compile on a small
multi-device mesh in BOTH TP modes, in a subprocess (the 8-device XLA flag
must not leak into this process — smoke tests see 1 device)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.core.hcmp import sharding as shd
from repro.models.api import get_model
from repro.runtime.cache import init_kv_cache, Cache
from repro.models import hybrid, xlstm_model

arch, mode = sys.argv[1], sys.argv[2]
cfg = get_config(arch).reduced()
model = get_model(cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params_struct = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
pspecs = shd.param_specs(cfg, params_struct, mode=mode)
ns = lambda t: jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), t,
                                      is_leaf=lambda x: isinstance(x, P))
B, S = 8, 64
def build_cache():
    if cfg.arch_type == "hybrid":
        return hybrid.init_cache(cfg, B, S)
    if cfg.arch_type == "ssm":
        return xlstm_model.init_cache(cfg, B)
    ck = init_kv_cache(cfg.num_layers, B, S, cfg.num_kv_heads, cfg.head_dim,
                       dtype=jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        cz = jnp.zeros((cfg.num_layers, B, 16, cfg.num_kv_heads, cfg.head_dim),
                       jnp.dtype(cfg.dtype))
        return Cache(kv=ck, cross_k=cz, cross_v=cz)
    return Cache(kv=ck)
cache_struct = jax.eval_shape(build_cache)
cspecs = shd.cache_specs(cfg, cache_struct, batch_axes=("data",))
tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
with mesh:
    f = jax.jit(lambda p, c, t: model.decode(p, c, t),
                in_shardings=(ns(pspecs), ns(cspecs),
                              NamedSharding(mesh, P("data", None))))
    compiled = f.lower(params_struct, cache_struct, tok).compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):          # older jax: one dict per device
    ca = ca[0] if ca else {}
print(json.dumps({"ok": True, "flops": ca.get("flops", 0)}))
"""


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-moe-30b-a3b",
                                  "zamba2-7b", "seamless-m4t-medium",
                                  "xlstm-125m"])
@pytest.mark.parametrize("mode", ["hcmp", "megatron"])
def test_reduced_arch_lowers_on_mesh(arch, mode):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT, arch, mode],
                         capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
