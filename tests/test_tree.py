"""Verification-tree properties (hypothesis) — paper §III-C1 machinery."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # container may not ship hypothesis
    from _mini_hypothesis import given, settings, strategies as st

from repro.core.speculative import tree as T


def accs_strategy():
    return st.tuples(
        st.integers(2, 5),                        # heads
        st.integers(2, 6),                        # top-k
        st.floats(0.3, 0.9),                      # a1
        st.floats(0.5, 0.95),                     # head decay
        st.floats(0.2, 0.8),                      # rank decay
    ).map(lambda t: T.default_accs(t[0], t[1], t[2], t[3], t[4]))


@given(accs=accs_strategy(), width=st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=25, deadline=None)
def test_tree_is_valid(accs, width):
    spec = T.build_tree_greedy(accs, width)
    assert spec.width <= width
    assert spec.parent[0] == -1
    for i in range(1, spec.width):
        p = spec.parent[i]
        assert 0 <= p < i                          # topo order
        assert spec.depth[i] == spec.depth[p] + 1
        assert spec.mask[i, p] and spec.mask[i, i]
    # every path's prefix is an ancestor chain
    for row in spec.paths:
        for d in range(1, spec.max_depth):
            if row[d] != row[d - 1]:
                assert spec.parent[row[d]] == row[d - 1]


@given(accs=accs_strategy())
@settings(max_examples=15, deadline=None)
def test_acceptance_monotone_in_width(accs):
    als = [T.expected_acceptance_length(T.build_tree_greedy(accs, w), accs)
           for w in (1, 2, 4, 8, 16, 32)]
    assert all(b >= a - 1e-9 for a, b in zip(als, als[1:]))


@given(accs=accs_strategy(), width=st.sampled_from([4, 8, 12]),
       seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_greedy_beats_random_trees(accs, width, seed):
    """Greedy-by-path-product selects the top-W node set => it is optimal
    under the estimator; any random valid tree must not beat it."""
    H, K = accs.shape
    # clamp to the tree capacity (sum of K^d, d<=H) or random growth can
    # exhaust the candidate space and loop forever
    cap = sum(K ** d for d in range(H + 1))
    width = min(width, cap)
    spec = T.build_tree_greedy(accs, width)
    best = T.expected_acceptance_length(spec, accs)
    rng = np.random.default_rng(seed)
    nodes = [(-1, 0, 0)]
    used = set()
    attempts = 0
    while len(nodes) < width and attempts < 10_000:
        attempts += 1
        p = int(rng.integers(0, len(nodes)))
        d = nodes[p][1] + 1
        r = int(rng.integers(0, K))
        if d > H or (p, r) in used:
            continue
        used.add((p, r))
        nodes.append((p, d, r))
    rand_spec = T.spec_from_nodes(nodes)
    rand_al = T.expected_acceptance_length(rand_spec, accs)
    assert best >= rand_al - 1e-9


@given(accs=accs_strategy(), width=st.sampled_from([4, 8]))
@settings(max_examples=10, deadline=None)
def test_refine_never_decreases(accs, width):
    g = T.build_tree_greedy(accs, width)
    r = T.refine_tree(g, accs)
    assert (T.expected_acceptance_length(r, accs)
            >= T.expected_acceptance_length(g, accs) - 1e-12)


def test_width_one_is_sequential():
    spec = T.spec_from_nodes([(-1, 0, 0)])
    accs = T.default_accs()
    assert T.expected_acceptance_length(spec, accs) == pytest.approx(1.0)


def test_table1_regime():
    """Estimator in the paper's Table-I numeric regime (MT-bench row)."""
    accs = T.default_accs(4, 10)
    al2 = T.expected_acceptance_length(T.build_tree(accs, 2), accs)
    al64 = T.expected_acceptance_length(T.build_tree(accs, 64), accs)
    assert 1.5 < al2 < 2.0                        # paper: 1.72
    assert 3.0 < al64 < 5.0                       # paper: 3.34-3.74
