"""Boundary-protocol model checker (analysis/modelcheck.py).

Three layers of pinning:

  * the checker has TEETH: seeded protocol bugs (a page leak in the abort
    sweep, a ``fail_all`` that forgets to drain the queue, an admission
    pass ordered before the abort sweep) are each caught with a concrete
    counterexample trace;
  * the documented default bound (3 requests, pool pressure, chunked
    prefill, crash at every reachable state) explores completely and
    violation-free — this is the same exploration the R9 lint rule and the
    CI gate run;
  * the model is FAITHFUL: identical action traces replayed against the
    real ``ContinuousScheduler`` + paged ``BatchEngine`` produce the same
    terminal states, the same per-request emission counts, the same
    per-boundary pool occupancy, and the same drained pool at the end.
"""
import jax
import numpy as np
import pytest

from repro.analysis import modelcheck as mc
from repro.configs import get_config
from repro.models.api import get_model
from repro.runtime.engine import BatchEngine
from repro.runtime.scheduler import ContinuousScheduler, Request

# ---------------------------------------------------------------------------
# explorer teeth: seeded bugs must be caught
# ---------------------------------------------------------------------------


class _LeakyAbortModel(mc.SchedModel):
    """Abort sweep 'releases' a row without returning its pages."""

    def _release(self, slot, kind):
        if kind == "abort_release":
            slot["pages"] = 0
            self.boundary_events.append(kind)
            return
        super()._release(slot, kind)


class _UndrainedFailModel(mc.SchedModel):
    """fail_all forgets self.pending: post-crash boundaries can admit."""

    def fail_all(self):
        self.failed = True
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            kept = min(s["out"], self.reqs[s["id"]].n_tokens)
            self._finalize(s["id"], kept, mc.FAILED)
            self._release(s, "fail_release")
            self.slots[b] = None
        self.aborts = {}


class _AdmitFirstModel(mc.SchedModel):
    """Boundary runs admissions BEFORE the abort sweep (protocol order
    inverted): freed pages arrive too late for same-boundary reuse, and
    an aborted-then-refilled row double-finalizes."""

    def boundary(self):
        ev_admit = []
        c = self.cfg
        for b in range(c.batch):
            if self.slots[b] is not None or not self.pending:
                continue
            req = self.reqs[self.pending[0]]
            need = self._need_pages(req)
            if self.started and self.free < need:
                break
            self.pending.pop(0)
            self.free -= need
            self.started = True
            self.slots[b] = {"id": req.req_id, "out": 1,
                             "rem": max(req.n_tokens - 1, 0),
                             "done": False, "left": None, "pages": need}
            self.state_of[req.req_id] = mc.DECODING
            ev_admit.append("admit")
        flushed = super().boundary()
        # true temporal order: these admissions happened FIRST
        self.boundary_events = ev_admit + self.boundary_events
        self._check_boundary_order()
        return flushed


def _explore_with(model_cls):
    orig = mc.SchedModel
    mc.SchedModel = model_cls
    try:
        return mc.explore(mc.DEFAULT_REQUESTS, mc.DEFAULT_CONFIG,
                          max_seconds=60.0)
    finally:
        mc.SchedModel = orig


def test_checker_catches_page_leak_on_abort():
    res = _explore_with(_LeakyAbortModel)
    assert res.violations
    assert all(msg.startswith("I1") for _, msg in res.violations)
    # every counterexample is a concrete actionable trace
    trace = mc.render_trace(res.violations[0][0])
    assert "abort(" in trace and "boundary" in trace


def test_checker_catches_undrained_fail_all():
    res = _explore_with(_UndrainedFailModel)
    assert res.violations
    assert any(msg.startswith("I4") for _, msg in res.violations)
    bad = next(p for p, m in res.violations if m.startswith("I4"))
    assert ("crash",) in bad


def test_checker_catches_admit_before_abort_sweep():
    res = _explore_with(_AdmitFirstModel)
    assert res.violations
    kinds = {m.split(":")[0] for _, m in res.violations}
    assert "I3" in kinds


def test_default_bound_explores_clean():
    res = mc.explore(mc.DEFAULT_REQUESTS, mc.DEFAULT_CONFIG,
                     max_seconds=60.0)
    assert res.complete and not res.violations
    # the bound is non-trivial: hundreds of canonical states, crash
    # reachable from each of them
    assert res.states > 100
    assert res.transitions > res.states


def test_cli_smoke(capsys):
    assert mc.main(["--max-seconds", "60"]) == 0
    out = capsys.readouterr().out
    assert "OK" in out and "states" in out


def test_wall_clock_cap_failure_is_loud(capsys):
    assert mc.main(["--max-seconds", "0"]) == 1
    assert "NOT verified" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# model-vs-real equivalence: identical traces, identical observables
# ---------------------------------------------------------------------------
_REAL = {}


def _real_pair():
    """A paged sequential engine + scheduler matching DEFAULT_CONFIG."""
    if not _REAL:
        cfg = get_config("qwen2-0.5b").reduced()
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        c = mc.DEFAULT_CONFIG
        eng = BatchEngine(model, params, max_len=c.max_len, chunk=c.chunk,
                          paged=True, page_size=c.page_size,
                          pool_pages=c.n_pages)
        _REAL["cfg"], _REAL["eng"] = cfg, eng
    return _REAL["cfg"], _REAL["eng"]


def _requests(cfg):
    rng = np.random.default_rng(11)
    out = {}
    for r in mc.DEFAULT_REQUESTS:
        toks = rng.integers(0, cfg.vocab_size, size=r.prompt_len)
        out[r.req_id] = Request(req_id=r.req_id,
                                tokens=np.asarray(toks, np.int32),
                                n_tokens=r.n_tokens)
    return out


TRACES = {
    "plain": [("submit", 1), ("submit", 3), ("boundary",), ("boundary",),
              ("submit", 2), ("boundary",), ("boundary",), ("boundary",),
              ("boundary",), ("boundary",)],
    "abort-resident": [("submit", 1), ("submit", 2), ("boundary",),
                       ("abort", 1), ("boundary",), ("submit", 3),
                       ("boundary",), ("boundary",), ("boundary",),
                       ("boundary",)],
    "abort-queued-and-prefilling": [("submit", 2), ("submit", 3),
                                    ("abort", 3), ("boundary",),
                                    ("abort", 2), ("boundary",),
                                    ("boundary",)],
    "crash-mid-flight": [("submit", 3), ("submit", 1), ("submit", 2),
                         ("boundary",), ("boundary",), ("crash",),
                         ("boundary",)],
    "crash-before-start": [("submit", 1), ("crash",), ("boundary",)],
}


@pytest.mark.parametrize("trace_name", sorted(TRACES))
def test_model_matches_real_scheduler(trace_name):
    trace = TRACES[trace_name]
    cfg, eng = _real_pair()
    reqs = _requests(cfg)
    c = mc.DEFAULT_CONFIG
    # the model's page arithmetic must use the REAL engine's overshoot
    assert c.overshoot == eng._overshoot

    model = mc.SchedModel(c, mc.DEFAULT_REQUESTS)
    sched = ContinuousScheduler(eng, batch=c.batch, chunk=c.chunk,
                                prefill_chunk=c.prefill_chunk)
    sched.start([], eos=None)
    for act in trace:
        if act[0] == "submit":
            model.submit(act[1])
            sched.submit(reqs[act[1]])
        elif act[0] == "abort":
            model.abort(act[1])
            sched.abort(act[1])
        elif act[0] == "crash":
            model.fail_all()
            sched.fail_all()
        else:
            flushed = model.boundary()
            rep = sched.boundary()
            real_flush = {rid: len(toks)
                          for rid, toks in rep.emitted.items() if toks}
            assert flushed == real_flush, (trace_name, act)
        # pool occupancy tracks after EVERY action
        real_free = eng._alloc.available if eng._alloc is not None \
            else c.n_pages
        assert model.free == real_free, (trace_name, act)
        assert eng._alloc is None or eng.sched_pool_conserved()
    # identical terminal results: state + emission count per request
    real = {rid: (res.state, res.n_emitted)
            for rid, res in sched._results.items()}
    assert model.results == real, trace_name
    # drained pool whenever the model says everything terminated
    if model.all_terminal():
        assert model.terminal_problems() == []
        assert eng._alloc is None or eng.sched_drained()
