"""Batched + device-resident chunked engines.

Invariants:
  * B=4 batched speculative decode is token-for-token identical to four
    independent B=1 runs (per-sequence acceptance lengths / positions), on
    both the ref and Pallas-interpret backends.
  * the chunked lax.scan driver (K=8) matches the per-step loop (K=1) for
    both engines — the device-resident loop changes the host-sync cadence,
    never the tokens.
  * per-sequence EOS masks out everything after each sequence's first EOS.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.models.api import get_model
from repro.runtime.engine import BatchEngine, SpeculativeEngine, \
    measure_acceptance


def _setup(arch="qwen2-0.5b"):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(7))
    spec = T.build_tree(T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 8)
    return cfg, model, params, heads, spec


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_batched_spec_matches_independent_runs(backend):
    cfg, model, params, heads, spec = _setup()
    B, N = 4, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, 8), 0,
                              cfg.vocab_size)
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64,
                            backend=backend, chunk=4)
    out, stats = eng.generate({"tokens": toks}, N)
    assert out.shape == (B, N)
    assert 1.0 <= stats["acceptance_length"] <= spec.max_depth
    for b in range(B):
        ob, _ = eng.generate({"tokens": toks[b:b + 1]}, N)
        np.testing.assert_array_equal(out[b], ob[:N],
                                      err_msg=f"seq {b} ({backend})")


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "zamba2-7b", "xlstm-125m"])
def test_batched_spec_all_families(arch):
    cfg, model, params, heads, spec = _setup(arch)
    B, N = 3, 10
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, 8), 0,
                              cfg.vocab_size)
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64)
    out, _ = eng.generate({"tokens": toks}, N)
    for b in range(B):
        ob, _ = eng.generate({"tokens": toks[b:b + 1]}, N)
        np.testing.assert_array_equal(out[b], ob[:N], err_msg=f"{arch} b={b}")


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_chunked_loop_matches_per_step(backend):
    cfg, model, params, heads, spec = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                              cfg.vocab_size)
    eng = SpeculativeEngine(model, heads, params, spec, max_len=96,
                            backend=backend)
    out1, _ = eng.generate({"tokens": toks}, 20, chunk=1)   # per-step cadence
    out8, _ = eng.generate({"tokens": toks}, 20, chunk=8)   # device-resident
    np.testing.assert_array_equal(out1, out8)

    seq = BatchEngine(model, params, max_len=96, backend=backend)
    s1, _ = seq.generate({"tokens": toks}, 20, chunk=1)
    s8, _ = seq.generate({"tokens": toks}, 20, chunk=8)
    np.testing.assert_array_equal(s1, s8)
    # speculative greedy == sequential greedy (losslessness, chunked)
    np.testing.assert_array_equal(out8[:20], s8[0][:20])


def test_batch_engine_eos_masks_tail():
    cfg, model, params, _, _ = _setup()
    B, N = 3, 14
    toks = jax.random.randint(jax.random.PRNGKey(9), (B, 8), 0,
                              cfg.vocab_size)
    eng = BatchEngine(model, params, max_len=64)
    free, _ = eng.generate({"tokens": toks}, N)
    # pick an EOS that sequence 0 emits mid-stream: everything after it must
    # be masked to EOS for that sequence, other sequences unaffected
    eos = int(free[0, N // 2])
    out, _ = eng.generate({"tokens": toks}, N, eos=eos)
    for b in range(B):
        hits = np.nonzero(out[b] == eos)[0]
        if hits.size:
            assert np.all(out[b, hits[0]:] == eos), out[b]
        cut = hits[0] if hits.size else out.shape[1]
        np.testing.assert_array_equal(out[b, :cut], free[b, :cut])


def test_spec_engine_eos_stops_sequence():
    cfg, model, params, heads, spec = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(11), (2, 8), 0,
                              cfg.vocab_size)
    eng = SpeculativeEngine(model, heads, params, spec, max_len=96)
    free, _ = eng.generate({"tokens": toks}, 16)
    eos = int(free[0, 5])
    out, _ = eng.generate({"tokens": toks}, 16, eos=eos)
    for b in range(2):
        hits = np.nonzero(out[b] == eos)[0]
        if hits.size:
            assert np.all(out[b, hits[0]:] == eos), out[b]
        cut = hits[0] if hits.size else out.shape[1]
        np.testing.assert_array_equal(out[b, :cut], free[b, :cut])


def test_measure_acceptance_reuses_engine_and_compiled_step():
    cfg, model, params, heads, _ = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0,
                              cfg.vocab_size)
    prompts = [{"tokens": toks}]
    # two distinct trees with IDENTICAL shapes (width, depths, paths): the
    # tree is a jit ARGUMENT, so the second must hit the compiled step cache
    spec_a = T.spec_from_nodes([(-1, 0, 0), (0, 1, 0), (1, 2, 0)])
    spec_b = T.spec_from_nodes([(-1, 0, 0), (0, 1, 1), (1, 2, 0)])
    eng = SpeculativeEngine(model, heads, params, spec_a, max_len=64)
    al0 = measure_acceptance(model, heads, params, spec_a, prompts,
                             n_tokens=10, engine=eng)
    sizes = {k: f._cache_size() for k, f in eng._chunks.items()}
    al1 = measure_acceptance(model, heads, params, spec_b, prompts,
                             n_tokens=10, engine=eng)
    # the budget-aware driver may compile NEW tail-chunk lengths (different
    # acceptance -> different remaining-budget schedule); what must not
    # happen is a re-jit of an existing chunk length for a same-shape tree
    for k, size in sizes.items():
        assert eng._chunks[k]._cache_size() == size, \
            "re-jitted for a same-shape tree"
    assert 1.0 <= al0 <= spec_a.max_depth
    assert 1.0 <= al1 <= spec_b.max_depth
