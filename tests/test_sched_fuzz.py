"""Randomized scheduler fuzz: random traces through the continuous
scheduler must be indistinguishable, per request, from solo B=1 runs.

Each drawn example is a full serve(): random arrivals, prompt lengths,
budgets, admission policy (fifo/sjf/lpt), layout (dense / paged fp32 /
paged int8 — the quantized arm obeys the SAME solo oracle, since
quantize-on-write is deterministic per resident), engine
(sequential/speculative), bank width and chunked-prefill setting.  The
oracle is ``engine.generate`` on each request alone — the scheduler may
only change WHEN a request runs, never WHAT it emits:

  * results are returned for every request exactly once, in request order;
  * per-request tokens are bit-identical to the solo run (admission
    order, slot reuse, chunked prefill and neighbors never perturb a
    sequence) and ``n_emitted`` matches the solo count (a pool-capped
    reservation freezes at the same shortfall solo does);
  * ``n_emitted <= budget`` and the token array carries exactly
    ``n_emitted`` entries — no emission after done;
  * a drained paged serve returns every page (free == pool).

A second fuzz stresses ``PageAllocator`` itself with interleaved
reserve/release orderings (fragmentation, aborted runs): free + reserved
must equal the pool at every step and a full drain must restore the
initial free list.

A third fuzz drives the request LIFECYCLE through the stepping API:
random cancellations (``abort()`` at random boundaries), random
deadlines and injected admission-exhaustion/stall faults mid-trace.
Every request must land in exactly one typed terminal state, every
emitted token array must be a bit-identical PREFIX of the solo run
(DONE requests the full solo output), and a drained paged pool must
conserve every page through mid-flight abort/timeout cleanup.

Seeds are fixed (``tests/_mini_hypothesis.py`` derives them from the test
name), so tier-1/CI replays the exact same traces every run.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # container may not ship hypothesis
    from _mini_hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.models.api import get_model
from repro.runtime.cache import PageAllocator
from repro.runtime.engine import BatchEngine, SpeculativeEngine
from repro.runtime.faults import FaultPlan
from repro.runtime.scheduler import (CANCELLED, DONE, TERMINAL_STATES,
                                     AdmissionPolicy, ContinuousScheduler,
                                     Request, get_policy)

MAX_LEN = 64
PAGE_SIZE = 8
POOL_PAGES = {False: None, True: 8}    # two 4-page reservations: a third
                                       # concurrent request gets DEFERRED
PROMPT_LENS = (3, 6, 14)               # small set: bounds prefill compiles
BUDGETS = (1, 2, 5, 9)
PREFILL_CHUNK = 4

_ENGINES = {}
_SOLO = {}                             # (engine key, prompt, budget) -> out


def _engine(kind, paged, kv_dtype=None):
    key = (kind, paged, kv_dtype)
    if key not in _ENGINES:
        cfg = get_config("qwen2-0.5b").reduced()
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        kw = dict(max_len=MAX_LEN, chunk=4, paged=paged,
                  page_size=PAGE_SIZE, pool_pages=POOL_PAGES[paged],
                  kv_dtype=kv_dtype)
        if kind == "spec":
            heads = init_medusa(cfg, jax.random.PRNGKey(7))
            spec = T.build_tree(
                T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 8)
            eng = SpeculativeEngine(model, heads, params, spec, **kw)
        else:
            eng = BatchEngine(model, params, **kw)
        _ENGINES[key] = (cfg, eng)
    return _ENGINES[key]


def _solo(key, eng, req):
    skey = (key, req.tokens.tobytes(), req.n_tokens)
    if skey not in _SOLO:
        out, stats = eng.generate({"tokens": req.tokens[None]}, req.n_tokens)
        _SOLO[skey] = (np.atleast_2d(out)[0], int(stats["n_emitted"][0]))
    return _SOLO[skey]


LAYOUTS = [(False, None), (True, None), (True, "int8")]
# (paged, kv_dtype): the int8 arm serves through the SAME solo-oracle
# contract — quantize-on-write is deterministic per resident, so the
# scheduler still may not change WHAT a request emits, only when.


@settings(max_examples=8, deadline=None)
@given(ex=st.tuples(
    st.integers(1, 6),                         # number of requests
    st.integers(0, 2 ** 31 - 1),               # trace seed
    st.sampled_from(["seq", "spec"]),
    st.sampled_from(LAYOUTS),                  # (paged, kv_dtype)
    st.sampled_from(["fifo", "sjf", "lpt"]),
    st.sampled_from([0, PREFILL_CHUNK]),
    st.sampled_from([2, 3]),                   # bank width B
))
def test_fuzz_continuous_matches_solo(ex):
    n, seed, kind, (paged, kv_dtype), policy, prefill_chunk, B = ex
    if kv_dtype == "int8":
        # frozen-first-write page scales make the quantized values depend
        # on prefill chunk boundaries (a partial first chunk arms the
        # scale, later chunks clip under it), so bit-parity with the
        # whole-prompt solo oracle is only guaranteed unchunked
        prefill_chunk = 0
    cfg, eng = _engine(kind, paged, kv_dtype)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.choice(PROMPT_LENS))
        reqs.append(Request(
            req_id=i,
            tokens=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            n_tokens=int(rng.choice(BUDGETS)),
            arrival=float(rng.choice([0.0, 0.02, 0.05]))))
    sched = ContinuousScheduler(eng, batch=B, policy=policy,
                                prefill_chunk=prefill_chunk)
    results, stats = sched.serve(reqs)

    # every request exactly once, in request order
    assert [r.req_id for r in results] == [r.req_id for r in reqs]
    assert stats["admitted"] == n
    for r, req in zip(results, reqs):
        solo_toks, solo_n = _solo((kind, paged, kv_dtype), eng, req)
        assert r.n_emitted <= req.n_tokens
        assert len(r.tokens) == r.n_emitted       # no emission after done
        assert r.n_emitted == solo_n, (r.req_id, r.n_emitted, solo_n)
        np.testing.assert_array_equal(
            r.tokens, solo_toks[:solo_n],
            err_msg=f"req {r.req_id} (policy={policy}, paged={paged}, "
                    f"kv_dtype={kv_dtype}, chunked={prefill_chunk}, B={B})")
    if paged:                                     # full drain returns pages
        assert eng._alloc.available == eng._alloc.n_pages
    if kv_dtype == "int8":
        # freed pages may keep stale ARMED scales (reset_rows must not
        # touch pool scales — see runtime/cache.py), but every row still
        # holding a reservation after drain would be a leak
        kv = sched.last_state.cache.kv
        assert np.all(np.asarray(kv.block_table) == -1)


@settings(max_examples=8, deadline=None)
@given(ex=st.tuples(
    st.integers(2, 6),                         # number of requests
    st.integers(0, 2 ** 31 - 1),               # lifecycle seed
    st.sampled_from(["seq", "spec"]),
    st.sampled_from(LAYOUTS),                  # (paged, kv_dtype)
    st.sampled_from([2, 3]),                   # bank width B
))
def test_fuzz_lifecycle_terminal_and_conserved(ex):
    """Random cancels/deadlines/faults mid-trace: every request ends in
    exactly one typed terminal state, emitted tokens are always a
    bit-identical prefix of the solo run, and the paged pool conserves
    every page through mid-flight abort and timeout cleanup."""
    n, seed, kind, (paged, kv_dtype), B = ex
    cfg, eng = _engine(kind, paged, kv_dtype)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.choice(PROMPT_LENS))
        reqs.append(Request(
            req_id=i,
            tokens=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            n_tokens=int(rng.choice(BUDGETS))))
    abort_at = {}                          # req_id -> boundary to cancel at
    for r in reqs:
        u = rng.random()
        if u < 0.35:
            abort_at[r.req_id] = int(rng.integers(1, 7))
        elif u < 0.5:
            r.deadline = float(rng.random() * 0.003)   # expires early
    plan = FaultPlan(seed=seed,
                     stall_rate=float(rng.choice([0.0, 0.2])),
                     stall_s=0.001,
                     exhaust_rate=float(rng.choice([0.0, 0.3])))
    sched = ContinuousScheduler(eng, batch=B, faults=plan.injector("fz"))
    sched.start(reqs)
    i = 0
    while sched.has_work:
        i += 1
        assert i < 500, "lifecycle trace did not converge"
        for rid, bnd in abort_at.items():
            if bnd == i:
                sched.abort(rid)
        sched.boundary()
    results, stats = sched.finish(reqs)

    assert [r.req_id for r in results] == [r.req_id for r in reqs]
    for r, req in zip(results, reqs):
        assert r.state in TERMINAL_STATES
        solo_toks, solo_n = _solo((kind, paged, kv_dtype), eng, req)
        assert len(r.tokens) == r.n_emitted <= solo_n
        np.testing.assert_array_equal(
            r.tokens, solo_toks[:r.n_emitted],
            err_msg=f"req {r.req_id} state={r.state} (kind={kind}, "
                    f"paged={paged}, kv_dtype={kv_dtype}, B={B})")
        if r.state == DONE:                # full solo output, nothing less
            assert r.n_emitted == solo_n
        if r.state == CANCELLED:
            assert req.req_id in abort_at  # only injected cancels
    assert sum(stats["states"].values()) == n
    if paged:                              # drained pool conserves pages
        assert eng._alloc.available == eng._alloc.n_pages
        assert eng.sched_pool_conserved() and eng.sched_drained()


@settings(max_examples=30, deadline=None)
@given(ex=st.tuples(st.integers(0, 2 ** 31 - 1),   # op-sequence seed
                    st.integers(4, 24),            # pool size
                    st.integers(5, 40)))           # number of ops
def test_fuzz_page_allocator_conservation(ex):
    """Interleaved reserve/release stress: free + reserved == pool at every
    step, fragmented release orderings reuse pages, and a full drain (an
    aborted run's cleanup) restores the initial free list."""
    seed, n_pages, n_ops = ex
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(n_pages)
    initial = list(alloc._free)
    held = []                                      # outstanding reservations
    for _ in range(n_ops):
        n_held = sum(len(h) for h in held)
        assert alloc.available + n_held == n_pages   # conservation
        if held and rng.random() < 0.4:
            # release a random (not necessarily oldest) reservation:
            # fragments the free list
            alloc.free(held.pop(int(rng.integers(len(held)))))
            continue
        want = int(rng.integers(1, max(n_pages // 2, 2)))
        if want > alloc.available:
            with pytest.raises(RuntimeError):
                alloc.alloc(want)
            pages = alloc.alloc_upto(want)         # partial reservation
        else:
            pages = alloc.alloc(want)
        assert len(set(pages)) == len(pages)       # no page handed out twice
        for other in held:
            assert not set(pages) & set(other)
        if pages:
            held.append(pages)
    for h in held:                                 # drain
        alloc.free(h)
    assert alloc._free == initial
    # double free is rejected
    if n_pages:
        got = alloc.alloc(1)
        alloc.free(got)
        with pytest.raises(RuntimeError):
            alloc.free(got)


class _Probe:
    """Engine stand-in for pure-policy fuzz: everything arrived is fundable
    unless its footprint exceeds ``limit``."""

    def __init__(self, limit):
        self.limit = limit

    def can_admit(self, r):
        return len(r.tokens) + r.n_tokens <= self.limit

    @staticmethod
    def footprint(r):
        return len(r.tokens) + r.n_tokens


@settings(max_examples=40, deadline=None)
@given(ex=st.tuples(st.integers(0, 2 ** 31 - 1),   # trace seed
                    st.integers(1, 10),            # pending length
                    st.sampled_from(["fifo", "sjf", "lpt"]),
                    st.integers(4, 30)))           # fundability limit
def test_fuzz_policy_pick_contract(ex):
    """Host-side policy contract, no model: a pick is always an ARRIVED,
    fundable request; FIFO never skips its head; SJF/LPT pick the
    smallest/largest fundable footprint with FIFO tie-breaks; bootstrap
    ignores fundability."""
    seed, n, name, limit = ex
    rng = np.random.default_rng(seed)
    now = 1.0
    pending = sorted(
        (Request(req_id=i, tokens=np.zeros(int(rng.integers(1, 16)),
                                           np.int32),
                 n_tokens=int(rng.integers(1, 16)),
                 arrival=float(rng.choice([0.0, 0.5, 2.0])))
         for i in range(n)), key=lambda r: (r.arrival, r.req_id))
    probe = _Probe(limit)
    policy = get_policy(name)
    idx = policy.pick(pending, now, probe.can_admit, probe.footprint,
                      bootstrap=False)
    arrived = [r for r in pending if r.arrival <= now]
    fundable = [r for r in arrived if probe.can_admit(r)]
    if name == "fifo":
        head_ok = (pending[0].arrival <= now
                   and probe.can_admit(pending[0]))
        assert (idx == 0) if head_ok else (idx is None)
    elif not fundable:
        assert idx is None
    else:
        picked = pending[idx]
        assert picked.arrival <= now and probe.can_admit(picked)
        best = (min if name == "sjf" else max)(
            probe.footprint(r) for r in fundable)
        assert probe.footprint(picked) == best
        ties = [r for r in fundable if probe.footprint(r) == best]
        assert picked.req_id == min(
            ties, key=lambda r: (r.arrival, r.req_id)).req_id
    # bootstrap: fundability is ignored, arrival is not
    bidx = policy.pick(pending, now, probe.can_admit, probe.footprint,
                       bootstrap=True)
    if arrived:
        assert bidx is not None and pending[bidx].arrival <= now
    else:
        assert bidx is None


def test_policy_registry():
    assert get_policy("sjf").name == "sjf"
    assert isinstance(get_policy(AdmissionPolicy()), AdmissionPolicy)
    with pytest.raises(ValueError):
        get_policy("srpt")
