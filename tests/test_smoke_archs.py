"""Per-architecture smoke tests: reduced config (<=2 layers, d_model<=512,
<=4 experts), one forward + one train step on CPU; output shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.api import get_model
from repro.training.optimizer import adamw_init
from repro.training.train import train_step

ARCHS = list_archs(include_paper_model=True)


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "vision":
        b["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_frontend_tokens, cfg.d_model)), cfg.dtype)
    if cfg.frontend == "audio":
        b["frame_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)), cfg.dtype)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_decode(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, extras, cache = model.prefill(params, batch, max_len=S + 8)
    S_total = S + (cfg.num_frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), "NaN in prefill logits"

    lg, cache = model.decode(params, cache, batch["tokens"][:, :1])
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(lg))), "NaN in decode logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = _batch(cfg, B=2, S=16)
    params2, opt2, metrics = train_step(cfg, model, params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), metrics
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[1]
    l1 = jax.tree_util.tree_leaves(params2)[1]
    assert not bool(jnp.allclose(l0, l1))
