"""Paged KV cache (runtime/cache.py PagedKVCache + engines' paged mode).

Invariants:
  * the paged layout is OBSERVATIONALLY IDENTICAL to the dense one: same
    logical view after interleaved writes/commits, same engine outputs
    token-for-token on ref and Pallas backends across every architecture
    family, and same outputs under ``ContinuousScheduler`` replay with
    staggered evictions;
  * the host-side ``PageAllocator`` hands out/reclaims pages correctly
    through fragmented alloc/free interleavings;
  * pool exhaustion is SAFE: a row whose reservation cannot grow freezes
    with the shortfall in ``n_emitted`` — its overflow writes go to the
    trash page and a neighbor's output is bit-identical to an uncontended
    run (the regression the trash-page redirect exists for);
  * the scheduler DEFERS admission while the pool cannot fund a
    reservation and admits once eviction frees pages;
  * an evicted slot is fully inert: cache cleared AND the decode carry
    (``cur_token``/``hidden``) zeroed, so recycled pages never see stale
    draft state.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.models.api import get_model
from repro.runtime import cache as C
from repro.runtime.engine import BatchEngine, SpeculativeEngine
from repro.runtime.scheduler import ContinuousScheduler, Request


def _setup(arch="qwen2-0.5b"):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(7))
    spec = T.build_tree(T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 8)
    return cfg, model, params, heads, spec


def _requests(cfg, n, budgets, prompt_len=8, seed=3):
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, prompt_len), 0, cfg.vocab_size),
        np.int32)
    return [Request(req_id=i, tokens=toks[i],
                    n_tokens=budgets[i % len(budgets)]) for i in range(n)]


def _assert_matches_solo(engine, results, requests):
    for r, req in zip(results, requests):
        solo, _ = engine.generate({"tokens": req.tokens[None]}, req.n_tokens)
        solo = np.atleast_2d(solo)[0]
        assert r.n_emitted == req.n_tokens, (r.req_id, r.n_emitted)
        np.testing.assert_array_equal(r.tokens, solo[:req.n_tokens],
                                      err_msg=f"req {r.req_id}")


# --------------------------------------------------------------------------
# allocator
# --------------------------------------------------------------------------
def test_allocator_alloc_free_reuse():
    a = C.PageAllocator(6)
    assert a.available == 6
    p0 = a.alloc(2)
    p1 = a.alloc(3)
    assert sorted(p0 + p1) == [0, 1, 2, 3, 4]
    assert a.available == 1
    a.free(p0)
    assert a.available == 3
    # reuse: freed ids come back (lowest-first, deterministic)
    p2 = a.alloc(3)
    assert p2 == sorted(p0 + [5])
    with pytest.raises(RuntimeError):
        a.alloc(1)                    # exhausted
    assert a.alloc_upto(4) == []      # partial degrades to empty


def test_allocator_fragmentation_across_admissions():
    a = C.PageAllocator(8)
    rows = {b: a.alloc(2) for b in range(4)}      # full pool, 4 rows
    a.free(rows.pop(1))
    a.free(rows.pop(3))                           # fragmented: {2,3,6,7}
    big = a.alloc(4)                              # spans both holes
    assert big == [2, 3, 6, 7]
    a.free(big)
    a.free(rows.pop(0))
    a.free(rows.pop(2))
    assert a.available == 8
    with pytest.raises(RuntimeError):
        a.free([0])                               # double free
    with pytest.raises(RuntimeError):
        a.alloc(9)


# --------------------------------------------------------------------------
# cache primitives: paged == dense on the logical view
# --------------------------------------------------------------------------
def test_paged_write_commit_match_dense():
    from repro.models.transformer import _bulk_write
    L, B, Hkv, hd, ps, max_len = 2, 3, 2, 4, 4, 16
    maxp = C.pages_for(max_len, ps)
    rng = np.random.default_rng(0)
    start = jnp.asarray([0, 3, 7], jnp.int32)     # diverged positions
    dense = dataclasses.replace(
        C.init_kv_cache(L, B, max_len, Hkv, hd, dtype=jnp.float32),
        pos=start)
    tables = jnp.asarray(
        np.arange(B * maxp, dtype=np.int32).reshape(B, maxp))
    paged = dataclasses.replace(
        C.init_paged_kv_cache(L, B, max_len, Hkv, hd, page_size=ps,
                              n_pages=B * maxp, dtype=jnp.float32),
        block_table=tables, pos=start)

    ks = jnp.asarray(rng.normal(size=(L, B, 5, Hkv, hd)), jnp.float32)
    dense = _bulk_write(dense, ks, ks + 1, start=start)
    paged = C.paged_kv_write(paged, ks, ks + 1, start)

    kn = jnp.asarray(rng.normal(size=(L, B, 4, Hkv, hd)), jnp.float32)
    nodes = jnp.asarray(rng.integers(0, 4, size=(B, 3)), jnp.int32)
    n_acc = jnp.asarray([1, 3, 2], jnp.int32)
    dense = C.kv_commit(dense, kn, kn * 2, nodes, n_acc, 3)
    paged = C.kv_commit(paged, kn, kn * 2, nodes, n_acc, 3)

    for l in range(L):
        view_k = C.gather_pages(paged.pool_k[l], paged.block_table)
        view_v = C.gather_pages(paged.pool_v[l], paged.block_table)
        np.testing.assert_allclose(np.asarray(view_k[:, :max_len]),
                                   np.asarray(dense.k[l]))
        np.testing.assert_allclose(np.asarray(view_v[:, :max_len]),
                                   np.asarray(dense.v[l]))
    np.testing.assert_array_equal(np.asarray(dense.key_pos),
                                  np.asarray(paged.key_pos)[:, :max_len])
    np.testing.assert_array_equal(np.asarray(dense.pos),
                                  np.asarray(paged.pos))
    np.testing.assert_array_equal(
        np.asarray(C.capacity_left(C.Cache(kv=paged))),
        maxp * ps - np.asarray(paged.pos))


def test_unreserved_write_hits_trash_not_neighbor():
    """A row writing past its (partial) reservation must not touch ANY
    reservable page — the write lands in the trash page."""
    L, B, Hkv, hd, ps = 1, 2, 1, 2, 4
    kv = C.init_paged_kv_cache(L, B, 16, Hkv, hd, page_size=ps, n_pages=4,
                               dtype=jnp.float32)
    tables = jnp.asarray([[0, 1, -1, -1],         # row 0: 8 slots reserved
                          [2, 3, -1, -1]], jnp.int32)
    kv = dataclasses.replace(kv, block_table=tables,
                             pos=jnp.asarray([8, 0], jnp.int32))
    before = np.asarray(kv.pool_k)
    # row 0 writes at pos 8..9 — logical page 2, UNRESERVED
    ks = jnp.full((L, 1, 2, Hkv, hd), 7.0, jnp.float32)
    ks = jnp.concatenate([ks, jnp.zeros_like(ks)], axis=1)  # row 1 writes 0s
    out = C.paged_kv_write(kv, ks, ks, jnp.asarray([8, 0], jnp.int32))
    after = np.asarray(out.pool_k)
    # all four REAL pages carry only row 1's legal write; row 0's overflow
    # is confined to the trash page
    assert not np.any(after[:, :4] == 7.0)
    assert np.any(after[:, 4] == 7.0)
    # and row 0's key_pos never claims the unreserved slots
    assert np.all(np.asarray(out.key_pos[0, 8:10]) == -1)
    # row 1's write is intact
    np.testing.assert_array_equal(np.asarray(out.key_pos[1, :2]), [0, 1])
    del before


def test_paged_reset_insert_row_surgery():
    L, B, Hkv, hd, ps = 2, 3, 2, 4, 4
    kv = C.init_paged_kv_cache(L, B, 16, Hkv, hd, page_size=ps, n_pages=12,
                               dtype=jnp.float32)
    tables = np.arange(12, dtype=np.int32).reshape(3, 4)
    kv = dataclasses.replace(kv, block_table=jnp.asarray(tables),
                             pos=jnp.asarray([5, 6, 7], jnp.int32))
    cache = C.Cache(kv=kv)
    out = C.reset_rows(cache, np.asarray([False, True, False]))
    assert np.all(np.asarray(out.kv.block_table[1]) == -1)
    assert np.all(np.asarray(out.kv.key_pos[1]) == -1)
    assert int(out.kv.pos[1]) == 0
    np.testing.assert_array_equal(np.asarray(out.kv.block_table[0]),
                                  tables[0])                # others untouched
    assert int(out.kv.pos[2]) == 7

    # splice a dense B=1 prefill into the freed row via fresh pages
    src = C.Cache(kv=dataclasses.replace(
        C.init_kv_cache(L, 1, 6, Hkv, hd, dtype=jnp.float32),
        k=jnp.full((L, 1, 6, Hkv, hd), 9.0),
        v=jnp.full((L, 1, 6, Hkv, hd), 9.0),
        key_pos=jnp.arange(6, dtype=jnp.int32)[None],
        pos=jnp.asarray([6], jnp.int32)))
    pages = jnp.asarray([5, 6, -1, -1], jnp.int32)
    ins = C.insert_rows(out, 1, src, pages=pages)
    view = C.gather_pages(ins.kv.pool_k[0], ins.kv.block_table)
    assert np.all(np.asarray(view[1, :6]) == 9.0)
    np.testing.assert_array_equal(np.asarray(ins.kv.key_pos[1, :6]),
                                  np.arange(6))
    assert np.all(np.asarray(ins.kv.key_pos[1, 6:]) == -1)
    assert int(ins.kv.pos[1]) == 6


# --------------------------------------------------------------------------
# kernel: paged Pallas == paged ref == dense ref on the gathered view
# --------------------------------------------------------------------------
def test_paged_kernel_matches_ref():
    from repro.kernels import ref as KR
    from repro.kernels import tree_attention as KT
    rng = np.random.default_rng(1)
    B, W, Hq, Hkv, hd, ps, n_pages, maxp = 3, 4, 4, 2, 8, 4, 10, 3
    P = n_pages + 1
    pool_k = jnp.asarray(rng.normal(size=(P, ps, Hkv, hd)), jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(P, ps, Hkv, hd)), jnp.float32)
    k_new = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, W, Hq, hd)), jnp.float32)
    # fragmented tables incl. partial reservations
    table = jnp.asarray([[0, 3, -1], [7, -1, -1], [2, 5, 9]], jnp.int32)
    fills = [6, 3, 11]
    key_pos = np.full((B, maxp * ps), -1, np.int32)
    for b, f in enumerate(fills):
        key_pos[b, :f] = np.arange(f)
    key_pos = jnp.asarray(key_pos)
    pos = jnp.asarray(fills, jnp.int32)
    q_pos = pos[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]
    lo = jnp.full_like(q_pos, -1)
    tm = jnp.tril(jnp.ones((W, W), bool))

    ones = jnp.ones((P, Hkv), jnp.float32)        # float pool: exact scales
    ref = KR.paged_tree_attention_ref(q, pool_k, pool_v, None, None, k_new,
                                      v_new, table, key_pos, q_pos, lo, tm)
    ker = KT.paged_tree_attention(
        q, pool_k, pool_v, ones, ones, k_new, v_new, table, key_pos, q_pos,
        lo, tm, interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               atol=2e-5, rtol=2e-5)
    ck = C.gather_pages(pool_k, table)
    cv = C.gather_pages(pool_v, table)
    dref = KR.tree_attention_ref(q, ck, cv, k_new, v_new, key_pos, q_pos,
                                 lo, tm)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(dref), atol=1e-6)


def _kernel_case(seed=1):
    """Fragmented paged fixture shared by the kernel-parity tests: 3 rows
    with partial reservations and diverged fills, tril tree mask."""
    rng = np.random.default_rng(seed)
    B, W, Hq, Hkv, hd, ps, n_pages, maxp = 3, 4, 4, 2, 8, 4, 10, 3
    P = n_pages + 1
    case = dict(
        pool_k=jnp.asarray(rng.normal(size=(P, ps, Hkv, hd)), jnp.float32),
        pool_v=jnp.asarray(rng.normal(size=(P, ps, Hkv, hd)), jnp.float32),
        k_new=jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32),
        v_new=jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32),
        q=jnp.asarray(rng.normal(size=(B, W, Hq, hd)), jnp.float32),
        table=jnp.asarray([[0, 3, -1], [7, -1, -1], [2, 5, 9]], jnp.int32),
        tm=jnp.tril(jnp.ones((W, W), bool)), P=P, Hkv=Hkv)
    fills = [6, 3, 11]
    key_pos = np.full((B, maxp * ps), -1, np.int32)
    for b, f in enumerate(fills):
        key_pos[b, :f] = np.arange(f)
    case["key_pos"] = jnp.asarray(key_pos)
    pos = jnp.asarray(fills, jnp.int32)
    case["q_pos"] = pos[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]
    case["lo"] = jnp.full_like(case["q_pos"], -1)
    return case


def _quantize_pool(pool):
    """Symmetric per-page per-head int8 quantization (the cache.py
    convention: scale = amax/127, element error <= scale/2)."""
    amax = jnp.max(jnp.abs(pool), axis=(1, 3))                  # (P, Hkv)
    scale = amax / 127.0
    qp = jnp.round(pool / jnp.maximum(scale, 1e-30)[:, None, :, None])
    return jnp.clip(qp, -127, 127).astype(jnp.int8), scale


def test_paged_kernel_int8_matches_ref():
    """int8 pool: Pallas fused-dequant page walk == int8 oracle to kernel
    tolerance, and both sit within the quantization bound of the fp32
    oracle (the dequant happens INSIDE the walk, not via a float view)."""
    from repro.kernels import ref as KR
    from repro.kernels import tree_attention as KT
    c = _kernel_case()
    qk, sk = _quantize_pool(c["pool_k"])
    qv, sv = _quantize_pool(c["pool_v"])
    args = (c["k_new"], c["v_new"], c["table"], c["key_pos"], c["q_pos"],
            c["lo"], c["tm"])
    ref8 = KR.paged_tree_attention_ref(c["q"], qk, qv, sk, sv, *args)
    ker8 = KT.paged_tree_attention(c["q"], qk, qv, sk, sv, *args,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(ker8), np.asarray(ref8),
                               atol=2e-5, rtol=2e-5)
    ref32 = KR.paged_tree_attention_ref(c["q"], c["pool_k"], c["pool_v"],
                                        None, None, *args)
    err = float(jnp.max(jnp.abs(ref8 - ref32)))
    assert 0.0 < err < 3e-2, err          # quantized, yet within the bound


@pytest.mark.parametrize("quantized", [False, True])
def test_paged_split_partials_match_fused(quantized):
    """tree_kernel=sparse decomposition: paged_cache_attention partials ==
    their oracle, and Eq.-1-merged with the sparse tree half they equal the
    fused paged_tree_attention output — at both pool dtypes."""
    from repro.kernels import ref as KR
    from repro.kernels import sparse_tree as KS
    from repro.kernels import tree_attention as KT
    from repro.models import common as cm
    c = _kernel_case(seed=2)
    if quantized:
        pk, sk = _quantize_pool(c["pool_k"])
        pv, sv = _quantize_pool(c["pool_v"])
        sk_ref, sv_ref = sk, sv
    else:
        pk, pv = c["pool_k"], c["pool_v"]
        sk = sv = jnp.ones((c["P"], c["Hkv"]), jnp.float32)
        sk_ref = sv_ref = None            # ref: None == verbatim gather
    walk = (c["table"], c["key_pos"], c["q_pos"], c["lo"])
    cache_ker = KT.paged_cache_attention(c["q"], pk, pv, sk, sv, *walk,
                                         interpret=True)
    cache_ref = KR.paged_cache_attention_ref(c["q"], pk, pv, sk_ref, sv_ref,
                                             *walk)
    for a, b in zip(cache_ker, cache_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)
    tree_ker = KS.sparse_tree_attention_partial(c["q"], c["k_new"],
                                                c["v_new"], c["tm"],
                                                interpret=True)
    tree_ref = KR.sparse_tree_attention_partial_ref(c["q"], c["k_new"],
                                                    c["v_new"], c["tm"])
    for a, b in zip(tree_ker, tree_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)
    merged = cm.merge_partials([cache_ker, tree_ker])
    fused = KT.paged_tree_attention(c["q"], pk, pv, sk, sv, c["k_new"],
                                    c["v_new"], *walk, c["tm"],
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(fused),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# int8 scale lifecycle: arm on paginate, freeze on write, zero on reset,
# re-arm on recycle (a stale scale must NEVER dequantize a new resident)
# --------------------------------------------------------------------------
def test_int8_scale_lifecycle_reset_and_recycle():
    L, B, Hkv, hd, ps, max_len = 1, 2, 2, 4, 4, 16
    rng = np.random.default_rng(7)
    fill = 6
    k = jnp.asarray(rng.normal(size=(L, B, fill, Hkv, hd)) * 3.0,
                    jnp.float32)
    dense = dataclasses.replace(
        C.init_kv_cache(L, B, fill, Hkv, hd, dtype=jnp.float32),
        k=k, v=k * 0.5,
        key_pos=jnp.broadcast_to(jnp.arange(fill), (B, fill)),
        pos=jnp.full((B,), fill, jnp.int32))
    tables = jnp.asarray([[0, 1, -1, -1], [2, 3, -1, -1]], jnp.int32)
    paged = C.paginate_cache(C.Cache(kv=dense), tables, page_size=ps,
                             n_pages=4, kv_dtype=jnp.int8).kv
    assert paged.pool_k.dtype == jnp.int8
    sk0 = np.asarray(paged.scale_k)                       # (L, P, Hkv)
    assert np.all(sk0[:, :4] > 0), "resident pages must arm on paginate"
    assert np.all(sk0[:, 4] == 0), "trash page scale must stay unarmed"
    view = C.gather_pages_dequant(paged.pool_k[0], paged.scale_k[0],
                                  paged.block_table)
    bound = float(np.max(sk0)) / 2 + 1e-6
    assert float(jnp.max(jnp.abs(view[:, :fill] - dense.k[0]))) <= bound

    # writes into an armed page must NOT move its scale (frozen-first-write)
    ks = jnp.asarray(rng.normal(size=(L, B, 2, Hkv, hd)) * 30.0, jnp.float32)
    written = C.paged_kv_write(paged, ks, ks, jnp.full((B,), fill, jnp.int32))
    np.testing.assert_array_equal(np.asarray(written.scale_k), sk0)

    # reset frees row 0: table/key_pos clear but pool scales are left
    # ALONE — the dead row's table is stale bookkeeping, and the scheduler
    # batches resets to the end of a boundary, so the pages it names may
    # already carry a same-boundary admission whose armed scale must
    # survive (zeroing here re-armed recycled pages from decode amax and
    # silently corrupted the resident's already-quantized prompt)
    out = C.reset_rows(C.Cache(kv=written), np.asarray([True, False]))
    sk1 = np.asarray(out.kv.scale_k)
    np.testing.assert_array_equal(sk1, sk0)
    assert np.all(np.asarray(out.kv.block_table)[0] == -1)

    # recycle pages 0..1 for a new resident with ~300x smaller magnitude:
    # the insert zero-then-arms to the NEW amax — dequantizing through the
    # stale scale would inflate the restored values ~300x
    small = jnp.asarray(rng.normal(size=(L, 1, fill, Hkv, hd)) * 0.01,
                        jnp.float32)
    src = C.Cache(kv=dataclasses.replace(
        C.init_kv_cache(L, 1, fill, Hkv, hd, dtype=jnp.float32),
        k=small, v=small,
        key_pos=jnp.arange(fill, dtype=jnp.int32)[None],
        pos=jnp.asarray([fill], jnp.int32)))
    ins = C.insert_rows(out, 0, src, pages=jnp.asarray([0, 1, -1, -1],
                                                       jnp.int32))
    sk2 = np.asarray(ins.kv.scale_k)
    assert np.all(sk2[:, :2] > 0)
    assert float(np.max(sk2[:, :2])) < float(np.min(sk0[:, :2])), \
        "recycled pages must re-arm to the new resident's amax"
    view2 = C.gather_pages_dequant(ins.kv.pool_k[0], ins.kv.scale_k[0],
                                   ins.kv.block_table)
    err = float(jnp.max(jnp.abs(view2[0, :fill] - small[0, 0])))
    assert err <= float(np.max(sk2[:, :2])) / 2 + 1e-7, err


# --------------------------------------------------------------------------
# engines: paged == dense token-for-token
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_engines_paged_match_dense(backend):
    cfg, model, params, heads, spec = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(3), (3, 8), 0,
                              cfg.vocab_size)
    budgets = np.asarray([6, 11, 9])
    dense = BatchEngine(model, params, max_len=64, chunk=4, backend=backend)
    paged = BatchEngine(model, params, max_len=64, chunk=4, backend=backend,
                        paged=True, page_size=8)
    od, sd = dense.generate({"tokens": toks}, budgets)
    op, sp = paged.generate({"tokens": toks}, budgets)
    np.testing.assert_array_equal(od, op)
    np.testing.assert_array_equal(sd["n_emitted"], sp["n_emitted"])

    dense = SpeculativeEngine(model, heads, params, spec, max_len=64,
                              chunk=4, backend=backend)
    paged = SpeculativeEngine(model, heads, params, spec, max_len=64,
                              chunk=4, backend=backend, paged=True,
                              page_size=8)
    od, _ = dense.generate({"tokens": toks}, 12)
    op, _ = paged.generate({"tokens": toks}, 12)
    np.testing.assert_array_equal(od, op)


def test_engines_int8_configs_agree():
    """Every int8 engine config — ref oracle, Pallas fused walk, and the
    tree_kernel=sparse split verify path — emits IDENTICAL tokens (same
    quantized pool, kernels parity-tested to 2e-5, so any disagreement is
    a dispatch bug).  Against fp32 only prefix agreement is asserted:
    quantization can legitimately flip a borderline argmax on this
    random-weights smoke model, and the first token always matches because
    prefill logits are computed before the pool is quantized.  The
    bounded-error parity gate is the kernel tests' job."""
    cfg, model, params, heads, spec = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(3), (3, 8), 0,
                              cfg.vocab_size)

    def run(backend, tree_kernel, kv_dtype):
        eng = SpeculativeEngine(model, heads, params, spec, max_len=64,
                                chunk=4, backend=backend, paged=True,
                                page_size=8, kv_dtype=kv_dtype,
                                tree_kernel=tree_kernel)
        out, _ = eng.generate({"tokens": toks}, 12)
        return np.asarray(out)

    i8 = {(b, tk): run(b, tk, "int8")
          for b, tk in [("ref", "dense"), ("pallas", "dense"),
                        ("pallas", "sparse")]}
    base = i8[("ref", "dense")]
    for key, out in i8.items():
        np.testing.assert_array_equal(base, out, err_msg=str(key))
    fp = run("pallas", "dense", None)
    np.testing.assert_array_equal(fp[:, 0], base[:, 0])


def test_kv_dtype_and_tree_kernel_validation():
    """int8 and the split verify path both presuppose the paged layout;
    the engine must refuse the meaningless combinations up front."""
    cfg, model, params, heads, spec = _setup()
    with pytest.raises(ValueError, match="paged"):
        SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4,
                          kv_dtype="int8")
    with pytest.raises(ValueError, match="paged"):
        SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4,
                          tree_kernel="sparse")
    with pytest.raises(ValueError):
        SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4,
                          paged=True, page_size=8, tree_kernel="bogus")
    with pytest.raises(ValueError):
        SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4,
                          paged=True, page_size=8, kv_dtype="int4")
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4,
                            paged=True, page_size=8, backend="pallas")
    # live switch: dense -> sparse -> dense, same tokens each way
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0,
                              cfg.vocab_size)
    od, _ = eng.generate({"tokens": toks}, 10)
    eng.set_tree_kernel("sparse")
    os_, _ = eng.generate({"tokens": toks}, 10)
    eng.set_tree_kernel("dense")
    od2, _ = eng.generate({"tokens": toks}, 10)
    np.testing.assert_array_equal(od, os_)
    np.testing.assert_array_equal(od, od2)
    with pytest.raises(ValueError):
        eng.set_tree_kernel("coo")


@pytest.mark.parametrize("arch", ["zamba2-7b", "seamless-m4t-medium",
                                  "xlstm-125m"])
def test_paged_all_families(arch):
    """Hybrid shared-attn sites, enc-dec decoder KV, and the recurrent
    no-KV family (paged degrades to a no-op) all match dense."""
    cfg, model, params, heads, spec = _setup(arch)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.is_encoder_decoder:
        batch["frame_embeds"] = jax.random.normal(
            jax.random.PRNGKey(6), (2, 6, cfg.d_model), jnp.float32)
    dense = SpeculativeEngine(model, heads, params, spec, max_len=64,
                              chunk=4)
    paged = SpeculativeEngine(model, heads, params, spec, max_len=64,
                              chunk=4, paged=True, page_size=8)
    od, _ = dense.generate(batch, 10)
    op, _ = paged.generate(batch, 10)
    np.testing.assert_array_equal(od, op)


# --------------------------------------------------------------------------
# scheduler replay: paged bank, staggered evictions, slot churn
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_paged_scheduler_matches_solo(backend):
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64,
                            backend=backend, chunk=4, paged=True,
                            page_size=8)
    # mixed budgets => staggered evictions; 5 requests through 2 slots
    reqs = _requests(cfg, 5, budgets=[6, 12, 9])
    sched = ContinuousScheduler(eng, batch=2)
    results, stats = sched.serve(reqs)
    assert stats["admitted"] == 5
    _assert_matches_solo(eng, results, reqs)
    # stream drained: every reservation returned, tables cleared
    assert eng._alloc.available == eng._alloc.n_pages
    kv = sched.last_state.cache.kv
    assert np.all(np.asarray(kv.block_table) == -1)
    assert np.all(np.asarray(kv.key_pos) == -1)


def test_paged_batch_engine_scheduler_matches_solo():
    cfg, model, params, _, _ = _setup()
    eng = BatchEngine(model, params, max_len=64, chunk=4, paged=True,
                      page_size=8)
    reqs = _requests(cfg, 4, budgets=[6, 11])
    results, stats = ContinuousScheduler(eng, batch=2).serve(reqs)
    assert stats["admitted"] == 4
    _assert_matches_solo(eng, results, reqs)


def test_paged_scheduler_hybrid_family():
    cfg, model, params, heads, spec = _setup("zamba2-7b")
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4,
                            paged=True, page_size=8)
    reqs = _requests(cfg, 4, budgets=[5, 10])
    results, _ = ContinuousScheduler(eng, batch=2).serve(reqs)
    _assert_matches_solo(eng, results, reqs)


# --------------------------------------------------------------------------
# pool exhaustion: freeze + defer, never corrupt
# --------------------------------------------------------------------------
def test_full_pool_freezes_without_corrupting_neighbor():
    """Regression: with the pool too small for row 1's need, row 1 must
    freeze (shortfall in n_emitted, padding after) while row 0's output is
    BIT-IDENTICAL to an uncontended run.  Fails if overflow writes ever
    land in a neighbor's pages instead of the trash page."""
    cfg, model, params, heads, spec = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0,
                              cfg.vocab_size)
    budgets = np.asarray([24, 24])
    big = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4,
                            paged=True, page_size=8)
    out_big, st_big = big.generate({"tokens": toks}, budgets)
    assert np.all(st_big["n_emitted"] == 24)      # uncontended: full output

    # row 0's reservation fits; row 1 gets the leftovers (partial)
    need_row0 = C.pages_for(8 + 24 + spec.max_depth, 8)
    small = SpeculativeEngine(model, heads, params, spec, max_len=64,
                              chunk=4, paged=True, page_size=8,
                              pool_pages=need_row0 + 2)
    out_small, st = small.generate({"tokens": toks}, budgets)
    # neighbor (row 0) untouched by row 1's starvation
    np.testing.assert_array_equal(out_small[0], out_big[0])
    assert int(st["n_emitted"][0]) == 24
    # starved row froze early with a clean prefix + padding
    n1 = int(st["n_emitted"][1])
    assert 1 <= n1 < 24, n1
    np.testing.assert_array_equal(out_small[1, :n1], out_big[1, :n1])
    assert np.all(out_small[1, n1:] == -1)


def test_fresh_serve_recovers_from_aborted_run():
    """An earlier serve() that died mid-run leaves the engine's allocator
    depleted; the next serve() must rebuild it at bootstrap instead of
    deferring admission forever on an empty bank."""
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4,
                            paged=True, page_size=8)
    eng._alloc = C.PageAllocator(1)               # simulate the aborted run
    eng._alloc.alloc(1)
    eng._row_pages = {0: [0]}
    reqs = _requests(cfg, 2, budgets=[6])
    results, stats = ContinuousScheduler(eng, batch=2).serve(reqs)
    assert stats["admitted"] == 2
    _assert_matches_solo(eng, results, reqs)


def test_pool_exhaustion_defers_admission():
    """A request that cannot fund its reservation waits in the queue (the
    bank runs below width) and is admitted — unperturbed — once eviction
    frees pages."""
    cfg, model, params, heads, spec = _setup()
    # pool funds exactly ONE resident (prompt 8 + budget 10 + depth 8 -> 4
    # pages of 8), so batch=2 degrades to sequential service
    eng = SpeculativeEngine(model, heads, params, spec, max_len=40, chunk=4,
                            paged=True, page_size=8, pool_pages=4)
    reqs = _requests(cfg, 3, budgets=[10])
    sched = ContinuousScheduler(eng, batch=2)
    results, stats = sched.serve(reqs)
    assert stats["admitted"] == 3
    assert stats["max_resident"] == 1             # pool-bound, not bank-bound
    _assert_matches_solo(eng, results, reqs)
    # admissions strictly follow the previous request's eviction
    order = [(ev, r) for ev, r, _ in sched.events]
    assert order.index(("admit", 1)) > order.index(("evict", 0))
    assert order.index(("admit", 2)) > order.index(("evict", 1))


# --------------------------------------------------------------------------
# slot lifecycle: evicted rows are fully inert (carry included)
# --------------------------------------------------------------------------
def test_evicted_spec_rows_clear_carry():
    """The cache-only reset left stale cur_token/hidden in freed slots;
    with pages recycled immediately that stale carry must die at eviction."""
    cfg, model, params, heads, spec = _setup()
    for paged in (False, True):
        eng = SpeculativeEngine(model, heads, params, spec, max_len=64,
                                chunk=4, paged=paged, page_size=8)
        reqs = _requests(cfg, 3, budgets=[5])
        sched = ContinuousScheduler(eng, batch=2)
        sched.serve(reqs)
        st = sched.last_state
        assert np.all(np.asarray(st.cur_token) == 0), f"paged={paged}"
        assert np.all(np.asarray(st.hidden) == 0), f"paged={paged}"


def test_evicted_seq_rows_clear_carry():
    cfg, model, params, _, _ = _setup()
    eng = BatchEngine(model, params, max_len=64, chunk=4)
    reqs = _requests(cfg, 3, budgets=[5])
    sched = ContinuousScheduler(eng, batch=2)
    results, _ = sched.serve(reqs)
    st = sched.last_state                 # unified protocol: SpecState with
    assert st.hidden is None              # no drafting carry for sequential
    # a freed row's carry is reset to 0; trailing chunks may overwrite it
    # with the EOS pad sentinel — either way it is never the evicted
    # request's live token
    cur = np.asarray(st.cur_token)
    assert np.all(np.isin(cur, [0, -1])), cur
    for r in results:
        assert not np.any(cur == r.tokens[-1]) or r.tokens[-1] in (0, -1)
