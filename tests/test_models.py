"""Decode-vs-prefill consistency per family + verify/commit semantics."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.api import get_model
from repro.core.speculative import tree as T

FAMILY_ARCHS = ["qwen2-0.5b", "qwen3-moe-30b-a3b", "zamba2-7b", "xlstm-125m",
                "seamless-m4t-medium", "glm4-9b"]


def _setup(arch, B=2, S=12):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend == "audio":
        batch["frame_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq_len, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return cfg, model, params, toks, batch


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_decode_matches_prefill(arch):
    cfg, model, params, toks, batch = _setup(arch)
    full, _, _ = model.prefill(params, batch, max_len=16)
    half = {**batch, "tokens": toks[:, :8]}
    _, _, cache = model.prefill(params, half, max_len=16)
    outs = []
    for i in range(8, 12):
        lg, cache = model.decode(params, cache, toks[:, i:i + 1])
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full[:, 8:12])))
    assert err < 5e-2, err


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_verify_chain_matches_teacher_forcing(arch):
    cfg, model, params, toks, batch = _setup(arch)
    full, _, _ = model.prefill(params, batch, max_len=20)
    half = {**batch, "tokens": toks[:, :8]}
    _, _, cache = model.prefill(params, half, max_len=20)
    # chain tree = the true continuation
    spec = T.spec_from_nodes([(-1, 0, 0), (0, 1, 0), (1, 2, 0), (2, 3, 0)])
    tr = T.Tree.from_spec(spec)
    vlog, extras = model.verify(params, cache, toks[:, 8:12], tr)
    err = float(jnp.max(jnp.abs(vlog - full[:, 8:12])))
    assert err < 5e-2, err

    # commit 3 of 4 (per-sequence args), then decode the 12th token ==
    # teacher forcing
    B = toks.shape[0]
    cache = model.commit(
        cache, extras, tr,
        jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32), (B, 4)),
        jnp.full((B,), 3, jnp.int32), jnp.zeros((B,), jnp.int32))
    lg, _ = model.decode(params, cache, toks[:, 11:12])
    err2 = float(jnp.max(jnp.abs(lg[:, 0] - full[:, 11])))
    assert err2 < 5e-2, err2


def test_windowed_decode_matches_windowed_prefill():
    cfg, model, params, toks, batch = _setup("glm4-9b")
    lw, _, cw = model.prefill(params, {**batch, "tokens": toks[:, :8]},
                              max_len=6, window=6)
    for i in range(8, 12):
        lwi, cw = model.decode(params, cw, toks[:, i:i + 1])
    lw_full, _, _ = model.prefill(params, batch, window=6)
    err = float(jnp.max(jnp.abs(lwi[:, 0] - lw_full[:, -1])))
    assert err < 5e-2, err


def test_vlm_prefix_embeddings():
    cfg = get_config("llava-next-mistral-7b").reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    patches = jax.random.normal(
        jax.random.PRNGKey(2), (B, cfg.num_frontend_tokens, cfg.d_model),
        jnp.dtype(cfg.dtype))
    logits, _, cache = model.prefill(
        params, {"tokens": toks, "patch_embeds": patches}, max_len=64)
    assert logits.shape == (B, S + cfg.num_frontend_tokens, cfg.vocab_size)
    # decode continues after the multimodal prefix
    lg, cache = model.decode(params, cache, toks[:, :1])
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(lg)))
