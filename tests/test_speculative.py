"""Speculative decoding invariants: LOSSLESSNESS (greedy spec == greedy
sequential) per family, accept-walk properties, emitted-token accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # container may not ship hypothesis
    from _mini_hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.core.speculative.verify import accept_walk, spec_prefill, spec_step
from repro.models.api import get_model


def _greedy_reference(model, params, toks, n):
    logits, _, cache = model.prefill(params, {"tokens": toks}, max_len=128)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    dec = jax.jit(lambda p, c, t: model.decode(p, c, t))
    out = [int(cur[0])]
    for _ in range(n - 1):
        lg, cache = dec(params, cache, cur[:, None])
        cur = jnp.argmax(lg[:, 0], -1).astype(jnp.int32)
        out.append(int(cur[0]))
    return out


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "zamba2-7b", "xlstm-125m"])
def test_speculative_lossless(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(7))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    N = 16
    ref = _greedy_reference(model, params, toks, N)

    spec = T.build_tree(T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 8)
    tr = T.Tree.from_spec(spec)
    st_ = spec_prefill(model, params, heads, {"tokens": toks}, max_len=128)
    out = [int(st_.cur_token[0])]
    step = jax.jit(lambda p, h, s: spec_step(model, p, h, tr, s))
    while len(out) < N:
        st_, emitted, n = step(params, heads, st_)
        out.extend(int(t) for t in np.asarray(emitted[0])[:int(n[0])])
    assert out[:N] == ref, f"{arch}: speculative != sequential greedy"


# ---------------------------------------------------------------------------
# accept_walk vs a trusted numpy reference, on random trees/logits
# ---------------------------------------------------------------------------
def _np_accept(parent, depth, tree_tokens, targets):
    cur, n = 0, 1
    while True:
        nxt = None
        for i in range(len(parent)):
            if parent[i] == cur and tree_tokens[i] == targets[cur] \
                    and depth[i] == depth[cur] + 1:
                nxt = i
                break
        if nxt is None:
            return n, cur
        cur, n = nxt, n + 1


@given(seed=st.integers(0, 10_000), width=st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=40, deadline=None)
def test_accept_walk_matches_numpy(seed, width):
    rng = np.random.default_rng(seed)
    nodes = [(-1, 0, 0)]
    used = set()
    while len(nodes) < width:
        p = int(rng.integers(0, len(nodes)))
        r = int(rng.integers(0, 6))
        if (p, r) in used or nodes[p][1] >= 4:
            continue
        used.add((p, r))
        nodes.append((p, nodes[p][1] + 1, r))
    spec = T.spec_from_nodes(nodes)
    tr = T.Tree.from_spec(spec)
    W = spec.width
    V = 12                                         # small vocab => collisions
    tree_tokens = rng.integers(0, V, (1, W)).astype(np.int32)
    logits = rng.normal(size=(1, W, V)).astype(np.float32)
    targets = logits[0].argmax(-1)

    acc = accept_walk(tr, jnp.asarray(tree_tokens), jnp.asarray(logits))
    n_ref, last_ref = _np_accept(spec.parent, spec.depth, tree_tokens[0],
                                 targets)
    assert int(acc["n_accept"][0]) == n_ref
    assert int(acc["bonus"][0]) == targets[int(acc["last_node"][0])]
    # chain is a valid root->last path
    chain = np.asarray(acc["chain"][0])
    assert chain[0] == 0
    n = int(acc["n_accept"][0])
    for j in range(1, n):
        assert spec.parent[chain[j]] == chain[j - 1]
