"""Chunked-parallel mLSTM prefill must be EXACT vs the per-step recurrence
(EXPERIMENTS §Perf hillclimb B) — including state carry across chunks and
ragged tails."""
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # container may not ship hypothesis
    from _mini_hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import xlstm as xl


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("xlstm-125m").reduced()
    p = xl.mlstm_init(cfg, jax.random.PRNGKey(0))
    return cfg, p


@given(S=st.integers(3, 40), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_chunked_matches_scan(setup, S, chunk, seed):
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, S, cfg.d_model),
                          jnp.float32)
    y_scan, st_scan = xl.mlstm_prefill_scan(cfg, p, x)
    y_chunk, st_chunk = xl.mlstm_prefill(cfg, p, x, chunk=chunk)
    assert float(jnp.max(jnp.abs(y_scan - y_chunk))) < 2e-3
    for k in ("C", "n", "m"):
        assert float(jnp.max(jnp.abs(st_scan[k] - st_chunk[k]))) < 2e-3


def test_state_continuation(setup):
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 30, cfg.d_model),
                          jnp.float32)
    y_full, _ = xl.mlstm_prefill(cfg, p, x, chunk=8)
    y1, st1 = xl.mlstm_prefill(cfg, p, x[:, :13], chunk=8)
    y2, _ = xl.mlstm_prefill(cfg, p, x[:, 13:], state=st1, chunk=8)
    err = float(jnp.max(jnp.abs(jnp.concatenate([y1, y2], 1) - y_full)))
    assert err < 2e-3
