"""Fault-tolerant serving: request lifecycle on the scheduler's stepping
API and the asyncio server/router front end (runtime/server.py,
runtime/router.py, runtime/faults.py).

Invariants:
  * a mid-flight ``abort()`` finalizes ONLY the victim — with the tokens
    emitted so far, a bit-identical PREFIX of its solo run — releases its
    reserved pages at that same boundary (available pages strictly
    increase while neighbors stay resident), and every surviving request
    still finishes bit-identical to its solo run;
  * a queued abort finalizes with zero tokens; deadlines finalize
    TIMED_OUT whether the request is queued or resident; ``fail_all``
    (the crash path) FAILs everything and returns every page;
  * the async server streams exactly the tokens of the final result,
    sheds load with typed REJECTED results at ``queue_limit``, and
    resolves every handle even through an injected replica crash;
  * the router retries FAILED/REJECTED attempts on another replica and
    never double-emits: delivered tokens across all attempts equal the
    solo run exactly once; with no healthy replica left it resolves
    REJECTED; the fleet's page pools stay conserved through all of it.

Async tests run under a ``signal.alarm`` hard timeout (pytest-timeout is
not available in the container): a deadlocked event loop fails loudly
instead of hanging tier-1.
"""
import asyncio
import signal
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import get_model
from repro.runtime.engine import BatchEngine
from repro.runtime.faults import FaultPlan, ReplicaCrash
from repro.runtime.router import ReplicaRouter
from repro.runtime.scheduler import (CANCELLED, DECODING, DONE, FAILED,
                                     REJECTED, TERMINAL_STATES, TIMED_OUT,
                                     ContinuousScheduler, Request)
from repro.runtime.server import AsyncEngineServer

MAX_LEN = 64
PAGE_SIZE = 8
POOL_PAGES = 12
_ENGINES = {}


@pytest.fixture(autouse=True)
def _hard_timeout():
    """Hard per-test wall clock: a hung worker thread or event loop must
    fail the test, not the whole tier-1 run."""
    def _boom(signum, frame):
        raise RuntimeError("serving test exceeded the hard timeout")
    old = signal.signal(signal.SIGALRM, _boom)
    signal.alarm(300)                  # generous: first test pays compile
    yield
    signal.alarm(0)
    signal.signal(signal.SIGALRM, old)


def _engine(name="a"):
    """Cached paged BatchEngine per replica name (replicas must not share
    a bank: each server thread steps its own engine)."""
    if name not in _ENGINES:
        cfg = get_config("qwen2-0.5b").reduced()
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        _ENGINES[name] = (cfg, BatchEngine(
            model, params, max_len=MAX_LEN, chunk=4, paged=True,
            page_size=PAGE_SIZE, pool_pages=POOL_PAGES))
    return _ENGINES[name]


def _requests(cfg, n, budget, prompt_len=6, seed=3):
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, prompt_len), 0, cfg.vocab_size),
        np.int32)
    return [Request(req_id=i, tokens=toks[i], n_tokens=budget)
            for i in range(n)]


def _solo(eng, req):
    out, _ = eng.generate({"tokens": req.tokens[None]}, req.n_tokens)
    return np.atleast_2d(out)[0]


# ---------------------------------------------------------------------------
# scheduler stepping API: abort / deadline / crash lifecycle
# ---------------------------------------------------------------------------

def test_abort_midflight_parity_and_page_release():
    """Cancel one resident request mid-decode: its pages come back at that
    same boundary, its partial tokens are a solo prefix, and the SURVIVING
    residents finish bit-identical to their solo runs (the parity pin for
    the whole abort path)."""
    cfg, eng = _engine()
    reqs = _requests(cfg, 3, budget=20)    # 5 boundaries: prefill emits too
    sched = ContinuousScheduler(eng, batch=2)
    sched.start(reqs[:2])                  # rows full, nothing queued
    sched.boundary()                       # admit 2, prefill + first chunk
    sched.boundary()                       # second chunk: mid-flight now
    assert sched.request_state(1) == DECODING
    avail = eng._alloc.available
    sched.abort(1)                         # takes effect next boundary
    rep = sched.boundary()
    got = [r for r in rep.finished if r.req_id == 1]
    assert got and got[0].state == CANCELLED
    assert eng._alloc.available > avail    # pages released MID-FLIGHT
    assert sched.request_state(0) == DECODING      # neighbor untouched
    sched.submit(reqs[2])                  # freed row + pages fund this
    sched.boundary()
    assert ("admit", 2, 1) in sched.events         # recycled the row
    while sched.has_work:
        sched.boundary()
    results, stats = sched.finish(reqs)
    assert [r.req_id for r in results] == [0, 1, 2]
    for r, req in zip(results, reqs):
        solo = _solo(eng, req)
        if r.req_id == 1:
            assert r.state == CANCELLED
            assert 0 < r.n_emitted < req.n_tokens  # partial, not empty
        else:
            assert r.state == DONE and r.n_emitted == req.n_tokens
        np.testing.assert_array_equal(r.tokens, solo[:r.n_emitted],
                                      err_msg=f"req {r.req_id}")
    assert ("abort", 1, 1) in sched.events         # row 1 was the victim
    assert eng.sched_drained() and eng.sched_pool_conserved()
    assert stats["states"] == {"DONE": 2, "CANCELLED": 1}


def test_abort_queued_and_deadlines():
    """A queued abort never runs (zero tokens); a deadline finalizes
    TIMED_OUT from the queue (never admitted) and mid-flight (partial
    solo-prefix tokens, pages released)."""
    cfg, eng = _engine()
    reqs = _requests(cfg, 3, budget=24)
    reqs[2].deadline = 0.0                 # already expired when serving
    sched = ContinuousScheduler(eng, batch=1)
    sched.start(reqs)
    rep = sched.boundary()                 # req 0 admitted; req 2 swept
    timed = {r.req_id: r for r in rep.finished}
    assert timed[2].state == TIMED_OUT and timed[2].n_emitted == 0
    sched.abort(1)                         # still queued behind req 0
    rep = sched.boundary()
    got = {r.req_id: r for r in rep.finished}
    assert got[1].state == CANCELLED and got[1].n_emitted == 0
    assert ("abort", 1, -1) in sched.events        # -1: never admitted
    reqs[0].deadline = sched.now()         # expire the RESIDENT request
    rep = sched.boundary()
    got = {r.req_id: r for r in rep.finished}
    assert got[0].state == TIMED_OUT
    assert 0 < got[0].n_emitted < reqs[0].n_tokens
    np.testing.assert_array_equal(
        got[0].tokens, _solo(eng, reqs[0])[:got[0].n_emitted])
    assert not sched.has_work
    results, stats = sched.finish(reqs)
    assert all(r.state in TERMINAL_STATES for r in results)
    admits = [e for e in sched.events if e[0] == "admit"]
    assert [e[1] for e in admits] == [0]   # only req 0 ever held a row
    assert eng.sched_drained() and eng.sched_pool_conserved()


def test_fail_all_releases_everything():
    """The crash path: every in-flight and queued request lands FAILED
    with solo-prefix tokens and the page pool is fully conserved — a dead
    replica leaks nothing."""
    cfg, eng = _engine()
    reqs = _requests(cfg, 3, budget=24)
    sched = ContinuousScheduler(eng, batch=2)
    sched.start(reqs)
    sched.boundary()
    sched.boundary()
    failed = sched.fail_all(RuntimeError("boom"))
    assert sorted(r.req_id for r in failed) == [0, 1, 2]
    for r in failed:
        assert r.state == FAILED
        req = reqs[r.req_id]
        np.testing.assert_array_equal(
            r.tokens, _solo(eng, req)[:r.n_emitted])
    assert not sched.has_work
    assert eng.sched_drained() and eng.sched_pool_conserved()


# ---------------------------------------------------------------------------
# async server + router
# ---------------------------------------------------------------------------

def test_server_stream_matches_result():
    """The streamed chunks concatenate to exactly the final result's
    tokens, which match the solo run; the handle resolves DONE."""
    cfg, eng = _engine()
    req = _requests(cfg, 1, budget=12)[0]

    async def go():
        srv = AsyncEngineServer(ContinuousScheduler(eng, batch=2),
                                name="s0")
        await srv.start()
        handle = await srv.submit(req)
        streamed = []
        async for toks in handle.stream():
            streamed.extend(toks)
        res = await handle.result()
        await srv.stop()
        return streamed, res

    streamed, res = asyncio.run(go())
    assert res.state == DONE
    np.testing.assert_array_equal(streamed, res.tokens)
    np.testing.assert_array_equal(res.tokens, _solo(eng, req)[:12])
    assert eng.sched_drained()


def test_server_backpressure_rejected():
    """Load over ``queue_limit`` is shed with an immediate typed REJECTED
    result; the admitted request is unaffected."""
    cfg, eng = _engine()
    reqs = _requests(cfg, 2, budget=12)

    async def go():
        srv = AsyncEngineServer(ContinuousScheduler(eng, batch=2),
                                name="s0", queue_limit=1)
        await srv.start()
        h0 = await srv.submit(reqs[0])     # load >= 1 from this instant
        h1 = await srv.submit(reqs[1])     # over the limit: shed
        r1 = await h1.result()
        r0 = await h0.result()
        await srv.stop()
        return r0, r1, srv.rejected

    r0, r1, rejected = asyncio.run(go())
    assert r1.state == REJECTED and r1.n_emitted == 0 and rejected == 1
    assert r0.state == DONE and r0.n_emitted == 12


def test_server_cancel_mid_stream():
    """A client cancel lands at the next chunk boundary: CANCELLED with a
    solo-prefix of the tokens delivered so far."""
    cfg, eng = _engine()
    req = _requests(cfg, 1, budget=56)[0]

    async def go():
        srv = AsyncEngineServer(ContinuousScheduler(eng, batch=2),
                                name="s0")
        await srv.start()
        handle = await srv.submit(req)
        streamed = []
        async for toks in handle.stream():
            streamed.extend(toks)
            if len(streamed) >= 4:         # hang up after the first chunk
                await srv.cancel(req.req_id)
        res = await handle.result()
        await srv.stop()
        return streamed, res

    streamed, res = asyncio.run(go())
    assert res.state == CANCELLED
    assert 0 < res.n_emitted < req.n_tokens
    np.testing.assert_array_equal(streamed, res.tokens)
    np.testing.assert_array_equal(res.tokens, _solo(eng, req)[:res.n_emitted])
    assert eng.sched_drained() and eng.sched_pool_conserved()


def test_server_deadline_times_out():
    cfg, eng = _engine()
    req = _requests(cfg, 1, budget=56)[0]

    async def go():
        srv = AsyncEngineServer(ContinuousScheduler(eng, batch=2),
                                name="s0")
        await srv.start()
        handle = await srv.submit(req, deadline_s=0.02)
        res = await handle.result()
        await srv.stop()
        return res

    res = asyncio.run(go())
    assert res.state == TIMED_OUT
    assert res.n_emitted < req.n_tokens
    assert eng.sched_drained()


def test_router_crash_retry_never_double_emits():
    """Replica ra crashes mid-request; the router retries on rb and the
    client's delivered stream is the solo run EXACTLY ONCE (the retried
    attempt's re-decoded prefix is skipped); ra is unhealthy afterwards
    and neither replica leaks pages."""
    cfg, ea = _engine("ra")
    _, eb = _engine("rb")
    req = _requests(cfg, 1, budget=24)[0]
    plan = FaultPlan(seed=5, crash={"ra": 2})

    async def go():
        servers = [
            AsyncEngineServer(ContinuousScheduler(
                ea, batch=2, faults=plan.injector("ra")), name="ra"),
            AsyncEngineServer(ContinuousScheduler(eb, batch=2), name="rb"),
        ]
        router = ReplicaRouter(servers, max_retries=2, backoff_base=0.01,
                               seed=5)
        await router.start()
        delivered, res = await router.generate(req)
        health = [s.healthy for s in servers]
        conserved = router.pages_conserved() and router.drained()
        await router.stop()
        return delivered, res, health, conserved, router.retries

    delivered, res, health, conserved, retries = asyncio.run(go())
    assert res.state == DONE and retries >= 1
    assert health == [False, True]         # ra crashed, rb survived
    np.testing.assert_array_equal(delivered, _solo(ea, req)[:24])
    np.testing.assert_array_equal(res.tokens, delivered)
    assert conserved


def test_router_no_healthy_replica_rejects():
    """Every replica crashes on its first boundary: after the retry
    budget the router resolves REJECTED rather than hanging, and the dead
    replicas' pools are still conserved (fail_all cleanup)."""
    cfg, ea = _engine("ra2")
    _, eb = _engine("rb2")
    req = _requests(cfg, 1, budget=24)[0]
    plan = FaultPlan(seed=6, crash={"ra2": 1, "rb2": 1})

    async def go():
        servers = [
            AsyncEngineServer(ContinuousScheduler(
                e, batch=2, faults=plan.injector(n)), name=n)
            for n, e in (("ra2", ea), ("rb2", eb))]
        router = ReplicaRouter(servers, max_retries=3, backoff_base=0.01,
                               seed=6)
        await router.start()
        _, res = await router.generate(req)
        conserved = router.pages_conserved() and router.drained()
        healthy = any(s.healthy for s in servers)
        await router.stop()
        return res, conserved, healthy

    res, conserved, healthy = asyncio.run(go())
    assert res.state == REJECTED and not healthy and conserved


def test_backoff_accepts_string_request_ids():
    """Regression: ``_backoff`` seeded ``np.random.default_rng`` with the
    raw ``req_id`` — any application-chosen non-int id (uuid-style
    strings) crashed the retry path at the first backoff.  Ids now seed
    through a stable digest of ``str(req_id)``: deterministic per
    (seed, id, attempt), identical for ``7`` and ``"7"``, and accepting
    any stringifiable id."""
    class _Stub:
        name = "r0"
    router = ReplicaRouter([_Stub()], seed=3)
    d = router._backoff("req-00c4-uuid", 1)
    assert 0.0 < d <= router.backoff_cap * (1.0 + router.jitter)
    assert d == router._backoff("req-00c4-uuid", 1)       # deterministic
    assert router._backoff(7, 2) == router._backoff("7", 2)
    # attempt growth still caps at backoff_cap regardless of id type
    assert router._backoff("x", 9) <= \
        router.backoff_cap * (1.0 + router.jitter)


def test_router_retry_with_string_request_id():
    """End-to-end regression for the backoff fix: a crash-forced retry of
    a request with a STRING id must reach DONE through the backoff path
    (previously a TypeError inside ``_backoff``) and never double-emit."""
    cfg, ea = _engine("rs_a")
    _, eb = _engine("rs_b")
    base = _requests(cfg, 1, budget=16)[0]
    req = Request(req_id="job/alpha-7", tokens=base.tokens,
                  n_tokens=base.n_tokens)
    plan = FaultPlan(seed=5, crash={"rs_a": 2})

    async def go():
        servers = [
            AsyncEngineServer(ContinuousScheduler(
                ea, batch=2, faults=plan.injector("rs_a")), name="rs_a"),
            AsyncEngineServer(ContinuousScheduler(eb, batch=2),
                              name="rs_b"),
        ]
        router = ReplicaRouter(servers, max_retries=2, backoff_base=0.01,
                               seed=5)
        await router.start()
        delivered, res = await router.generate(req)
        conserved = router.pages_conserved() and router.drained()
        await router.stop()
        return delivered, res, conserved, router.retries

    delivered, res, conserved, retries = asyncio.run(go())
    assert res.state == DONE and retries >= 1
    assert res.req_id == "job/alpha-7"
    np.testing.assert_array_equal(delivered, _solo(ea, base)[:16])
    np.testing.assert_array_equal(res.tokens, delivered)
    assert conserved


def test_router_liveness_probe_drains_stalled_replica():
    """Replica rs is alive but WEDGED (every boundary stalls far longer
    than ``stall_timeout_s``): its boundary-progress heartbeat goes
    stale, the router's liveness watcher drains it proactively — the
    outstanding handle fails over to rs2 and the client still gets the
    solo stream exactly once — and rs is sticky-unhealthy so routing
    skips it from then on.  Without the probe this request would sit on
    the wedged worker for the stall's full duration."""
    cfg, ea = _engine("rs")
    _, eb = _engine("rs2")
    req = _requests(cfg, 1, budget=12)[0]
    # prewarm both engines' scheduler-path jits: a cold compile inside
    # the first boundary is indistinguishable from a stall and would
    # trip the probe on the HEALTHY replica too
    for e in (ea, eb):
        warm = ContinuousScheduler(e, batch=2)
        warm.start([], eos=None)
        warm.submit(_requests(cfg, 1, budget=12, seed=9)[0])
        while warm.has_work:
            warm.boundary()
        warm.finish()
    plan = FaultPlan(seed=7, stall_rate=1.0, stall_s=2.0)

    async def go():
        servers = [
            AsyncEngineServer(ContinuousScheduler(
                ea, batch=2, faults=plan.injector("rs")), name="rs",
                stall_timeout_s=0.5),
            AsyncEngineServer(ContinuousScheduler(eb, batch=2),
                              name="rs2", stall_timeout_s=0.5),
        ]
        router = ReplicaRouter(servers, max_retries=2, backoff_base=0.01,
                               seed=7)
        await router.start(health_every_s=0.05)
        delivered, res = await router.generate(req)
        health = [s.healthy for s in servers]
        seen_stalled = any(h["name"] == "rs" and h["stalled"]
                           for snap in router.health_log for h in snap)
        conserved = router.pages_conserved()
        await router.stop()               # joins rs once its decode ends
        drained = router.drained()
        return (delivered, res, health, seen_stalled, conserved,
                drained, router.retries, router.stall_drains)

    (delivered, res, health, seen_stalled, conserved, drained, retries,
     stall_drains) = asyncio.run(go())
    assert res.state == DONE and retries >= 1
    assert stall_drains >= 1               # the probe did the failover
    assert health == [False, True]         # rs sticky-unhealthy, rs2 fine
    assert seen_stalled                    # health() surfaced the stall
    np.testing.assert_array_equal(delivered, _solo(ea, req)[:12])
    np.testing.assert_array_equal(res.tokens, delivered)
    assert conserved and drained           # wedged != leaking


def test_fault_plan_validation_and_determinism():
    with pytest.raises(ValueError):
        FaultPlan(cancel_rate=1.5)
    with pytest.raises(ValueError):
        FaultPlan(cancel_after=(0, 4))
    plan = FaultPlan(seed=11, cancel_rate=0.5, exhaust_rate=0.4)
    # client behavior is a pure function of (seed, req_id)
    a = [plan.client().disconnect_after(i) for i in range(32)]
    b = [plan.client().disconnect_after(i) for i in range(32)]
    assert a == b and any(x is not None for x in a)
    # replica injectors replay identically for the same (seed, name)
    def draws(name):
        inj = plan.injector(name)
        return [inj.block_admission() for _ in range(30)]
    seq = draws("r0")
    assert seq == draws("r0") and any(seq)
    assert draws("r1") == draws("r1")
    with pytest.raises(ReplicaCrash):
        FaultPlan(crash={"r0": 1}).injector("r0").on_boundary(1)


def test_loop_observability_uses_worker_snapshots():
    """The event-loop side (``health()``, the router's pool audits, the
    reject path of ``submit``) must never call into the worker-owned
    scheduler or engine: every scheduler/engine call during a serving
    session originates on the worker thread, and the loop reads only
    worker-published snapshots (regression test for the R4
    thread-discipline fixes in server.py/router.py)."""
    cfg, eng = _engine("rlock")
    reqs = _requests(cfg, 2, budget=12)
    calls = []

    def _spy(obj, name):
        orig = getattr(obj, name)

        def wrap(*a, **k):
            calls.append((name, threading.get_ident()))
            return orig(*a, **k)

        setattr(obj, name, wrap)

    sched = ContinuousScheduler(eng, batch=2)
    for n in ("now", "submit", "abort", "boundary", "fail_all"):
        _spy(sched, n)
    for n in ("sched_pool_conserved", "sched_drained"):
        _spy(eng, n)

    async def go():
        srv = AsyncEngineServer(sched, name="rlock", queue_limit=1)
        router = ReplicaRouter([srv])
        await router.start()
        h0 = await srv.submit(reqs[0])
        h1 = await srv.submit(reqs[1])     # shed: loop-side reject path
        health = srv.health()              # loop-side observability
        r1 = await h1.result()
        r0 = await h0.result()
        audits = router.pages_conserved(), router.drained()
        await router.stop()
        return srv._thread.ident, health, audits, r0, r1

    try:
        worker, health, audits, r0, r1 = asyncio.run(go())
    finally:
        for n in ("sched_pool_conserved", "sched_drained"):
            del eng.__dict__[n]            # engine is cached across tests

    assert r0.state == DONE and r1.state == REJECTED
    assert worker is not None and worker != threading.get_ident()
    offenders = sorted({n for n, t in calls if t != worker})
    assert not offenders, \
        f"scheduler/engine touched off the worker thread: {offenders}"
    assert health["pool_conserved"] and audits == (True, True)
