"""Unified decode-strategy layer (runtime/engine.py DecodeEngine /
DecodeStrategy, core/arca.py profile_engine, runtime/scheduler.py
AdaptiveSpeculation).

Invariants:
  * ``BatchEngine`` / ``SpeculativeEngine`` are thin aliases: one
    ``DecodeEngine`` implementation underneath (no overridden driver or
    sched protocol), sequential = the degenerate chain_spec(width=1)
    strategy;
  * ``choose_strategy`` over a measured ``time_fn`` produces a sane
    argmax (monotone step times push the optimum down; free steps push it
    to the widest) and width=1 degenerates to the sequential chain;
  * ``profile_engine`` times the engine's compiled steps once per tree
    shape and feeds the search;
  * runtime strategy switches at chunk boundaries are output-neutral
    (greedy tree verification commits the greedy chain whatever the
    tree): an adaptive run's per-request tokens are bit-identical to
    fixed-width solo runs;
  * same-shape strategy switches reuse the compiled chunk scans (no
    re-jit), and returning to an already-compiled width is free.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import arca
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.models.api import get_model
from repro.runtime.engine import (BatchEngine, DecodeEngine, DecodeStrategy,
                                  SpeculativeEngine)
from repro.runtime.scheduler import (AdaptiveSpeculation,
                                     ContinuousScheduler, Request)


def _setup(arch="qwen2-0.5b"):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(7))
    accs = T.default_accs(cfg.medusa_heads, cfg.medusa_top_k)
    return cfg, model, params, heads, accs


def _requests(cfg, n, budgets, prompt_len=8, seed=3):
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, prompt_len), 0, cfg.vocab_size),
        np.int32)
    return [Request(req_id=i, tokens=toks[i],
                    n_tokens=budgets[i % len(budgets)]) for i in range(n)]


# --------------------------------------------------------------------------
# one engine, two aliases
# --------------------------------------------------------------------------
def test_aliases_are_thin():
    """The legacy entry points add a constructor, nothing else: the chunk
    driver, generate loop and whole sched_* protocol are DecodeEngine's."""
    for alias in (BatchEngine, SpeculativeEngine):
        assert issubclass(alias, DecodeEngine)
        for name in ("generate", "sched_step", "sched_admit",
                     "sched_insert", "sched_reset", "sched_blank",
                     "sched_prefill", "sched_emitted", "_chunk_fn",
                     "set_strategy", "time_step"):
            assert name not in vars(alias), \
                f"{alias.__name__}.{name} overrides the unified engine"


def test_sequential_is_degenerate_chain():
    cfg, model, params, heads, _ = _setup()
    seq = BatchEngine(model, params, max_len=64)
    assert seq.strategy.draft == "none"
    assert seq.strategy.width == 1
    assert seq.strategy.tree.max_depth == 1          # chain_spec(1): root
    assert seq.strategy.shape() == ("none", 1, 1, 1)
    assert seq._overshoot == 1                       # one slot past budget
    # draft-kind guards: no heads -> width-1 only; no cross-kind switches
    with pytest.raises(ValueError):
        seq.strategy_for(T.build_tree(T.default_accs(4, 4), 4))
    spec_eng = SpeculativeEngine(model, heads, params,
                                 T.build_tree(T.default_accs(4, 4), 4),
                                 max_len=64)
    with pytest.raises(ValueError):
        spec_eng.set_strategy(DecodeStrategy.sequential())
    with pytest.raises(ValueError):
        DecodeEngine(model, params, heads=heads)     # heads need a strategy


# --------------------------------------------------------------------------
# choose_strategy over a measured time_fn
# --------------------------------------------------------------------------
def test_choose_strategy_measured_time_fn():
    cfg, _, _, _, accs = _setup()
    widths = (1, 2, 4, 8)

    # width=1 degenerates to the sequential chain whatever the timer says
    flat = arca.choose_strategy(cfg, accs, ctx=32, widths=widths,
                                time_fn=lambda c, w, ctx, s: 1e-3)
    assert flat[1].tree.width == 1 and flat[1].tree.max_depth == 1
    assert flat[1].acceptance == pytest.approx(1.0)
    # free extra width: acceptance is monotone, so the argmax is widest
    assert arca.best(flat).width == widths[-1]

    # strongly monotone step times (cost ~ width) overwhelm the sublinear
    # acceptance gain: the argmax moves DOWN, and every strategy carries
    # the measured time it was scored with
    steep = arca.choose_strategy(cfg, accs, ctx=32, widths=widths,
                                 time_fn=lambda c, w, ctx, s: 1e-3 * w)
    assert arca.best(steep).width < widths[-1]
    for w in widths:
        assert steep[w].step_time == pytest.approx(1e-3 * w)
        assert steep[w].throughput == pytest.approx(
            steep[w].acceptance / (1e-3 * w))


def test_profile_engine_measures_compiled_steps():
    cfg, model, params, heads, accs = _setup()
    eng = SpeculativeEngine(model, heads, params, T.build_tree(accs, 4),
                            max_len=96, chunk=4)
    calls = {"n": 0}
    real = eng.time_step

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    eng.time_step = counting
    widths = (1, 2, 4)
    time_fn = arca.profile_engine(eng, widths, accs=accs, reps=1)
    assert calls["n"] == len(widths)                 # pre-warmed per width
    strats = arca.choose_strategy(cfg, accs, ctx=16, time_fn=time_fn,
                                  widths=widths)
    # the search re-builds the same trees -> same shapes -> zero re-timing
    assert calls["n"] == len(widths)
    for w in widths:
        assert np.isfinite(strats[w].step_time) and strats[w].step_time > 0
    assert arca.best(strats).width in widths


# --------------------------------------------------------------------------
# runtime strategy switching
# --------------------------------------------------------------------------
def test_adaptive_run_matches_fixed_width_solo():
    """Strategy switches mid-stream never change tokens: every request of
    an adaptive run is bit-identical to its solo run under EITHER fixed
    width (greedy verification commits the greedy chain for any tree)."""
    cfg, model, params, heads, accs = _setup()
    specs = {2: T.build_tree(accs, 2), 8: T.build_tree(accs, 8)}
    max_len = 96 + max(s.max_depth for s in specs.values())
    eng = SpeculativeEngine(model, heads, params, specs[8], max_len=max_len,
                            chunk=4)
    # synthetic measured table rigged so the argmax flips to width 2 as
    # soon as the (random-heads, AL~1) observation lands
    strategies = arca.choose_strategy(
        cfg, accs, ctx=8, widths=(2, 8),
        time_fn=lambda c, w, ctx, s: 1e-3 * w)
    sched = ContinuousScheduler(
        eng, batch=2,
        adaptive=AdaptiveSpeculation(strategies, min_steps=4,
                                     switch_every=1))
    reqs = _requests(cfg, 5, budgets=[16, 9])
    results, stats = sched.serve(reqs)
    assert stats["strategy_switches"], "no switch happened — dead test"
    assert stats["width_final"] == 2
    assert any(ev == "switch" for ev, _, _ in sched.events)
    for w, spec in specs.items():
        solo = SpeculativeEngine(model, heads, params, spec,
                                 max_len=max_len, chunk=4)
        for r, req in zip(results, reqs):
            out, _ = solo.generate({"tokens": req.tokens[None]},
                                   req.n_tokens)
            np.testing.assert_array_equal(
                r.tokens, np.atleast_2d(out)[0][:req.n_tokens],
                err_msg=f"req {r.req_id} vs fixed width {w}")


def test_same_shape_switches_reuse_compiled_chunks():
    cfg, model, params, heads, accs = _setup()
    # two distinct trees with IDENTICAL shapes (width, depths, paths)
    spec_a = T.spec_from_nodes([(-1, 0, 0), (0, 1, 0), (1, 2, 0)])
    spec_b = T.spec_from_nodes([(-1, 0, 0), (0, 1, 1), (1, 2, 0)])
    assert spec_a.shape() == spec_b.shape()
    eng = SpeculativeEngine(model, heads, params, spec_a, max_len=64,
                            chunk=4)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                              cfg.vocab_size)
    out_a, _ = eng.generate({"tokens": toks}, 10)
    sizes = {k: f._cache_size() for k, f in eng._chunks.items()}
    eng.set_strategy(spec_b)                     # same shape bucket
    out_b, _ = eng.generate({"tokens": toks}, 10)
    for k, size in sizes.items():
        assert eng._chunks[k]._cache_size() == size, \
            "re-jitted for a same-shape strategy"
    np.testing.assert_array_equal(out_a, out_b)  # greedy: tree-independent

    # a different shape compiles once; toggling BACK is then free
    wide = T.build_tree(accs, 4)
    eng.set_strategy(wide)
    eng.generate({"tokens": toks}, 10)
    sizes = {k: f._cache_size() for k, f in eng._chunks.items()}
    eng.set_strategy(spec_a)
    eng.generate({"tokens": toks}, 10)
    eng.set_strategy(wide)
    eng.generate({"tokens": toks}, 10)
    for k, size in sizes.items():
        assert eng._chunks[k]._cache_size() == size, \
            "toggling between compiled widths re-jitted"


def test_adaptive_controller_unit():
    """Ratio anchoring: width 1 is pinned at AL=1, the observed/estimated
    ratio rescales the rest, and width-1 chunks feed no signal (so the
    controller can leave width 1 again)."""
    mk = lambda w, al, t: arca.Strategy(width=w, tree=None, ratio=0.5,
                                        acceptance=al, step_time=t,
                                        throughput=al / t)
    ctrl = AdaptiveSpeculation({1: mk(1, 1.0, 1e-3), 4: mk(4, 3.0, 2e-3)},
                               min_steps=4, switch_every=1)
    # estimates alone (ratio=1): width 4 wins 3.0/2e-3 > 1.0/1e-3
    assert ctrl.pick(1) == 4
    # observe AL~1 at width 4 -> ratio ~0 -> every al_hat -> 1 -> fastest
    ctrl.observe(np.asarray([[1, 1, 1, 1, 0]]), width=4)
    assert ctrl.al_hat(1) == pytest.approx(1.0)
    assert ctrl.al_hat(4) == pytest.approx(1.0)
    assert ctrl.pick(4) == 1
    # width-1 chunks are signal-free: ratio untouched
    r = ctrl.ratio
    ctrl.observe(np.asarray([[1, 1, 1, 1]]), width=1)
    assert ctrl.ratio == r
    # sustained strong observations at width 4 pull the EMA back up and
    # restore the wide pick (one sample cannot: the window smooths it)
    for _ in range(4):
        ctrl.observe(np.asarray([[3, 3, 3, 3]]), width=4)
    assert ctrl.pick(1) == 4

    # width 1 is NOT absorbing: with no signal the ratio relaxes toward
    # the calibration prior, so a controller parked at width 1 with
    # ratio 0 eventually re-probes the best drafted width on its own
    ctrl2 = AdaptiveSpeculation({1: mk(1, 1.0, 1e-3), 4: mk(4, 3.0, 2e-3)},
                                min_steps=4, switch_every=1)
    ctrl2.observe(np.asarray([[1, 1, 1, 1]]), width=4)    # ratio -> 0
    assert ctrl2.pick(4) == 1
    probed = None
    for _ in range(200):
        probed = ctrl2.pick(1)
        if probed is not None:
            break
    assert probed == 4
    with pytest.raises(ValueError):
        AdaptiveSpeculation({})
    with pytest.raises(ValueError):
        # draft-free engines cannot adapt
        cfg, model, params, _, _ = _setup()
        ContinuousScheduler(BatchEngine(model, params, max_len=64),
                            adaptive={1: mk(1, 1.0, 1e-3)})


def test_adaptive_perwidth_probe_unit():
    """Scheduled online acceptance probes de-bias the per-width ratios:
    every ``probe_every`` boundaries the controller switches the bank to
    a NON-ACTIVE drafted width for ``probe_boundaries`` boundaries, that
    width's observation lands in ``ratios[w]`` without touching the
    active width's measured ratio, and the post-probe argmax reads each
    width through its OWN ratio instead of extrapolating the active
    one."""
    mk = lambda w, al, t: arca.Strategy(width=w, tree=None, ratio=0.5,
                                        acceptance=al, step_time=t,
                                        throughput=al / t)
    # step times keep width 4 the argmax at full ratios and width 1 the
    # argmax when every ratio collapses to 0
    ctrl = AdaptiveSpeculation(
        {1: mk(1, 1.0, 1e-3), 2: mk(2, 2.0, 1.4e-3), 4: mk(4, 3.0, 2e-3)},
        min_steps=1, switch_every=1, probe_every=3, probe_boundaries=2)
    ctrl.observe(np.asarray([[3, 3, 3, 3]]), width=4)   # w4 self-reports
    assert ctrl.ratios[4] == pytest.approx(1.0)
    assert ctrl.pick(4) is None                  # b1: argmax stays put
    assert ctrl.pick(4) is None                  # b2
    # b3: the scheduled probe fires on the non-active drafted width (2)
    assert ctrl.pick(4) == 2
    assert ctrl.switches[-1] == (3, 4, 2)
    # the probed width's observation is BAD: its own ratio collapses,
    # the previously measured width-4 ratio is untouched
    ctrl.observe(np.asarray([[1, 1, 1, 1]]), width=2)
    assert ctrl.ratios[2] == pytest.approx(0.0)
    assert ctrl.ratios[4] == pytest.approx(1.0)
    assert ctrl.pick(2) is None                  # b4: probe window holds
    # b5: window closes; argmax reads al_hat(4)=3 via ratios[4], NOT the
    # collapsed global ratio — the probe de-biased, it did not poison
    assert ctrl.pick(2) == 4
    assert ctrl.al_hat(4) == pytest.approx(3.0)
    assert ctrl.al_hat(2) == pytest.approx(1.0)
    assert ctrl.switches[-1] == (5, 2, 4)

    # round-robin: with two non-active candidates the next probe targets
    # the OTHER one
    ctrl2 = AdaptiveSpeculation(
        {1: mk(1, 1.0, 1e-3), 2: mk(2, 2.0, 1.4e-3), 4: mk(4, 3.0, 2e-3),
         8: mk(8, 4.0, 2.5e-3)},
        min_steps=1, switch_every=1, probe_every=2, probe_boundaries=1)
    ctrl2.observe(np.asarray([[4, 4, 4, 4]]), width=8)
    first = None
    targets = []
    for _ in range(12):
        w = ctrl2.pick(8)
        if w is not None and ctrl2._probing is not None:
            targets.append(w)
            # probe window is 1 boundary: next pick closes it
            back = ctrl2.pick(w)
            assert back in (None, 8)
        if len(targets) >= 2:
            break
    assert len(set(targets)) == 2            # two different probe widths

    # defaults keep probing OFF (legacy behavior) and bad args raise
    assert AdaptiveSpeculation({4: mk(4, 3.0, 2e-3)}).probe_every == 0
    with pytest.raises(ValueError):
        AdaptiveSpeculation({4: mk(4, 3.0, 2e-3)}, probe_every=-1)
    with pytest.raises(ValueError):
        AdaptiveSpeculation({4: mk(4, 3.0, 2e-3)}, probe_boundaries=0)
