"""Training substrate: loss decreases, Medusa heads learn, checkpoint I/O."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import MarkovDataset
from repro.models.api import get_model
from repro.training import checkpoint
from repro.training.optimizer import adamw_init
from repro.training.train import medusa_step, train_step
from repro.core.speculative.medusa import init_medusa


def test_loss_decreases():
    cfg = get_config("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    data = MarkovDataset(cfg.vocab_size, seed=1)
    step = jax.jit(lambda p, o, b: train_step(cfg, model, p, o, b, lr=3e-3))
    losses = []
    for batch in data.batches(8, 64, 30):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.8, losses[:3] + losses[-3:]


def test_medusa_heads_learn():
    cfg = get_config("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(1))
    hopt = adamw_init(heads)
    data = MarkovDataset(cfg.vocab_size, seed=1)
    step = jax.jit(lambda h, o, b: medusa_step(cfg, model, params, h, o, b,
                                              lr=3e-3))
    losses = []
    # 25 steps lands right at the 0.9 threshold (measured ratio 0.915 —
    # flaky); 60 steps gives a comfortable margin (~0.887)
    for batch in data.batches(8, 64, 60):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        heads, hopt, m = step(heads, hopt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("xlstm-125m").reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ck.npz")
    checkpoint.save(path, params)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    restored = checkpoint.restore(path, zeros)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_aux_loss_nonzero():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16)), jnp.int32)
    _, extras, _ = model.prefill(params, {"tokens": toks}, max_len=16)
    assert float(extras["aux_loss"]) > 0.0
