"""Chunked SSD Mamba2 prefill must be EXACT vs the time-scan recurrence
(EXPERIMENTS §Perf iteration F), incl. state carry and ragged tails."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # container may not ship hypothesis
    from _mini_hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import mamba2 as mb


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("zamba2-7b").reduced()
    p = mb.mamba_init(cfg, jax.random.PRNGKey(0))
    return cfg, dataclasses.replace(cfg, mamba_chunked=False), p


@given(S=st.integers(3, 40), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 50))
@settings(max_examples=12, deadline=None)
def test_chunked_matches_scan(setup, S, chunk, seed):
    cfg, cfg_scan, p = setup
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, S, cfg.d_model),
                          jnp.float32)
    y_scan, st_scan = mb.mamba_prefill(cfg_scan, p, x)
    y_chunk, st_chunk = mb.mamba_prefill(cfg, p, x, chunk=chunk)
    assert float(jnp.max(jnp.abs(y_scan - y_chunk))) < 2e-3
    assert float(jnp.max(jnp.abs(st_scan["ssm"] - st_chunk["ssm"]))) < 2e-3


def test_state_continuation(setup):
    cfg, cfg_scan, p = setup
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 30, cfg.d_model),
                          jnp.float32)
    y_full, _ = mb.mamba_prefill(cfg_scan, p, x)
    y1, st1 = mb.mamba_prefill(cfg, p, x[:, :13], chunk=8)
    y2, _ = mb.mamba_prefill(cfg, p, x[:, 13:], state=st1, chunk=8)
    err = float(jnp.max(jnp.abs(jnp.concatenate([y1, y2], 1) - y_full)))
    assert err < 2e-3
