"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import sparse_tree_ref, tree_attention_ref
from repro.kernels.sparse_tree import sparse_tree_attention
from repro.kernels.tree_attention import tree_attention


def _rand_tree_mask(W, seed=0):
    rng = np.random.default_rng(seed)
    parent = np.full(W, -1)
    for i in range(1, W):
        parent[i] = rng.integers(0, i)
    mask = np.zeros((W, W), bool)
    depth = np.zeros(W, np.int32)
    for i in range(W):
        j = i
        while j >= 0:
            mask[i, j] = True
            j = parent[j]
        d, j = 0, i
        while parent[j] >= 0:
            d, j = d + 1, parent[j]
        depth[i] = d
    return jnp.asarray(mask), jnp.asarray(depth)


def _ring_key_pos(pos, S):
    """Ring-buffer key positions: slots hold [pos-S, pos) when full else
    [0, pos)."""
    base = np.arange(S)
    if pos >= S:
        return pos - S + ((base - (pos % S)) % S)
    return np.where(base < pos, base, -1)


CASES = [
    # B, W, Hq, Hkv, hd, S, pos, window, block_s, dtype
    (1, 1, 4, 4, 64, 32, 17, 0, 16, jnp.float32),        # plain decode
    (2, 8, 4, 2, 64, 40, 33, 0, 16, jnp.float32),        # GQA tree
    (1, 16, 8, 1, 128, 128, 100, 0, 64, jnp.float32),    # MQA, wide tree
    (2, 4, 4, 4, 32, 24, 24, 16, 8, jnp.float32),        # sliding window
    (1, 8, 4, 2, 64, 64, 64, 0, 64, jnp.bfloat16),       # bf16, full ring
    (1, 32, 2, 2, 16, 8, 6, 0, 8, jnp.float32),          # tiny cache, big tree
    (4, 8, 4, 2, 32, 24, 20, 0, 8, jnp.float32),         # B=4 diverged pos
    (3, 4, 4, 4, 32, 16, 14, 8, 8, jnp.float32),         # diverged + window
]


@pytest.mark.parametrize("B,W,Hq,Hkv,hd,S,pos,window,block_s,dtype", CASES)
def test_tree_attention_vs_oracle(B, W, Hq, Hkv, hd, S, pos, window,
                                  block_s, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * W + S), 5)
    q = jax.random.normal(ks[0], (B, W, Hq, hd), dtype)
    ck = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    cv = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    kn = jax.random.normal(ks[3], (B, W, Hkv, hd), dtype)
    vn = jax.random.normal(ks[4], (B, W, Hkv, hd), dtype)
    # per-sequence positions diverge (batched speculative decoding): each
    # sequence sits a little behind the previous one
    pos_b = np.array([max(pos - 2 * b, 1) for b in range(B)], np.int32)
    key_pos = jnp.asarray(np.stack([_ring_key_pos(p, S) for p in pos_b]),
                          jnp.int32)                              # (B, S)
    mask, depth = _rand_tree_mask(W, seed=S)
    q_pos = pos_b[:, None] + np.asarray(depth)[None, :]           # (B, W)
    q_pos = jnp.asarray(q_pos, jnp.int32)
    lo = q_pos - window if window else jnp.full_like(q_pos, -1)

    ref = tree_attention_ref(q, ck, cv, kn, vn, key_pos, q_pos, lo, mask)
    out = tree_attention(q, ck, cv, kn, vn, key_pos, q_pos, lo, mask,
                         block_s=block_s, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


PAGED_INT8_CASES = [
    # B, W, Hq, Hkv, hd, ps, n_pages, maxp
    (1, 1, 4, 4, 32, 8, 6, 2),      # decode-shaped walk
    (2, 8, 4, 2, 64, 16, 10, 3),    # GQA tree, fragmented reservations
    (3, 4, 8, 1, 32, 4, 12, 4),     # MQA, many small pages
]


@pytest.mark.parametrize("B,W,Hq,Hkv,hd,ps,n_pages,maxp", PAGED_INT8_CASES)
def test_paged_int8_kernel_vs_oracle(B, W, Hq, Hkv, hd, ps, n_pages, maxp):
    """Fused-dequant page walk sweep: the int8 Pallas kernel matches the
    int8 oracle to float tolerance (dequant is exact math — scale * int),
    and both sit within the symmetric-quantization bound (scale/2 per
    element) of the fp32 oracle on the same logical view."""
    from repro.kernels.ref import paged_tree_attention_ref
    from repro.kernels.tree_attention import paged_tree_attention
    rng = np.random.default_rng(B * W + n_pages)
    P = n_pages + 1
    pool = rng.normal(size=(2, P, ps, Hkv, hd)).astype(np.float32)
    scale = np.abs(pool).max(axis=(2, 4)) / 127.0            # (2, P, Hkv)
    qpool = np.clip(np.round(pool / np.maximum(
        scale, 1e-30)[:, :, None, :, None]), -127, 127).astype(np.int8)
    q = jnp.asarray(rng.normal(size=(B, W, Hq, hd)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32)
    # every row holds a random fragmented reservation with a partial fill
    table = np.full((B, maxp), -1, np.int32)
    key_pos = np.full((B, maxp * ps), -1, np.int32)
    fills = []
    for b in range(B):
        n_res = int(rng.integers(1, maxp + 1))
        table[b, :n_res] = rng.choice(n_pages, n_res, replace=False)
        fills.append(int(rng.integers(1, n_res * ps + 1)))
        key_pos[b, :fills[-1]] = np.arange(fills[-1])
    table, key_pos = jnp.asarray(table), jnp.asarray(key_pos)
    mask, depth = _rand_tree_mask(W, seed=ps)
    q_pos = jnp.asarray(np.asarray(fills)[:, None]
                        + np.asarray(depth)[None, :], jnp.int32)
    lo = jnp.full_like(q_pos, -1)
    walk = (kn, vn, table, key_pos, q_pos, lo, mask)

    ref8 = paged_tree_attention_ref(q, qpool[0], qpool[1], scale[0],
                                    scale[1], *walk)
    ker8 = paged_tree_attention(q, jnp.asarray(qpool[0]),
                                jnp.asarray(qpool[1]),
                                jnp.asarray(scale[0]), jnp.asarray(scale[1]),
                                *walk, interpret=True)
    np.testing.assert_allclose(np.asarray(ker8), np.asarray(ref8),
                               atol=2e-5, rtol=2e-5)
    ref32 = paged_tree_attention_ref(q, jnp.asarray(pool[0]),
                                     jnp.asarray(pool[1]), None, None, *walk)
    assert float(jnp.max(jnp.abs(ref8 - ref32))) < 3e-2


@pytest.mark.parametrize("W,Hq,Hkv,hd,dtype", [
    (4, 4, 2, 32, jnp.float32),
    (16, 8, 8, 64, jnp.float32),
    (64, 4, 1, 128, jnp.bfloat16),
])
def test_sparse_tree_vs_oracle(W, Hq, Hkv, hd, dtype):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(W), 3)
    q = jax.random.normal(ks[0], (B, W, Hq, hd), dtype)
    kn = jax.random.normal(ks[1], (B, W, Hkv, hd), dtype)
    vn = jax.random.normal(ks[2], (B, W, Hkv, hd), dtype)
    mask, _ = _rand_tree_mask(W, seed=W)
    ref = sparse_tree_ref(q, kn, vn, mask)
    out = sparse_tree_attention(q, kn, vn, mask, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)
