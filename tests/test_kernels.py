"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import sparse_tree_ref, tree_attention_ref
from repro.kernels.sparse_tree import sparse_tree_attention
from repro.kernels.tree_attention import tree_attention


def _rand_tree_mask(W, seed=0):
    rng = np.random.default_rng(seed)
    parent = np.full(W, -1)
    for i in range(1, W):
        parent[i] = rng.integers(0, i)
    mask = np.zeros((W, W), bool)
    depth = np.zeros(W, np.int32)
    for i in range(W):
        j = i
        while j >= 0:
            mask[i, j] = True
            j = parent[j]
        d, j = 0, i
        while parent[j] >= 0:
            d, j = d + 1, parent[j]
        depth[i] = d
    return jnp.asarray(mask), jnp.asarray(depth)


def _ring_key_pos(pos, S):
    """Ring-buffer key positions: slots hold [pos-S, pos) when full else
    [0, pos)."""
    base = np.arange(S)
    if pos >= S:
        return pos - S + ((base - (pos % S)) % S)
    return np.where(base < pos, base, -1)


CASES = [
    # B, W, Hq, Hkv, hd, S, pos, window, block_s, dtype
    (1, 1, 4, 4, 64, 32, 17, 0, 16, jnp.float32),        # plain decode
    (2, 8, 4, 2, 64, 40, 33, 0, 16, jnp.float32),        # GQA tree
    (1, 16, 8, 1, 128, 128, 100, 0, 64, jnp.float32),    # MQA, wide tree
    (2, 4, 4, 4, 32, 24, 24, 16, 8, jnp.float32),        # sliding window
    (1, 8, 4, 2, 64, 64, 64, 0, 64, jnp.bfloat16),       # bf16, full ring
    (1, 32, 2, 2, 16, 8, 6, 0, 8, jnp.float32),          # tiny cache, big tree
    (4, 8, 4, 2, 32, 24, 20, 0, 8, jnp.float32),         # B=4 diverged pos
    (3, 4, 4, 4, 32, 16, 14, 8, 8, jnp.float32),         # diverged + window
]


@pytest.mark.parametrize("B,W,Hq,Hkv,hd,S,pos,window,block_s,dtype", CASES)
def test_tree_attention_vs_oracle(B, W, Hq, Hkv, hd, S, pos, window,
                                  block_s, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * W + S), 5)
    q = jax.random.normal(ks[0], (B, W, Hq, hd), dtype)
    ck = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    cv = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    kn = jax.random.normal(ks[3], (B, W, Hkv, hd), dtype)
    vn = jax.random.normal(ks[4], (B, W, Hkv, hd), dtype)
    # per-sequence positions diverge (batched speculative decoding): each
    # sequence sits a little behind the previous one
    pos_b = np.array([max(pos - 2 * b, 1) for b in range(B)], np.int32)
    key_pos = jnp.asarray(np.stack([_ring_key_pos(p, S) for p in pos_b]),
                          jnp.int32)                              # (B, S)
    mask, depth = _rand_tree_mask(W, seed=S)
    q_pos = pos_b[:, None] + np.asarray(depth)[None, :]           # (B, W)
    q_pos = jnp.asarray(q_pos, jnp.int32)
    lo = q_pos - window if window else jnp.full_like(q_pos, -1)

    ref = tree_attention_ref(q, ck, cv, kn, vn, key_pos, q_pos, lo, mask)
    out = tree_attention(q, ck, cv, kn, vn, key_pos, q_pos, lo, mask,
                         block_s=block_s, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("W,Hq,Hkv,hd,dtype", [
    (4, 4, 2, 32, jnp.float32),
    (16, 8, 8, 64, jnp.float32),
    (64, 4, 1, 128, jnp.bfloat16),
])
def test_sparse_tree_vs_oracle(W, Hq, Hkv, hd, dtype):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(W), 3)
    q = jax.random.normal(ks[0], (B, W, Hq, hd), dtype)
    kn = jax.random.normal(ks[1], (B, W, Hkv, hd), dtype)
    vn = jax.random.normal(ks[2], (B, W, Hkv, hd), dtype)
    mask, _ = _rand_tree_mask(W, seed=W)
    ref = sparse_tree_ref(q, kn, vn, mask)
    out = sparse_tree_attention(q, kn, vn, mask, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)
