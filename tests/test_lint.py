"""reprolint (src/repro/analysis): per-rule trigger + near-miss
fixtures, the suppression and baseline machinery, SchedulableEngine
conformance, and — the gate — a clean run over the real ``src/`` tree.

Each rule gets one minimal fixture that MUST fire and one near-miss
that must NOT: the near-misses pin the rules' precision (a linter that
cries wolf gets suppressed wholesale and enforces nothing).
"""
import textwrap
from pathlib import Path

from repro.analysis.core import (Finding, lint_paths, load_baseline,
                                 write_baseline)
from repro.analysis.lint import main as lint_main

SRC = Path(__file__).resolve().parent.parent / "src"


def _lint(tmp_path, files, rules=None):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return lint_paths([tmp_path], rules=rules)


# ---------------------------------------------------------------------------
# R1 jit purity
# ---------------------------------------------------------------------------

def test_r1_flags_host_clock_reachable_from_jit(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import time
        import jax

        def helper(x):
            t = time.time()
            return x * t

        @jax.jit
        def step(x):
            return helper(x)
        """}, rules=["R1"])
    assert [f.rule for f in found] == ["R1"]
    assert "time.time" in found[0].message and found[0].line == 5


def test_r1_near_miss_unreachable_host_clock(tmp_path):
    # identical helper, but nothing jits it: host clocks are fine there
    found = _lint(tmp_path, {"mod.py": """\
        import time

        def helper(x):
            t = time.time()
            return x * t

        def step(x):
            return helper(x)
        """}, rules=["R1"])
    assert found == []


def test_r1_mutable_default_and_coercion(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import jax

        @jax.jit
        def step(x, acc=[]):
            return x + float(x)
        """}, rules=["R1"])
    msgs = " | ".join(f.message for f in found)
    assert "mutable default" in msgs and "float(x)" in msgs


# ---------------------------------------------------------------------------
# R2 donation discipline
# ---------------------------------------------------------------------------

def test_r2_flags_undonated_state_carry(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import jax

        def update(state, x):
            return state + x

        step = jax.jit(update)
        """}, rules=["R2"])
    assert [f.rule for f in found] == ["R2"]
    assert "donate_argnums" in found[0].message and found[0].line == 6


def test_r2_near_miss_donated_carry(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import jax

        def update(state, x):
            return state + x

        step = jax.jit(update, donate_argnums=(0,))
        """}, rules=["R2"])
    assert found == []


def test_r2_read_after_donate_vs_rebound_carry(tmp_path):
    bad = _lint(tmp_path, {"bad.py": """\
        import jax

        def update(state, x):
            return state + x

        def run(state, xs):
            step = jax.jit(update, donate_argnums=(0,))
            out = step(state, xs)
            return out + state
        """}, rules=["R2"])
    assert any("read after being donated" in f.message for f in bad)
    good = _lint(tmp_path / "g", {"good.py": """\
        import jax

        def update(state, x):
            return state + x

        def run(state, xs):
            step = jax.jit(update, donate_argnums=(0,))
            state = step(state, xs)
            return state
        """}, rules=["R2"])
    assert good == []


# ---------------------------------------------------------------------------
# R3 host-sync discipline
# ---------------------------------------------------------------------------

def test_r3_flags_sync_in_runtime_hot_path(tmp_path):
    found = _lint(tmp_path, {"runtime/hot.py": """\
        import numpy as np

        class E:
            def sched_step(self, x):
                return np.asarray(x)
        """}, rules=["R3"])
    assert [f.rule for f in found] == ["R3"]
    assert "np.asarray" in found[0].message and found[0].line == 5


def test_r3_near_miss_cold_function_and_benchmark(tmp_path):
    # same sync outside a hot function, and a benchmark's
    # block_until_ready (the measurement itself): both clean
    found = _lint(tmp_path, {
        "runtime/cold.py": """\
            import numpy as np

            class E:
                def snapshot(self, x):
                    return np.asarray(x)
            """,
        "benchmarks/bench_decode.py": """\
            import jax
            import time

            def run(f, x):
                t0 = time.time()
                jax.block_until_ready(f(x))
                return time.time() - t0
            """}, rules=["R3"])
    assert found == []


def test_r3_flags_wall_clock_outside_benchmarks(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import time

        def measure(f):
            t0 = time.time()
            f()
            return time.time() - t0
        """}, rules=["R3"])
    assert len(found) == 2
    assert all("perf_counter" in f.message for f in found)


# ---------------------------------------------------------------------------
# R4 lock + thread-ownership discipline
# ---------------------------------------------------------------------------

def test_r4_flags_off_lock_read_of_guarded_attr(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0

            def bump(self):
                with self._lock:
                    self.count += 1

            def peek(self):
                return self.count
        """}, rules=["R4"])
    assert [f.rule for f in found] == ["R4"]
    assert "off-lock" in found[0].message and found[0].line == 13


def test_r4_near_miss_locked_read(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0

            def bump(self):
                with self._lock:
                    self.count += 1

            def peek(self):
                with self._lock:
                    return self.count
        """}, rules=["R4"])
    assert found == []


def test_r4_flags_scheduler_reached_off_worker(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import threading

        class Srv:
            def __init__(self, scheduler):
                self.scheduler = scheduler
                self._thread = None

            def start(self):
                self._thread = threading.Thread(target=self._run)
                self._thread.start()

            def _run(self):
                self.scheduler.boundary()

            def peek(self):
                return self.scheduler.load
        """}, rules=["R4"])
    assert [f.rule for f in found] == ["R4"]
    assert "worker-owned" in found[0].message and found[0].line == 16


# ---------------------------------------------------------------------------
# R5 pytree completeness
# ---------------------------------------------------------------------------

def test_r5_flags_missing_field(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import dataclasses
        from functools import partial
        import jax

        @partial(jax.tree_util.register_dataclass,
                 data_fields=["a"], meta_fields=[])
        @dataclasses.dataclass
        class S:
            a: int
            b: int
        """}, rules=["R5"])
    assert [f.rule for f in found] == ["R5"]
    assert "`b`" in found[0].message


def test_r5_near_miss_complete_registration(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import dataclasses
        from functools import partial
        import jax

        @partial(jax.tree_util.register_dataclass,
                 data_fields=["a"], meta_fields=["b"])
        @dataclasses.dataclass
        class S:
            a: int
            b: int
        """}, rules=["R5"])
    assert found == []


def test_r5_flags_dropped_flatten_field(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import jax

        class P:
            def __init__(self, a, b):
                self.a = a
                self.b = b

        jax.tree_util.register_pytree_node(
            P, lambda p: ((p.a,), None), lambda aux, kids: P(kids[0], 0))
        """}, rules=["R5"])
    assert any("never reads field `b`" in f.message for f in found)


# ---------------------------------------------------------------------------
# R6 slot-protocol conformance
# ---------------------------------------------------------------------------

_R6_SCHED = """\
    def drive(eng):
        eng.sched_step()
        eng.sched_reset()
        if hasattr(eng, "sched_abort"):
            eng.sched_abort(0)
    """


def test_r6_flags_partial_engine(tmp_path):
    found = _lint(tmp_path, {
        "runtime/scheduler.py": _R6_SCHED,
        "runtime/engine.py": """\
            class ToyEngine:
                def sched_step(self):
                    return 0
            """}, rules=["R6"])
    assert [f.rule for f in found] == ["R6"]
    assert "sched_reset" in found[0].message
    # the hasattr-probed slot is an optional extension, never required
    assert "sched_abort" not in found[0].message.split("optional")[0]


def test_r6_near_miss_full_engine_without_optional(tmp_path):
    found = _lint(tmp_path, {
        "runtime/scheduler.py": _R6_SCHED,
        "runtime/engine.py": """\
            class ToyEngine:
                def sched_step(self):
                    return 0

                def sched_reset(self):
                    return 0
            """}, rules=["R6"])
    assert found == []


def test_r6_flags_protocol_lagging_scheduler(tmp_path):
    found = _lint(tmp_path, {
        "runtime/scheduler.py": _R6_SCHED,
        "runtime/engine.py": """\
            from typing import Protocol

            class SchedulableEngine(Protocol):
                def sched_step(self):
                    ...
            """}, rules=["R6"])
    assert any("does not declare" in f.message and "sched_reset"
               in f.message for f in found)


def test_engine_aliases_conform_to_protocol():
    """Both engine aliases satisfy the typed contract at runtime, not
    just under R6's static scrape."""
    from repro.runtime.engine import (BatchEngine, DecodeEngine,
                                      SchedulableEngine, SpeculativeEngine)
    for cls in (DecodeEngine, BatchEngine, SpeculativeEngine):
        assert issubclass(cls, SchedulableEngine), cls.__name__


# ---------------------------------------------------------------------------
# R7 retrace / compile-cache audit
# ---------------------------------------------------------------------------

def test_r7_flags_jit_built_in_hot_path_and_loop(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import jax

        def f(x):
            return x

        class Eng:
            def generate(self, x):
                return jax.jit(f)(x)

        def warm(xs):
            for x in xs:
                y = jax.jit(f)(x)
            return y
        """}, rules=["R7"])
    msgs = " | ".join(f.message for f in found)
    assert len(found) == 2
    assert "hot path" in msgs and "inside a loop" in msgs


def test_r7_near_miss_memoised_and_init_construction(tmp_path):
    # the two sanctioned patterns: build once in __init__, or memoise
    # per static key — neither defeats the compile cache
    found = _lint(tmp_path, {"mod.py": """\
        import jax

        def f(x):
            return x

        class Eng:
            def __init__(self):
                self._step = jax.jit(f)
                self._memo = {}

            def generate(self, x):
                if "f" not in self._memo:
                    self._memo["f"] = jax.jit(f)
                return self._memo["f"](self._step(x))
        """}, rules=["R7"])
    assert found == []


def test_r7_flags_fresh_lambda_static_arg(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import jax

        def apply(x, fn):
            return fn(x)

        step = jax.jit(apply, static_argnums=(1,))

        def run(x):
            return step(x, lambda y: y + 1)
        """}, rules=["R7"])
    assert [f.rule for f in found] == ["R7"]
    assert "lambda" in found[0].message and "static" in found[0].message


def test_r7_near_miss_stable_static_arg(tmp_path):
    # a module-level def is one object for the process lifetime: the
    # identity-hash static key is stable, so the cache hits
    found = _lint(tmp_path, {"mod.py": """\
        import jax

        def apply(x, fn):
            return fn(x)

        def bump(y):
            return y + 1

        step = jax.jit(apply, static_argnums=(1,))

        def run(x):
            return step(x, bump)
        """}, rules=["R7"])
    assert found == []


def test_r7_flags_scalar_vs_array_skew_across_call_sites(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x, eos):
            return x + eos

        def from_scheduler(x):
            return step(x, 7)

        def from_generate(x):
            return step(x, jnp.asarray(7))
        """}, rules=["R7"])
    assert [f.rule for f in found] == ["R7"]
    assert "eos" in found[0].message and "retraces" in found[0].message


def test_r7_near_miss_consistent_avals(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x, eos):
            return x + eos

        def from_scheduler(x):
            return step(x, jnp.asarray(7))

        def from_generate(x):
            return step(x, jnp.asarray(9))
        """}, rules=["R7"])
    assert found == []


# ---------------------------------------------------------------------------
# R8 kernel bounds verifier
# ---------------------------------------------------------------------------

_REAL_TREE = SRC / "repro/kernels/tree_attention.py"


def test_r8_flags_unclamped_index_map(tmp_path):
    """Drop the tail-block clamp from the REAL kernel's KV index maps:
    the verifier must prove the resulting block starts run off the end
    of the operand, for concrete (config, grid point) witnesses."""
    src = _REAL_TREE.read_text()
    assert "jnp.minimum(i, _n - 1)" in src    # the clamp under mutation
    found = _lint(tmp_path, {
        "kernels/tree_attention.py":
            src.replace("jnp.minimum(i, _n - 1)", "i")}, rules=["R8"])
    assert found and all(f.rule == "R8" for f in found)
    assert any("out of bounds" in f.message and "grid point" in f.message
               for f in found)


def test_r8_near_miss_real_kernel_verifies(tmp_path):
    # the committed kernel, verbatim: every index map proves in-bounds,
    # every out_spec tiles exactly once, for the whole config matrix
    found = _lint(tmp_path, {
        "kernels/tree_attention.py": _REAL_TREE.read_text()},
        rules=["R8"])
    assert found == []


# ---------------------------------------------------------------------------
# R9 boundary-protocol conformance
# ---------------------------------------------------------------------------

def test_r9_flags_admit_before_sweep_and_undrained_fail_all(tmp_path):
    found = _lint(tmp_path, {"runtime/scheduler.py": """\
        class ContinuousScheduler:
            def submit(self, req):
                self._pending.append(req)

            def abort(self, req_id):
                self._aborts[req_id] = 1

            def boundary(self):
                req = self.policy.pick(self._pending)
                self._apply_aborts()
                return req

            def fail_all(self):
                self._aborts = {}
        """}, rules=["R9"])
    msgs = " | ".join(f.message for f in found)
    assert "BEFORE the abort sweep" in msgs
    assert "does not drain self._pending" in msgs
    # the model exploration itself is clean: only the two static
    # protocol-order findings fire
    assert len(found) == 2


def test_r9_near_miss_correct_protocol_order(tmp_path):
    found = _lint(tmp_path, {"runtime/scheduler.py": """\
        class ContinuousScheduler:
            def submit(self, req):
                self._pending.append(req)

            def abort(self, req_id):
                self._aborts[req_id] = 1

            def boundary(self):
                self._apply_aborts()
                req = self.policy.pick(self._pending)
                return req

            def fail_all(self):
                self._pending = []
                self._aborts = {}
        """}, rules=["R9"])
    assert found == []


# ---------------------------------------------------------------------------
# suppressions, baseline, CLI
# ---------------------------------------------------------------------------

def test_inline_and_file_suppressions(tmp_path):
    found = _lint(tmp_path, {"mod.py": """\
        import time

        def measure(f):
            t0 = time.time()  # reprolint: disable=R3 (absolute timestamp)
            # reprolint: disable=R3 — line-above form
            t1 = time.time()
            f()
            return t1 - t0
        """}, rules=["R3"])
    assert found == []
    found = _lint(tmp_path / "f", {"mod.py": """\
        # reprolint: disable-file=R3
        import time

        def measure(f):
            f()
            return time.time()
        """}, rules=["R3"])
    assert found == []


def test_suppression_is_rule_specific(tmp_path):
    # a R4 suppression must not silence R3 on the same line
    found = _lint(tmp_path, {"mod.py": """\
        import time

        def measure():
            return time.time()  # reprolint: disable=R4
        """}, rules=["R3"])
    assert [f.rule for f in found] == ["R3"]


def test_baseline_roundtrip_and_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "mod.py"
    bad.write_text("import time\n\n\ndef f():\n    return time.time()\n")
    baseline = tmp_path / "baseline.txt"
    # fresh finding: exit 1, rendered as path:line RULE message
    assert lint_main([str(tmp_path), "--rules", "R3",
                      "--baseline", str(baseline)]) == 1
    out = capsys.readouterr().out
    assert "mod.py:5 R3" in out
    # grandfather it, then the same tree is clean
    assert lint_main([str(tmp_path), "--rules", "R3",
                      "--baseline", str(baseline),
                      "--write-baseline"]) == 0
    assert lint_main([str(tmp_path), "--rules", "R3",
                      "--baseline", str(baseline)]) == 0
    keys = load_baseline(baseline)
    assert len(keys) == 1 and next(iter(keys)).startswith("mod.py::R3::")
    # fixing the finding leaves a stale entry but stays exit 0
    bad.write_text("import time\n\n\ndef f():\n    return 0\n")
    assert lint_main([str(tmp_path), "--rules", "R3",
                      "--baseline", str(baseline)]) == 0


def test_github_format_emits_workflow_annotations(tmp_path, capsys):
    """--format github adds an ::error workflow command per fresh
    finding (on top of the human rendering) so CI annotates the PR."""
    (tmp_path / "mod.py").write_text(
        "import time\n\n\ndef f():\n    return time.time()\n")
    assert lint_main([str(tmp_path), "--rules", "R3",
                      "--baseline", str(tmp_path / "b.txt"),
                      "--format", "github"]) == 1
    out = capsys.readouterr().out
    assert "mod.py:5 R3" in out                      # human line kept
    assert "::error file=mod.py,line=5,title=reprolint R3::R3: " in out


def test_finding_key_is_line_number_free(tmp_path):
    f = Finding(path="a.py", line=7, rule="R1", message="m")
    assert f.key == "a.py::R1::m" and "7" not in f.key
    write_baseline(tmp_path / "b.txt", [f])
    assert load_baseline(tmp_path / "b.txt") == {"a.py::R1::m"}


# ---------------------------------------------------------------------------
# the gate: the real tree is clean
# ---------------------------------------------------------------------------

def test_src_tree_is_clean():
    """Every finding in src/ is fixed or carries a reasoned inline
    suppression; the committed baseline stays empty.  A regression here
    means new code broke one of the nine invariants — fix it or suppress
    it with a reason, don't baseline it."""
    findings = lint_paths([SRC])
    assert findings == [], "\n".join(f.render() for f in findings)
    assert load_baseline(SRC / "repro/analysis/baseline.txt") == set()
