"""HCMP runtime executor split (core/hcmp/executors.py + the
DecodeEngine routing in runtime/engine.py).

Invariants:
  * the overlapped draft/verify schedule is BIT-IDENTICAL to the fused
    inline chunk scan — same emitted tokens, same per-row counts — on
    every engine config (dense/paged x ref/pallas), because greedy tree
    verification commits the greedy chain whatever was drafted;
  * runtime strategy switches under the adaptive scheduler stay
    output-neutral with the overlap engine, and the scheduler surfaces
    the runner's stats (``stats["hcmp"]``) for boundary accounting;
  * the cross-chunk pre-draft is reused over quiet chunk boundaries
    (hits) and DISCARDED whenever the bank epoch moved underneath it —
    a new stream, an admission, an abort sweep (mis-speculated overlap
    is redrafted, never committed);
  * a mid-flight ``abort()`` at a chunk boundary on a paged overlap
    engine leaks no pages and leaves the survivors' outputs untouched;
  * ``arca.profile_engine`` times BOTH partitions on an overlap-capable
    engine and ``choose_strategy`` stamps the measured winner on the
    ``Strategy`` (``time_step(..., hcmp=...)`` always restores the
    engine's mode).

Single-device note: tests run on one host CPU device, where the runner
degrades to a serial schedule over the same three executor jits — the
parity, pre-draft and abort semantics are device-count independent
(the two-device path is exercised by the serve launcher's CI smoke,
``--hcmp overlap``).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import arca
from repro.core.hcmp.executors import executor_pair
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.models.api import get_model
from repro.runtime.engine import BatchEngine, SpeculativeEngine
from repro.runtime.scheduler import (CANCELLED, DONE, AdaptiveSpeculation,
                                     ContinuousScheduler, Request)

_CTX = None


def _setup():
    global _CTX
    if _CTX is None:
        cfg = get_config("qwen2-0.5b").reduced()
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        heads = init_medusa(cfg, jax.random.PRNGKey(7))
        accs = T.default_accs(cfg.medusa_heads, cfg.medusa_top_k)
        _CTX = (cfg, model, params, heads, accs)
    return _CTX


def _requests(cfg, n, budgets, prompt_len=8, seed=3):
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, prompt_len), 0, cfg.vocab_size),
        np.int32)
    return [Request(req_id=i, tokens=toks[i],
                    n_tokens=budgets[i % len(budgets)]) for i in range(n)]


# --------------------------------------------------------------------------
# overlap == inline, bit for bit
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("paged,kv_dtype", [(False, None), (True, None),
                                            (True, "int8")])
def test_overlap_generate_matches_inline(paged, kv_dtype, backend):
    """Disaggregated draft/verify emits the exact token stream of the
    fused chunk scan — dense, paged fp32 and paged int8 (quantize-on-write
    is deterministic, so the quantized pool must not break overlap/inline
    bit parity either), on both attention backends."""
    cfg, model, params, heads, accs = _setup()
    spec = T.build_tree(accs, 4)
    kw = dict(max_len=64, chunk=4, backend=backend)
    if paged:
        kw.update(paged=True, page_size=8, kv_dtype=kv_dtype)
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(5), (2, 8), 0, cfg.vocab_size), np.int32)
    inline = SpeculativeEngine(model, heads, params, spec, **kw)
    overlap = SpeculativeEngine(model, heads, params, spec,
                                hcmp="overlap", **kw)
    out_i, st_i = inline.generate({"tokens": toks}, 12)
    out_o, st_o = overlap.generate({"tokens": toks}, 12)
    np.testing.assert_array_equal(out_i, out_o)
    np.testing.assert_array_equal(st_i["n_emitted"], st_o["n_emitted"])
    hs = overlap.hcmp_stats
    assert hs["mode"] == "overlap"
    assert hs["chunks"] >= 1 and hs["steps"] >= hs["chunks"]
    assert inline.hcmp_stats is None          # runner never built


def test_overlap_adaptive_switches_match_inline():
    """Mid-stream strategy switches on an overlap engine stay
    output-neutral, the scheduler surfaces the runner stats, and the
    admissions/evictions of the stream force pre-draft discards (the
    mis-speculated overlap is dropped, not committed)."""
    cfg, model, params, heads, accs = _setup()
    specs = {2: T.build_tree(accs, 2), 8: T.build_tree(accs, 8)}
    max_len = 96 + max(s.max_depth for s in specs.values())
    eng = SpeculativeEngine(model, heads, params, specs[8], max_len=max_len,
                            chunk=4, paged=True, page_size=8,
                            hcmp="overlap")
    strategies = arca.choose_strategy(
        cfg, accs, ctx=8, widths=(2, 8),
        time_fn=lambda c, w, ctx, s: 1e-3 * w)
    sched = ContinuousScheduler(
        eng, batch=2,
        adaptive=AdaptiveSpeculation(strategies, min_steps=4,
                                     switch_every=1))
    reqs = _requests(cfg, 5, budgets=[16, 9])
    results, stats = sched.serve(reqs)
    assert stats["strategy_switches"], "no switch happened — dead test"
    assert stats["hcmp"]["mode"] == "overlap"
    assert stats["hcmp"]["predraft_discards"] >= 1
    solo = SpeculativeEngine(model, heads, params, specs[8],
                             max_len=max_len, chunk=4)
    for r, req in zip(results, reqs):
        out, _ = solo.generate({"tokens": req.tokens[None]}, req.n_tokens)
        np.testing.assert_array_equal(
            r.tokens, np.atleast_2d(out)[0][:req.n_tokens],
            err_msg=f"req {r.req_id} diverged under overlap+adaptive")


# --------------------------------------------------------------------------
# pre-draft lifecycle
# --------------------------------------------------------------------------
def test_predraft_reuse_and_invalidation():
    """Quiet chunk boundaries inside one stream REUSE the dangling
    pre-draft; a new stream (bank epoch bump) DISCARDS it."""
    cfg, model, params, heads, accs = _setup()
    eng = SpeculativeEngine(model, heads, params, T.build_tree(accs, 4),
                            max_len=96, chunk=2, hcmp="overlap")
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(9), (1, 8), 0, cfg.vocab_size), np.int32)
    eng.generate({"tokens": toks}, 24)           # several 2-step chunks
    hs1 = dict(eng.hcmp_stats)
    assert hs1["predraft_hits"] >= 1
    assert hs1["predraft_discards"] == 0         # nothing moved the bank
    eng.generate({"tokens": toks}, 24)           # fresh stream: stale slot
    hs2 = eng.hcmp_stats
    assert hs2["predraft_discards"] == hs1["predraft_discards"] + 1
    assert hs2["predraft_hits"] > hs1["predraft_hits"]


def test_overlap_abort_midflight_conserves_pages():
    """abort() lands at a chunk boundary while a pre-draft is dangling:
    the sweep releases every page, the stale pre-draft is discarded, and
    the surviving requests' outputs are untouched."""
    cfg, model, params, heads, accs = _setup()
    spec = T.build_tree(accs, 4)
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64,
                            chunk=2, paged=True, page_size=8,
                            hcmp="overlap")
    reqs = _requests(cfg, 3, budgets=[20, 8, 8])
    sched = ContinuousScheduler(eng, batch=2, chunk=2)
    sched.start(reqs)
    i = 0
    while sched.has_work:
        i += 1
        assert i < 200, "abort trace did not converge"
        if i == 2:
            sched.abort(0)                       # mid-decode of req 0
        sched.boundary()
    results, stats = sched.finish(reqs)
    assert results[0].state == CANCELLED
    assert eng.sched_pool_conserved() and eng.sched_drained()
    assert eng._alloc.available == eng._alloc.n_pages
    assert eng.hcmp_stats["predraft_discards"] >= 1
    solo = SpeculativeEngine(model, heads, params, spec, max_len=64,
                             chunk=2)
    for r, req in zip(results[1:], reqs[1:]):
        assert r.state == DONE
        out, _ = solo.generate({"tokens": req.tokens[None]}, req.n_tokens)
        np.testing.assert_array_equal(
            r.tokens, np.atleast_2d(out)[0][:req.n_tokens],
            err_msg=f"survivor {r.req_id} diverged after abort")


# --------------------------------------------------------------------------
# ARCA partition profiling + engine guards
# --------------------------------------------------------------------------
def test_profile_engine_times_both_partitions():
    """An overlap-capable engine is profiled under BOTH partitions; the
    measured winner lands on ``Strategy.hcmp`` via choose_strategy, and
    time_step's hcmp override always restores the engine's mode."""
    cfg, model, params, heads, accs = _setup()
    spec = T.candidate_spec(accs, 2)
    eng = SpeculativeEngine(model, heads, params, T.build_tree(accs, 2),
                            max_len=64, chunk=2, hcmp="overlap")
    tf = arca.profile_engine(eng, (2,), accs=accs, batch=1, prompt_len=8,
                             reps=1)
    assert tf.hcmp_modes == ("inline", "overlap")
    assert eng.hcmp == "overlap"                 # override restored
    key = (spec.width, spec.max_depth, spec.n_paths, 1)
    assert key + ("inline",) in tf.times
    assert key + ("overlap",) in tf.times
    part = tf.partition_for(spec)
    assert part == min(("inline", "overlap"),
                       key=lambda m: tf.times[key + (m,)])
    strategies = arca.choose_strategy(cfg, accs, ctx=8, widths=(2,),
                                      time_fn=tf)
    assert strategies[2].hcmp == part
    # synthetic (unmeasured) time sources keep the inline default
    synth = arca.choose_strategy(cfg, accs, ctx=8, widths=(2,),
                                 time_fn=lambda c, w, ctx, s: 1e-3)
    assert synth[2].hcmp == "inline"


def test_overlap_guards():
    """No draft source -> no overlap; bogus modes rejected; profiling
    the overlap partition on a sequential engine is a typed error."""
    cfg, model, params, heads, accs = _setup()
    seq = BatchEngine(model, params, max_len=32)
    assert not seq.hcmp_capable
    with pytest.raises(ValueError):
        seq.set_hcmp("overlap")
    with pytest.raises(ValueError):
        arca.profile_engine(seq, hcmp_modes=("overlap",))
    eng = SpeculativeEngine(model, heads, params, T.build_tree(accs, 2),
                            max_len=32)
    with pytest.raises(ValueError):
        eng.set_hcmp("fused")
    # single-device fallback: the pair degenerates to one device
    v, d = executor_pair()
    assert v in jax.devices() and d in jax.devices()
