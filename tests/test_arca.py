"""ARCA strategy-search properties + simulator sanity (paper §III-C)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import arca
from repro.core.speculative import tree as T


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("vicuna-7b")
    accs = T.default_accs(4, 10)
    return cfg, accs


def test_strategy_search_structure(setup):
    cfg, accs = setup
    strats = arca.choose_strategy(cfg, accs, ctx=256)
    assert set(strats) == set(arca.WIDTHS)
    best = arca.best(strats)
    # optimum must be interior (not the widest) on edge hardware — the
    # paper's central claim about balancing acceptance vs parallelism
    assert best.width < 64
    assert best.width >= 4
    # acceptance monotone in width, throughput NOT monotone
    als = [strats[w].acceptance for w in arca.WIDTHS]
    assert all(b >= a - 1e-9 for a, b in zip(als, als[1:]))
    thr = [strats[w].throughput for w in arca.WIDTHS]
    assert max(thr) > thr[-1], "wider must eventually hurt"


def test_partition_ratio_balances(setup):
    cfg, accs = setup
    soc = arca.JETSON_NX
    r = arca.contention_aware_ratio(soc, cfg, 16, 256)
    wl = arca.decode_workload(cfg, 16, 256)
    tg = wl.linear_flops * r / (soc.gpu.flops * soc.gpu.gemm_eff)
    tc = wl.linear_flops * (1 - r) / (soc.cpu.flops * soc.cpu.gemm_eff)
    assert abs(tg - tc) / max(tg, tc) < 0.05


def test_system_ordering(setup):
    """Ghidorah >= Medusa+EM >= Medusa-GPU at the paper's width (16)."""
    cfg, accs = setup
    soc = arca.JETSON_NX
    spec = T.build_tree(accs, 16)
    g = arca.step_time_ghidorah(soc, cfg, 16, 256, spec)
    em = arca.step_time_megatron(soc, cfg, 16, 256, spec)
    m = arca.step_time_medusa_gpu(soc, cfg, 16, 256, spec)
    assert g <= em <= m * 1.01


def test_ghidorah_speedup_regime(setup):
    """End-to-end speedup at W=16 lands in the paper's reported regime."""
    cfg, accs = setup
    strats = arca.choose_strategy(cfg, accs, ctx=256)
    seq = arca.step_time_sequential(arca.JETSON_NX, cfg, 256)
    speed16 = strats[16].throughput * seq
    assert speed16 > 3.0, f"W=16 speedup too small: {speed16:.2f}"


def test_roofline_time():
    r = arca.roofline_time(1e12, 1e9, 1e8)
    assert r["bound"] == "compute"
    assert r["step_s"] == pytest.approx(1e12 / 197e12)
    r2 = arca.roofline_time(1e9, 1e12, 1e8)
    assert r2["bound"] == "memory"
