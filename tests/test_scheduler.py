"""Continuous-batching scheduler (runtime/scheduler.py).

Invariants:
  * every request served through the continuous scheduler gets EXACTLY the
    tokens it would get running alone at B=1 (ref + Pallas backends, all
    architecture families) — admission into a busy bank, sharing chunks
    with other residents, and slot reuse never perturb a sequence;
  * eviction frees cache rows (key_pos cleared, pos reset) and freed rows
    are re-used for later admissions (more requests than slots);
  * mid-run admission does not perturb already-resident sequences;
  * the static baseline (``serve_static``) also matches solo runs and
    honours per-request budgets;
  * the per-row cache primitives (reset/insert/tile) do row surgery without
    touching other rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.models.api import get_model
from repro.runtime import cache as C
from repro.runtime.engine import BatchEngine, SpeculativeEngine
from repro.runtime.scheduler import (ContinuousScheduler, Request,
                                     serve_static)


def _setup(arch="qwen2-0.5b"):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(7))
    spec = T.build_tree(T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 8)
    return cfg, model, params, heads, spec


def _requests(cfg, n, budgets, prompt_len=8, seed=3):
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, prompt_len), 0, cfg.vocab_size),
        np.int32)
    return [Request(req_id=i, tokens=toks[i],
                    n_tokens=budgets[i % len(budgets)]) for i in range(n)]


def _assert_matches_solo(engine, results, requests):
    for r, req in zip(results, requests):
        solo, _ = engine.generate({"tokens": req.tokens[None]}, req.n_tokens)
        solo = np.atleast_2d(solo)[0]
        assert r.n_emitted == req.n_tokens, (r.req_id, r.n_emitted)
        np.testing.assert_array_equal(r.tokens, solo[:req.n_tokens],
                                      err_msg=f"req {r.req_id}")


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_continuous_spec_matches_solo_runs(backend):
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64,
                            backend=backend, chunk=4)
    # 5 requests through 2 slots with mixed budgets: admissions land
    # mid-run next to resident sequences, rows get reused
    reqs = _requests(cfg, 5, budgets=[6, 12, 9])
    sched = ContinuousScheduler(eng, batch=2)
    results, stats = sched.serve(reqs)
    assert stats["admitted"] == 5
    assert stats["max_resident"] <= 2
    _assert_matches_solo(eng, results, reqs)
    # slot reuse actually happened (5 requests, 2 rows)
    rows_used = {b for ev, _, b in sched.events if ev == "admit"}
    assert rows_used == {0, 1}


def test_continuous_batch_engine_matches_solo_runs():
    cfg, model, params, _, _ = _setup()
    eng = BatchEngine(model, params, max_len=64, chunk=4)
    reqs = _requests(cfg, 4, budgets=[6, 11])
    results, stats = ContinuousScheduler(eng, batch=2).serve(reqs)
    assert stats["admitted"] == 4
    _assert_matches_solo(eng, results, reqs)


@pytest.mark.parametrize("arch", ["zamba2-7b", "xlstm-125m"])
def test_continuous_all_families(arch):
    cfg, model, params, heads, spec = _setup(arch)
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4)
    reqs = _requests(cfg, 4, budgets=[5, 10])
    results, _ = ContinuousScheduler(eng, batch=2).serve(reqs)
    _assert_matches_solo(eng, results, reqs)


def test_eviction_frees_rows():
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4)
    reqs = _requests(cfg, 3, budgets=[5])
    sched = ContinuousScheduler(eng, batch=2)
    results, _ = sched.serve(reqs)
    assert all(r.n_emitted == 5 for r in results)
    # after the stream drains every slot was evicted: freed rows hold no
    # KV (key_pos cleared to -1, pos back to 0) — done rows never commit,
    # so the reset state survives the trailing chunks
    kv = sched.last_state.cache.kv
    assert np.all(np.asarray(kv.key_pos) == -1)
    assert np.all(np.asarray(kv.pos) == 0)
    evicted = [r for ev, r, _ in sched.events if ev == "evict"]
    assert sorted(evicted) == [0, 1, 2]


@pytest.mark.parametrize("arch", ["zamba2-7b", "xlstm-125m"])
def test_eviction_frees_recurrent_state(arch):
    """Frozen rows commit NOTHING — a reset recurrent row stays zeroed
    through trailing chunks (n_accept=0 must not clamp-select depth-0
    state back into it)."""
    cfg, model, params, heads, spec = _setup(arch)
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4)
    # budgets differ so one row drains chunks after the other was evicted
    reqs = _requests(cfg, 2, budgets=[4, 16])
    sched = ContinuousScheduler(eng, batch=2)
    results, _ = sched.serve(reqs)
    _assert_matches_solo(eng, results, reqs)
    cache = sched.last_state.cache
    if cache.mamba is not None:
        assert np.all(np.asarray(cache.mamba.ssm) == 0)
        assert np.all(np.asarray(cache.mamba.conv) == 0)
    if cache.xlstm is not None:
        for leaf in jax.tree_util.tree_leaves(cache.xlstm.layers):
            assert np.all(np.asarray(leaf) == 0)
    if cache.kv is not None:
        assert np.all(np.asarray(cache.kv.key_pos) == -1)


def test_admission_does_not_perturb_resident_sequences():
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=96, chunk=4)
    # request 0 holds its row for the whole run; 1..3 churn through the
    # second slot while 0 decodes
    reqs = _requests(cfg, 4, budgets=[24, 4, 4, 4])
    sched = ContinuousScheduler(eng, batch=2)
    results, _ = sched.serve(reqs)
    _assert_matches_solo(eng, results, reqs)
    # the churn really happened while request 0 was resident: its eviction
    # comes after every other admission
    order = [(ev, r) for ev, r, _ in sched.events]
    assert order.index(("evict", 0)) > order.index(("admit", 3))


def test_static_baseline_matches_solo_and_budgets():
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4)
    reqs = _requests(cfg, 4, budgets=[5, 12])
    results, stats = serve_static(eng, reqs, batch=2)
    _assert_matches_solo(eng, results, reqs)
    assert stats["emitted_total"] == 5 + 12 + 5 + 12


def test_row_primitives_unit():
    kv = C.init_kv_cache(2, 3, 8, 2, 4)
    cache = C.Cache(kv=kv)
    cache = C.Cache(kv=C.KVCache(
        k=jnp.ones_like(kv.k), v=jnp.ones_like(kv.v),
        key_pos=jnp.zeros_like(kv.key_pos), pos=kv.pos + 5, window=0))
    # reset row 1 only
    out = C.reset_rows(cache, np.asarray([False, True, False]))
    assert np.all(np.asarray(out.kv.key_pos[1]) == -1)
    assert int(out.kv.pos[1]) == 0
    assert np.all(np.asarray(out.kv.k[:, 1]) == 0)
    # other rows untouched
    assert np.all(np.asarray(out.kv.k[:, 0]) == 1)
    assert int(out.kv.pos[0]) == 5
    # insert a B=1 cache into row 2
    src = C.Cache(kv=C.KVCache(
        k=jnp.full((2, 1, 8, 2, 4), 7.0, kv.k.dtype),
        v=jnp.full((2, 1, 8, 2, 4), 7.0, kv.v.dtype),
        key_pos=jnp.full((1, 8), 3, jnp.int32),
        pos=jnp.full((1,), 9, jnp.int32), window=0))
    out2 = C.insert_rows(out, 2, src)
    assert np.all(np.asarray(out2.kv.k[:, 2]) == 7)
    assert int(out2.kv.pos[2]) == 9
    assert np.all(np.asarray(out2.kv.key_pos[2]) == 3)
    assert np.all(np.asarray(out2.kv.k[:, 0]) == 1)      # row 0 untouched
    # tile a B=1 cache to 4 rows
    tiled = C.tile_rows(src, 4)
    assert tiled.kv.k.shape[1] == 4
    assert np.all(np.asarray(tiled.kv.pos) == 9)


def test_capacity_left():
    kv = C.init_kv_cache(1, 2, 16, 2, 4)
    cache = C.Cache(kv=C.KVCache(k=kv.k, v=kv.v, key_pos=kv.key_pos,
                                 pos=jnp.asarray([4, 16], jnp.int32),
                                 window=0))
    np.testing.assert_array_equal(np.asarray(C.capacity_left(cache)),
                                  [12, 0])
    # sliding-window rings wrap by design: unbounded
    wkv = C.init_kv_cache(1, 2, 16, 2, 4, window=16)
    left = C.capacity_left(C.Cache(kv=wkv))
    assert np.all(np.asarray(left) > 1 << 20)
