"""Continuous-batching scheduler (runtime/scheduler.py).

Invariants:
  * every request served through the continuous scheduler gets EXACTLY the
    tokens it would get running alone at B=1 (ref + Pallas backends, all
    architecture families) — admission into a busy bank, sharing chunks
    with other residents, and slot reuse never perturb a sequence;
  * eviction frees cache rows (key_pos cleared, pos reset) and freed rows
    are re-used for later admissions (more requests than slots);
  * mid-run admission does not perturb already-resident sequences;
  * the static baseline (``serve_static``) also matches solo runs and
    honours per-request budgets;
  * the per-row cache primitives (reset/insert/tile, and write_row_at /
    slice_row for chunked prefill) do row surgery without touching other
    rows;
  * non-FIFO admission policies (sjf/lpt) reorder ADMISSION only — outputs
    still match solo runs — and SJF admits fundable small requests past a
    pool-deferred head-of-line request;
  * chunked prefill admits a long prompt piecewise (extend events between
    chunk boundaries), never stalls resident sequences, and the finished
    row is indistinguishable from a whole-prompt admission.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.models.api import get_model
from repro.runtime import cache as C
from repro.runtime.engine import BatchEngine, SpeculativeEngine
from repro.runtime.scheduler import (ContinuousScheduler, Request,
                                     serve_static)


def _setup(arch="qwen2-0.5b"):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(7))
    spec = T.build_tree(T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 8)
    return cfg, model, params, heads, spec


def _requests(cfg, n, budgets, prompt_len=8, seed=3):
    toks = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, prompt_len), 0, cfg.vocab_size),
        np.int32)
    return [Request(req_id=i, tokens=toks[i],
                    n_tokens=budgets[i % len(budgets)]) for i in range(n)]


def _assert_matches_solo(engine, results, requests):
    for r, req in zip(results, requests):
        solo, _ = engine.generate({"tokens": req.tokens[None]}, req.n_tokens)
        solo = np.atleast_2d(solo)[0]
        assert r.n_emitted == req.n_tokens, (r.req_id, r.n_emitted)
        np.testing.assert_array_equal(r.tokens, solo[:req.n_tokens],
                                      err_msg=f"req {r.req_id}")


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_continuous_spec_matches_solo_runs(backend):
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64,
                            backend=backend, chunk=4)
    # 5 requests through 2 slots with mixed budgets: admissions land
    # mid-run next to resident sequences, rows get reused
    reqs = _requests(cfg, 5, budgets=[6, 12, 9])
    sched = ContinuousScheduler(eng, batch=2)
    results, stats = sched.serve(reqs)
    assert stats["admitted"] == 5
    assert stats["max_resident"] <= 2
    _assert_matches_solo(eng, results, reqs)
    # slot reuse actually happened (5 requests, 2 rows)
    rows_used = {b for ev, _, b in sched.events if ev == "admit"}
    assert rows_used == {0, 1}


def test_continuous_batch_engine_matches_solo_runs():
    cfg, model, params, _, _ = _setup()
    eng = BatchEngine(model, params, max_len=64, chunk=4)
    reqs = _requests(cfg, 4, budgets=[6, 11])
    results, stats = ContinuousScheduler(eng, batch=2).serve(reqs)
    assert stats["admitted"] == 4
    _assert_matches_solo(eng, results, reqs)


@pytest.mark.parametrize("arch", ["zamba2-7b", "xlstm-125m"])
def test_continuous_all_families(arch):
    cfg, model, params, heads, spec = _setup(arch)
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4)
    reqs = _requests(cfg, 4, budgets=[5, 10])
    results, _ = ContinuousScheduler(eng, batch=2).serve(reqs)
    _assert_matches_solo(eng, results, reqs)


def test_eviction_frees_rows():
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4)
    reqs = _requests(cfg, 3, budgets=[5])
    sched = ContinuousScheduler(eng, batch=2)
    results, _ = sched.serve(reqs)
    assert all(r.n_emitted == 5 for r in results)
    # after the stream drains every slot was evicted: freed rows hold no
    # KV (key_pos cleared to -1, pos back to 0) — done rows never commit,
    # so the reset state survives the trailing chunks
    kv = sched.last_state.cache.kv
    assert np.all(np.asarray(kv.key_pos) == -1)
    assert np.all(np.asarray(kv.pos) == 0)
    evicted = [r for ev, r, _ in sched.events if ev == "evict"]
    assert sorted(evicted) == [0, 1, 2]


@pytest.mark.parametrize("arch", ["zamba2-7b", "xlstm-125m"])
def test_eviction_frees_recurrent_state(arch):
    """Frozen rows commit NOTHING — a reset recurrent row stays zeroed
    through trailing chunks (n_accept=0 must not clamp-select depth-0
    state back into it)."""
    cfg, model, params, heads, spec = _setup(arch)
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4)
    # budgets differ so one row drains chunks after the other was evicted
    reqs = _requests(cfg, 2, budgets=[4, 16])
    sched = ContinuousScheduler(eng, batch=2)
    results, _ = sched.serve(reqs)
    _assert_matches_solo(eng, results, reqs)
    cache = sched.last_state.cache
    if cache.mamba is not None:
        assert np.all(np.asarray(cache.mamba.ssm) == 0)
        assert np.all(np.asarray(cache.mamba.conv) == 0)
    if cache.xlstm is not None:
        for leaf in jax.tree_util.tree_leaves(cache.xlstm.layers):
            assert np.all(np.asarray(leaf) == 0)
    if cache.kv is not None:
        assert np.all(np.asarray(cache.kv.key_pos) == -1)


def test_admission_does_not_perturb_resident_sequences():
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=96, chunk=4)
    # request 0 holds its row for the whole run; 1..3 churn through the
    # second slot while 0 decodes
    reqs = _requests(cfg, 4, budgets=[24, 4, 4, 4])
    sched = ContinuousScheduler(eng, batch=2)
    results, _ = sched.serve(reqs)
    _assert_matches_solo(eng, results, reqs)
    # the churn really happened while request 0 was resident: its eviction
    # comes after every other admission
    order = [(ev, r) for ev, r, _ in sched.events]
    assert order.index(("evict", 0)) > order.index(("admit", 3))


def test_static_baseline_matches_solo_and_budgets():
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4)
    reqs = _requests(cfg, 4, budgets=[5, 12])
    results, stats = serve_static(eng, reqs, batch=2)
    _assert_matches_solo(eng, results, reqs)
    assert stats["emitted_total"] == 5 + 12 + 5 + 12


def test_row_primitives_unit():
    kv = C.init_kv_cache(2, 3, 8, 2, 4)
    cache = C.Cache(kv=kv)
    cache = C.Cache(kv=C.KVCache(
        k=jnp.ones_like(kv.k), v=jnp.ones_like(kv.v),
        key_pos=jnp.zeros_like(kv.key_pos), pos=kv.pos + 5, window=0))
    # reset row 1 only
    out = C.reset_rows(cache, np.asarray([False, True, False]))
    assert np.all(np.asarray(out.kv.key_pos[1]) == -1)
    assert int(out.kv.pos[1]) == 0
    assert np.all(np.asarray(out.kv.k[:, 1]) == 0)
    # other rows untouched
    assert np.all(np.asarray(out.kv.k[:, 0]) == 1)
    assert int(out.kv.pos[0]) == 5
    # insert a B=1 cache into row 2
    src = C.Cache(kv=C.KVCache(
        k=jnp.full((2, 1, 8, 2, 4), 7.0, kv.k.dtype),
        v=jnp.full((2, 1, 8, 2, 4), 7.0, kv.v.dtype),
        key_pos=jnp.full((1, 8), 3, jnp.int32),
        pos=jnp.full((1,), 9, jnp.int32), window=0))
    out2 = C.insert_rows(out, 2, src)
    assert np.all(np.asarray(out2.kv.k[:, 2]) == 7)
    assert int(out2.kv.pos[2]) == 9
    assert np.all(np.asarray(out2.kv.key_pos[2]) == 3)
    assert np.all(np.asarray(out2.kv.k[:, 0]) == 1)      # row 0 untouched
    # tile a B=1 cache to 4 rows
    tiled = C.tile_rows(src, 4)
    assert tiled.kv.k.shape[1] == 4
    assert np.all(np.asarray(tiled.kv.pos) == 9)


def _mixed_pool_setup():
    """Paged spec engine + a trace built to expose head-of-line blocking:
    one page-hungry request (req 0) ahead of four small ones, all arriving
    at t=0, on a pool that cannot hold the big one next to more than one
    small one."""
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=160,
                            chunk=4, paged=True, page_size=8, pool_pages=20)
    long_req = _requests(cfg, 1, budgets=[96], prompt_len=24)[0]   # 16 pages
    shorts = _requests(cfg, 4, budgets=[6], prompt_len=8, seed=5)  # 3 pages
    for i, r in enumerate(shorts):
        r.req_id = i + 1
    return eng, [long_req] + shorts


def test_sjf_admits_small_past_deferred_big():
    """SJF orders admission by reserved footprint and keeps admitting
    fundable small requests while a big one cannot be funded; FIFO lets the
    big head-of-line request block the line.  Outputs stay solo-identical
    under both."""
    eng, reqs = _mixed_pool_setup()
    fifo = ContinuousScheduler(eng, batch=4, policy="fifo")
    f_res, f_stats = fifo.serve(reqs)
    f_admits = [r for ev, r, _ in fifo.events if ev == "admit"]
    assert f_admits[0] == 0                       # arrival order: big first

    sjf = ContinuousScheduler(eng, batch=4, policy="sjf")
    s_res, s_stats = sjf.serve(reqs)
    s_admits = [r for ev, r, _ in sjf.events if ev == "admit"]
    # smallest footprints first; the big request lands only once the pool
    # can fund it again (here: last)
    assert s_admits == [1, 2, 3, 4, 0]
    # the shorts pack the bank while the big one is deferred: strictly more
    # residency than FIFO, which holds rows empty behind the blocked head
    assert s_stats["max_resident"] > f_stats["max_resident"]
    assert s_stats["policy"] == "sjf" and f_stats["policy"] == "fifo"
    _assert_matches_solo(eng, f_res, reqs)
    _assert_matches_solo(eng, s_res, reqs)


def test_lpt_admits_big_first():
    eng, reqs = _mixed_pool_setup()
    lpt = ContinuousScheduler(eng, batch=4, policy="lpt")
    res, stats = lpt.serve(reqs)
    admits = [r for ev, r, _ in lpt.events if ev == "admit"]
    assert admits[0] == 0                         # largest footprint first
    assert stats["policy"] == "lpt"
    _assert_matches_solo(eng, res, reqs)


def test_sjf_aging_bounds_starvation():
    """The PR 4 caveat, closed: plain SJF starves the convoy's long
    request until every short has drained; with ``age_limit=N`` the long
    request is promoted to FIFO-head priority after N deferred boundaries
    — admitted mid-stream, and its latency (the trace's latency_max_s)
    drops accordingly."""
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=160,
                            chunk=4, paged=True, page_size=8, pool_pages=20)

    def convoy():
        # 12 shorts = three admission waves through the 4-row bank: the
        # long request is passed over at waves 1 and 2 (age 2) and the
        # promotion fires at wave 3, while shorts still queue behind it
        long_req = _requests(cfg, 1, budgets=[96], prompt_len=24)[0]
        shorts = _requests(cfg, 12, budgets=[6], prompt_len=8, seed=5)
        for i, r in enumerate(shorts):
            r.req_id = i + 1
        return [long_req] + shorts

    plain = ContinuousScheduler(eng, batch=4, policy="sjf")
    _, s_plain = plain.serve(convoy())
    plain_admits = [r for ev, r, _ in plain.events if ev == "admit"]
    assert plain_admits[-1] == 0          # starved to the very end

    aged = ContinuousScheduler(eng, batch=4, policy="sjf", age_limit=2)
    res, s_aged = aged.serve(convoy())
    aged_admits = [r for ev, r, _ in aged.events if ev == "admit"]
    # promoted: the long request lands strictly before the queue drains,
    # and while it is unfundable nothing skips past it (FIFO-head block)
    assert aged_admits.index(0) < len(aged_admits) - 1
    assert aged_admits.index(0) < plain_admits.index(0)
    assert s_aged["age_limit"] == 2 and s_plain["age_limit"] == 0
    # the long request's latency (== latency_max_s on this trace) is
    # bounded well below the starved run's
    assert s_aged["latency_max_s"] < s_plain["latency_max_s"]
    # outputs stay solo-identical under aging, like any admission reorder
    _assert_matches_solo(eng, res, convoy())


def test_unknown_policy_rejected():
    cfg, model, params, _, _ = _setup()
    eng = BatchEngine(model, params, max_len=64, chunk=4)
    with pytest.raises(ValueError):
        ContinuousScheduler(eng, policy="srpt")
    with pytest.raises(ValueError):
        ContinuousScheduler(eng, prefill_chunk=-1)
    with pytest.raises(ValueError):
        ContinuousScheduler(eng, policy="sjf", age_limit=-1)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("paged", [False, True])
def test_chunked_prefill_matches_solo(backend, paged):
    """A long prompt admitted in prefill_chunk-sized pieces emits exactly
    the solo-run tokens, dense and paged, ref and Pallas decode."""
    cfg, model, params, heads, spec = _setup()
    kw = dict(paged=True, page_size=8, pool_pages=24) if paged else {}
    eng = SpeculativeEngine(model, heads, params, spec, max_len=96,
                            backend=backend, chunk=4, **kw)
    reqs = _requests(cfg, 3, budgets=[8, 5], prompt_len=21)
    sched = ContinuousScheduler(eng, batch=2, prefill_chunk=6)
    results, stats = sched.serve(reqs)
    assert stats["prefill_chunk"] == 6
    # 21 tokens = 6 admitted + 3 extend pieces (6, 6, 3) per request
    per_req = {}
    for ev, r, _ in sched.events:
        per_req.setdefault(r, []).append(ev)
    for r in range(3):
        assert per_req[r].count("extend") == 3
        assert "prefill_done" in per_req[r]
    _assert_matches_solo(eng, results, reqs)


def test_chunked_prefill_batch_engine_matches_solo():
    cfg, model, params, _, _ = _setup()
    eng = BatchEngine(model, params, max_len=96, chunk=4)
    reqs = _requests(cfg, 3, budgets=[7, 4], prompt_len=17)
    results, _ = ContinuousScheduler(eng, batch=2,
                                     prefill_chunk=5).serve(reqs)
    _assert_matches_solo(eng, results, reqs)


def test_chunked_prefill_does_not_stall_residents():
    """While a long prompt lands piecewise, resident sequences keep
    decoding: a short resident finishes (and is evicted) strictly between
    the long request's admission and its prefill completion."""
    cfg, model, params, heads, spec = _setup()
    eng = SpeculativeEngine(model, heads, params, spec, max_len=160, chunk=4)
    short = _requests(cfg, 1, budgets=[6], prompt_len=8)[0]
    long_req = _requests(cfg, 1, budgets=[8], prompt_len=65, seed=9)[0]
    long_req.req_id = 1
    sched = ContinuousScheduler(eng, batch=2, prefill_chunk=8)
    results, _ = sched.serve([short, long_req])
    order = [(ev, r) for ev, r, _ in sched.events]
    assert order.index(("evict", 0)) < order.index(("prefill_done", 1))
    assert order.index(("admit", 1)) < order.index(("evict", 0))
    _assert_matches_solo(eng, results, [short, long_req])


def test_chunked_prefill_gated_off_for_recurrent_families():
    """Hybrid/xLSTM prefill state sequentially: the scheduler silently
    falls back to whole-prompt admission (no extend events, same outputs)."""
    cfg, model, params, heads, spec = _setup("xlstm-125m")
    eng = SpeculativeEngine(model, heads, params, spec, max_len=64, chunk=4)
    assert not eng.sched_chunked_ok
    reqs = _requests(cfg, 2, budgets=[5], prompt_len=12)
    sched = ContinuousScheduler(eng, batch=2, prefill_chunk=4)
    assert sched.prefill_chunk == 0               # gate at construction
    results, _ = sched.serve(reqs)
    assert not any(ev == "extend" for ev, _, _ in sched.events)
    _assert_matches_solo(eng, results, reqs)


def test_write_row_at_and_slice_row_unit():
    kv = C.init_kv_cache(2, 3, 8, 2, 4)
    cache = C.Cache(kv=C.KVCache(
        k=jnp.ones_like(kv.k), v=jnp.ones_like(kv.v),
        key_pos=jnp.full_like(kv.key_pos, -1),
        pos=jnp.asarray([0, 2, 0], jnp.int32), window=0))
    ks = jnp.full((2, 4, 2, 4), 5.0, kv.k.dtype)
    vs = jnp.full((2, 4, 2, 4), 6.0, kv.v.dtype)
    # write 3 valid entries (1 padding) into row 1 at offset 2
    out = C.write_row_at(cache, 1, ks, vs, 2, 3)
    assert np.all(np.asarray(out.kv.k[:, 1, 2:5]) == 5)
    assert np.all(np.asarray(out.kv.v[:, 1, 2:5]) == 6)
    assert np.all(np.asarray(out.kv.k[:, 1, 5:]) == 1)   # padding dropped
    np.testing.assert_array_equal(np.asarray(out.kv.key_pos[1]),
                                  [-1, -1, 2, 3, 4, -1, -1, -1])
    assert int(out.kv.pos[1]) == 5
    # other rows untouched
    assert np.all(np.asarray(out.kv.k[:, 0]) == 1)
    assert np.all(np.asarray(out.kv.key_pos[0]) == -1)
    assert int(out.kv.pos[0]) == 0
    # slice_row returns the B=1 view of the written row
    view = C.slice_row(out, 1)
    assert view.kv.k.shape[1] == 1
    assert int(view.kv.pos[0]) == 5
    np.testing.assert_array_equal(np.asarray(view.kv.key_pos[0]),
                                  np.asarray(out.kv.key_pos[1]))
    # recurrent state is out of contract
    bad = C.Cache(kv=out.kv, mamba=C.MambaState(
        ssm=jnp.zeros((1, 3, 1, 1, 1)), conv=jnp.zeros((1, 3, 1, 1)),
        pos=jnp.zeros((3,), jnp.int32)))
    with pytest.raises(ValueError):
        C.slice_row(bad, 0)
    with pytest.raises(ValueError):
        C.write_row_at(bad, 1, ks, vs, 2, 3)


def test_write_row_at_paged_unit():
    kv = C.init_paged_kv_cache(2, 2, 32, 2, 4, page_size=8, n_pages=6)
    cache = C.Cache(kv=kv)
    # row 0 owns pages [3, 1]; row 1 unreserved
    table = kv.block_table.at[0, 0].set(3).at[0, 1].set(1)
    cache = C.Cache(kv=C.PagedKVCache(
        pool_k=kv.pool_k, pool_v=kv.pool_v, block_table=table,
        key_pos=kv.key_pos, pos=kv.pos, page_size=8))
    ks = jnp.full((2, 4, 2, 4), 9.0, kv.pool_k.dtype)
    vs = jnp.full((2, 4, 2, 4), 4.0, kv.pool_v.dtype)
    # logical slots 6..9 straddle the page boundary: 6,7 -> page 3,
    # 8,9 -> page 1
    out = C.write_row_at(cache, 0, ks, vs, 6, 4)
    assert np.all(np.asarray(out.kv.pool_k[:, 3, 6:8]) == 9)
    assert np.all(np.asarray(out.kv.pool_k[:, 1, 0:2]) == 9)
    assert np.all(np.asarray(out.kv.pool_v[:, 1, 0:2]) == 4)
    assert int(out.kv.pos[0]) == 10
    np.testing.assert_array_equal(np.asarray(out.kv.key_pos[0, 6:10]),
                                  [6, 7, 8, 9])
    # write past the reservation (row 1, no pages): trash page only
    out2 = C.write_row_at(cache, 1, ks, vs, 0, 4)
    assert np.all(np.asarray(out2.kv.key_pos[1]) == -1)
    assert np.all(np.asarray(out2.kv.pool_k[:, :6]) ==
                  np.asarray(cache.kv.pool_k[:, :6]))


def test_capacity_left():
    kv = C.init_kv_cache(1, 2, 16, 2, 4)
    cache = C.Cache(kv=C.KVCache(k=kv.k, v=kv.v, key_pos=kv.key_pos,
                                 pos=jnp.asarray([4, 16], jnp.int32),
                                 window=0))
    np.testing.assert_array_equal(np.asarray(C.capacity_left(cache)),
                                  [12, 0])
    # sliding-window rings wrap by design: unbounded
    wkv = C.init_kv_cache(1, 2, 16, 2, 4, window=16)
    left = C.capacity_left(C.Cache(kv=wkv))
    assert np.all(np.asarray(left) > 1 << 20)
