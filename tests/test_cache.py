"""Ring-buffer KV cache properties (hypothesis)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.runtime.cache import decode_mask, kv_write, prefill_mask


@given(size=st.integers(2, 16), n_writes=st.integers(1, 40),
       window=st.sampled_from([0, 4, 8]))
@settings(max_examples=40, deadline=None)
def test_ring_buffer_semantics(size, n_writes, window):
    B, H, hd = 1, 1, 4
    ck = jnp.zeros((B, size, H, hd))
    cv = jnp.zeros((B, size, H, hd))
    kp = jnp.full((size,), -1, jnp.int32)
    for pos in range(n_writes):
        k = jnp.full((B, 1, H, hd), float(pos))
        ck, cv, kp = kv_write(ck, cv, kp, k, k, jnp.asarray(pos, jnp.int32))
    kp_np = np.asarray(kp)
    # slot s holds the latest absolute position congruent to s
    for s in range(size):
        expect = max((p for p in range(n_writes) if p % size == s),
                     default=-1)
        assert kp_np[s] == expect
        if expect >= 0:
            assert float(np.asarray(ck)[0, s, 0, 0]) == float(expect)
    # decode mask at q_pos = n_writes: only valid, causal, in-window slots
    ok = np.asarray(decode_mask(kp, jnp.asarray(n_writes), window))
    for s in range(size):
        valid = kp_np[s] >= 0 and kp_np[s] <= n_writes
        if window:
            valid = valid and kp_np[s] > n_writes - window
        assert ok[s] == valid


@given(S=st.integers(1, 24), window=st.sampled_from([0, 3, 7]))
@settings(max_examples=30, deadline=None)
def test_prefill_mask(S, window):
    m = np.asarray(prefill_mask(S, window))
    for q in range(S):
        for k in range(S):
            expect = k <= q and (window == 0 or k > q - window)
            assert m[q, k] == expect
