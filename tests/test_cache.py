"""Ring-buffer KV cache properties (hypothesis)."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # container may not ship hypothesis
    from _mini_hypothesis import given, settings, strategies as st

from repro.runtime.cache import (batched_decode_mask, decode_mask, kv_write,
                                 prefill_mask)


@given(size=st.integers(2, 16), n_writes=st.integers(1, 40),
       window=st.sampled_from([0, 4, 8]))
@settings(max_examples=40, deadline=None)
def test_ring_buffer_semantics(size, n_writes, window):
    # B=2 with DIVERGED per-sequence positions (as after a batched
    # speculative commit): sequence b's write stream starts at offset[b]
    B, H, hd = 2, 1, 4
    offset = [0, 3]
    ck = jnp.zeros((B, size, H, hd))
    cv = jnp.zeros((B, size, H, hd))
    kp = jnp.full((B, size), -1, jnp.int32)
    for i in range(n_writes):
        vals = np.array([offset[b] + i for b in range(B)], np.float32)
        k = jnp.asarray(vals[:, None, None, None]
                        * np.ones((B, 1, H, hd), np.float32))
        ck, cv, kp = kv_write(ck, cv, kp, k, k,
                              jnp.asarray(vals, jnp.int32))
    kp_np = np.asarray(kp)
    # per sequence: slot s holds the latest written position congruent to s
    for b in range(B):
        positions = range(offset[b], offset[b] + n_writes)
        for s in range(size):
            expect = max((p for p in positions if p % size == s), default=-1)
            assert kp_np[b, s] == expect, (b, s)
            if expect >= 0:
                assert float(np.asarray(ck)[b, s, 0, 0]) == float(expect)
    # per-sequence decode masks at each sequence's own q_pos
    q = [offset[b] + n_writes for b in range(B)]
    ok = np.asarray(batched_decode_mask(
        kp, jnp.asarray([[qb] for qb in q], jnp.int32), window))  # (B, 1, S)
    for b in range(B):
        ref = np.asarray(decode_mask(kp[b], jnp.asarray(q[b]), window))
        np.testing.assert_array_equal(ok[b, 0], ref)
        for s in range(size):
            valid = kp_np[b, s] >= 0 and kp_np[b, s] <= q[b]
            if window:
                valid = valid and kp_np[b, s] > q[b] - window
            assert ok[b, 0, s] == valid


@given(S=st.integers(1, 24), window=st.sampled_from([0, 3, 7]))
@settings(max_examples=30, deadline=None)
def test_prefill_mask(S, window):
    m = np.asarray(prefill_mask(S, window))
    for q in range(S):
        for k in range(S):
            expect = k <= q and (window == 0 or k > q - window)
            assert m[q, k] == expect
