"""Deterministic fallback for the subset of `hypothesis` this suite uses.

The container may not ship hypothesis; property tests then fall back to this
shim, which draws a fixed number of seeded pseudo-random examples per test
(deterministic across runs) instead of erroring at collection.  API surface:
``given``, ``settings``, and ``strategies.{integers,floats,sampled_from,
tuples}`` with ``.map``.  Shrinking/reporting are intentionally absent — on
failure the raw example values appear in the assertion traceback.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def sample(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))


class strategies:                                   # noqa: N801 (mimic module)
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(len(items)))])

    @staticmethod
    def tuples(*strats):
        return _Strategy(lambda rng: tuple(s.sample(rng) for s in strats))


def settings(max_examples=20, deadline=None, **_ignored):
    def deco(fn):
        fn._mini_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        n_default = getattr(fn, "_mini_max_examples", 20)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n_default):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # hide the drawn params from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        return wrapper
    return deco
