"""Capacity and token-budget regressions in the chunked engines.

Pre-fix behaviour this guards against (PR 2):
  * a full (window=0) KV cache wrapped writes via ``abs_pos % size`` once
    ``pos`` passed ``max_len``, silently overwriting the oldest KV and
    corrupting attention — the engine kept emitting *diverged* tokens;
  * chunk drivers launched full K-step chunks past every sequence's token
    budget and kept decoding sequences that had hit ``n_tokens``.

Post-fix: capacity folds into the scan done-mask — a near-capacity
sequence FREEZES (stops emitting; the speculative engine also stops
committing) and its emitted prefix is identical to a run with a larger
cache; ``stats["n_emitted"]`` reports the shortfall.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.models.api import get_model
from repro.runtime.engine import BatchEngine, SpeculativeEngine


def _setup(arch="qwen2-0.5b"):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(7))
    spec = T.build_tree(T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 8)
    return cfg, model, params, heads, spec


def test_spec_engine_near_capacity_freezes_instead_of_wrapping():
    cfg, model, params, heads, spec = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                              cfg.vocab_size)
    big = SpeculativeEngine(model, heads, params, spec, max_len=256, chunk=4)
    out_big, _ = big.generate({"tokens": toks}, 40)
    small = SpeculativeEngine(model, heads, params, spec, max_len=24,
                              chunk=4)
    out_small, st = small.generate({"tokens": toks}, 40)
    n = int(st["n_emitted"][0])
    # froze before the budget, after a meaningful prefix
    assert 4 <= n < 40, n
    # the emitted prefix is EXACTLY what the larger cache produces — the
    # ring never wrapped into the attended history
    np.testing.assert_array_equal(out_small[:n], out_big[:n])
    # everything past the freeze is padding, not corrupted decode output
    assert np.all(out_small[n:] == -1)


def test_batch_engine_near_capacity_freezes_instead_of_wrapping():
    cfg, model, params, _, _ = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0,
                              cfg.vocab_size)
    big = BatchEngine(model, params, max_len=256, chunk=4)
    out_big, _ = big.generate({"tokens": toks}, 40)
    small = BatchEngine(model, params, max_len=20, chunk=4)
    out_small, st = small.generate({"tokens": toks}, 40)
    for b in range(2):
        n = int(st["n_emitted"][b])
        assert 4 <= n < 40, (b, n)
        np.testing.assert_array_equal(out_small[b, :n], out_big[b, :n])
        assert np.all(out_small[b, n:] == -1)


def test_sliding_window_still_wraps_by_design():
    cfg, model, params, _, _ = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0,
                              cfg.vocab_size)
    eng = BatchEngine(model, params, max_len=64, window=16, chunk=4)
    out, st = eng.generate({"tokens": toks}, 30)
    # a windowed ring is SUPPOSED to wrap: no capacity freeze
    assert int(st["n_emitted"][0]) == 30


def test_budget_stops_chunks_and_counts_real_tokens():
    cfg, model, params, heads, spec = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0,
                              cfg.vocab_size)
    eng = BatchEngine(model, params, max_len=96, chunk=8)
    # n_tokens NOT a multiple of chunk: the driver clamps the tail chunk
    # instead of launching a full 8-step scan for 2 remaining tokens
    out, st = eng.generate({"tokens": toks}, 11)
    assert out.shape == (2, 11)
    np.testing.assert_array_equal(st["n_emitted"], [11, 11])
    assert st["emitted_total"] == 22
    # 10 decode steps = chunks of 8 + 2, never 8 + 8
    assert len(st["step_times"]) == 2

    # per-sequence budgets: each row stops at ITS budget, output padded
    out2, st2 = eng.generate({"tokens": toks}, np.asarray([4, 11]))
    assert out2.shape == (2, 11)
    np.testing.assert_array_equal(st2["n_emitted"], [4, 11])
    np.testing.assert_array_equal(out2[0, :4], out[0, :4])
    np.testing.assert_array_equal(out2[1], out[1])
    assert np.all(out2[0, 4:] == -1)


def test_spec_budget_per_sequence():
    cfg, model, params, heads, spec = _setup()
    toks = jax.random.randint(jax.random.PRNGKey(8), (2, 8), 0,
                              cfg.vocab_size)
    eng = SpeculativeEngine(model, heads, params, spec, max_len=96, chunk=4)
    out, st = eng.generate({"tokens": toks}, 14)
    out2, st2 = eng.generate({"tokens": toks}, np.asarray([5, 14]))
    np.testing.assert_array_equal(st2["n_emitted"], [5, 14])
    np.testing.assert_array_equal(out2[0, :5], out[0, :5])
    np.testing.assert_array_equal(out2[1], out[1])
    assert np.all(out2[0, 5:] == -1)
