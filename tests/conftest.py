import os
import sys

# allow running plain `pytest tests/` too
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# tests dir itself (for the _mini_hypothesis fallback import)
sys.path.insert(0, os.path.dirname(__file__))

# smoke tests must see the single real CPU device (the 512-device flag is
# set ONLY inside launch/dryrun.py, per the dry-run contract)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
