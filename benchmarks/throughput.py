"""Fig. 9 reproduction: decoding throughput of Sequential / Medusa /
Medusa+EM / Ghidorah across verification widths, on the calibrated Jetson
NX simulator (hardware constants from the paper's testbed; four efficiency
scalars calibrated once against the paper's three reported aggregate
numbers, then the full table is *predicted*).

Paper targets: Ghidorah up to 7.6x vs Sequential at W=16; avg 2.06x over
Medusa and 1.20x over Medusa+EM (MBPP); Medusa's own optimum at W=64 vs
Ghidorah's at W=16.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.benchlib import PAPER_MBPP_AL
from repro.configs import get_config
from repro.core import arca
from repro.core.speculative import tree as T

WIDTHS = (4, 8, 16, 32, 64)


_SPEC_CACHE = {}


def _tree(accs, w):
    key = (accs.tobytes(), w)
    if key not in _SPEC_CACHE:
        _SPEC_CACHE[key] = T.build_tree(accs, w)
    return _SPEC_CACHE[key]


def systems_table(soc, cfg, accs, ctx=256, al_row=None):
    """Throughput (tok/s) per system per width.  ``al_row`` overrides the
    estimator with measured ALs (paper Table I row)."""
    seq_t = arca.step_time_sequential(soc, cfg, ctx)
    rows = {}
    for i, w in enumerate(WIDTHS):
        spec = _tree(accs, w)
        al = al_row[i] if al_row else T.expected_acceptance_length(spec, accs)
        ratio = arca.contention_aware_ratio(soc, cfg, w, ctx)
        rows[w] = {
            "AL": al,
            "sequential": 1.0 / seq_t,
            "medusa": al / arca.step_time_medusa_gpu(soc, cfg, w, ctx, spec),
            "medusa_em": al / arca.step_time_megatron(soc, cfg, w, ctx,
                                                      spec),
            "ghidorah": al / arca.step_time_ghidorah(soc, cfg, w, ctx, spec,
                                                     ratio),
        }
    return rows


def calibrate(cfg, accs, ctx=256):
    """Grid-search 4 efficiency scalars against the paper's aggregates."""
    targets = {"peak": 7.6, "vs_medusa": 2.06, "vs_em": 1.20}
    al_row = PAPER_MBPP_AL
    best, best_err = None, np.inf
    grid = itertools.product(
        np.linspace(0.5, 1.0, 6),      # gpu gemm_eff
        np.linspace(0.3, 0.7, 5),      # gpu bw_frac
        np.linspace(0.3, 0.7, 5),      # cpu gemm_eff
        np.linspace(1.0, 1.3, 3),      # contention
        np.linspace(0.0, 0.12, 5),     # EdgeNN ratio misallocation
    )
    base = arca.JETSON_NX
    for ge, gb, ce, cont, emr in grid:
        soc = dataclasses.replace(
            base,
            units=(dataclasses.replace(base.gpu, gemm_eff=ge, bw_frac=gb),
                   dataclasses.replace(base.cpu, gemm_eff=ce)),
            contention=cont, em_ratio_err=emr)
        t = systems_table(soc, cfg, accs, ctx, al_row)
        seq = t[16]["sequential"]
        peak = max(t[w]["ghidorah"] for w in WIDTHS) / seq
        vs_m = np.mean([t[w]["ghidorah"] / t[w]["medusa"] for w in WIDTHS])
        vs_e = np.mean([t[w]["ghidorah"] / t[w]["medusa_em"] for w in WIDTHS])
        err = ((peak - targets["peak"]) / targets["peak"]) ** 2 \
            + (vs_m - targets["vs_medusa"]) ** 2 + (vs_e - targets["vs_em"]) ** 2
        if err < best_err:
            best, best_err = soc, err
    return best, best_err


def run() -> list:
    cfg = get_config("vicuna-7b")
    accs, _, _ = _fit_accs()
    soc, err = calibrate(cfg, accs)
    t = systems_table(soc, cfg, accs, al_row=PAPER_MBPP_AL)
    seq = t[16]["sequential"]
    print(f"# calibrated soc: gpu_eff={soc.gpu.gemm_eff:.2f} "
          f"gpu_bw={soc.gpu.bw_frac:.2f} cpu_eff={soc.cpu.gemm_eff:.2f} "
          f"contention={soc.contention:.2f} em_ratio_err={soc.em_ratio_err:.2f} "
          f"(err {err:.3f})")
    print("width   AL   seq    medusa  med+em  ghidorah  (speedup vs seq)")
    for w in WIDTHS:
        r = t[w]
        print(f"{w:5d} {r['AL']:5.2f} {1.0:5.2f}x {r['medusa']/seq:6.2f}x "
              f"{r['medusa_em']/seq:6.2f}x {r['ghidorah']/seq:7.2f}x")
    peak = max(t[w]["ghidorah"] for w in WIDTHS) / seq
    w_star = max(WIDTHS, key=lambda w: t[w]["ghidorah"])
    w_med = max(WIDTHS, key=lambda w: t[w]["medusa"])
    vs_m = float(np.mean([t[w]["ghidorah"] / t[w]["medusa"] for w in WIDTHS]))
    vs_e = float(np.mean([t[w]["ghidorah"] / t[w]["medusa_em"] for w in WIDTHS]))
    print(f"# peak {peak:.2f}x at W={w_star} (paper: 7.6x at 16); "
          f"medusa optimum W={w_med} (paper: 64); "
          f"avg vs medusa {vs_m:.2f}x (paper 2.06); vs EM {vs_e:.2f}x (paper 1.20)")
    return [("fig9_peak_speedup", peak, f"W={w_star}"),
            ("fig9_avg_vs_medusa", vs_m, "paper=2.06"),
            ("fig9_avg_vs_em", vs_e, "paper=1.20")]


def _fit_accs():
    from benchmarks.acceptance import fit_accs
    return fit_accs()


if __name__ == "__main__":
    run()
