"""Ablations beyond the paper's headline figures:

  1. tree refinement (Fig. 8's brute-force step) vs greedy-only — how much
     acceptance length the local search adds at each width;
  2. contention-aware partition ratio (ARCA §III-C3) vs EdgeNN's
     solo-profiled ratio — step-time cost of the misallocation;
  3. verification-width sweet spots across model scales (the wave-
     quantization argument §III-C2): optimum width vs model size.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import get_config
from repro.core import arca
from repro.core.speculative import tree as T


def tree_refinement_ablation():
    accs = T.default_accs(5, 10)
    print("width  greedy-E[AL]  refined-E[AL]  gain")
    rows = []
    for w in (4, 8, 16, 32):
        g = T.build_tree_greedy(accs, w)
        r = T.refine_tree(g, accs)
        ag = T.expected_acceptance_length(g, accs)
        ar = T.expected_acceptance_length(r, accs)
        print(f"{w:5d} {ag:12.4f} {ar:13.4f} {ar/ag:6.4f}x")
        rows.append((w, ag, ar))
    # greedy is estimator-optimal (top-W path products) => refinement under
    # the SAME estimator is a no-op; its value appears only with an
    # empirical evaluator (paper: "compare their real acceptance lengths").
    return [("ablation_refine_gain_w16", rows[2][2] / rows[2][1], "estimator")]


def contention_ratio_ablation():
    cfg = get_config("vicuna-7b")
    soc = arca.JETSON_NX
    spec = T.build_tree(T.default_accs(5, 10), 16)
    print("em_ratio_err  step_time(ms)  vs aware")
    aware = arca.step_time_ghidorah(soc, cfg, 16, 256, spec,
                                    arca.contention_aware_ratio(soc, cfg, 16, 256))
    out = []
    for err in (0.0, 0.03, 0.06, 0.12):
        r = max(0.05, arca.optimal_ratio(soc) - err)
        t = arca.step_time_ghidorah(soc, cfg, 16, 256, spec, r)
        print(f"{err:12.2f} {t*1e3:13.1f} {t/aware:8.2f}x")
        out.append(t / aware)
    return [("ablation_ratio_err12_slowdown", out[-1], "vs contention-aware")]


def width_vs_scale_ablation():
    accs = T.default_accs(5, 10)
    print("model        params  ARCA width  (Jetson sim)")
    rows = []
    for arch in ("qwen2-0.5b", "stablelm-3b", "vicuna-7b", "glm4-9b"):
        cfg = get_config(arch)
        strats = arca.choose_strategy(cfg, accs, ctx=256)
        best = arca.best(strats)
        print(f"{arch:12s} {cfg.param_count()/1e9:5.1f}B {best.width:8d}")
        rows.append((arch, best.width))
    return [("ablation_width_" + a.replace("-", "_"), float(w), "jetson-sim")
            for a, w in rows]


def run() -> list:
    out = []
    print("-- tree refinement (greedy vs brute-force) --")
    out += tree_refinement_ablation()
    print("-- contention-aware vs solo-profiled ratio --")
    out += contention_ratio_ablation()
    print("-- ARCA width vs model scale --")
    out += width_vs_scale_ablation()
    return out


if __name__ == "__main__":
    run()
