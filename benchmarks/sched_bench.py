"""Measured continuous-batching gain: static batches vs per-sequence
admission/eviction under staggered Poisson arrivals, on THIS machine.

Workload: N requests with a mixed token budget (alternating short/long —
the regime where static batching loses: a finished short request's row sits
idle until the whole group drains, while the continuous scheduler refills
it from the queue at the next chunk boundary).  Arrival times are a Poisson
process whose rate is calibrated against a measured warm static makespan,
so the stream is genuinely staggered (neither all-at-once nor fully idle)
at any machine speed.

Admission-policy comparison (``record["policies"]``): FIFO vs SJF vs
FIFO+chunked-prefill on the mixed 16/192-budget convoy trace (mixed
16/64 prompts, burst arrivals, a paged pool that funds ten 3-page short
reservations but never a 17-page long one beside them — the head-of-line
regime).  Reports per-arm p50/p95 latency (the tail the policies target;
mean alone hides it) and asserts SJF and/or chunked prefill beat FIFO on
p95.

Adaptive-speculation comparison (``record["adaptive"]``): ONE
``DecodeEngine`` bank serves the mixed-budget trace under each fixed
candidate width and under the scheduler's adaptive mode (measured-ARCA:
``arca.profile_engine`` step times x observed-acceptance EMA, strategy
switched at chunk boundaries).  Asserts adaptive matches-or-beats the
WORST fixed-width arm on aggregate tok/s and logs every per-boundary
strategy switch in the record — with this repo's random heads the
observed AL is ~1, so the right move is walking from the wide start down
to the fastest width, and the record shows exactly that.

Paged KV comparison (``record["paged"]``): at FIXED pool memory — the
paged pool's reservable slots round DOWN from what the dense B-row bank
holds, so the paged side never gets extra KV memory — a
double-width bank over the shared pool sustains a strictly larger resident
batch on the same mixed 16/192-budget burst, because short requests
reserve ~3 pages while only long ones reserve the dense row's worth.  The
section also asserts the donation wiring: after a chunk step the input
pool buffer must be DELETED (aliased in place) and exactly one pool-sized
buffer may be live — a ~2x pool-size peak fails the bench.
``--paged`` runs ONLY this comparison (the CI smoke).

Fault-tolerance comparison (``record["faults"]``): the mixed-budget
Poisson trace served through the async multi-replica router
(``runtime.router`` over ``runtime.server``), faults off vs a seeded
chaos plan (replica crash mid-serve + ~10% client disconnects + finite
deadlines).  Asserts every request ends in a typed terminal state, no
replica leaks pool pages through crash/cancel/timeout cleanup, and
goodput-under-SLO stays >= 0.8x the fault-free arm.  ``--faults`` runs
ONLY this comparison (the chaos smoke).

Runs in a SUBPROCESS with XLA CPU intra-op threading pinned off, same
measurement contract as engine_bench (see that module's docstring).

  PYTHONPATH=src python benchmarks/sched_bench.py [--requests 32] [--paged]

Emits a JSON record to ``benchmarks/results/sched_bench.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))
from benchmarks.engine_bench import (RESULT_DIR, bootstrap_worker_path,
                                     spawn_pinned_worker)

BATCH = 8
PROMPT_LEN = 16
BUDGETS = (16, 192)           # alternating short/long generation budgets


def _sched_smoke_cfg():
    """Like engine_bench's smoke config but 2x wider: per-chunk device time
    has to dominate the per-admission dispatch overhead (B=1 prefill +
    row insert) or the bench measures Python, not scheduling."""
    import dataclasses

    from benchmarks.engine_bench import _engine_smoke_cfg
    return dataclasses.replace(_engine_smoke_cfg(),
                               name="qwen2-sched-smoke", d_model=256,
                               num_heads=4, num_kv_heads=4, d_ff=512)


def _requests(cfg, n, arrivals):
    import jax
    import numpy as np

    from repro.runtime.scheduler import Request
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (n, PROMPT_LEN), 0, cfg.vocab_size),
        np.int32)
    return [Request(req_id=i, tokens=prompts[i],
                    n_tokens=BUDGETS[i % len(BUDGETS)],
                    arrival=float(arrivals[i]))
            for i in range(n)]


def _best_of(fn, reps):
    """Highest-throughput run of ``reps`` (same contract as engine_bench's
    best-of-N timing: scheduling makespans on a busy 2-CPU container are
    noisy in one direction only — slowdowns)."""
    best = None
    for _ in range(reps):
        _, s = fn()
        if best is None or s["tok_s"] > best["tok_s"]:
            best = s
    return best


PAGE_SIZE = 16


def _paged_compare(cfg, model, params, heads, spec, max_len, n_requests,
                   chunk, reps) -> dict:
    """Fixed-memory paged-vs-dense resident-batch comparison + the
    in-place-update (donation) buffer check."""
    import jax
    import numpy as np

    from repro.runtime.engine import SpeculativeEngine, _eos_scalar
    from repro.runtime.scheduler import ContinuousScheduler

    # FIXED MEMORY: the pool's reservable slots round DOWN from the dense
    # BATCH-row bank's (never more KV memory than the baseline; the +1
    # trash page is bookkeeping, not reservable capacity); the paged bank
    # is twice as wide and lives off reservations
    pool_pages = (BATCH * max_len) // PAGE_SIZE
    paged_batch = 2 * BATCH
    dense = SpeculativeEngine(model, heads, params, spec, max_len=max_len,
                              chunk=chunk)
    paged = SpeculativeEngine(model, heads, params, spec, max_len=max_len,
                              chunk=chunk, paged=True, page_size=PAGE_SIZE,
                              pool_pages=pool_pages)

    # ---- donation buffer check: chunk updates the pool IN PLACE ----------
    row = paged.sched_prefill(
        {"tokens": np.zeros((1, PROMPT_LEN), np.int32)})
    state = paged.sched_blank(row, paged_batch)
    state = paged.sched_insert(state, 0, row, prompt_len=PROMPT_LEN,
                               n_tokens=BUDGETS[0])
    pool_before = state.cache.kv.pool_k
    pool_nbytes = pool_before.nbytes

    def n_pool_sized():
        return sum(1 for a in jax.live_arrays() if a.nbytes == pool_nbytes)

    jax.block_until_ready(pool_before)
    baseline = n_pool_sized()                    # the state's pool (+ any
    done = np.ones((paged_batch,), bool)         # coincidental constants)
    done[0] = False
    rem = np.zeros((paged_batch,), np.int32)
    rem[0] = BUDGETS[0]
    state, _, _, _ = paged.sched_step(state, done, rem, chunk,
                                      int(_eos_scalar(None)))
    jax.block_until_ready(state.cache.kv.pool_k)
    if not pool_before.is_deleted():
        raise AssertionError("chunk scan did not donate the KV pool "
                             "(per-chunk pool copy)")
    if n_pool_sized() > baseline:
        raise AssertionError(
            "extra pool-sized buffer live after a chunk (~2x pool peak) — "
            "donation/aliasing regressed")
    paged.sched_release(0)
    del state, row, pool_before

    # ---- resident-batch + throughput on the mixed-budget burst -----------
    zero = np.zeros(n_requests)
    for eng, b in ((dense, BATCH), (paged, paged_batch)):   # warm/compile
        ContinuousScheduler(eng, batch=b, chunk=chunk).serve(
            _requests(cfg, n_requests, zero))
    dn = _best_of(lambda: ContinuousScheduler(
        dense, batch=BATCH, chunk=chunk).serve(
            _requests(cfg, n_requests, zero)), reps)
    pg = _best_of(lambda: ContinuousScheduler(
        paged, batch=paged_batch, chunk=chunk).serve(
            _requests(cfg, n_requests, zero)), reps)
    if n_requests > BATCH and pg["max_resident"] <= dn["max_resident"]:
        raise AssertionError(
            f"paged resident batch {pg['max_resident']} not larger than "
            f"dense {dn['max_resident']} at fixed pool memory")

    # ---- int8 pages: a byte-equal pool funds more reservable tokens ------
    # Hold the fp32 pool's BYTE budget fixed and re-derive the page count
    # at kv_dtype=int8 (page_bytes includes the per-page scale overhead);
    # the quantized engine then serves the same burst off the bigger
    # reservation.  Token agreement with the fp32 paged stream is recorded
    # as a fraction, not asserted — quantization CAN flip a borderline
    # argmax; the bounded-error parity gate lives in tests/.
    import jax.numpy as jnp

    from repro.runtime.cache import page_bytes, pages_at_fixed_bytes
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    pool_dt = jnp.dtype(cfg.dtype)
    budget_bytes = pool_pages * page_bytes(L, PAGE_SIZE, Hkv, hd, pool_dt)
    int8_pages = pages_at_fixed_bytes(budget_bytes, L, PAGE_SIZE, Hkv, hd,
                                      jnp.int8)
    token_gain = int8_pages / pool_pages
    if token_gain < 1.8:
        raise AssertionError(
            f"int8 pages fund only {token_gain:.2f}x reservable tokens at "
            f"fixed pool bytes (>= 1.8x required)")
    int8 = SpeculativeEngine(model, heads, params, spec, max_len=max_len,
                             chunk=chunk, paged=True, page_size=PAGE_SIZE,
                             pool_pages=int8_pages, kv_dtype="int8")

    def serve_int8():
        return ContinuousScheduler(int8, batch=paged_batch,
                                   chunk=chunk).serve(
            _requests(cfg, n_requests, zero))

    res8, _ = serve_int8()                               # warm/compile
    res32, _ = ContinuousScheduler(paged, batch=paged_batch,
                                   chunk=chunk).serve(
        _requests(cfg, n_requests, zero))
    by_id8 = {r.req_id: r.tokens for r in res8}
    match = sum(np.array_equal(by_id8[r.req_id], r.tokens) for r in res32)
    i8 = _best_of(serve_int8, reps)
    return {
        "page_size": PAGE_SIZE, "pool_pages": pool_pages,
        "pool_slots": pool_pages * PAGE_SIZE,
        "dense_batch": BATCH, "paged_batch": paged_batch,
        "dense_max_resident": dn["max_resident"],
        "paged_max_resident": pg["max_resident"],
        "dense_tok_s": dn["tok_s"], "paged_tok_s": pg["tok_s"],
        "dense_makespan_s": dn["makespan_s"],
        "paged_makespan_s": pg["makespan_s"],
        "dense_latency_mean_s": dn["latency_mean_s"],
        "paged_latency_mean_s": pg["latency_mean_s"],
        "resident_gain": pg["max_resident"] / max(dn["max_resident"], 1),
        "speedup_paged_vs_dense": pg["tok_s"] / dn["tok_s"],
        "donation_in_place": True,
        "int8_pool_pages": int8_pages,
        "int8_pool_bytes_budget": int(budget_bytes),
        "int8_reservable_token_gain": token_gain,
        "int8_max_resident": i8["max_resident"],
        "int8_tok_s": i8["tok_s"],
        "int8_makespan_s": i8["makespan_s"],
        "int8_latency_mean_s": i8["latency_mean_s"],
        "int8_token_match_frac": match / max(len(res32), 1),
    }


FAULT_SEED = 9                # cancels reqs 4 and 6 (both short-budget)
FAULT_REPLICAS = 2
FAULT_BATCH = 4               # per replica: half the single-bank BATCH
FAULT_CANCEL_RATE = 0.10


def _faults_compare(cfg, model, params, heads, spec, max_len, n_requests,
                    chunk, reps) -> dict:
    """Fault-tolerance arm (``record["faults"]``): the SAME mixed
    16/192-budget Poisson trace served through the async router over
    ``FAULT_REPLICAS`` paged replicas, faults off vs faults on (replica
    r0 crashes mid-serve, ~10% of clients hang up mid-stream, every
    request carries a finite deadline).  Asserts every request lands in
    a typed terminal state, no replica leaks pool pages (free + held ==
    pool after drain, on BOTH arms — including through ``fail_all`` on
    the crashed replica), and goodput-under-SLO (tokens of DONE requests
    per second of makespan) stays >= 0.8x the fault-free arm: the
    crash's lost work is re-decoded on the surviving replica and the
    cancelled clients' budgets leave the denominator with them."""
    import asyncio

    import numpy as np

    from repro.runtime.cache import pages_for
    from repro.runtime.engine import SpeculativeEngine
    from repro.runtime.faults import FaultPlan
    from repro.runtime.router import ReplicaRouter
    from repro.runtime.router import replay as router_replay
    from repro.runtime.scheduler import (ContinuousScheduler, Request,
                                         poisson_arrivals)
    from repro.runtime.server import AsyncEngineServer

    n = min(n_requests, 12)
    pool_pages = FAULT_BATCH * pages_for(max_len, PAGE_SIZE)
    engines = [SpeculativeEngine(model, heads, params, spec,
                                 max_len=max_len, chunk=chunk, paged=True,
                                 page_size=PAGE_SIZE, pool_pages=pool_pages)
               for _ in range(FAULT_REPLICAS)]

    # warm/compile each replica's bank + measure single-replica throughput
    warm_reqs = _requests(cfg, 4, np.zeros(4))
    warm = None
    for eng in engines:
        _, warm = ContinuousScheduler(eng, batch=FAULT_BATCH,
                                      chunk=chunk).serve(
            [Request(req_id=r.req_id, tokens=r.tokens, n_tokens=r.n_tokens,
                     arrival=0.0) for r in warm_reqs])
    total_budget = sum(BUDGETS[i % len(BUDGETS)] for i in range(n))
    w1 = total_budget / warm["tok_s"]          # est. 1-replica makespan
    # arrivals span ~35% of the est. fleet makespan (same staggering
    # contract as the main grid); deadlines bind at 2x the single-replica
    # makespan — real pressure once a crash serializes the fleet
    rate = n / max(0.35 * w1 / FAULT_REPLICAS, 1e-6)
    arrivals = poisson_arrivals(n, rate, seed=3)
    deadline_s = 2.0 * w1
    # r0 dies ~60% through its share of the trace: enough in-flight work
    # to make the retry path real, enough runway to re-decode it on r1
    crash_boundary = max(6, int(0.6 * warm["chunks"]))

    def arm(plan):
        scheds = [ContinuousScheduler(
            eng, batch=FAULT_BATCH, chunk=chunk,
            faults=None if plan is None else plan.injector(f"r{i}"))
            for i, eng in enumerate(engines)]
        servers = [AsyncEngineServer(s, name=f"r{i}")
                   for i, s in enumerate(scheds)]
        router = ReplicaRouter(
            servers, seed=FAULT_SEED,
            client_faults=None if plan is None else plan.client())

        async def go():
            await router.start()
            try:
                return await router_replay(
                    router, _requests(cfg, n, arrivals),
                    deadline_s=deadline_s)
            finally:
                await router.stop()

        _, stats = asyncio.run(go())
        if not stats["terminal"]:
            raise AssertionError(
                f"non-terminal request states: {stats['states']}")
        if not (router.pages_conserved() and router.drained()):
            raise AssertionError(
                "leaked pool pages after drain (faults "
                f"{'on' if plan else 'off'})")
        stats["pages_drained"] = True
        return stats

    plan = FaultPlan(seed=FAULT_SEED, crash={"r0": crash_boundary},
                     cancel_rate=FAULT_CANCEL_RATE)

    def best(fn):
        runs = [fn() for _ in range(reps)]
        return max(runs, key=lambda s: s["goodput_tok_s"])

    clean = best(lambda: arm(None))
    chaos = best(lambda: arm(plan))
    ratio = chaos["goodput_tok_s"] / max(clean["goodput_tok_s"], 1e-9)
    out = {"replicas": FAULT_REPLICAS, "batch": FAULT_BATCH, "requests": n,
           "page_size": PAGE_SIZE, "pool_pages": pool_pages,
           "seed": FAULT_SEED, "cancel_rate": FAULT_CANCEL_RATE,
           "crash_boundary": crash_boundary, "deadline_s": deadline_s,
           "fault_free": clean, "faulted": chaos,
           "goodput_ratio_faulted_vs_fault_free": ratio}
    if ratio < 0.8:
        raise AssertionError(
            f"faulted goodput {chaos['goodput_tok_s']:.1f} tok/s fell "
            f"below 0.8x fault-free {clean['goodput_tok_s']:.1f} tok/s")
    return out


def _hcmp_compare(cfg, model, params, heads, spec, max_len, n_requests,
                  chunk, reps) -> dict:
    """hcmp arm (``record["hcmp"]``): the mixed-budget burst trace served
    by an inline engine vs the disaggregated overlap engine through the
    SAME continuous scheduler, with the bit-identity gate (per-request
    tokens must match exactly) and ARCA's measured partition choice.
    Runs only in the two-device worker (``--hcmp``)."""
    import jax
    import numpy as np

    from repro.core import arca
    from repro.runtime.engine import SpeculativeEngine
    from repro.runtime.scheduler import ContinuousScheduler

    inline = SpeculativeEngine(model, heads, params, spec, max_len=max_len,
                               chunk=chunk)
    overlap = SpeculativeEngine(model, heads, params, spec,
                                max_len=max_len, chunk=chunk,
                                hcmp="overlap")
    zero = np.zeros(n_requests)

    def serve(eng):
        return ContinuousScheduler(eng, batch=BATCH, chunk=chunk).serve(
            _requests(cfg, n_requests, zero))

    r_i, _ = serve(inline)                            # warm/compile + gate
    r_o, _ = serve(overlap)
    bad = [a.req_id for a, b in zip(r_i, r_o)
           if not np.array_equal(a.tokens, b.tokens)]
    if bad:
        raise AssertionError(
            f"overlap diverged from inline for requests {bad} — the arm "
            f"is meaningless without bit-identity")
    si = _best_of(lambda: serve(inline), reps)
    so = _best_of(lambda: serve(overlap), reps)
    tf = arca.profile_engine(overlap, batch=BATCH, prompt_len=PROMPT_LEN,
                             reps=1)
    part = tf.partition_for(spec)
    hs = overlap.hcmp_stats
    out = {"devices": len(jax.devices()), "host_cores": os.cpu_count(),
           "batch": BATCH, "requests": n_requests,
           "inline_tok_s": si["tok_s"], "overlap_tok_s": so["tok_s"],
           "inline_makespan_s": si["makespan_s"],
           "overlap_makespan_s": so["makespan_s"],
           "speedup_overlap_vs_inline": so["tok_s"] / si["tok_s"],
           "arca_partition": part,
           "predraft_hits": hs["predraft_hits"],
           "predraft_discards": hs["predraft_discards"]}
    if out["speedup_overlap_vs_inline"] <= 1.0:
        # honest annotation, not a failure (see engine_bench._hcmp_worker)
        out["note"] = (
            f"overlap did not beat inline under the scheduler on this "
            f"container ({out['host_cores']} visible core(s) under "
            f"{out['devices']} XLA host devices): the trace is "
            f"compute-bound, so the draft/commit overlap window frees no "
            f"wall time; ARCA's measured choice ({part}) records it")
    return out


ADAPT_WIDTHS = (1, 2, 8)      # sequential-degenerate, narrow, wide


def _adaptive_compare(cfg, model, params, heads, n_requests, chunk,
                      reps) -> dict:
    """Measured-ARCA adaptive arm: ONE DecodeEngine bank serves the mixed
    16/192-budget trace under (a) each fixed candidate width and (b) the
    scheduler's adaptive mode, which starts at the WIDEST candidate and
    re-decides from the observed-acceptance EMA x the measured per-width
    step times (``arca.profile_engine``).  With random heads the observed
    AL is ~1, so the measured argmax is the fastest step — adaptive must
    walk away from the wide start and match-or-beat the WORST fixed arm
    on aggregate tok/s; every strategy switch is logged per boundary in
    the record."""
    import numpy as np

    from repro.core import arca
    from repro.core.speculative import tree as T
    from repro.runtime.engine import DecodeEngine, DecodeStrategy
    from repro.runtime.scheduler import ContinuousScheduler

    accs = T.default_accs(cfg.medusa_heads, cfg.medusa_top_k)
    specs = {w: T.candidate_spec(accs, w) for w in ADAPT_WIDTHS}
    max_len = PROMPT_LEN + max(BUDGETS) + max(
        s.max_depth for s in specs.values())
    eng = DecodeEngine(model, params, heads=heads,
                       strategy=DecodeStrategy.medusa(
                           specs[max(ADAPT_WIDTHS)]),
                       max_len=max_len, chunk=chunk)
    time_fn = arca.profile_engine(eng, ADAPT_WIDTHS, accs=accs, batch=BATCH,
                                  prompt_len=PROMPT_LEN, reps=reps)
    strategies = arca.choose_strategy(cfg, accs, ctx=PROMPT_LEN,
                                      time_fn=time_fn, widths=ADAPT_WIDTHS)
    zero = np.zeros(n_requests)

    out = {"widths": list(ADAPT_WIDTHS), "batch": BATCH,
           "step_time_measured_s": {w: strategies[w].step_time
                                    for w in ADAPT_WIDTHS},
           "arms": {}}
    for w in ADAPT_WIDTHS:
        eng.set_strategy(specs[w])
        ContinuousScheduler(eng, batch=BATCH, chunk=chunk).serve(
            _requests(cfg, n_requests, zero))              # warm/compile
        s = _best_of(lambda: ContinuousScheduler(
            eng, batch=BATCH, chunk=chunk).serve(
                _requests(cfg, n_requests, zero)), reps)
        out["arms"][f"fixed_w{w}"] = {
            "tok_s": s["tok_s"], "makespan_s": s["makespan_s"],
            "latency_p95_s": s["latency_p95_s"]}

    def adaptive_run():
        eng.set_strategy(specs[max(ADAPT_WIDTHS)])         # wide start
        return ContinuousScheduler(eng, batch=BATCH, chunk=chunk,
                                   adaptive=strategies).serve(
            _requests(cfg, n_requests, zero))

    adaptive_run()                                         # warm/compile
    best_stats = _best_of(adaptive_run, reps)
    out["arms"]["adaptive"] = {
        "tok_s": best_stats["tok_s"],
        "makespan_s": best_stats["makespan_s"],
        "latency_p95_s": best_stats["latency_p95_s"],
        "width_start": max(ADAPT_WIDTHS),
        "width_final": best_stats["width_final"],
        "al_observed": best_stats["al_observed"],
        # per-boundary switch events: the acceptance-criterion log
        "strategy_switches": best_stats["strategy_switches"]}
    worst = min(out["arms"][f"fixed_w{w}"]["tok_s"] for w in ADAPT_WIDTHS)
    out["worst_fixed_tok_s"] = worst
    out["gain_adaptive_vs_worst_fixed"] = \
        out["arms"]["adaptive"]["tok_s"] / worst
    if out["arms"]["adaptive"]["tok_s"] < worst:
        raise AssertionError(
            f"adaptive ({out['arms']['adaptive']['tok_s']:.1f} tok/s) lost "
            f"to the worst fixed width ({worst:.1f} tok/s)")
    return out


POLICY_PROMPTS = (16, 64)     # short budget <-> short prompt, long <-> long
POLICY_PREFILL_CHUNK = 16
POLICY_LONG_EVERY = 16        # one 192-budget request per 16 shorts
POLICY_BATCH = 12


def _policy_requests(cfg, n):
    """Burst trace for the policy comparison: mixed 16/192 budgets with the
    192-budget requests in the MIDDLE of each 16-request block — the convoy
    shape.  When such a request reaches the FIFO head while shorts hold the
    pool, its 17-page reservation is unfundable and every fundable 3-page
    short behind it waits for a whole eviction generation; SJF lets them
    pass.  Long requests are ~6% of the trace so the p95 latency sits on
    the SHORT requests the convoy delays (more longs and p95 degenerates to
    'who finishes the 192-token jobs last', which on this compute-bound
    container is policy-independent)."""
    import jax
    import numpy as np

    from repro.runtime.scheduler import Request
    short_p, long_p = POLICY_PROMPTS
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (n, long_p), 0, cfg.vocab_size), np.int32)
    reqs = []
    for i in range(n):
        is_long = i % POLICY_LONG_EVERY == POLICY_LONG_EVERY // 2
        reqs.append(Request(
            req_id=i, tokens=prompts[i, :long_p if is_long else short_p],
            n_tokens=max(BUDGETS) if is_long else min(BUDGETS),
            arrival=0.0))
    return reqs


def _policy_compare(cfg, model, params, heads, spec, n_requests, chunk,
                    reps) -> dict:
    """Admission-policy comparison on the mixed 16/192-budget convoy trace
    (see ``_policy_requests``): FIFO vs SJF vs FIFO+chunked-prefill on a
    PAGED bank whose pool holds ten short reservations but never a long one
    next to them — the head-of-line regime.  All requests arrive in one
    burst, so every latency difference is scheduling, not arrival luck.
    p50/p95 are the headline numbers (mean alone hides exactly this tail);
    the flip side is recorded too: SJF starves the long request until the
    shorts drain (its latency ~= the makespan), the starvation caveat the
    scheduler docstring spells out."""
    import numpy as np

    from repro.runtime.cache import pages_for
    from repro.runtime.engine import SpeculativeEngine
    from repro.runtime.scheduler import ContinuousScheduler

    max_len = max(POLICY_PROMPTS) + max(BUDGETS) + spec.max_depth
    short_pages = pages_for(
        min(POLICY_PROMPTS) + min(BUDGETS) + spec.max_depth, PAGE_SIZE)
    # ten shorts fit with one page to spare; a long (17 pages) never fits
    # beside a full complement of shorts, so FIFO's head-of-line defers
    pool_pages = 10 * short_pages + 1
    batch = POLICY_BATCH
    eng = SpeculativeEngine(model, heads, params, spec, max_len=max_len,
                            chunk=chunk, paged=True, page_size=PAGE_SIZE,
                            pool_pages=pool_pages)

    def serve(**kw):
        return ContinuousScheduler(eng, batch=batch, chunk=chunk,
                                   **kw).serve(_policy_requests(cfg,
                                                                n_requests))

    arms = {
        "fifo": dict(policy="fifo"),
        "sjf": dict(policy="sjf"),
        "chunked_prefill": dict(policy="fifo",
                                prefill_chunk=POLICY_PREFILL_CHUNK),
    }
    out = {"page_size": PAGE_SIZE, "pool_pages": pool_pages, "batch": batch,
           "prompt_lens": list(POLICY_PROMPTS), "budgets": list(BUDGETS),
           "prefill_chunk": POLICY_PREFILL_CHUNK, "requests": n_requests,
           "arms": {}}
    for name, kw in arms.items():
        serve(**kw)                                  # warm/compile
        s = _best_of(lambda: serve(**kw), reps)
        out["arms"][name] = {
            "tok_s": s["tok_s"], "makespan_s": s["makespan_s"],
            "max_resident": s["max_resident"],
            "latency_mean_s": s["latency_mean_s"],
            "latency_p50_s": s["latency_p50_s"],
            "latency_p95_s": s["latency_p95_s"],
            # max = the long request: under SJF it is starved to ~the
            # makespan (the recorded cost of the p95/p50 win)
            "latency_max_s": s["latency_max_s"],
            "queue_wait_p95_s": s["queue_wait_p95_s"]}
    fifo95 = out["arms"]["fifo"]["latency_p95_s"]
    best95 = min(out["arms"]["sjf"]["latency_p95_s"],
                 out["arms"]["chunked_prefill"]["latency_p95_s"])
    if best95 >= fifo95:
        raise AssertionError(
            f"neither sjf ({out['arms']['sjf']['latency_p95_s']:.2f}s) nor "
            f"chunked prefill "
            f"({out['arms']['chunked_prefill']['latency_p95_s']:.2f}s) beat "
            f"fifo ({fifo95:.2f}s) on p95 latency")
    out["p95_gain_best_vs_fifo"] = fifo95 / best95
    out["p50_gain_sjf_vs_fifo"] = (
        out["arms"]["fifo"]["latency_p50_s"]
        / max(out["arms"]["sjf"]["latency_p50_s"], 1e-9))
    return out


def _worker(n_requests: int, chunk: int, reps: int,
            paged_only: bool = False, faults_only: bool = False,
            hcmp_only: bool = False) -> dict:
    import jax
    import numpy as np

    from repro.core.speculative import tree as T
    from repro.core.speculative.medusa import init_medusa
    from repro.models.api import get_model
    from repro.runtime.engine import BatchEngine, SpeculativeEngine
    from repro.runtime.scheduler import (ContinuousScheduler,
                                         poisson_arrivals, serve_static)

    cfg = _sched_smoke_cfg()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(1))
    spec = T.build_tree(T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 4)
    max_len = PROMPT_LEN + max(BUDGETS) + spec.max_depth

    if paged_only:
        return {"arch": cfg.name, "requests": n_requests, "chunk": chunk,
                "paged": _paged_compare(cfg, model, params, heads, spec,
                                        max_len, n_requests, chunk, reps)}
    if faults_only:
        return {"arch": cfg.name, "requests": n_requests, "chunk": chunk,
                "faults": _faults_compare(cfg, model, params, heads, spec,
                                          max_len, n_requests, chunk, reps)}
    if hcmp_only:
        return {"arch": cfg.name, "requests": n_requests, "chunk": chunk,
                "hcmp": _hcmp_compare(cfg, model, params, heads, spec,
                                      max_len, n_requests, chunk, reps)}

    engines = {
        "sequential": BatchEngine(model, params, max_len=max_len,
                                  chunk=chunk),
        "speculative": SpeculativeEngine(model, heads, params, spec,
                                         max_len=max_len, chunk=chunk),
    }
    record = {"arch": cfg.name, "requests": n_requests, "batch": BATCH,
              "chunk": chunk, "prompt_len": PROMPT_LEN,
              "budgets": list(BUDGETS), "grid": []}

    for name, eng in engines.items():
        zero = np.zeros(n_requests)
        # warm-up + compile both paths AND measure the warm static makespan
        serve_static(eng, _requests(cfg, n_requests, zero), batch=BATCH)
        ContinuousScheduler(eng, batch=BATCH, chunk=chunk).serve(
            _requests(cfg, n_requests, zero))
        _, warm = serve_static(eng, _requests(cfg, n_requests, zero),
                               batch=BATCH)
        # arrivals span ~35% of the warm static makespan: genuinely
        # staggered (static pays batch-formation waits) while the
        # continuous path stays decode-bound rather than arrival-starved
        rate = n_requests / (0.35 * warm["makespan_s"])
        arrivals = poisson_arrivals(n_requests, rate, seed=3)

        st = _best_of(lambda: serve_static(
            eng, _requests(cfg, n_requests, arrivals), batch=BATCH), reps)
        ct = _best_of(lambda: ContinuousScheduler(
            eng, batch=BATCH, chunk=chunk).serve(
                _requests(cfg, n_requests, arrivals)), reps)
        for sched, s in (("static", st), ("continuous", ct)):
            record["grid"].append({
                "engine": name, "sched": sched, "rate": rate,
                "tok_s": s["tok_s"], "makespan_s": s["makespan_s"],
                "emitted_total": s["emitted_total"],
                "latency_mean_s": s["latency_mean_s"],
                "latency_p90_s": s["latency_p90_s"],
                "queue_wait_mean_s": s["queue_wait_mean_s"]})
        record[f"speedup_continuous_vs_static_{name}"] = \
            ct["tok_s"] / st["tok_s"]
        record[f"latency_ratio_static_vs_continuous_{name}"] = \
            st["latency_mean_s"] / max(ct["latency_mean_s"], 1e-9)

    record["speedup_continuous_vs_static"] = min(
        record["speedup_continuous_vs_static_sequential"],
        record["speedup_continuous_vs_static_speculative"])
    record["paged"] = _paged_compare(cfg, model, params, heads, spec,
                                     max_len, n_requests, chunk, reps)
    record["policies"] = _policy_compare(cfg, model, params, heads, spec,
                                         n_requests, chunk, reps)
    record["adaptive"] = _adaptive_compare(cfg, model, params, heads,
                                           n_requests, chunk, reps)
    record["faults"] = _faults_compare(cfg, model, params, heads, spec,
                                       max_len, n_requests, chunk, reps)
    return record


def run(n_requests=32, chunk=8, reps=2, paged_only=False,
        faults_only=False, hcmp_only=False) -> list:
    """Spawn the pinned-environment worker, persist + pretty-print results."""
    from benchmarks.engine_bench import _HCMP_DEV_FLAG
    argv = ["--requests", str(n_requests), "--chunk", str(chunk),
            "--reps", str(reps)]
    if paged_only:
        argv.append("--paged")
    if faults_only:
        argv.append("--faults")
    if hcmp_only:
        record = spawn_pinned_worker(__file__, argv + ["--hcmp"],
                                     extra_xla_flags=_HCMP_DEV_FLAG)
    else:
        record = spawn_pinned_worker(__file__, argv)
    if not (paged_only or faults_only or hcmp_only):
        # the hcmp arm needs its own subprocess: the second XLA host
        # device must be requested before the backend initializes
        record["hcmp"] = spawn_pinned_worker(
            __file__, argv + ["--hcmp"],
            extra_xla_flags=_HCMP_DEV_FLAG)["hcmp"]

    rows = []
    for g in record.get("grid", ()):
        name = f"sched_{g['sched'][:4]}_{g['engine'][:4]}_b{BATCH}"
        rows.append((name, 1e6 / g["tok_s"],
                     f"{g['tok_s']:.1f} tok/s agg, "
                     f"lat p90 {g['latency_p90_s']:.2f}s"))
    if "grid" in record:
        for eng in ("sequential", "speculative"):
            rows.append((f"sched_speedup_cont_vs_static_{eng[:4]}",
                         record[f"speedup_continuous_vs_static_{eng}"],
                         "x aggregate tok/s"))
            rows.append((f"sched_latencyx_static_vs_cont_{eng[:4]}",
                         record[f"latency_ratio_static_vs_continuous_{eng}"],
                         "x mean latency (higher = static worse)"))
    if "paged" in record:
        pg = record["paged"]
        rows.append(("sched_paged_resident_gain", pg["resident_gain"],
                     f"{pg['paged_max_resident']} vs "
                     f"{pg['dense_max_resident']} resident at "
                     f"{pg['pool_slots']} pool slots"))
        rows.append(("sched_paged_vs_dense_tok_s",
                     pg["speedup_paged_vs_dense"],
                     f"{pg['paged_tok_s']:.1f} vs {pg['dense_tok_s']:.1f} "
                     "tok/s agg at fixed pool memory"))
    if "policies" in record:
        pol = record["policies"]
        for name, a in pol["arms"].items():
            rows.append((f"sched_policy_{name}", a["latency_p95_s"],
                         f"p95 lat s (p50 {a['latency_p50_s']:.2f}s, "
                         f"{a['tok_s']:.1f} tok/s, "
                         f"resident {a['max_resident']})"))
        rows.append(("sched_policy_p95_gain_vs_fifo",
                     pol["p95_gain_best_vs_fifo"],
                     "x fifo p95 latency (best of sjf/chunked-prefill)"))
    if "adaptive" in record:
        ad = record["adaptive"]
        for name, a in ad["arms"].items():
            extra = ""
            if name == "adaptive":
                sw = a["strategy_switches"]
                extra = (f", w {a['width_start']}->{a['width_final']}, "
                         f"{len(sw)} switch(es)")
            rows.append((f"sched_{name}", 1e6 / a["tok_s"],
                         f"{a['tok_s']:.1f} tok/s agg{extra}"))
        rows.append(("sched_adaptive_vs_worst_fixed",
                     ad["gain_adaptive_vs_worst_fixed"],
                     "x worst fixed-width arm (measured-ARCA selection)"))
    if "hcmp" in record:
        hc = record["hcmp"]
        rows.append(("sched_hcmp_overlap_vs_inline",
                     hc["speedup_overlap_vs_inline"],
                     f"x inline ({hc['overlap_tok_s']:.1f} vs "
                     f"{hc['inline_tok_s']:.1f} tok/s agg, "
                     f"{hc['devices']} devices, arca picks "
                     f"{hc['arca_partition']}, predraft "
                     f"{hc['predraft_hits']}h/{hc['predraft_discards']}d)"))
        if "note" in hc:
            rows.append(("sched_hcmp_note", float(hc["devices"]),
                         hc["note"]))
    if "faults" in record:
        fl = record["faults"]
        for name in ("fault_free", "faulted"):
            a = fl[name]
            rows.append((f"sched_{name}", a["goodput_tok_s"],
                         f"goodput tok/s ({a['tok_s']:.1f} raw, "
                         f"states {a['states']}, {a['retries']} retried, "
                         f"pages drained {a['pages_drained']})"))
        rows.append(("sched_faults_goodput_ratio",
                     fl["goodput_ratio_faulted_vs_fault_free"],
                     f"x fault-free goodput under crash@"
                     f"{fl['crash_boundary']} + "
                     f"{fl['cancel_rate']:.0%} cancel + "
                     f"{fl['deadline_s']:.1f}s deadline "
                     f"({fl['replicas']} replicas)"))

    os.makedirs(RESULT_DIR, exist_ok=True)
    path = os.path.join(RESULT_DIR, "sched_bench.json")
    if (paged_only or faults_only or hcmp_only) and os.path.exists(path):
        # partial run: refresh only that section of the checked-in record
        with open(path) as f:
            full = json.load(f)
        key = "paged" if paged_only else \
            ("faults" if faults_only else "hcmp")
        full[key] = record[key]
        record = full
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    print(f"[sched_bench] wrote {path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--paged", action="store_true",
                    help="run ONLY the fixed-memory paged-vs-dense "
                         "comparison (CI smoke)")
    ap.add_argument("--faults", action="store_true",
                    help="run ONLY the fault-tolerance router comparison "
                         "(chaos smoke)")
    ap.add_argument("--hcmp", action="store_true",
                    help="run ONLY the hcmp inline-vs-overlap comparison "
                         "(two-device worker)")
    ap.add_argument("--worker", action="store_true")
    args = ap.parse_args()
    if sum((args.paged, args.faults, args.hcmp)) > 1:
        ap.error("--paged/--faults/--hcmp are mutually exclusive")
    if args.worker:
        bootstrap_worker_path()
        print(json.dumps(_worker(args.requests, args.chunk, args.reps,
                                 paged_only=args.paged,
                                 faults_only=args.faults,
                                 hcmp_only=args.hcmp)))
    else:
        run(args.requests, args.chunk, args.reps, paged_only=args.paged,
            faults_only=args.faults, hcmp_only=args.hcmp)
