"""Measured continuous-batching gain: static batches vs per-sequence
admission/eviction under staggered Poisson arrivals, on THIS machine.

Workload: N requests with a mixed token budget (alternating short/long —
the regime where static batching loses: a finished short request's row sits
idle until the whole group drains, while the continuous scheduler refills
it from the queue at the next chunk boundary).  Arrival times are a Poisson
process whose rate is calibrated against a measured warm static makespan,
so the stream is genuinely staggered (neither all-at-once nor fully idle)
at any machine speed.

Paged KV comparison (``record["paged"]``): at FIXED pool memory — the
paged pool's reservable slots round DOWN from what the dense B-row bank
holds, so the paged side never gets extra KV memory — a
double-width bank over the shared pool sustains a strictly larger resident
batch on the same mixed 16/192-budget burst, because short requests
reserve ~3 pages while only long ones reserve the dense row's worth.  The
section also asserts the donation wiring: after a chunk step the input
pool buffer must be DELETED (aliased in place) and exactly one pool-sized
buffer may be live — a ~2x pool-size peak fails the bench.
``--paged`` runs ONLY this comparison (the CI smoke).

Runs in a SUBPROCESS with XLA CPU intra-op threading pinned off, same
measurement contract as engine_bench (see that module's docstring).

  PYTHONPATH=src python benchmarks/sched_bench.py [--requests 32] [--paged]

Emits a JSON record to ``benchmarks/results/sched_bench.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))
from benchmarks.engine_bench import (RESULT_DIR, bootstrap_worker_path,
                                     spawn_pinned_worker)

BATCH = 8
PROMPT_LEN = 16
BUDGETS = (16, 192)           # alternating short/long generation budgets


def _sched_smoke_cfg():
    """Like engine_bench's smoke config but 2x wider: per-chunk device time
    has to dominate the per-admission dispatch overhead (B=1 prefill +
    row insert) or the bench measures Python, not scheduling."""
    import dataclasses

    from benchmarks.engine_bench import _engine_smoke_cfg
    return dataclasses.replace(_engine_smoke_cfg(),
                               name="qwen2-sched-smoke", d_model=256,
                               num_heads=4, num_kv_heads=4, d_ff=512)


def _requests(cfg, n, arrivals):
    import jax
    import numpy as np

    from repro.runtime.scheduler import Request
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (n, PROMPT_LEN), 0, cfg.vocab_size),
        np.int32)
    return [Request(req_id=i, tokens=prompts[i],
                    n_tokens=BUDGETS[i % len(BUDGETS)],
                    arrival=float(arrivals[i]))
            for i in range(n)]


def _best_of(fn, reps):
    """Highest-throughput run of ``reps`` (same contract as engine_bench's
    best-of-N timing: scheduling makespans on a busy 2-CPU container are
    noisy in one direction only — slowdowns)."""
    best = None
    for _ in range(reps):
        _, s = fn()
        if best is None or s["tok_s"] > best["tok_s"]:
            best = s
    return best


PAGE_SIZE = 16


def _paged_compare(cfg, model, params, heads, spec, max_len, n_requests,
                   chunk, reps) -> dict:
    """Fixed-memory paged-vs-dense resident-batch comparison + the
    in-place-update (donation) buffer check."""
    import jax
    import numpy as np

    from repro.runtime.engine import SpeculativeEngine, _eos_scalar
    from repro.runtime.scheduler import ContinuousScheduler

    # FIXED MEMORY: the pool's reservable slots round DOWN from the dense
    # BATCH-row bank's (never more KV memory than the baseline; the +1
    # trash page is bookkeeping, not reservable capacity); the paged bank
    # is twice as wide and lives off reservations
    pool_pages = (BATCH * max_len) // PAGE_SIZE
    paged_batch = 2 * BATCH
    dense = SpeculativeEngine(model, heads, params, spec, max_len=max_len,
                              chunk=chunk)
    paged = SpeculativeEngine(model, heads, params, spec, max_len=max_len,
                              chunk=chunk, paged=True, page_size=PAGE_SIZE,
                              pool_pages=pool_pages)

    # ---- donation buffer check: chunk updates the pool IN PLACE ----------
    row = paged.sched_prefill(
        {"tokens": np.zeros((1, PROMPT_LEN), np.int32)})
    state = paged.sched_blank(row, paged_batch)
    state = paged.sched_insert(state, 0, row, prompt_len=PROMPT_LEN,
                               n_tokens=BUDGETS[0])
    pool_before = state.cache.kv.pool_k
    pool_nbytes = pool_before.nbytes

    def n_pool_sized():
        return sum(1 for a in jax.live_arrays() if a.nbytes == pool_nbytes)

    jax.block_until_ready(pool_before)
    baseline = n_pool_sized()                    # the state's pool (+ any
    done = np.ones((paged_batch,), bool)         # coincidental constants)
    done[0] = False
    rem = np.zeros((paged_batch,), np.int32)
    rem[0] = BUDGETS[0]
    state, _, _, _ = paged.sched_step(state, done, rem, chunk,
                                      int(_eos_scalar(None)))
    jax.block_until_ready(state.cache.kv.pool_k)
    if not pool_before.is_deleted():
        raise AssertionError("chunk scan did not donate the KV pool "
                             "(per-chunk pool copy)")
    if n_pool_sized() > baseline:
        raise AssertionError(
            "extra pool-sized buffer live after a chunk (~2x pool peak) — "
            "donation/aliasing regressed")
    paged.sched_release(0)
    del state, row, pool_before

    # ---- resident-batch + throughput on the mixed-budget burst -----------
    zero = np.zeros(n_requests)
    for eng, b in ((dense, BATCH), (paged, paged_batch)):   # warm/compile
        ContinuousScheduler(eng, batch=b, chunk=chunk).serve(
            _requests(cfg, n_requests, zero))
    dn = _best_of(lambda: ContinuousScheduler(
        dense, batch=BATCH, chunk=chunk).serve(
            _requests(cfg, n_requests, zero)), reps)
    pg = _best_of(lambda: ContinuousScheduler(
        paged, batch=paged_batch, chunk=chunk).serve(
            _requests(cfg, n_requests, zero)), reps)
    if n_requests > BATCH and pg["max_resident"] <= dn["max_resident"]:
        raise AssertionError(
            f"paged resident batch {pg['max_resident']} not larger than "
            f"dense {dn['max_resident']} at fixed pool memory")
    return {
        "page_size": PAGE_SIZE, "pool_pages": pool_pages,
        "pool_slots": pool_pages * PAGE_SIZE,
        "dense_batch": BATCH, "paged_batch": paged_batch,
        "dense_max_resident": dn["max_resident"],
        "paged_max_resident": pg["max_resident"],
        "dense_tok_s": dn["tok_s"], "paged_tok_s": pg["tok_s"],
        "dense_makespan_s": dn["makespan_s"],
        "paged_makespan_s": pg["makespan_s"],
        "dense_latency_mean_s": dn["latency_mean_s"],
        "paged_latency_mean_s": pg["latency_mean_s"],
        "resident_gain": pg["max_resident"] / max(dn["max_resident"], 1),
        "speedup_paged_vs_dense": pg["tok_s"] / dn["tok_s"],
        "donation_in_place": True,
    }


def _worker(n_requests: int, chunk: int, reps: int,
            paged_only: bool = False) -> dict:
    import jax
    import numpy as np

    from repro.core.speculative import tree as T
    from repro.core.speculative.medusa import init_medusa
    from repro.models.api import get_model
    from repro.runtime.engine import BatchEngine, SpeculativeEngine
    from repro.runtime.scheduler import (ContinuousScheduler,
                                         poisson_arrivals, serve_static)

    cfg = _sched_smoke_cfg()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(1))
    spec = T.build_tree(T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 4)
    max_len = PROMPT_LEN + max(BUDGETS) + spec.max_depth

    if paged_only:
        return {"arch": cfg.name, "requests": n_requests, "chunk": chunk,
                "paged": _paged_compare(cfg, model, params, heads, spec,
                                        max_len, n_requests, chunk, reps)}

    engines = {
        "sequential": BatchEngine(model, params, max_len=max_len,
                                  chunk=chunk),
        "speculative": SpeculativeEngine(model, heads, params, spec,
                                         max_len=max_len, chunk=chunk),
    }
    record = {"arch": cfg.name, "requests": n_requests, "batch": BATCH,
              "chunk": chunk, "prompt_len": PROMPT_LEN,
              "budgets": list(BUDGETS), "grid": []}

    for name, eng in engines.items():
        zero = np.zeros(n_requests)
        # warm-up + compile both paths AND measure the warm static makespan
        serve_static(eng, _requests(cfg, n_requests, zero), batch=BATCH)
        ContinuousScheduler(eng, batch=BATCH, chunk=chunk).serve(
            _requests(cfg, n_requests, zero))
        _, warm = serve_static(eng, _requests(cfg, n_requests, zero),
                               batch=BATCH)
        # arrivals span ~35% of the warm static makespan: genuinely
        # staggered (static pays batch-formation waits) while the
        # continuous path stays decode-bound rather than arrival-starved
        rate = n_requests / (0.35 * warm["makespan_s"])
        arrivals = poisson_arrivals(n_requests, rate, seed=3)

        st = _best_of(lambda: serve_static(
            eng, _requests(cfg, n_requests, arrivals), batch=BATCH), reps)
        ct = _best_of(lambda: ContinuousScheduler(
            eng, batch=BATCH, chunk=chunk).serve(
                _requests(cfg, n_requests, arrivals)), reps)
        for sched, s in (("static", st), ("continuous", ct)):
            record["grid"].append({
                "engine": name, "sched": sched, "rate": rate,
                "tok_s": s["tok_s"], "makespan_s": s["makespan_s"],
                "emitted_total": s["emitted_total"],
                "latency_mean_s": s["latency_mean_s"],
                "latency_p90_s": s["latency_p90_s"],
                "queue_wait_mean_s": s["queue_wait_mean_s"]})
        record[f"speedup_continuous_vs_static_{name}"] = \
            ct["tok_s"] / st["tok_s"]
        record[f"latency_ratio_static_vs_continuous_{name}"] = \
            st["latency_mean_s"] / max(ct["latency_mean_s"], 1e-9)

    record["speedup_continuous_vs_static"] = min(
        record["speedup_continuous_vs_static_sequential"],
        record["speedup_continuous_vs_static_speculative"])
    record["paged"] = _paged_compare(cfg, model, params, heads, spec,
                                     max_len, n_requests, chunk, reps)
    return record


def run(n_requests=32, chunk=8, reps=2, paged_only=False) -> list:
    """Spawn the pinned-environment worker, persist + pretty-print results."""
    argv = ["--requests", str(n_requests), "--chunk", str(chunk),
            "--reps", str(reps)]
    if paged_only:
        argv.append("--paged")
    record = spawn_pinned_worker(__file__, argv)

    rows = []
    for g in record.get("grid", ()):
        name = f"sched_{g['sched'][:4]}_{g['engine'][:4]}_b{BATCH}"
        rows.append((name, 1e6 / g["tok_s"],
                     f"{g['tok_s']:.1f} tok/s agg, "
                     f"lat p90 {g['latency_p90_s']:.2f}s"))
    if "grid" in record:
        for eng in ("sequential", "speculative"):
            rows.append((f"sched_speedup_cont_vs_static_{eng[:4]}",
                         record[f"speedup_continuous_vs_static_{eng}"],
                         "x aggregate tok/s"))
            rows.append((f"sched_latencyx_static_vs_cont_{eng[:4]}",
                         record[f"latency_ratio_static_vs_continuous_{eng}"],
                         "x mean latency (higher = static worse)"))
    pg = record["paged"]
    rows.append(("sched_paged_resident_gain", pg["resident_gain"],
                 f"{pg['paged_max_resident']} vs "
                 f"{pg['dense_max_resident']} resident at "
                 f"{pg['pool_slots']} pool slots"))
    rows.append(("sched_paged_vs_dense_tok_s", pg["speedup_paged_vs_dense"],
                 f"{pg['paged_tok_s']:.1f} vs {pg['dense_tok_s']:.1f} "
                 "tok/s agg at fixed pool memory"))

    os.makedirs(RESULT_DIR, exist_ok=True)
    path = os.path.join(RESULT_DIR, "sched_bench.json")
    if paged_only and os.path.exists(path):
        # CI smoke: refresh only the paged section of the checked-in record
        with open(path) as f:
            full = json.load(f)
        full["paged"] = record["paged"]
        record = full
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    print(f"[sched_bench] wrote {path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--paged", action="store_true",
                    help="run ONLY the fixed-memory paged-vs-dense "
                         "comparison (CI smoke)")
    ap.add_argument("--worker", action="store_true")
    args = ap.parse_args()
    if args.worker:
        bootstrap_worker_path()
        print(json.dumps(_worker(args.requests, args.chunk, args.reps,
                                 paged_only=args.paged)))
    else:
        run(args.requests, args.chunk, args.reps, paged_only=args.paged)
