"""Fig. 10a analogue: static vs dynamic dense/sparse attention partitioning
as context grows (attention-module time at verification width 64).

Static  = all sparse work on CPU, all dense on GPU, boundary fixed.
Dynamic = ARCA re-balances the boundary per context length (the dense part's
left columns can move to whichever unit has slack — §III-B2 'each partition
may optionally include a portion of the other part').
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import arca
from repro.core.speculative import tree as T

CTXS = (128, 256, 512, 1024, 2048, 4096)
WIDTH = 64


def attn_times(soc, cfg, ctx, spec):
    wl = arca.decode_workload(cfg, WIDTH, ctx, spec)
    g, c = soc.gpu, soc.cpu
    t_static = max(wl.attn_dense_flops / (g.flops * g.gemm_eff),
                   wl.attn_sparse_flops / (c.flops * c.sparse_eff))
    # dynamic: move fraction x of dense work to the CPU to balance
    best = t_static
    for x in np.linspace(0, 0.4, 41):
        tg = wl.attn_dense_flops * (1 - x) / (g.flops * g.gemm_eff)
        tc = (wl.attn_sparse_flops / c.sparse_eff
              + wl.attn_dense_flops * x / c.gemm_eff) / c.flops
        best = min(best, max(tg, tc))
    return t_static, best


def run() -> list:
    cfg = get_config("vicuna-7b")
    soc = arca.JETSON_NX
    accs = T.default_accs(5, 10)
    spec = T.build_tree(accs, WIDTH)
    print("ctx     static(ms)  dynamic(ms)  gain")
    gains = []
    for ctx in CTXS:
        ts, td = attn_times(soc, cfg, ctx, spec)
        gains.append(ts / td)
        print(f"{ctx:6d} {ts*1e3:10.3f} {td*1e3:11.3f}  {ts/td:5.2f}x")
    print(f"# dynamic gain grows with context: {gains[0]:.2f}x @128 -> "
          f"{gains[-1]:.2f}x @4096 (paper Fig10a: 'obvious improvements at "
          f"large context lengths')")
    return [("fig10a_dynamic_gain_ctx128", gains[0], "small ctx"),
            ("fig10a_dynamic_gain_ctx4096", gains[-1], "large ctx")]


if __name__ == "__main__":
    run()
