"""Table I reproduction: acceptance length under given verification widths.

We cannot ship Vicuna-7B + trained Medusa heads, so the *head-accuracy
table* is fitted (3 scalars: a1, head-decay, rank-decay) to the paper's
MT-bench row; the tree-construction machinery (greedy + brute-force) and the
acceptance-length estimator are then exercised exactly as the paper does,
and the remaining three dataset rows are compared as held-out targets
(the paper itself transfers MT-bench trees to them).

Real measured acceptance (trained tiny Medusa model, no fit anywhere) is
produced by examples/e2e_train_serve.py and tests/test_system.py.
"""
from __future__ import annotations

import numpy as np

from repro.core.speculative import tree as T

WIDTHS = (1, 2, 4, 8, 16, 32, 64)

# Paper Table I
PAPER = {
    "MT-bench":   [1, 1.72, 2.28, 2.59, 2.93, 3.19, 3.34],
    "GSM8K":      [1, 1.76, 2.43, 2.69, 3.08, 3.34, 3.56],
    "MBPP":       [1, 1.78, 2.54, 2.89, 3.27, 3.55, 3.74],
    "Human-eval": [1, 1.77, 2.49, 2.80, 3.19, 3.48, 3.71],
}


def estimator_curve(accs, refine=False) -> list:
    out = []
    for w in WIDTHS:
        spec = (T.spec_from_nodes([(-1, 0, 0)]) if w == 1
                else T.build_tree(accs, w, refine=refine))
        out.append(T.expected_acceptance_length(spec, accs))
    return out


def fit_accs(target=None, H=5, K=10):
    """Least-squares fit of (a1, head_decay, rank_decay) to an AL row.
    Greedy-only trees inside the search (greedy is estimator-optimal, so
    refinement cannot change the fit); coarse-to-fine grid."""
    target = np.asarray(target if target is not None else PAPER["MT-bench"])

    def err_of(a1, hd, rd):
        accs = T.default_accs(H, K, a1, hd, rd)
        cur = np.asarray(estimator_curve(accs, refine=False))
        return float(np.mean((cur - target) ** 2))

    best, best_err = (0.7, 0.8, 0.4), np.inf
    for a1 in np.linspace(0.55, 0.85, 7):
        for hd in np.linspace(0.55, 0.95, 5):
            for rd in np.linspace(0.15, 0.6, 6):
                e = err_of(a1, hd, rd)
                if e < best_err:
                    best, best_err = (a1, hd, rd), e
    # local refinement around the coarse optimum
    a1, hd, rd = best
    for da in np.linspace(-0.03, 0.03, 5):
        for dh in np.linspace(-0.06, 0.06, 5):
            for dr in np.linspace(-0.06, 0.06, 5):
                e = err_of(a1 + da, hd + dh, rd + dr)
                if e < best_err:
                    best, best_err = (a1 + da, hd + dh, rd + dr), e
    return T.default_accs(H, K, *best), best, best_err


def run() -> list:
    accs, params, err = fit_accs()
    ours = estimator_curve(accs)
    rows = []
    print(f"# fitted accs: a1={params[0]:.3f} head_decay={params[1]:.3f} "
          f"rank_decay={params[2]:.3f} (mse {err:.4f})")
    print("width  " + "  ".join(f"{w:>5d}" for w in WIDTHS))
    print("ours   " + "  ".join(f"{a:5.2f}" for a in ours))
    for ds, row in PAPER.items():
        rel = np.abs(np.asarray(ours) - np.asarray(row)) / np.asarray(row)
        print(f"{ds:10s} " + "  ".join(f"{a:5.2f}" for a in row)
              + f"   max rel dev {rel.max()*100:.1f}%")
        rows.append((ds, float(rel.max())))
    return [("acceptance_table1_fit_mse", err,
             f"a1={params[0]:.3f},hd={params[1]:.3f},rd={params[2]:.3f}"),
            ("acceptance_table1_maxdev_mtbench", rows[0][1], "held-in"),
            ("acceptance_table1_maxdev_mbpp", rows[2][1], "held-out")]


if __name__ == "__main__":
    run()
