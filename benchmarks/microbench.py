"""Micro-benchmarks: jitted step latencies at smoke scale on CPU (regression
tracking; not TPU predictions) — one speculative step vs one sequential step.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.core.speculative.verify import spec_prefill, spec_step
from repro.models.api import get_model


def _bench(f, *args, reps=10):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6     # us


def run() -> list:
    cfg = get_config("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0,
                              cfg.vocab_size)
    rows = []

    _, _, cache = model.prefill(params, {"tokens": toks}, max_len=128)
    dec = jax.jit(lambda p, c, t: model.decode(p, c, t))
    us = _bench(lambda: dec(params, cache, toks[:, :1]))
    rows.append(("decode_step_smoke", us, "1 token"))

    spec = T.build_tree(T.default_accs(4, 4), 16)
    tr = T.Tree.from_spec(spec)
    st = spec_prefill(model, params, heads, {"tokens": toks}, max_len=128)
    step = jax.jit(lambda p, h, s: spec_step(model, p, h, tr, s))
    us = _bench(lambda: step(params, heads, st))
    rows.append(("spec_step_w16_smoke", us, "verify 16 nodes"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
