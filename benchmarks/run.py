"""Benchmark harness — one function per paper table/figure.

  Table I   -> acceptance.run()     (verification-tree acceptance lengths)
  Fig 9     -> throughput.run()     (4 systems x widths, calibrated Jetson sim)
  Fig 10a   -> partitioning.run()   (static vs dynamic attention partitioning)
  Fig 10b   -> sparse.run()         (tree-sparse kernel strategies)
  §Roofline -> roofline.main()      (from dry-run artifacts, if present)
  micro     -> microbench.run()     (jitted step latencies, CPU smoke scale)

Prints ``name,us_per_call,derived`` CSV at the end.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    rows = []
    from benchmarks import acceptance, microbench, partitioning, sparse, \
        throughput

    print("=" * 70); print("## Table I — acceptance length vs width")
    rows += acceptance.run()
    print("=" * 70); print("## Fig 9 — decoding throughput (Jetson sim)")
    rows += throughput.run()
    print("=" * 70); print("## Fig 10a — dynamic partitioning")
    rows += partitioning.run()
    print("=" * 70); print("## Fig 10b — sparse strategies")
    rows += sparse.run()
    print("=" * 70); print("## micro — step latencies (CPU smoke)")
    rows += microbench.run()

    from benchmarks import engine_bench
    print("=" * 70); print("## engine — measured tokens/sec "
                           "(batch x chunk, CPU smoke)")
    rows += engine_bench.run(n_tokens=32)

    from benchmarks import sched_bench
    print("=" * 70); print("## sched — continuous vs static batching "
                           "(poisson arrivals, CPU smoke)")
    rows += sched_bench.run()

    from benchmarks import ablations
    print("=" * 70); print("## ablations (beyond paper)")
    rows += ablations.run()

    from benchmarks import roofline
    try:
        tb = roofline.table()
        if tb:
            print("=" * 70); print("## Roofline (from dry-run artifacts)")
            print(roofline.render_markdown(tb))
            ok = [r for r in tb if r.get("status") == "ok"]
            rows.append(("roofline_cases_ok", float(len(ok)),
                         f"of {len(tb)}"))
        kvn = roofline.int8_kv_note()
        rows.append(("roofline_int8_kv_bytes_reduction", kvn["reduction"],
                     f"{kvn['arch']} ps={kvn['page_size']}"))
    except Exception as e:  # dry-run artifacts may not exist yet
        print(f"## Roofline skipped: {e}")

    print("=" * 70)
    print("name,us_per_call,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.4f},{derived}")


if __name__ == "__main__":
    main()
