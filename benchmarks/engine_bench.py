"""Measured engine throughput: sequential vs speculative decoding across
batch sizes B x chunk depths K, on THIS machine (CPU container smoke scale).

First *measured* record of the BENCH trajectory: the chunked device-resident
driver (K speculative steps per host sync) and batched speculative decode
(per-sequence acceptance lengths) vs the seed's B=1 per-step Python loop.

The ``trained`` record is the realistic arm (ROADMAP item): the base model
and Medusa heads are e2e-trained on the Markov corpus (training/train.py,
fixed seeds), the verification tree is built from MEASURED per-head
accuracies (core/speculative/medusa.py ``head_accuracies``), and the
recorded tokens/sec is acceptance-weighted by a real AL > 1 instead of the
random-heads AL ~= 1 the grid measures.  The worker asserts the trained
acceptance beats random — the arm is meaningless otherwise.

Measurement environment: the grid runs in a SUBPROCESS with XLA CPU
intra-op threading pinned off — on the 2-core container the thread-handoff
cost exceeds the parallel gain at smoke shapes and adds ~2x noise (measured;
see CHANGES.md PR 1), so pinning makes runs comparable across PRs.  The
model is an "engine-smoke" config (d=128, 2 layers) chosen so that engine
overheads — host syncs, dispatch, cache writes — are the measured quantity
rather than GEMM time; a 2-CPU box cannot expose memory-bandwidth batching
gains, so aggregate scale-up numbers here are a floor, not the TPU story.

  PYTHONPATH=src python benchmarks/engine_bench.py [--tokens 64]

Emits a JSON record to ``benchmarks/results/engine_bench.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

BATCHES = (1, 4, 8)
CHUNKS = (1, 8)
RESULT_DIR = os.path.join(os.path.dirname(__file__), "results")
_WORKER_ENV = {
    "XLA_FLAGS": "--xla_cpu_multi_thread_eigen=false "
                 "intra_op_parallelism_threads=1",
    "JAX_PLATFORMS": "cpu",
}


# the hcmp arm's worker: same pinned contract plus a second XLA host
# device, so the disaggregated draft/verify executors get real device
# objects (the flag must be set before the subprocess initializes jax)
_HCMP_DEV_FLAG = "--xla_force_host_platform_device_count=2"


def spawn_pinned_worker(script: str, argv: list,
                        extra_xla_flags: str = "") -> dict:
    """Run ``script --worker *argv`` in the pinned measurement environment
    (single-thread XLA CPU, src + repo root on PYTHONPATH) and return its
    JSON record.  Shared by every bench that measures in a subprocess so
    the environment contract cannot drift between them."""
    env = dict(os.environ)
    env.update(_WORKER_ENV)
    if extra_xla_flags:
        # PREPEND: the pinned env ends with a bare (non --xla) token that
        # terminates XLA's flag parsing — flags appended after it are
        # silently ignored
        env["XLA_FLAGS"] = f"{extra_xla_flags} {env['XLA_FLAGS']}"
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root \
        + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, os.path.abspath(script), "--worker"] + argv,
        capture_output=True, text=True, env=env, timeout=1800)
    if out.returncode != 0:
        name = os.path.basename(script)
        raise RuntimeError(f"{name} worker failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def bootstrap_worker_path():
    """sys.path setup for the subprocess side of a --worker entry point."""
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(root, "src"))
    sys.path.insert(0, root)


def _engine_smoke_cfg():
    import dataclasses

    from repro.configs import get_config
    return dataclasses.replace(
        get_config("qwen2-0.5b").reduced(),
        name="qwen2-engine-smoke", d_model=128, num_heads=2, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=256)


def _time(fn, reps=3):
    fn()                                     # warm-up (compile)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _trained_arm(cfg, model, n_tokens, reps, steps, head_steps) -> dict:
    """e2e-train base + heads on the Markov corpus, build the tree from
    MEASURED head accuracies, and record the acceptance-weighted tokens/sec
    the random-heads grid cannot show (AL ~= 1 there)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.speculative import tree as T
    from repro.core.speculative.medusa import head_accuracies, init_medusa
    from repro.data.pipeline import MarkovDataset
    from repro.runtime.engine import SpeculativeEngine
    from repro.training.optimizer import adamw_init
    from repro.training.train import medusa_step, train_step

    data = MarkovDataset(cfg.vocab_size, seed=1)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(lambda p, o, b: train_step(cfg, model, p, o, b, lr=1e-3))
    for batch in data.batches(8, 64, steps):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, _ = step(params, opt, b)
    heads = init_medusa(cfg, jax.random.PRNGKey(1))
    hopt = adamw_init(heads)
    hstep = jax.jit(lambda h, o, b: medusa_step(cfg, model, params, h, o, b))
    for batch in data.batches(8, 64, head_steps, seed=500):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        heads, hopt, _ = hstep(heads, hopt, b)

    accs = head_accuracies(
        cfg, model, params, heads,
        (data.sample(8, 96, seed=100 + s)[:, :-1] for s in range(3)))
    spec = T.build_tree(accs, 4)
    max_len = 16 + n_tokens + spec.max_depth
    prompt = {"tokens": jnp.asarray(
        data.sample(1, 16, seed=7)[:, :-1].astype(np.int32))}
    eng = SpeculativeEngine(model, heads, params, spec, max_len=max_len,
                            chunk=8)
    _, st = eng.generate(prompt, n_tokens)       # warm + acceptance
    t = _time(lambda: eng.generate(prompt, n_tokens), reps)
    return {"train_steps": steps, "head_steps": head_steps,
            "tree_width": 4,
            "accs_top1": [round(float(x), 4) for x in accs[:, 0]],
            "acceptance": st["acceptance_length"],
            "tok_s_b1_k8": n_tokens / t}


def _hcmp_worker(n_tokens: int, reps: int) -> dict:
    """hcmp arm, in its OWN pinned subprocess with two XLA host devices:
    inline (fused chunk scan) vs overlap (disaggregated draft/verify
    executors, core/hcmp/executors.py) tokens/sec, the bit-identity gate,
    and ARCA's measured partition choice (``profile_engine`` timing both
    layouts through ``time_step(..., hcmp=...)``)."""
    import jax
    import numpy as np

    from repro.core import arca
    from repro.core.speculative import tree as T
    from repro.core.speculative.medusa import init_medusa
    from repro.models.api import get_model
    from repro.runtime.engine import SpeculativeEngine

    cfg = _engine_smoke_cfg()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(1))
    accs = T.default_accs(cfg.medusa_heads, cfg.medusa_top_k)
    spec = T.build_tree(accs, 4)
    max_len = 16 + n_tokens + spec.max_depth
    out = {"devices": len(jax.devices()), "tree_width": 4, "chunk": 8,
           "host_cores": os.cpu_count(), "grid": []}
    for B in (1, 4):
        prompt = {"tokens": jax.random.randint(
            jax.random.PRNGKey(2), (B, 16), 0, cfg.vocab_size)}
        inline = SpeculativeEngine(model, heads, params, spec,
                                   max_len=max_len, chunk=8)
        overlap = SpeculativeEngine(model, heads, params, spec,
                                    max_len=max_len, chunk=8,
                                    hcmp="overlap")
        out_i, _ = inline.generate(prompt, n_tokens)
        out_o, _ = overlap.generate(prompt, n_tokens)
        if not np.array_equal(np.asarray(out_i), np.asarray(out_o)):
            raise AssertionError(
                f"overlap diverged from inline at B={B} — the arm is "
                f"meaningless without bit-identity")
        t_i = _time(lambda: inline.generate(prompt, n_tokens), reps)
        t_o = _time(lambda: overlap.generate(prompt, n_tokens), reps)
        # ARCA's view of the same choice: time_step under both partitions
        tf = arca.profile_engine(overlap, accs=accs, batch=B,
                                 prompt_len=16, reps=reps)
        part = tf.partition_for(spec)
        key = (spec.width, spec.max_depth, spec.n_paths, B)
        hs = overlap.hcmp_stats
        out["grid"].append({
            "B": B, "inline_tok_s": B * n_tokens / t_i,
            "overlap_tok_s": B * n_tokens / t_o,
            "speedup_overlap_vs_inline": t_i / t_o,
            "arca_partition": part,
            "arca_step_inline_s": tf.times[key + ("inline",)],
            "arca_step_overlap_s": tf.times[key + ("overlap",)],
            "predraft_hits": hs["predraft_hits"],
            "predraft_discards": hs["predraft_discards"]})
    if all(g["speedup_overlap_vs_inline"] <= 1.0 for g in out["grid"]):
        # honest annotation, not a failure: with every visible core
        # shared by both executor devices the draft(t+1)/commit(t)
        # window buys no wall time — the arm still pins the parity-safe
        # schedule and records ARCA picking the measured winner
        out["note"] = (
            f"overlap did not beat inline on this container "
            f"({out['host_cores']} visible core(s), {out['devices']} XLA "
            f"host device(s) sharing them): the measurement is "
            f"compute-bound, so the overlap window adds dispatch cost "
            f"without freeing wall time; ARCA's measured partition "
            f"choice reflects exactly that")
    return out


def _worker(n_tokens: int, reps: int, train_steps: int = 120,
            head_steps: int = 80) -> dict:
    """Runs inside the pinned subprocess; returns the JSON record."""
    import jax
    import numpy as np

    from repro.core.speculative import tree as T
    from repro.core.speculative.medusa import init_medusa
    from repro.models.api import get_model
    from repro.runtime.engine import BatchEngine, SpeculativeEngine
    from repro.runtime.sampling import greedy

    cfg = _engine_smoke_cfg()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    heads = init_medusa(cfg, jax.random.PRNGKey(1))
    spec = T.build_tree(T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 4)
    # 16-token prompts + budget + one speculative step of overshoot (the
    # budget-aware chunk driver stops each sequence within max_depth tokens
    # of its budget, so this is the exact worst case, not a guess)
    max_len = 16 + n_tokens + spec.max_depth

    record = {"arch": cfg.name, "n_tokens": n_tokens, "tree_width": 4,
              "grid": []}
    prompts = {
        B: {"tokens": jax.random.randint(jax.random.PRNGKey(2), (B, 16), 0,
                                         cfg.vocab_size)}
        for B in BATCHES
    }

    # the seed's per-step Python sequential loop (pre-chunking baseline)
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(lambda p, c, t: model.decode(p, c, t))

    def legacy(batch, n):
        logits, _, cache = prefill(params, batch)
        cur = greedy(logits[:, -1])
        out = [np.asarray(cur)]
        for _ in range(n - 1):
            lg, cache = decode(params, cache, cur[:, None])
            cur = greedy(lg[:, 0])
            cur.block_until_ready()
            out.append(np.asarray(cur))
        return np.stack(out, axis=1)

    t_legacy = _time(lambda: legacy(prompts[1], n_tokens), reps)
    record["legacy_seq_b1_tok_s"] = n_tokens / t_legacy

    for B in BATCHES:
        for K in CHUNKS:
            seq = BatchEngine(model, params, max_len=max_len, chunk=K)
            t = _time(lambda: seq.generate(prompts[B], n_tokens), reps)
            record["grid"].append({"engine": "sequential", "B": B, "K": K,
                                   "tok_s": B * n_tokens / t})

            eng = SpeculativeEngine(model, heads, params, spec,
                                    max_len=max_len, chunk=K)
            _, stats = eng.generate(prompts[B], n_tokens)
            t = _time(lambda: eng.generate(prompts[B], n_tokens), reps)
            record["grid"].append({"engine": "speculative", "B": B, "K": K,
                                   "tok_s": B * n_tokens / t,
                                   "acceptance": stats["acceptance_length"]})

    def _tok_s(engine, B, K):
        return next(g["tok_s"] for g in record["grid"]
                    if (g["engine"], g["B"], g["K"]) == (engine, B, K))

    record["speedup_spec_k8_vs_legacy_b1"] = \
        _tok_s("speculative", 1, 8) / record["legacy_seq_b1_tok_s"]
    record["scaleup_spec_b8_vs_b1_k8"] = \
        _tok_s("speculative", 8, 8) / _tok_s("speculative", 1, 8)
    # batched + chunked engine vs what the seed engine could do (B=1,
    # per-step cadence) — the serving-shaped end-to-end gain this PR adds
    record["speedup_spec_b8k8_vs_seed_b1k1"] = \
        _tok_s("speculative", 8, 8) / _tok_s("speculative", 1, 1)

    # ---- trained-heads arm (realistic acceptance-weighted tok/s) ---------
    trained = _trained_arm(cfg, model, n_tokens, reps, train_steps,
                           head_steps)
    rand_al = next(g["acceptance"] for g in record["grid"]
                   if (g["engine"], g["B"], g["K"]) == ("speculative", 1, 8))
    trained["acceptance_random_heads"] = rand_al
    trained["speedup_vs_random_heads_b1_k8"] = \
        trained["tok_s_b1_k8"] / _tok_s("speculative", 1, 8)
    if trained["acceptance"] <= rand_al:
        raise AssertionError(
            f"trained heads did not beat random acceptance "
            f"({trained['acceptance']:.2f} <= {rand_al:.2f})")
    record["trained"] = trained
    return record


def run(n_tokens=64, reps=3, train_steps=120, head_steps=80) -> list:
    """Spawn the pinned-environment worker, persist + pretty-print results."""
    record = spawn_pinned_worker(__file__, ["--tokens", str(n_tokens),
                                            "--reps", str(reps),
                                            "--train-steps",
                                            str(train_steps),
                                            "--head-steps", str(head_steps)])
    # hcmp arm: its own subprocess — the second XLA host device can only
    # be requested before the backend initializes
    record["hcmp"] = spawn_pinned_worker(
        __file__, ["--tokens", str(n_tokens), "--reps", str(reps),
                   "--hcmp-arm"], extra_xla_flags=_HCMP_DEV_FLAG)

    rows = [("engine_legacy_seq_b1", 1e6 / record["legacy_seq_b1_tok_s"],
             f"{record['legacy_seq_b1_tok_s']:.1f} tok/s")]
    for g in record["grid"]:
        name = f"engine_{g['engine'][:4]}_b{g['B']}_k{g['K']}"
        derived = f"{g['tok_s']:.1f} tok/s agg"
        if "acceptance" in g:
            derived += f", AL={g['acceptance']:.2f}"
        rows.append((name, 1e6 / g["tok_s"], derived))
    rows.append(("engine_speedup_spec_k8_vs_legacy",
                 record["speedup_spec_k8_vs_legacy_b1"], "x vs per-step loop"))
    rows.append(("engine_scaleup_spec_b8_vs_b1",
                 record["scaleup_spec_b8_vs_b1_k8"], "x aggregate (2-CPU box)"))
    rows.append(("engine_speedup_b8k8_vs_seed",
                 record["speedup_spec_b8k8_vs_seed_b1k1"],
                 "x vs seed B=1 per-step engine"))
    tr = record["trained"]
    rows.append(("engine_trained_heads_b1_k8", 1e6 / tr["tok_s_b1_k8"],
                 f"{tr['tok_s_b1_k8']:.1f} tok/s, AL={tr['acceptance']:.2f} "
                 f"(random AL={tr['acceptance_random_heads']:.2f})"))
    rows.append(("engine_trained_vs_random_heads",
                 tr["speedup_vs_random_heads_b1_k8"],
                 "x tok/s vs random-heads arm (e2e-trained Medusa heads)"))
    hc = record["hcmp"]
    for g in hc["grid"]:
        rows.append((f"engine_hcmp_overlap_b{g['B']}_k8",
                     g["speedup_overlap_vs_inline"],
                     f"x inline ({g['overlap_tok_s']:.1f} vs "
                     f"{g['inline_tok_s']:.1f} tok/s, "
                     f"{hc['devices']} devices, arca picks "
                     f"{g['arca_partition']})"))
    if "note" in hc:
        rows.append(("engine_hcmp_note", float(hc["devices"]),
                     hc["note"]))

    os.makedirs(RESULT_DIR, exist_ok=True)
    path = os.path.join(RESULT_DIR, "engine_bench.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    for name, val, derived in rows:
        print(f"{name},{val:.1f},{derived}")
    print(f"[engine_bench] wrote {path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--train-steps", type=int, default=120,
                    help="base-LM steps for the trained-heads arm")
    ap.add_argument("--head-steps", type=int, default=80,
                    help="Medusa-head steps for the trained-heads arm")
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--hcmp-arm", action="store_true",
                    help="(worker-internal) run only the hcmp "
                         "inline-vs-overlap arm")
    args = ap.parse_args()
    if args.worker:
        bootstrap_worker_path()
        if args.hcmp_arm:
            print(json.dumps(_hcmp_worker(args.tokens, args.reps)))
        else:
            print(json.dumps(_worker(args.tokens, args.reps,
                                     args.train_steps, args.head_steps)))
    else:
        run(args.tokens, args.reps, args.train_steps, args.head_steps)
