"""Fig. 10b analogue: strategies for the tree-sparse attention component.

The paper compares (ARM CPU): naive COO sparse vs optimized COO SpMM vs
dense-with-mask.  On TPU the comparison becomes (DESIGN.md §2):

  dense-with-mask  — attend the W tree tokens against (cache + tree) as one
                     dense masked matmul (what cloud systems do),
  block-masked     — our Pallas sparse_tree kernel: tree part computed as a
                     VMEM-resident WxW masked block, dense part untouched,
  naive            — per-element gather/FMA oracle (the scalar-COO port that
                     does NOT fit the MXU; here to show WHY it non-transfers).

We report FLOPs + bytes (structural, hardware-independent) and CPU
wall-clock of the jitted forms (labelled: CPU time is NOT a TPU prediction).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.speculative import tree as T
from repro.kernels.ref import sparse_tree_ref
from repro.kernels.sparse_tree import sparse_tree_attention


def _naive_coo(q, k, v, mask):
    """Scalar-style COO reference: loop over nonzeros via masked gather —
    deliberately non-vectorized math (einsum-free inner ops)."""
    W = q.shape[1]
    scale = q.shape[-1] ** -0.5
    rows, cols = np.nonzero(np.asarray(mask))
    out_s = jnp.full(q.shape[:1] + (q.shape[2], W, W), -1e30, jnp.float32)
    qf = jnp.swapaxes(q.astype(jnp.float32), 1, 2)      # (B,H,W,hd)
    kf = jnp.swapaxes(k.astype(jnp.float32), 1, 2)
    g = q.shape[2] // k.shape[2]
    for r, c in zip(rows.tolist(), cols.tolist()):
        s = jnp.sum(qf[:, :, r] * jnp.repeat(kf, g, 1)[:, :, c], -1) * scale
        out_s = out_s.at[:, :, r, c].set(s)
    p = jax.nn.softmax(out_s, -1)
    vf = jnp.repeat(jnp.swapaxes(v.astype(jnp.float32), 1, 2), g, 1)
    o = jnp.einsum("bhrc,bhcd->bhrd", p, vf)
    return jnp.swapaxes(o, 1, 2)


def _time(f, *args, reps=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def run(width=64, ctx=256, H=32, Hkv=8, hd=128) -> list:
    accs = T.default_accs(5, 10)
    spec = T.build_tree(accs, width)
    mask = jnp.asarray(spec.mask)
    nnz = int(spec.mask.sum())
    B = 1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, width, H, hd), jnp.float32)
    kn = jax.random.normal(ks[1], (B, width, Hkv, hd), jnp.float32)
    vn = jax.random.normal(ks[2], (B, width, Hkv, hd), jnp.float32)

    # structural terms
    dense_flops = 2 * 2 * width * (ctx + width) * H * hd
    block_flops = 2 * 2 * width * width * H * hd      # block-masked tree part
    coo_flops = 2 * 2 * nnz * H * hd                  # true nnz work
    print(f"# W={width} nnz={nnz}/{width*width} "
          f"dense-with-mask(ctx+tree)={dense_flops/1e6:.1f}MF "
          f"block-masked={block_flops/1e6:.1f}MF true-sparse={coo_flops/1e6:.1f}MF")

    t_block = _time(lambda: sparse_tree_attention(q, kn, vn, mask))
    t_densemask = _time(lambda: jax.jit(sparse_tree_ref)(q, kn, vn,
                                                         jnp.ones_like(mask) & mask))
    t_naive = _time(lambda: _naive_coo(q, kn, vn, mask), reps=1)
    print(f"# CPU wall (NOT a TPU prediction): block={t_block*1e3:.2f}ms "
          f"dense-masked={t_densemask*1e3:.2f}ms naive-coo={t_naive*1e3:.1f}ms")
    print(f"# naive/block = {t_naive/t_block:.2f}x (paper: optimized sparse "
          f"3.49x over naive); tree-part FLOP saving vs dense-over-everything "
          f"= {dense_flops/block_flops:.2f}x")
    return [("fig10b_block_kernel_ms", t_block * 1e3, "cpu-interpret"),
            ("fig10b_naive_over_block", t_naive / t_block, "paper=3.49"),
            ("fig10b_flops_saving", dense_flops / block_flops,
             f"nnz={nnz}")]


if __name__ == "__main__":
    run()
