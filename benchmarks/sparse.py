"""Fig. 10b analogue: strategies for the tree-sparse attention component.

The paper compares (ARM CPU): naive COO sparse vs optimized COO SpMM vs
dense-with-mask.  On TPU the comparison becomes (DESIGN.md §2):

  dense-with-mask  — attend the W tree tokens against (cache + tree) as one
                     dense masked matmul (what cloud systems do),
  block-masked     — our Pallas sparse_tree kernel: tree part computed as a
                     VMEM-resident WxW masked block, dense part untouched,
  naive            — per-element gather/FMA oracle (the scalar-COO port that
                     does NOT fit the MXU; here to show WHY it non-transfers).

We report FLOPs + bytes (structural, hardware-independent) and CPU
wall-clock of the jitted forms (labelled: CPU time is NOT a TPU prediction).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.speculative import tree as T
from repro.kernels import ops as kops
from repro.kernels.ref import sparse_tree_ref
from repro.kernels.sparse_tree import sparse_tree_attention
from repro.models import common as cm


def _naive_coo(q, k, v, mask):
    """Scalar-style COO reference: loop over nonzeros via masked gather —
    deliberately non-vectorized math (einsum-free inner ops)."""
    W = q.shape[1]
    scale = q.shape[-1] ** -0.5
    rows, cols = np.nonzero(np.asarray(mask))
    out_s = jnp.full(q.shape[:1] + (q.shape[2], W, W), -1e30, jnp.float32)
    qf = jnp.swapaxes(q.astype(jnp.float32), 1, 2)      # (B,H,W,hd)
    kf = jnp.swapaxes(k.astype(jnp.float32), 1, 2)
    g = q.shape[2] // k.shape[2]
    for r, c in zip(rows.tolist(), cols.tolist()):
        s = jnp.sum(qf[:, :, r] * jnp.repeat(kf, g, 1)[:, :, c], -1) * scale
        out_s = out_s.at[:, :, r, c].set(s)
    p = jax.nn.softmax(out_s, -1)
    vf = jnp.repeat(jnp.swapaxes(v.astype(jnp.float32), 1, 2), g, 1)
    o = jnp.einsum("bhrc,bhcd->bhrd", p, vf)
    return jnp.swapaxes(o, 1, 2)


def _time(f, *args, reps=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def run(width=64, ctx=256, H=32, Hkv=8, hd=128) -> list:
    accs = T.default_accs(5, 10)
    spec = T.build_tree(accs, width)
    mask = jnp.asarray(spec.mask)
    nnz = int(spec.mask.sum())
    B = 1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, width, H, hd), jnp.float32)
    kn = jax.random.normal(ks[1], (B, width, Hkv, hd), jnp.float32)
    vn = jax.random.normal(ks[2], (B, width, Hkv, hd), jnp.float32)

    # structural terms
    dense_flops = 2 * 2 * width * (ctx + width) * H * hd
    block_flops = 2 * 2 * width * width * H * hd      # block-masked tree part
    coo_flops = 2 * 2 * nnz * H * hd                  # true nnz work
    print(f"# W={width} nnz={nnz}/{width*width} "
          f"dense-with-mask(ctx+tree)={dense_flops/1e6:.1f}MF "
          f"block-masked={block_flops/1e6:.1f}MF true-sparse={coo_flops/1e6:.1f}MF")

    t_block = _time(lambda: sparse_tree_attention(q, kn, vn, mask))
    t_densemask = _time(lambda: jax.jit(sparse_tree_ref)(q, kn, vn,
                                                         jnp.ones_like(mask) & mask))
    t_naive = _time(lambda: _naive_coo(q, kn, vn, mask), reps=1)
    print(f"# CPU wall (NOT a TPU prediction): block={t_block*1e3:.2f}ms "
          f"dense-masked={t_densemask*1e3:.2f}ms naive-coo={t_naive*1e3:.1f}ms")
    print(f"# naive/block = {t_naive/t_block:.2f}x (paper: optimized sparse "
          f"3.49x over naive); tree-part FLOP saving vs dense-over-everything "
          f"= {dense_flops/block_flops:.2f}x")
    return [("fig10b_block_kernel_ms", t_block * 1e3, "cpu-interpret"),
            ("fig10b_naive_over_block", t_naive / t_block, "paper=3.49"),
            ("fig10b_flops_saving", dense_flops / block_flops,
             f"nnz={nnz}")] + run_int8(width=width, mask=mask, q=q,
                                       kn=kn, vn=vn, ctx=ctx, Hkv=Hkv,
                                       hd=hd)


def run_int8(*, width, mask, q, kn, vn, ctx, Hkv, hd) -> list:
    """int8 arm of the verify-path comparison: the fused fp32 paged walk
    vs the fused int8 (dequant-in-kernel) walk vs the split int8 page walk
    + block-masked tree kernel (``tree_kernel=sparse``).

    Cache-side BYTES are the structural story (an edge decode step is
    bandwidth-bound on the KV read, paper §II): int8 pages move 4x fewer
    pool bytes per step; wall-clock is CPU interpret-mode, labelled as
    such.  Parity is asserted against the fp32 fused walk inside the
    run (max|Δ| must sit under the documented quantization bound)."""
    from repro.runtime.cache import init_kv_cache, page_bytes, paginate_cache
    from repro.runtime.cache import Cache as _Cache
    B = q.shape[0]
    ps = 16
    n_pages = (ctx + ps - 1) // ps
    # one resident sequence of ctx tokens, paginated at both pool dtypes
    k_ctx = jax.random.normal(jax.random.PRNGKey(9), (1, B, ctx, Hkv, hd),
                              jnp.float32)
    v_ctx = jax.random.normal(jax.random.PRNGKey(10), (1, B, ctx, Hkv, hd),
                              jnp.float32)
    dense = init_kv_cache(1, B, ctx, Hkv, hd)
    dense = type(dense)(k=k_ctx, v=v_ctx,
                        key_pos=jnp.broadcast_to(jnp.arange(ctx), (B, ctx)),
                        pos=jnp.full((B,), ctx, jnp.int32), window=0)
    tables = jnp.broadcast_to(jnp.arange(n_pages, dtype=jnp.int32),
                              (B, n_pages))
    paged32 = paginate_cache(_Cache(kv=dense), tables, page_size=ps,
                             n_pages=n_pages).kv
    paged8 = paginate_cache(_Cache(kv=dense), tables, page_size=ps,
                            n_pages=n_pages, kv_dtype=jnp.int8).kv
    depth = jnp.zeros((width,), jnp.int32)     # flat tree at pos=ctx

    def fused(kv):
        return kops.paged_tree_attention(
            q, kv.pool_k[0], kv.pool_v[0], kn, vn, kv.block_table,
            kv.key_pos, kv.pos, depth, mask,
            scale_k=None if kv.scale_k is None else kv.scale_k[0],
            scale_v=None if kv.scale_v is None else kv.scale_v[0])

    def split(kv):
        cache_part = kops.paged_cache_attention(
            q, kv.pool_k[0], kv.pool_v[0], kv.block_table, kv.key_pos,
            kv.pos, depth, scale_k=kv.scale_k[0], scale_v=kv.scale_v[0])
        tree_part = kops.sparse_tree_attention_partial(q, kn, vn, mask)
        return cm.merge_partials([cache_part, tree_part])

    o32 = fused(paged32)
    o8 = fused(paged8)
    o8s = split(paged8)
    err_fused = float(jnp.max(jnp.abs(o8 - o32)))
    err_split = float(jnp.max(jnp.abs(o8s - o32)))
    assert err_fused < 3e-2 and err_split < 3e-2, (err_fused, err_split)

    t32 = _time(lambda: fused(paged32))
    t8 = _time(lambda: fused(paged8))
    t8s = _time(lambda: split(paged8))
    by32 = n_pages * page_bytes(1, ps, Hkv, hd, jnp.float32)
    by8 = n_pages * page_bytes(1, ps, Hkv, hd, jnp.int8)
    print(f"# int8 verify arm (ctx={ctx}, W={width}): cache bytes/step "
          f"fp32={by32} int8={by8} ({by32/by8:.2f}x fewer); max|err| "
          f"fused={err_fused:.2e} split={err_split:.2e}")
    print(f"# CPU wall (NOT a TPU prediction): fused-fp32={t32*1e3:.2f}ms "
          f"fused-int8={t8*1e3:.2f}ms split-int8={t8s*1e3:.2f}ms")
    return [("int8_cache_bytes_reduction", by32 / by8, f"ctx={ctx}"),
            ("int8_fused_err_vs_fp32", err_fused, "bound 3e-2"),
            ("int8_split_err_vs_fp32", err_split, "tree_kernel=sparse"),
            ("int8_fused_walk_ms", t8 * 1e3, "cpu-interpret"),
            ("int8_split_walk_ms", t8s * 1e3, "cpu-interpret")]


if __name__ == "__main__":
    run()
