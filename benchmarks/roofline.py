"""§Roofline: derive compute / memory / collective terms per (arch × shape ×
mesh) from the dry-run artifacts in results/dryrun/.

  compute    = HLO_FLOPs_per_dev / peak_FLOPs
  memory     = HLO_bytes_per_dev / HBM_bw
  collective = collective_bytes_per_dev / ICI_bw

plus MODEL_FLOPS = 6·N·D (train) or 2·N_active·tokens (serve) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import INPUT_SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops_per_device(rec) -> float:
    shape = INPUT_SHAPES[rec["shape"]]
    n_act = rec["model_params_active"]
    dev = rec["n_devices"]
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_act * tokens / dev
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_act * tokens / dev
    tokens = shape.global_batch            # decode: 1 new token per sample
    return 2.0 * n_act * tokens / dev


def load(results_dir=None, mesh="single", mode="hcmp", variant="baseline"):
    rows = []
    for f in sorted(glob.glob(os.path.join(results_dir or RESULTS, "*.json"))):
        rec = json.load(open(f))
        if rec.get("mesh") != mesh or rec.get("mode") != mode:
            continue
        if rec.get("variant", "baseline") != variant:
            continue
        rows.append(rec)
    return rows


def analyse(rec) -> dict:
    if rec["status"] != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "status": rec["status"]}
    corr = rec.get("corrected")
    if corr:                    # scan-trip-count-corrected costs (preferred)
        flops, hbytes = corr["flops"], corr["hlo_bytes_accessed"]
        cbytes = corr["collective_total"]
    else:
        flops, hbytes = rec["flops"], rec["hlo_bytes_accessed"]
        cbytes = rec["collectives"]["total"]
    t_c = flops / PEAK_FLOPS_BF16
    t_m = hbytes / HBM_BW
    t_x = cbytes / ICI_BW
    bound = max([(t_c, "compute"), (t_m, "memory"), (t_x, "collective")])[1]
    mf = model_flops_per_device(rec)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "bound": bound, "step_s": max(t_c, t_m, t_x),
        "model_flops": mf, "useful_ratio": mf / max(flops, 1.0),
        "peak_gb_per_dev": rec["memory"]["peak_bytes"] / 1e9,
    }


def table(mesh="single", mode="hcmp", results_dir=None) -> list:
    return [analyse(r) for r in load(results_dir, mesh, mode)]


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "bound | useful FLOP ratio | peak GB/dev |\n"
           "|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.3f} | "
            f"{r['memory_s']*1e3:.3f} | {r['collective_s']*1e3:.3f} | "
            f"**{r['bound']}** | {min(r['useful_ratio'],9.99):.2f} | "
            f"{r['peak_gb_per_dev']:.2f} |\n")
    return "".join(out)


def int8_kv_note(arch="qwen2-0.5b", page_size=16) -> dict:
    """Structural bytes-reduction note for the decode roofline: an edge
    decode step is memory-bound on the KV-cache read (paper §II, and the
    ``bound`` column above for the decode shapes), so quantized int8 pages
    — which move ~4x fewer pool bytes per attended token, per-page scale
    overhead included (runtime/cache.py ``page_bytes``) — shift the decode
    memory term by the same factor.  No dry-run artifact is needed: the
    term is per-token cache traffic, a pure shape computation.
    """
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.runtime.cache import kv_bytes_per_token
    cfg = get_config(arch)
    b32 = kv_bytes_per_token(cfg.num_layers, cfg.num_kv_heads,
                             cfg.head_dim, jnp.float32, page_size)
    b8 = kv_bytes_per_token(cfg.num_layers, cfg.num_kv_heads,
                            cfg.head_dim, jnp.int8, page_size)
    return {"arch": arch, "page_size": page_size,
            "kv_bytes_per_token_fp32": b32, "kv_bytes_per_token_int8": b8,
            "reduction": b32 / b8}


def main():
    rows = table()
    print(render_markdown(rows))
    n = int8_kv_note()
    print(f"\nint8 KV pages ({n['arch']}, ps={n['page_size']}): "
          f"{n['kv_bytes_per_token_fp32']:.0f} -> "
          f"{n['kv_bytes_per_token_int8']:.0f} cache bytes/token "
          f"({n['reduction']:.2f}x less decode KV traffic)")


if __name__ == "__main__":
    main()
