"""Quickstart: build a model from the registry, prefill, decode, and run one
Ghidorah speculative step.

  PYTHONPATH=src python examples/quickstart.py [--arch qwen2-0.5b-smoke]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.core.speculative.verify import spec_prefill, spec_step
from repro.models.api import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b-smoke")
    args = ap.parse_args()

    print("registry:", ", ".join(list_archs()))
    cfg = get_config(args.arch)
    model = get_model(cfg)
    print(f"\n{cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"{cfg.num_heads}H(kv={cfg.num_kv_heads}) "
          f"{cfg.param_count()/1e6:.1f}M params ({model.family})")

    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                              cfg.vocab_size)

    # 1. prefill
    logits, extras, cache = model.prefill(params, {"tokens": toks},
                                          max_len=128)
    print(f"prefill: logits {logits.shape}")

    # 2. sequential decode
    cur = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    for i in range(4):
        lg, cache = model.decode(params, cache, cur)
        cur = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
        print(f"decode step {i}: token {int(cur[0,0])}")

    # 3. one speculative step (width-8 verification tree)
    heads = init_medusa(cfg, jax.random.PRNGKey(2))
    spec = T.build_tree(T.default_accs(cfg.medusa_heads, cfg.medusa_top_k), 8)
    tr = T.Tree.from_spec(spec)
    state = spec_prefill(model, params, heads, {"tokens": toks}, max_len=128)
    state, emitted, n = spec_step(model, params, heads, tr, state)
    print(f"speculative step: verified 8 tree nodes, "
          f"accepted {int(n[0])} token(s): "
          f"{[int(t) for t in emitted[0][:int(n[0])]]}")


if __name__ == "__main__":
    main()
