"""End-to-end driver (the paper's pipeline at laptop scale):

  1. train a small LM (~15M params) on a structured synthetic corpus,
  2. train Medusa drafting heads on the frozen base model,
  3. ARCA: measure REAL per-head top-k accuracies on calibration data,
     build verification trees per width, pick the deployment strategy,
  4. serve: sequential vs Ghidorah speculative decoding; report measured
     acceptance length (the real Table-I analogue) and wall-clock speedup.

  PYTHONPATH=src python examples/e2e_train_serve.py [--steps 200]
"""
import argparse
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import arca
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import head_accuracies, init_medusa
from repro.data.pipeline import MarkovDataset
from repro.models.api import get_model
from repro.runtime.engine import BatchEngine, SpeculativeEngine
from repro.training.optimizer import adamw_init
from repro.training.train import medusa_step, train_step


def measure_head_accuracies(cfg, model, params, heads, data, n_batches=4,
                            seq=128):
    """Real per-head top-k accuracy table (core/speculative/medusa.py
    ``head_accuracies`` over sampled calibration batches)."""
    return head_accuracies(
        cfg, model, params, heads,
        (data.sample(8, seq, seed=100 + s)[:, :-1] for s in range(n_batches)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--head-steps", type=int, default=150)
    ap.add_argument("--tokens", type=int, default=96)
    args = ap.parse_args()

    cfg = get_config("qwen2-0.5b").reduced()
    model = get_model(cfg)
    data = MarkovDataset(cfg.vocab_size, seed=1)

    # ---- 1. base model training ------------------------------------
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(lambda p, o, b: train_step(cfg, model, p, o, b, lr=1e-3))
    print(f"[1/4] training base model ({cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps)")
    for i, batch in enumerate(data.batches(8, 64, args.steps)):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, b)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"  step {i:4d} ce={float(m['ce']):.3f}")

    # ---- 2. Medusa heads (base frozen) -------------------------------
    heads = init_medusa(cfg, jax.random.PRNGKey(1))
    hopt = adamw_init(heads)
    hstep = jax.jit(lambda h, o, b: medusa_step(cfg, model, params, h, o, b))
    print(f"[2/4] training {cfg.medusa_heads} Medusa heads "
          f"({args.head_steps} steps, base frozen)")
    for i, batch in enumerate(data.batches(8, 64, args.head_steps, seed=500)):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        heads, hopt, m = hstep(heads, hopt, b)
        if i % 50 == 0 or i == args.head_steps - 1:
            print(f"  step {i:4d} head-loss={float(m['loss']):.3f}")

    # ---- 3. ARCA: real accuracies -> trees -> MEASURED strategy -------
    print("[3/4] ARCA: head accuracies + measured step times (this machine)")
    accs = measure_head_accuracies(cfg, model, params, heads, data)
    print("  top-1 accuracy per head:", np.round(accs[:, 0], 3).tolist())
    cal_prompt = {"tokens": jnp.asarray(
        data.sample(1, 32, seed=777)[:, :-1].astype(np.int32))}
    best_w, best_thr, chosen = None, 0.0, None
    for w in (2, 4, 8, 16, 32):
        spec = T.build_tree(accs, w)
        eng = SpeculativeEngine(model, heads, params, spec, max_len=256)
        eng.generate(cal_prompt, 48)                      # warm-up (compile)
        out, st = eng.generate(cal_prompt, 48)            # measure
        t = float(np.sum(st["step_times"]))               # per-CHUNK times
        thr = len(out) / t
        print(f"  W={w:3d}: E[AL]={T.expected_acceptance_length(spec, accs):.2f} "
              f"measured AL={st['acceptance_length']:.2f} "
              f"thr={thr:.1f} tok/s")
        if thr > best_thr:
            best_w, best_thr, chosen = w, thr, spec
    print(f"  ARCA chose width={best_w} (measured-throughput mode)")

    # ---- 4. serve: sequential vs Ghidorah ---------------------------
    print(f"[4/4] serving {args.tokens} tokens")
    prompt = {"tokens": jnp.asarray(
        data.sample(1, 32, seed=999)[:, :-1].astype(np.int32))}
    max_len = 32 + args.tokens + 8

    seq_eng = BatchEngine(model, params, max_len=max_len)
    out_seq, _ = seq_eng.generate(prompt, args.tokens)       # warm + result
    t0 = time.perf_counter()
    out_seq, _ = seq_eng.generate(prompt, args.tokens)
    t_seq = time.perf_counter() - t0

    spec_eng = SpeculativeEngine(model, heads, params, chosen,
                                 max_len=max_len)
    out_spec, stats = spec_eng.generate(prompt, args.tokens)
    t0 = time.perf_counter()
    out_spec, stats = spec_eng.generate(prompt, args.tokens)
    t_spec = time.perf_counter() - t0

    match = np.array_equal(out_spec[:args.tokens], out_seq[0][:args.tokens])
    print(f"  sequential: {args.tokens/t_seq:7.1f} tok/s")
    print(f"  ghidorah:   {args.tokens/t_spec:7.1f} tok/s  "
          f"(REAL acceptance length {stats['acceptance_length']:.2f}, "
          f"{stats['steps']} steps)")
    print(f"  lossless: {match}; wall speedup {t_seq/t_spec:.2f}x "
          f"(CPU smoke scale — algorithmic gain; HCMP parallel gain needs "
          f"the pod)")
    assert match, "speculative output diverged from sequential!"


if __name__ == "__main__":
    main()
