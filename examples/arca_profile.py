"""ARCA profiling walkthrough (paper §III-C, Fig. 8): tree construction,
width selection, contention-aware partitioning — on the calibrated Jetson
simulator AND the TPU roofline (from dry-run artifacts when present).

  PYTHONPATH=src python examples/arca_profile.py [--arch vicuna-7b]
"""
import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config
from repro.core import arca
from repro.core.speculative import tree as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vicuna-7b")
    ap.add_argument("--ctx", type=int, default=256)
    args = ap.parse_args()
    cfg = get_config(args.arch)

    accs = T.default_accs(cfg.medusa_heads, cfg.medusa_top_k)
    print(f"== verification-tree construction (width 16, Fig. 8) ==")
    greedy = T.build_tree_greedy(accs, 16)
    refined = T.refine_tree(greedy, accs)
    print(f"greedy  E[AL] = {T.expected_acceptance_length(greedy, accs):.3f}")
    print(f"refined E[AL] = {T.expected_acceptance_length(refined, accs):.3f}")
    print("node (parent, depth, rank):")
    for i in range(refined.width):
        print(f"  n{i:02d} <- p{refined.parent[i]:02d} "
              f"d{refined.depth[i]} r{refined.rank[i]}")

    print(f"\n== strategy table ({args.arch}, ctx={args.ctx}, Jetson sim) ==")
    strats = arca.choose_strategy(cfg, accs, ctx=args.ctx)
    seq_t = arca.step_time_sequential(arca.JETSON_NX, cfg, args.ctx)
    for w, s in strats.items():
        print(f"W={w:3d} E[AL]={s.acceptance:5.2f} ratio={s.ratio:.3f} "
              f"step={s.step_time*1e3:7.1f}ms thr={s.throughput:6.2f} tok/s "
              f"({s.throughput*seq_t:4.2f}x)")
    print(f"ARCA deployment choice: width={arca.best(strats).width}")

    # TPU roofline source, if the dry-run artifacts exist
    res = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    hits = sorted(glob.glob(os.path.join(res, f"{args.arch}__decode*single*json")))
    if hits:
        rec = json.load(open(hits[0]))
        if rec["status"] == "ok":
            r = arca.roofline_time(rec["flops"], rec["hlo_bytes_accessed"],
                                   rec["collectives"]["total"])
            print(f"\n== TPU roofline ({rec['shape']}, 256 chips) ==")
            print(f"compute {r['compute_s']*1e6:.1f}us  "
                  f"memory {r['memory_s']*1e6:.1f}us  "
                  f"collective {r['collective_s']*1e6:.1f}us -> "
                  f"bound: {r['bound']}")


if __name__ == "__main__":
    main()
