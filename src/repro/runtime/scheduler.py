"""Continuous-batching scheduler: iteration-level admission/eviction on top
of the chunked engines (Orca-style scheduling, vLLM-style slot reuse).

The engines decode a fixed bank of B rows device-resident, K steps per host
sync.  This module turns those rows into *slots* a request stream flows
through:

  queue --admit--> slot b --chunks--> done --evict--> slot b free --admit-->

Slot lifecycle
--------------
* **admit** (chunk boundary, row free, request arrived): the prompt is
  prefilled at B=1 and the row is spliced into the resident state with the
  engine's jitted ``sched_insert`` (``cache.insert_rows``: per-row KV /
  recurrent-state write, ``pos[b]`` and ``key_pos[b]`` taken from the fresh
  prefill, done-mask cleared).  The compiled K-step scan never changes —
  admission is pure data movement, so the chunk driver is reused across the
  whole request stream.
* **decode**: every chunk runs the full bank; free/finished rows ride along
  masked by the scan's done-mask (no emission, no commit) and cost no extra
  compilation.  Chunk length is clamped to the largest remaining budget
  (power-of-two schedule, bounded compile cache).
* **evict** (chunk boundary, row done): EOS, per-request token budget, or
  KV-capacity freeze ends a sequence; its outputs are finalized and the row
  is freed.  If a queued request takes the slot at the same boundary the
  admission insert overwrites the whole row (it copies every slot of the
  fresh B=1 prefill, ``key_pos`` included); rows that stay empty are
  cleared in one batched ``sched_reset`` (``cache.reset_rows``:
  ``key_pos`` -> -1, ``pos`` -> 0, state zeroed).  With the speculative
  engine the reset is durable — masked rows commit nothing, so no stale
  KV/state outlives its request.  ``BatchEngine``'s chunk body decodes
  every row unconditionally, so a freed row re-accumulates masked scratch
  (derived from the dead request's last token) until the next admission
  overwrites it; its emission stays masked throughout.

Capacity semantics: a request whose prompt+budget exceed the engine's
``max_len`` is not rejected — the chunk driver freezes it at the capacity
boundary (see runtime/engine.py) and it returns fewer tokens, reported via
``RequestResult.n_emitted``.

Paged engines add a reservation step: admission asks ``sched_can_admit``
whether the page pool can fund ``ceil((prompt + budget + overshoot) /
page_size)`` pages and DEFERS the request (FIFO head-of-line) while it
cannot; eviction returns the row's pages via ``sched_release`` before the
device-side reset, so a freed reservation funds the same boundary's
admissions.  Pool exhaustion therefore shows up as queueing delay, never
as a failed or corrupted request.

Arrivals are wall-clock: a request is admissible once ``arrival`` seconds
(relative to ``serve()`` entry) have elapsed, which is how ``serve.py
--arrivals poisson`` and ``benchmarks/sched_bench.py`` replay traces.
``serve_static`` is the baseline the bench compares against: requests are
grouped into fixed batches in arrival order, each batch runs to completion
(its rows cannot be refilled) before the next one starts.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional, Sequence

import numpy as np

from repro.runtime.engine import _eos_scalar, _pow2_chunk


@dataclasses.dataclass
class Request:
    """One generation request in the replayed stream."""
    req_id: int
    tokens: np.ndarray           # (S,) int32 prompt
    n_tokens: int                # generation budget (includes first token)
    arrival: float = 0.0         # seconds after serve() start


@dataclasses.dataclass
class RequestResult:
    req_id: int
    tokens: np.ndarray           # real emitted tokens (length n_emitted)
    n_emitted: int
    arrival: float
    t_admit: float               # when the request got a slot
    t_finish: float              # when its outputs were finalized

    @property
    def latency(self) -> float:
        return self.t_finish - self.arrival

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.arrival


def _aggregate(results: Sequence[RequestResult], makespan: float) -> dict:
    lats = np.asarray([r.latency for r in results])
    total = int(sum(r.n_emitted for r in results))
    return {
        "requests": len(results),
        "makespan_s": makespan,
        "emitted_total": total,
        "tok_s": total / makespan if makespan > 0 else float("inf"),
        "latency_mean_s": float(lats.mean()) if lats.size else 0.0,
        "latency_p50_s": float(np.percentile(lats, 50)) if lats.size else 0.0,
        "latency_p90_s": float(np.percentile(lats, 90)) if lats.size else 0.0,
        "queue_wait_mean_s": float(np.mean([r.queue_wait for r in results]))
        if results else 0.0,
    }


class ContinuousScheduler:
    """Per-sequence admission/eviction over an engine's B-row slot bank.

    Works with any engine implementing the slot protocol
    (``sched_prefill`` / ``sched_blank`` / ``sched_insert`` /
    ``sched_reset`` / ``sched_step`` / ``sched_emitted`` plus the paged
    reservation hooks ``sched_can_admit`` / ``sched_release`` — both
    ``BatchEngine`` and ``SpeculativeEngine`` do).
    """

    def __init__(self, engine, *, batch: int = 8,
                 chunk: Optional[int] = None):
        self.engine = engine
        self.batch = batch
        self.chunk = chunk or engine.chunk
        # introspection for tests / debugging, populated by serve()
        self.last_state = None
        self.events: List[tuple] = []

    def serve(self, requests: Sequence[Request], *, eos: Optional[int] = None
              ) -> tuple:
        """Replay ``requests`` (admitting each no earlier than its arrival)
        and return ``(results, stats)`` with results in request order."""
        eng, B = self.engine, self.batch
        eos_val = int(_eos_scalar(eos))
        queue = deque(sorted(requests, key=lambda r: (r.arrival, r.req_id)))
        slots: list = [None] * B          # per-row {req, out, t_admit}
        done_np = np.ones((B,), bool)     # free rows are masked done
        rem_np = np.zeros((B,), np.int32)
        state = None
        results = {}
        self.events = []
        max_resident = 0
        chunks = 0
        dirty = set()                     # evicted rows not yet reset
        t0 = time.perf_counter()

        def now():
            return time.perf_counter() - t0

        while queue or any(s is not None for s in slots):
            # ---- admit arrived requests into free rows (FIFO) ------------
            for b in range(B):
                if slots[b] is not None or not queue:
                    continue
                if queue[0].arrival > now():
                    break
                if state is not None and not eng.sched_can_admit(
                        len(queue[0].tokens), queue[0].n_tokens):
                    # page pool exhausted: DEFER (FIFO head-of-line) until
                    # evictions return pages; an empty bank always admits
                    # (a request larger than the whole pool gets the whole
                    # pool and freezes with a shortfall, it is never lost).
                    # The bootstrap admission is NOT gated: sched_blank
                    # rebuilds the allocator, so a depleted allocator left
                    # by an aborted earlier run cannot wedge a fresh serve
                    break
                req = queue.popleft()
                prompt = np.asarray(req.tokens, np.int32)[None]
                if state is None:         # bootstrap the bank once
                    row = eng.sched_prefill({"tokens": prompt})
                    state = eng.sched_blank(row, B)
                    state = eng.sched_insert(state, b, row,
                                             prompt_len=prompt.shape[1],
                                             n_tokens=req.n_tokens)
                    first = eng.sched_first(row)
                else:                     # ONE fused prefill+insert dispatch
                    state, first = eng.sched_admit(state, b,
                                                   {"tokens": prompt},
                                                   n_tokens=req.n_tokens)
                dirty.discard(b)          # insert overwrote the whole row
                # `first` may be an unsynced device scalar — only force it
                # when EOS filtering needs the value now
                slots[b] = {"req": req, "out": [first], "t": now()}
                done_np[b] = eos is not None and int(first) == eos_val
                rem_np[b] = max(req.n_tokens - 1, 0)
                self.events.append(("admit", req.req_id, b))
            if dirty:                     # rows left empty: one batched reset
                state = eng.sched_reset(state, sorted(dirty))
                dirty.clear()
            occupied = [b for b in range(B) if slots[b] is not None]
            max_resident = max(max_resident, len(occupied))
            if not occupied:
                if not queue:
                    break
                wait = queue[0].arrival - now()
                if wait > 0:
                    time.sleep(wait)
                continue

            # ---- run one chunk over the whole bank -----------------------
            live = [b for b in occupied if not done_np[b] and rem_np[b] > 0]
            if live:
                K = _pow2_chunk(self.chunk, int(rem_np[live].max()))
                state, done, rem, raw = eng.sched_step(
                    state, done_np, rem_np, K, eos_val)
                done_np = np.asarray(done).copy()
                rem_np = np.asarray(rem).copy()
                per_row = eng.sched_emitted(raw)
                chunks += 1
                for b in occupied:
                    slots[b]["out"].extend(per_row[b])

            # ---- evict finished rows (EOS / budget / capacity freeze) ----
            for b in occupied:
                s = slots[b]
                budget = s["req"].n_tokens
                if not (done_np[b] or rem_np[b] <= 0
                        or len(s["out"]) >= budget):
                    continue
                kept = s["out"][:budget]
                results[s["req"].req_id] = RequestResult(
                    req_id=s["req"].req_id,
                    tokens=np.asarray(kept, np.int32),
                    n_emitted=len(kept),
                    arrival=s["req"].arrival,
                    t_admit=s["t"], t_finish=now())
                eng.sched_release(b)      # paged: pages back to the pool NOW
                dirty.add(b)              # reset lazily unless re-admitted
                slots[b] = None
                done_np[b] = True
                rem_np[b] = 0
                self.events.append(("evict", s["req"].req_id, b))

        if dirty and state is not None:   # final evictions: leave rows clean
            state = eng.sched_reset(state, sorted(dirty))
            dirty.clear()
        makespan = now()
        self.last_state = state
        ordered = [results[r.req_id] for r in requests]
        stats = _aggregate(ordered, makespan)
        stats.update(admitted=len(ordered), chunks=chunks,
                     max_resident=max_resident, batch=B, chunk=self.chunk)
        return ordered, stats


def serve_static(engine, requests: Sequence[Request], *, batch: int = 8,
                 eos: Optional[int] = None) -> tuple:
    """Static-batching baseline: fixed groups of ``batch`` requests in
    arrival order; a group prefills only after ALL its members have arrived
    (batch formation) and runs until EVERY member finishes (per-sequence
    budgets mask early finishers, but their rows cannot be reused), then the
    next group starts.  Prompts within a group must share one length."""
    reqs = sorted(requests, key=lambda r: (r.arrival, r.req_id))
    results = {}
    t0 = time.perf_counter()

    def now():
        return time.perf_counter() - t0

    for i in range(0, len(reqs), batch):
        group = reqs[i:i + batch]
        wait = max(r.arrival for r in group) - now()
        if wait > 0:
            time.sleep(wait)
        prompts = np.stack([np.asarray(r.tokens, np.int32) for r in group])
        budgets = np.asarray([r.n_tokens for r in group], np.int32)
        t_admit = now()
        out, stats = engine.generate({"tokens": prompts}, budgets, eos=eos)
        if out.ndim == 1:                     # B=1 tail group
            out = out[None]
        t_fin = now()
        for j, r in enumerate(group):
            n = int(stats["n_emitted"][j])
            results[r.req_id] = RequestResult(
                req_id=r.req_id, tokens=out[j, :n].copy(), n_emitted=n,
                arrival=r.arrival, t_admit=t_admit, t_finish=t_fin)

    makespan = now()
    ordered = [results[r.req_id] for r in requests]
    stats = _aggregate(ordered, makespan)
    stats.update(batch=batch)
    return ordered, stats


def poisson_arrivals(n: int, rate: float, *, seed: int = 0) -> np.ndarray:
    """Cumulative Poisson-process arrival times (rate = requests/second)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))
