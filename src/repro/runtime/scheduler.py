"""Continuous-batching scheduler: iteration-level admission/eviction on top
of the chunked engines (Orca-style scheduling, vLLM-style slot reuse).

The engines decode a fixed bank of B rows device-resident, K steps per host
sync.  This module turns those rows into *slots* a request stream flows
through:

  queue --admit--> slot b --chunks--> done --evict--> slot b free --admit-->

Slot lifecycle
--------------
* **admit** (chunk boundary, row free, request arrived): the prompt is
  prefilled at B=1 and the row is spliced into the resident state with the
  engine's jitted ``sched_insert`` (``cache.insert_rows``: per-row KV /
  recurrent-state write, ``pos[b]`` and ``key_pos[b]`` taken from the fresh
  prefill, done-mask cleared).  The compiled K-step scan never changes —
  admission is pure data movement, so the chunk driver is reused across the
  whole request stream.
* **decode**: every chunk runs the full bank; free/finished rows ride along
  masked by the scan's done-mask (no emission, no commit) and cost no extra
  compilation.  Chunk length is clamped to the largest remaining budget
  (power-of-two schedule, bounded compile cache).
* **evict** (chunk boundary, row done): EOS, per-request token budget, or
  KV-capacity freeze ends a sequence; its outputs are finalized and the row
  is freed.  If a queued request takes the slot at the same boundary the
  admission insert overwrites the whole row (it copies every slot of the
  fresh B=1 prefill, ``key_pos`` included); rows that stay empty are
  cleared in one batched ``sched_reset`` (``cache.reset_rows``:
  ``key_pos`` -> -1, ``pos`` -> 0, state zeroed).  With the speculative
  engine the reset is durable — masked rows commit nothing, so no stale
  KV/state outlives its request.  ``BatchEngine``'s chunk body decodes
  every row unconditionally, so a freed row re-accumulates masked scratch
  (derived from the dead request's last token) until the next admission
  overwrites it; its emission stays masked throughout.

Capacity semantics: a request whose prompt+budget exceed the engine's
``max_len`` is not rejected — the chunk driver freezes it at the capacity
boundary (see runtime/engine.py) and it returns fewer tokens, reported via
``RequestResult.n_emitted``.

Paged engines add a reservation step: admission asks ``sched_can_admit``
whether the page pool can fund ``ceil((prompt + budget + overshoot) /
page_size)`` pages and DEFERS the request while it cannot; eviction
returns the row's pages via ``sched_release`` before the device-side
reset, so a freed reservation funds the same boundary's admissions.  Pool
exhaustion therefore shows up as queueing delay, never as a failed or
corrupted request.

Admission policies
------------------
*Which* queued request a freed row takes is a pluggable
``AdmissionPolicy`` (``policy=`` — ``"fifo"`` default, ``"sjf"``,
``"lpt"``, or any object with the ``pick`` protocol):

* **fifo** — strict arrival order; a request the pool cannot fund blocks
  everything behind it (head-of-line).  Bit-compatible with the pre-policy
  scheduler: same requests, same engine calls, same outputs.
* **sjf** — shortest job first by ``engine.sched_footprint`` (reserved
  pages when paged, else slots): among ARRIVED requests, the smallest one
  the pool can fund is admitted, skipping past a deferred head-of-line
  request.  Cuts queueing delay for the short-budget bulk of a mixed
  trace.  CAVEAT: SJF is starvation-prone — a stream of small requests
  can postpone a large one indefinitely; it never *loses* the large
  request (every policy admits it once the bank drains, because an empty
  bank always funds the pool's worth), but its latency is unbounded under
  sustained load.  FIFO remains the fairness-preserving default.
* **lpt** — longest footprint first (reverse of SJF): packs big
  reservations early; same skip-past-deferred rule, same starvation
  caveat with the roles reversed.

``age_limit=N`` (0 = off) bounds SJF/LPT starvation: every boundary an
ARRIVED request is PASSED OVER — another request admitted past it, or a
free row left empty because its own reservation could not be funded —
increments its ``age`` (waiting behind a full bank ages nobody, so
ordinary saturation never triggers the bound); once ``age >= age_limit``
the oldest such request is promoted to FIFO-HEAD priority — the
size-ordered ranking is suspended and, exactly like FIFO, nothing may be
admitted past the starved request while its reservation cannot be funded
(skipping past it is what made the starvation unbounded).  A deferred
request is therefore passed over at most ``age_limit`` times before it
gets FIFO's own worst case.  FIFO ignores ``age_limit`` (strict arrival
order cannot starve).

Per-request OUTPUT is policy-independent: a policy only reorders
admission; decode math is untouched (the fuzz suite pins per-request
parity with solo B=1 runs across policies).

Chunked prefill
---------------
``prefill_chunk=N`` (0 = off) admits a long prompt PIECEWISE instead of
in one prompt-sized prefill dispatch (Sarathi/vLLM-style chunked prefill):

* admission inserts only the first N prompt tokens (the normal fused
  ``sched_admit``, reservation sized to the WHOLE prompt via
  ``reserve_len``), and the row joins the bank done-masked;
* each following chunk boundary runs ``engine.sched_extend`` once per
  prefilling row: the next N-token piece is pushed through the causal
  verify path against the row's resident cache and spliced in at the
  row's offset (``cache.write_row_at``) — paged pieces are paginated
  incrementally, so the paged path's dense prefill transient is bounded
  by the piece size, never the prompt;
* the LAST piece's final logits produce the request's first token and the
  row goes live (``done`` cleared, budget armed) — from then on the slot
  is indistinguishable from a whole-prompt admission.

The resident bank keeps decoding between pieces, so one long prompt no
longer stalls every resident sequence for a prompt-sized dispatch.  Only
attention-family engines support it (``engine.sched_chunked_ok``);
recurrent families and prompts <= N fall back to whole-prompt admission.

Adaptive speculation
--------------------
``adaptive=`` arms runtime strategy selection over a ``DecodeEngine``
bank (measured ARCA, paper §III-C run *online* instead of once at
startup — the Dovetail observation that the best width moves with the
workload).  Pass the ``{width: arca.Strategy}`` table that
``arca.choose_strategy`` returns — ideally with the MEASURED ``time_fn``
from ``arca.profile_engine`` — or a pre-built ``AdaptiveSpeculation``.
The scheduler then:

* tracks a windowed EMA of the acceptance length actually observed on the
  bank (per-step accepted counts from the chunk raw, free rows excluded);
* at an eviction/admission boundary, rescales every candidate width's
  ESTIMATED acceptance by the observed/estimated ratio of the active
  width (width 1 stays exactly 1) and switches the bank's strategy when
  the ``AL / step_time`` argmax moves (``engine.set_strategy``);
* logs every switch as a ``("switch", from_width, to_width)`` event and
  in ``stats["strategy_switches"]``.

Switching is output-neutral: greedy tree verification commits exactly the
greedy chain whatever the tree, so a mid-request width change alters speed,
never tokens (the strategy-parity tests pin this).  Candidate strategies
are registered with the engine up front (``register_strategies``), which
buckets them for compile-cache reuse and ratchets the paged reservation
overshoot to the deepest candidate tree.

Request lifecycle
-----------------
Every request moves through a typed state machine::

    QUEUED -> PREFILLING -> DECODING -> { DONE, CANCELLED, TIMED_OUT,
                                          FAILED, REJECTED }

``serve()`` only ever produces DONE, but the scheduler also runs as a
*stepping* core for the async front end (``runtime/server.py``):
``start()`` / ``submit()`` / ``abort()`` / ``boundary()`` / ``finish()``
expose one admit/chunk/evict iteration at a time, and ``serve()`` is a
thin loop over them (the fuzz suite pins bit-identical outputs).  A
client cancellation (``abort(req_id)``) or an expired per-request
``deadline`` takes effect at the NEXT chunk boundary: the request's
partial tokens are finalized with a typed terminal state and — the core
robustness change — the row's reserved pages go back to the pool
mid-flight via ``engine.sched_abort`` (releasing a live row is safe
because the allocator is host state and the row is reset, clearing its
block table, before any later chunk can touch the freed pages; an
admission at the SAME boundary may therefore fund itself from the
aborted row's reservation).  ``fail_all()`` is the replica-crash cleanup:
every in-flight and queued request is finalized FAILED and pages are
released, so a crashed replica never leaks pool pages.  Surviving
residents are untouched by an abort — their tokens stay bit-identical to
solo runs (pinned by the abort parity test).

Arrivals are wall-clock: a request is admissible once ``arrival`` seconds
(relative to ``serve()`` entry) have elapsed, which is how ``serve.py
--arrivals poisson`` and ``benchmarks/sched_bench.py`` replay traces.
``serve_static`` is the baseline the bench compares against: requests are
grouped into fixed batches in arrival order, each batch runs to completion
(its rows cannot be refilled) before the next one starts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.runtime.engine import _eos_scalar, _pow2_chunk

# ---- request lifecycle states --------------------------------------------
QUEUED = "QUEUED"            # submitted, waiting for a slot
PREFILLING = "PREFILLING"    # resident, prompt still landing piecewise
DECODING = "DECODING"        # resident, emitting tokens
DONE = "DONE"                # ran to natural completion (EOS/budget/freeze)
CANCELLED = "CANCELLED"      # client abort took effect at a boundary
TIMED_OUT = "TIMED_OUT"      # per-request deadline expired at a boundary
FAILED = "FAILED"            # replica/engine fault while in flight
REJECTED = "REJECTED"        # shed by backpressure before ever running
TERMINAL_STATES = frozenset({DONE, CANCELLED, TIMED_OUT, FAILED, REJECTED})


@dataclasses.dataclass
class Request:
    """One generation request in the replayed stream."""
    req_id: int
    tokens: np.ndarray           # (S,) int32 prompt
    n_tokens: int                # generation budget (includes first token)
    arrival: float = 0.0         # seconds after serve() start
    deadline: Optional[float] = None  # absolute (serve-clock) deadline; the
                                 # request TIMES OUT at the first boundary
                                 # past it, queued or resident
    age: int = 0                 # boundaries this request was passed over
                                 # (scheduler-managed; fuels age_limit)


@dataclasses.dataclass
class RequestResult:
    req_id: int
    tokens: np.ndarray           # real emitted tokens (length n_emitted)
    n_emitted: int
    arrival: float
    t_admit: float               # when the request got a slot
    t_finish: float              # when its outputs were finalized
    state: str = DONE            # terminal lifecycle state (TERMINAL_STATES)

    @property
    def latency(self) -> float:
        return self.t_finish - self.arrival

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.arrival


def _aggregate(results: Sequence[RequestResult], makespan: float) -> dict:
    lats = np.asarray([r.latency for r in results])
    waits = np.asarray([r.queue_wait for r in results])
    total = int(sum(r.n_emitted for r in results))
    # goodput counts only requests that ran to natural completion: a
    # cancelled/timed-out/failed request's partial tokens were wasted work
    good = int(sum(r.n_emitted for r in results if r.state == DONE))
    states: Dict[str, int] = {}
    for r in results:
        states[r.state] = states.get(r.state, 0) + 1

    def pct(a, q):
        return float(np.percentile(a, q)) if a.size else 0.0

    # mean alone hides the tail the admission policies target: p50/p95 are
    # first-class alongside it (p90 kept for older consumers)
    return {
        "requests": len(results),
        "makespan_s": makespan,
        "emitted_total": total,
        "tok_s": total / makespan if makespan > 0 else float("inf"),
        "goodput_tok_s": good / makespan if makespan > 0 else float("inf"),
        "states": states,
        "latency_mean_s": float(lats.mean()) if lats.size else 0.0,
        "latency_p50_s": pct(lats, 50),
        "latency_p90_s": pct(lats, 90),
        "latency_p95_s": pct(lats, 95),
        "latency_max_s": float(lats.max()) if lats.size else 0.0,
        "queue_wait_mean_s": float(waits.mean()) if waits.size else 0.0,
        "queue_wait_p50_s": pct(waits, 50),
        "queue_wait_p95_s": pct(waits, 95),
    }


# --------------------------------------------------------------------------
# Admission policies: which queued request a freed row takes.
#
# ``pick`` sees the pending list in FIFO order (sorted by (arrival,
# req_id)) and returns an index into it, or None to leave the remaining
# free rows empty this boundary.  ``can_admit(req)`` is the engine's page-
# reservation gate (always admissible when the engine is dense);
# ``footprint(req)`` is ``engine.sched_footprint`` — reserved pages when
# paged, else slots.  ``bootstrap`` is True for the very first admission
# of a serve(): the bank (and paged allocator) are rebuilt from scratch,
# so the reservation gate must not apply (a depleted allocator left by an
# aborted run cannot wedge a fresh serve, and a request larger than the
# whole pool is admitted alone and freezes with a shortfall rather than
# being lost).
# --------------------------------------------------------------------------
class AdmissionPolicy:
    """Protocol + FIFO base: strict arrival order, defer-blocks-the-line.

    ``age_limit`` (0 = off) is the starvation bound the size-ordered
    policies honour; FIFO cannot starve and ignores it."""

    name = "fifo"

    def __init__(self, age_limit: int = 0):
        if age_limit < 0:
            raise ValueError("age_limit must be >= 0")
        self.age_limit = age_limit

    def pick(self, pending: Sequence["Request"], now: float,
             can_admit: Callable, footprint: Callable,
             bootstrap: bool) -> Optional[int]:
        if pending[0].arrival > now:
            return None
        if not bootstrap and not can_admit(pending[0]):
            # pool exhausted: DEFER head-of-line until evictions free pages
            return None
        return 0


class _SizeOrderedPolicy(AdmissionPolicy):
    """Shared SJF/LPT machinery: rank ARRIVED requests by footprint and
    admit the best-ranked one the pool can fund — i.e. admission may skip
    past a deferred head-of-line request whenever a differently-sized one
    fits.  Ties break FIFO (arrival, req_id).

    Aging: a request whose ``age`` (boundaries it was passed over,
    scheduler-maintained) reaches ``age_limit`` is promoted to FIFO-head
    priority — the ranking is suspended and, like FIFO, NOTHING may be
    admitted past the starved request while it cannot be funded; skipping
    past it is exactly what made the starvation unbounded."""

    reverse = False

    def pick(self, pending, now, can_admit, footprint, bootstrap):
        if self.age_limit:
            aged = [i for i, r in enumerate(pending)
                    if r.arrival <= now and r.age >= self.age_limit]
            if aged:                  # oldest starved request, FIFO order
                i = aged[0]
                return i if (bootstrap or can_admit(pending[i])) else None
        sign = -1 if self.reverse else 1
        ranked = sorted(
            (sign * footprint(r), r.arrival, r.req_id, i)
            for i, r in enumerate(pending) if r.arrival <= now)
        for *_, i in ranked:
            if bootstrap or can_admit(pending[i]):
                return i
        return None


class SJFPolicy(_SizeOrderedPolicy):
    """Shortest reserved footprint first.  Starvation-prone under
    sustained small-request load (see module docstring) unless
    ``age_limit`` bounds the deferral."""
    name = "sjf"


class LPTPolicy(_SizeOrderedPolicy):
    """Longest footprint first (packs big reservations early)."""
    name = "lpt"
    reverse = True


POLICIES = {"fifo": AdmissionPolicy, "sjf": SJFPolicy, "lpt": LPTPolicy}


def get_policy(policy, age_limit: int = 0) -> AdmissionPolicy:
    """Resolve a policy name (constructed with ``age_limit``) or pass
    through an AdmissionPolicy instance (which keeps its own)."""
    if isinstance(policy, str):
        try:
            return POLICIES[policy](age_limit=age_limit)
        except KeyError:
            raise ValueError(f"unknown admission policy {policy!r} "
                             f"(have: {sorted(POLICIES)})") from None
    return policy


# --------------------------------------------------------------------------
# Adaptive speculation: measured-ARCA width selection at runtime.
# --------------------------------------------------------------------------
class AdaptiveSpeculation:
    """Runtime decode-strategy selection for a ``DecodeEngine`` bank.

    Wraps the ``{width: arca.Strategy}`` table ``choose_strategy`` returns
    — each entry carries the candidate tree, its ESTIMATED acceptance
    length (calibration accuracies) and a step time, ideally MEASURED via
    ``arca.profile_engine`` — plus a windowed EMA of the acceptance length
    actually observed on the bank.

    The observed signal only exists for the width that actually RAN, so
    candidate ALs are compared by rescaling every width's estimate with an
    observed/estimated ratio, anchored so width 1 stays exactly AL=1
    (``al_hat(w) = 1 + (est(w) - 1) * ratio(w)``).  Ratios are tracked PER
    WIDTH: a width the bank has observed uses its own measured ratio
    (``ratios[w]``); a never-observed width falls back to the active
    width's ratio (the legacy single-ratio rescaling).  Ratios are only
    updated while a width > 1 is active — width 1 observes AL == 1 by
    construction and carries no draft-quality information, so while it is
    active every ratio instead RELAXES toward the calibration prior at
    rate ``probe`` per boundary: width 1 is never absorbing, the bank
    periodically re-probes the best drafted width and drops back if the
    observation still disagrees.

    ``probe_every=K`` (0 = off) additionally schedules ONLINE acceptance
    probes on non-active widths: every K-th boundary the controller
    switches the bank to the next non-active drafted width (round-robin)
    for ``probe_boundaries`` boundaries, so that width's ratio is
    re-measured instead of forever being extrapolated from the active
    width's — a width whose real acceptance diverges from the active
    width's ratio is caught.  Probing is output-neutral like any strategy
    switch (greedy verification commits the greedy chain whatever the
    tree); when the probe window closes the argmax re-decides from the
    freshly de-biased per-width ratios.

    ``pick`` (called by the scheduler at an eviction/admission boundary)
    returns the new width when the ``al_hat / step_time`` argmax moved
    (or a scheduled probe fires), else None.  ``switch_every`` throttles
    how often a switch may happen; ``min_steps`` delays the first
    observation-driven switch until the EMA has seen that many accepted
    steps.  A switch resets the observation window (the EMA is read
    against the ACTIVE width's estimate, so stale cross-width samples
    would corrupt the ratio and flap the argmax); the normalized ratios
    themselves persist across switches.
    """

    def __init__(self, strategies, *, ema: float = 0.3,
                 switch_every: int = 2, min_steps: int = 8,
                 probe: float = 0.05, probe_every: int = 0,
                 probe_boundaries: int = 2):
        if not strategies:
            raise ValueError("adaptive mode needs candidate strategies")
        self.strategies = {int(w): s for w, s in strategies.items()}
        self.ema, self.switch_every = ema, switch_every
        self.min_steps = min_steps
        self.probe = probe
        if probe_every < 0 or probe_boundaries < 1:
            raise ValueError("probe_every must be >= 0 and "
                             "probe_boundaries >= 1")
        self.probe_every = probe_every
        self.probe_boundaries = probe_boundaries
        self.reset()

    def reset(self) -> None:
        """Back to the calibration prior: observation EMA, ratios, counters,
        probe state and the switch log all cleared.  ``serve()`` calls this
        on entry so a reused controller never carries one stream's
        observations (or switch events) into the next run's decisions and
        stats."""
        self.al_obs: Optional[float] = None   # EMA of observed AL
        self.ratio = 1.0                      # active-width obs/est, anchored
        self.ratios: Dict[int, float] = {}    # per-width measured ratios
        self.steps_seen = 0
        self.boundaries = 0
        self.switches: List[tuple] = []       # (boundary, from_w, to_w)
        self._probing: Optional[int] = None   # width under a scheduled probe
        self._probe_left = 0
        self._probe_cycle = 0                 # round-robin over probe targets

    def observe(self, ns, width: int) -> None:
        """Feed one chunk's per-step accepted counts (``ns (K, B)``; zeros
        = masked/free rows, dropped).  Width-1 chunks carry no signal."""
        if width <= 1 or width not in self.strategies:
            return
        ns = np.asarray(ns).ravel()
        ns = ns[ns > 0]
        if not ns.size:
            return
        al = float(ns.mean())
        self.al_obs = al if self.al_obs is None else \
            (1.0 - self.ema) * self.al_obs + self.ema * al
        est = self.strategies[width].acceptance
        self.ratio = max(self.al_obs - 1.0, 0.0) / max(est - 1.0, 1e-9)
        self.ratios[width] = self.ratio       # this width now self-reports
        self.steps_seen += int(ns.size)

    def al_hat(self, width: int) -> float:
        """Rescaled acceptance estimate (width 1 is exactly 1); a width the
        bank has observed (directly or via a scheduled probe) uses its own
        measured ratio."""
        r = self.ratios.get(width, self.ratio)
        return 1.0 + (self.strategies[width].acceptance - 1.0) * r

    def _switch_to(self, old: int, new: int) -> None:
        self.switches.append((self.boundaries, old, new))
        # fresh observation window for the new width: the AL EMA is read
        # against the ACTIVE width's estimate, so stale samples from the
        # old width would corrupt the ratio (an inflated ratio right after
        # a downswitch flips the argmax straight back — flapping).  The
        # ratios themselves persist: they are the width-normalized
        # draft-quality signal and stay comparable across switches.
        self.al_obs = None
        self.steps_seen = 0

    def _decide(self, width: int) -> Optional[int]:
        best = max(sorted(self.strategies),
                   key=lambda w: self.al_hat(w)
                   / self.strategies[w].step_time)
        if best == width:
            return None
        self._switch_to(width, best)
        return best

    def pick(self, width: int) -> Optional[int]:
        """New width when the measured AL/step_time argmax moved (or a
        scheduled probe fires), else None.  Call at an eviction/admission
        boundary only."""
        self.boundaries += 1
        if width <= 1:
            # width 1 observes AL == 1 by construction (no signal), so it
            # would be an ABSORBING state once the ratio hits 0.  Relax
            # every ratio toward the calibration prior (1.0) instead:
            # after enough signal-free boundaries the argmax re-probes the
            # best drafted width, and a still-bad observation sends it
            # straight back down — bounded-duty-cycle probing, no pinned
            # serve.
            self.ratio += self.probe * (1.0 - self.ratio)
            for w in self.ratios:
                self.ratios[w] += self.probe * (1.0 - self.ratios[w])
        # ---- scheduled probe in progress: hold, then re-decide -----------
        if self._probing is not None:
            if width != self._probing:        # external interference ends it
                self._probing = None
            else:
                self._probe_left -= 1
                if self._probe_left > 0:
                    return None               # keep measuring the probe width
                self._probing = None
                return self._decide(width)    # fresh per-width ratios
        # ---- start a scheduled probe of a non-active width ---------------
        if self.probe_every and self.boundaries % self.probe_every == 0:
            others = [w for w in sorted(self.strategies)
                      if w > 1 and w != width]
            if others:
                target = others[self._probe_cycle % len(others)]
                self._probe_cycle += 1
                self._probing = target
                self._probe_left = self.probe_boundaries
                self._switch_to(width, target)
                return target
        if width > 1 and self.steps_seen < self.min_steps:
            return None                       # EMA not warmed up yet
        if self.boundaries % self.switch_every:
            return None
        return self._decide(width)


@dataclasses.dataclass
class BoundaryReport:
    """What one ``boundary()`` produced for the streaming front end."""
    emitted: Dict[int, list]        # req_id -> tokens newly available
    finished: List[RequestResult]   # requests finalized this boundary
    idle: bool                      # nothing resident, nothing admitted
    next_arrival: Optional[float]   # earliest queued arrival (idle only)
    boundary: int                   # 1-based boundary index


class ContinuousScheduler:
    """Per-sequence admission/eviction over an engine's B-row slot bank.

    Works with any engine implementing the slot protocol
    (``sched_prefill`` / ``sched_blank`` / ``sched_insert`` /
    ``sched_reset`` / ``sched_step`` / ``sched_emitted`` plus the paged
    reservation hooks ``sched_can_admit`` / ``sched_release`` /
    ``sched_abort`` / ``sched_footprint`` and, for ``prefill_chunk``, the
    piecewise admission hook ``sched_extend`` gated by
    ``sched_chunked_ok`` — the unified ``DecodeEngine`` implements all of
    it once; ``BatchEngine`` / ``SpeculativeEngine`` are its aliases).

    ``policy`` picks which queued request a freed row takes (``"fifo"`` /
    ``"sjf"`` / ``"lpt"`` or an ``AdmissionPolicy``); ``age_limit=N``
    bounds SJF/LPT starvation (a request deferred for more than N
    boundaries is promoted to FIFO-head priority); ``prefill_chunk=N``
    admits prompts longer than N in N-token pieces; ``adaptive=`` arms
    measured-ARCA runtime strategy switching (a ``{width: arca.Strategy}``
    table or an ``AdaptiveSpeculation`` — drafted engines only).  See the
    module docstring for all four.

    Besides the blocking ``serve()`` replay the scheduler runs as a
    STEPPING core for the async front end: ``start()`` arms a stream,
    ``submit()`` / ``abort()`` feed it between boundaries, ``boundary()``
    runs exactly one admit/chunk/evict iteration and reports incremental
    tokens + finalized results, ``finish()`` closes the stream, and
    ``fail_all()`` is the crash path (every in-flight request finalized
    FAILED, pages released).  ``faults=`` accepts a
    ``faults.ReplicaFaults`` injector: its ``on_boundary`` hook runs at
    every boundary entry (stalls sleep, crashes raise out of
    ``boundary()``), and ``block_admission`` simulates admission-time
    pool exhaustion (requests defer exactly like a real exhausted pool —
    queueing delay, never corruption).
    """

    def __init__(self, engine, *, batch: int = 8,
                 chunk: Optional[int] = None, policy="fifo",
                 prefill_chunk: int = 0, age_limit: int = 0,
                 adaptive=None, faults=None):
        self.engine = engine
        self.batch = batch
        self.chunk = chunk or engine.chunk
        self.policy = get_policy(policy, age_limit)
        if prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0")
        # chunked prefill: 0 = whole-prompt admission; N = admit long
        # prompts in N-token pieces (attention-family engines only — other
        # families silently use whole-prompt admission)
        self.prefill_chunk = prefill_chunk if getattr(
            engine, "sched_chunked_ok", False) else 0
        self.adaptive: Optional[AdaptiveSpeculation] = None
        self._strategy_table = {}
        if adaptive is not None:
            if getattr(engine, "strategy", None) is None or \
                    engine.strategy.draft != "medusa":
                raise ValueError("adaptive speculation needs a drafted "
                                 "DecodeEngine (strategy.draft == 'medusa')")
            self.adaptive = adaptive if isinstance(
                adaptive, AdaptiveSpeculation) else \
                AdaptiveSpeculation(adaptive)
            # build each candidate DecodeStrategy once (switches reuse the
            # pytrees) and ratchet the paged reservation overshoot to the
            # deepest candidate tree
            self._strategy_table = engine.register_strategies(
                {w: s.tree for w, s in self.adaptive.strategies.items()})
        self.faults = faults
        # introspection for tests / debugging, populated by serve()
        self.last_state = None
        self.events: List[tuple] = []
        # streaming-core state (armed by start(); empty defaults so load /
        # has_work are safe to read before a stream begins)
        self._pending: List[Request] = []
        self._slots: list = []
        self._results: Dict[int, RequestResult] = {}
        self._state_of: Dict[int, str] = {}   # ACTIVE requests only
        self._aborts: Dict[int, str] = {}
        self._dirty: set = set()
        self._dev = None
        self._t0 = time.perf_counter()
        self._boundary_i = 0
        self._n_chunks = 0
        self._max_resident = 0
        self._eos = None
        self._eos_val = int(_eos_scalar(None))

    # ------------------------------------------------------------------
    # stepping API: start / submit / abort / boundary / finish
    # ------------------------------------------------------------------
    def now(self) -> float:
        """Seconds since ``start()`` — the stream's arrival/deadline clock."""
        return time.perf_counter() - self._t0

    @property
    def has_work(self) -> bool:
        return bool(self._pending) or any(
            s is not None for s in self._slots)

    @property
    def load(self) -> int:
        """Queued + resident requests (the router's balance signal)."""
        return len(self._pending) + sum(
            s is not None for s in self._slots)

    def request_state(self, req_id: int) -> Optional[str]:
        """Lifecycle state of a known request (terminal states from the
        result log), or None for an unknown id."""
        if req_id in self._results:
            return self._results[req_id].state
        return self._state_of.get(req_id)

    def start(self, requests: Sequence[Request] = (), *,
              eos: Optional[int] = None) -> None:
        """Arm a stream: reset all per-serve state and start the clock.
        ``requests`` seeds the queue; ``submit()`` adds more later."""
        B = self.batch
        self._eos = eos
        self._eos_val = int(_eos_scalar(eos))
        # pending stays in FIFO order; policies index into it
        self._pending = sorted(requests, key=lambda r: (r.arrival, r.req_id))
        for r in self._pending:
            r.age = 0                 # aging state is per-stream
        if self.adaptive is not None:
            self.adaptive.reset()     # so is the observation window
        self._slots = [None] * B          # per-row {req, out, t, pending,
        self._done_np = np.ones((B,), bool)  # flushed}; free rows masked
        self._rem_np = np.zeros((B,), np.int32)
        self._dev = None
        self._results = {}
        self._state_of = {r.req_id: QUEUED for r in self._pending}
        self._aborts = {}
        self.events = []
        self._max_resident = 0
        self._n_chunks = 0
        self._boundary_i = 0
        self._dirty = set()               # evicted rows not yet reset
        self._t0 = time.perf_counter()

    def submit(self, request: Request) -> None:
        """Queue a request mid-stream (between boundaries).  The server
        thread owns the scheduler: calls must come from that thread."""
        if request.req_id in self._state_of:
            raise ValueError(f"req_id {request.req_id} is already active")
        request.age = 0
        self._state_of[request.req_id] = QUEUED
        self._pending.append(request)
        self._pending.sort(key=lambda r: (r.arrival, r.req_id))

    def abort(self, req_id: int, state: str = CANCELLED) -> None:
        """Request cancellation: takes effect at the NEXT boundary, where
        the request (queued or resident) is finalized with ``state`` and a
        resident row's reserved pages return to the pool mid-flight.
        Unknown or already-terminal ids are a no-op."""
        if state not in TERMINAL_STATES:
            raise ValueError(f"not a terminal state: {state!r}")
        if req_id not in self._results:
            self._aborts.setdefault(req_id, state)

    def _finalize(self, req: Request, tokens, t_admit: float,
                  state: str) -> RequestResult:
        toks = np.asarray(tokens, np.int32) if len(tokens) else \
            np.zeros((0,), np.int32)
        res = RequestResult(
            req_id=req.req_id, tokens=toks, n_emitted=len(toks),
            arrival=req.arrival, t_admit=t_admit, t_finish=self.now(),
            state=state)
        self._results[req.req_id] = res
        self._state_of.pop(req.req_id, None)
        return res

    def _abort_row(self, b: int, state: str, emitted: dict,
                   finished: list) -> None:
        """Release a LIVE row mid-flight: partial tokens finalized with a
        typed state, pages back to the pool NOW (the dirty reset clears
        the row's block table before any later chunk, so a same-boundary
        admission may safely reuse the freed pages)."""
        s = self._slots[b]
        req = s["req"]
        kept = s["out"][:req.n_tokens]
        tail = kept[s["flushed"]:]
        if tail:
            emitted[req.req_id] = [int(t) for t in tail]
        finished.append(self._finalize(req, kept, s["t"], state))
        eng = self.engine
        getattr(eng, "sched_abort", eng.sched_release)(b)
        self._dirty.add(b)
        self._slots[b] = None
        self._done_np[b] = True
        self._rem_np[b] = 0
        self.events.append(("abort", req.req_id, b))

    def _apply_aborts(self, t_now: float, emitted: dict,
                      finished: list) -> None:
        """Boundary-start lifecycle sweep: expired deadlines join the
        pending cancellations, then every abort lands — queued requests
        finalize with zero tokens, resident rows release mid-flight."""
        for s in self._slots:
            if s is not None and s["req"].deadline is not None \
                    and t_now > s["req"].deadline:
                self._aborts.setdefault(s["req"].req_id, TIMED_OUT)
        for r in self._pending:
            if r.deadline is not None and t_now > r.deadline:
                self._aborts.setdefault(r.req_id, TIMED_OUT)
        if not self._aborts:
            return
        aborts, self._aborts = self._aborts, {}
        rows = {s["req"].req_id: b for b, s in enumerate(self._slots)
                if s is not None}
        for req_id, state in aborts.items():
            if req_id in self._results:
                continue                  # already terminal: no-op
            if req_id in rows:
                self._abort_row(rows[req_id], state, emitted, finished)
                continue
            i = next((j for j, r in enumerate(self._pending)
                      if r.req_id == req_id), None)
            if i is None:
                continue                  # unknown id: no-op
            req = self._pending.pop(i)
            finished.append(self._finalize(req, [], self.now(), state))
            self.events.append(("abort", req_id, -1))

    def boundary(self) -> BoundaryReport:
        """Run ONE admit/chunk/evict iteration and report what it emitted.
        Never sleeps: an idle report carries the earliest queued arrival
        so the caller decides whether to wait (``serve()``) or keep the
        event loop spinning (the async server)."""
        eng, B, C = self.engine, self.batch, self.prefill_chunk
        eos, eos_val = self._eos, self._eos_val
        slots, done_np, rem_np = self._slots, self._done_np, self._rem_np
        emitted: Dict[int, list] = {}
        finished: List[RequestResult] = []
        self._boundary_i += 1
        if self.faults is not None:
            # stalls sleep here; an injected crash raises out of boundary()
            self.faults.on_boundary(self._boundary_i)
        # ---- cancels / expired deadlines take effect at the boundary ----
        self._apply_aborts(self.now(), emitted, finished)

        def can_admit(r):
            return eng.sched_can_admit(len(r.tokens), r.n_tokens)

        def footprint(r):
            return eng.sched_footprint(len(r.tokens), r.n_tokens)

        # ---- advance chunked prefills: one piece per row/boundary ----
        for b in range(B):
            s = slots[b]
            if s is None or s.get("pending") is None:
                continue
            rest = s["pending"]
            piece = rest[:C]
            padded = np.zeros((1, C), np.int32)
            padded[0, :len(piece)] = piece
            self._dev, last = eng.sched_extend(self._dev, b, padded,
                                               len(piece))
            self.events.append(("extend", s["req"].req_id, b))
            if len(rest) > C:
                s["pending"] = rest[C:]
            else:                     # last piece: the row goes LIVE
                s["pending"] = None
                s["out"] = [last]     # unsynced device scalar, like
                done_np[b] = (eos is not None  # an admission's `first`
                              and int(last) == eos_val)
                rem_np[b] = max(s["req"].n_tokens - 1, 0)
                self._state_of[s["req"].req_id] = DECODING
                self.events.append(("prefill_done", s["req"].req_id, b))

        # ---- admit arrived requests into free rows (policy order) ----
        # ONE arrival cutoff for the whole boundary: pick and the
        # aging filter below must agree on who was visible, or a
        # request arriving mid-dispatch would be aged (and promoted)
        # without ever having been passed over
        t_bound = self.now()
        admitted_n, free_rows = 0, False
        # injected admission-time pool exhaustion: defer everything this
        # boundary, exactly like a real exhausted pool would
        blocked = (self.faults is not None and bool(self._pending)
                   and self.faults.block_admission())
        if blocked:
            free_rows = any(s is None for s in slots)
        for b in range(B):
            if blocked or slots[b] is not None or not self._pending:
                continue
            idx = self.policy.pick(self._pending, t_bound, can_admit,
                                   footprint, self._dev is None)
            if idx is None:           # nothing arrived / nothing the
                free_rows = True      # pool can fund: leave rows empty
                break
            req = self._pending.pop(idx)
            # reprolint: disable=R3 (req.tokens is a host list, no sync)
            prompt_np = np.asarray(req.tokens, np.int32)
            S = len(prompt_np)
            chunked = bool(C) and S > C
            prompt = (prompt_np[:C] if chunked else prompt_np)[None]
            if self._dev is None:     # bootstrap the bank once
                row = eng.sched_prefill({"tokens": prompt})
                self._dev = eng.sched_blank(row, B)
                self._dev = eng.sched_insert(self._dev, b, row,
                                             prompt_len=S,
                                             n_tokens=req.n_tokens)
                first = eng.sched_first(row)
            else:                     # ONE fused prefill+insert dispatch
                self._dev, first = eng.sched_admit(self._dev, b,
                                                   {"tokens": prompt},
                                                   n_tokens=req.n_tokens,
                                                   reserve_len=S)
            self._dirty.discard(b)    # insert overwrote the whole row
            if chunked:               # rest of the prompt lands piece-
                slots[b] = {"req": req, "out": [], "t": self.now(),
                            "pending": prompt_np[C:], "flushed": 0}
                done_np[b] = True     # masked until the last piece
                rem_np[b] = 0
                self._state_of[req.req_id] = PREFILLING
            else:
                # `first` may be an unsynced device scalar — only force
                # it when EOS filtering needs the value now
                slots[b] = {"req": req, "out": [first], "t": self.now(),
                            "pending": None, "flushed": 0}
                done_np[b] = eos is not None and int(first) == eos_val
                rem_np[b] = max(req.n_tokens - 1, 0)
                self._state_of[req.req_id] = DECODING
            admitted_n += 1
            self.events.append(("admit", req.req_id, b))
        # aging counts boundaries a request was PASSED OVER: another
        # request was admitted past it, or a free row stayed empty
        # because its own reservation could not be funded.  Waiting
        # behind a FULL bank ages nobody — otherwise ordinary
        # saturation would push every request past age_limit and
        # permanently degrade SJF/LPT to FIFO.
        if admitted_n or free_rows:
            for r in self._pending:
                if r.arrival <= t_bound:
                    r.age += 1
        if self._dirty and self._dev is not None:
            # rows left empty: one batched reset (clears aborted rows'
            # block tables BEFORE the next chunk can touch freed pages)
            self._dev = eng.sched_reset(self._dev, sorted(self._dirty))
            self._dirty.clear()
        occupied = [b for b in range(B) if slots[b] is not None]
        self._max_resident = max(self._max_resident, len(occupied))
        if not occupied:
            nxt = self._pending[0].arrival if self._pending else None
            return BoundaryReport(emitted, finished, True, nxt,
                                  self._boundary_i)

        # ---- run one chunk over the whole bank -----------------------
        live = [b for b in occupied if not done_np[b] and rem_np[b] > 0]
        if live:
            K = _pow2_chunk(self.chunk, int(rem_np[live].max()))
            self._dev, done, rem, raw = eng.sched_step(
                self._dev, done_np, rem_np, K, eos_val)
            # the boundary's budgeted sync: done/rem cross with the chunk
            # reprolint: disable=R3 (intended boundary sync)
            done_np = self._done_np = np.asarray(done).copy()
            # reprolint: disable=R3 (intended boundary sync)
            rem_np = self._rem_np = np.asarray(rem).copy()
            per_row = eng.sched_emitted(raw)
            self._n_chunks += 1
            for b in occupied:
                if slots[b]["pending"] is None:
                    slots[b]["out"].extend(per_row[b])
            if self.adaptive is not None:
                # raw[1] = (K, B) per-step accepted counts; masked/free
                # rows are 0 and dropped by the EMA
                self.adaptive.observe(raw[1], eng.strategy.width)

        # ---- flush newly available tokens (the streaming boundary) ---
        for b in occupied:
            s = slots[b]
            if s is None or s["pending"] is not None:
                continue
            avail = min(len(s["out"]), s["req"].n_tokens)
            if avail > s["flushed"]:
                emitted[s["req"].req_id] = [
                    int(t) for t in s["out"][s["flushed"]:avail]]
                s["flushed"] = avail

        # ---- evict finished rows (EOS / budget / capacity freeze) ----
        for b in occupied:
            s = slots[b]
            if s is None or s["pending"] is not None:
                continue              # aborted / still prefilling
            budget = s["req"].n_tokens
            if not (done_np[b] or rem_np[b] <= 0
                    or len(s["out"]) >= budget):
                continue
            kept = s["out"][:budget]
            finished.append(self._finalize(s["req"], kept, s["t"], DONE))
            eng.sched_release(b)      # paged: pages back to the pool NOW
            self._dirty.add(b)        # reset lazily unless re-admitted
            slots[b] = None
            done_np[b] = True
            rem_np[b] = 0
            self.events.append(("evict", s["req"].req_id, b))

        # ---- adaptive: re-decide the decode strategy at the boundary -
        if self.adaptive is not None and live:
            new_w = self.adaptive.pick(eng.strategy.width)
            if new_w is not None:
                old_w = eng.strategy.width
                eng.set_strategy(self._strategy_table[new_w])
                self.events.append(("switch", old_w, new_w))
        return BoundaryReport(emitted, finished, False, None,
                              self._boundary_i)

    def fail_all(self, error=None) -> List[RequestResult]:
        """Replica-crash cleanup: finalize EVERY in-flight and queued
        request as FAILED and release resident pages (the allocator is
        host state, so it survives an engine fault and must stay
        conserved).  Device state is left as-is — a crashed replica's
        engine is never stepped again."""
        finished = []
        for b, s in enumerate(self._slots):
            if s is None:
                continue
            req = s["req"]
            try:
                kept = list(s["out"][:req.n_tokens])
                np.asarray(kept, np.int32)
            except Exception:         # device output unreadable post-fault
                kept = []
            finished.append(self._finalize(req, kept, s["t"], FAILED))
            try:
                eng = self.engine
                getattr(eng, "sched_abort", eng.sched_release)(b)
            except Exception:
                pass
            self._slots[b] = None
            self._done_np[b] = True
            self._rem_np[b] = 0
            self.events.append(("fail", req.req_id, b))
        for req in self._pending:
            finished.append(self._finalize(req, [], self.now(), FAILED))
            self.events.append(("fail", req.req_id, -1))
        self._pending = []
        self._aborts = {}
        return finished

    def finish(self, requests: Optional[Sequence[Request]] = None) -> tuple:
        """Close the stream: final batched reset, aggregate stats.  With
        ``requests`` the results come back in that order (serve());
        otherwise in finalization order (the async server)."""
        if self._dirty and self._dev is not None:
            self._dev = self.engine.sched_reset(self._dev,
                                                sorted(self._dirty))
            self._dirty.clear()
        makespan = self.now()
        self.last_state = self._dev
        if requests is not None:
            ordered = [self._results[r.req_id] for r in requests]
        else:
            ordered = sorted(self._results.values(),
                             key=lambda r: r.t_finish)
        stats = _aggregate(ordered, makespan)
        stats.update(admitted=len(ordered), chunks=self._n_chunks,
                     max_resident=self._max_resident, batch=self.batch,
                     chunk=self.chunk, policy=self.policy.name,
                     age_limit=getattr(self.policy, "age_limit", 0),
                     prefill_chunk=self.prefill_chunk)
        if self.adaptive is not None:
            stats.update(
                strategy_switches=[
                    {"boundary": n, "from": f, "to": t}
                    for n, f, t in self.adaptive.switches],
                width_final=self.engine.strategy.width,
                al_observed=self.adaptive.al_obs)
        # HCMP boundary accounting: when the engine ran the disaggregated
        # overlap schedule, surface its executor placement and how many
        # chunk boundaries reused vs discarded the cross-chunk pre-draft
        # (a quiet boundary keeps it; any admission/reset/switch bumps
        # the bank epoch and forces a redraft)
        hcmp = getattr(self.engine, "hcmp_stats", None)
        if hcmp is not None:
            stats["hcmp"] = hcmp
        return ordered, stats

    def serve(self, requests: Sequence[Request], *, eos: Optional[int] = None
              ) -> tuple:
        """Replay ``requests`` (admitting each no earlier than its arrival)
        and return ``(results, stats)`` with results in request order.
        A thin loop over the stepping core — same engine calls, same
        outputs as the pre-stepping scheduler (fuzz-pinned)."""
        self.start(requests, eos=eos)
        while self.has_work:
            report = self.boundary()
            if report.idle:
                if not self._pending:
                    break
                wait = self._pending[0].arrival - self.now()
                if wait > 0:
                    time.sleep(wait)
        return self.finish(requests)


def serve_static(engine, requests: Sequence[Request], *, batch: int = 8,
                 eos: Optional[int] = None) -> tuple:
    """Static-batching baseline: fixed groups of ``batch`` requests in
    arrival order; a group prefills only after ALL its members have arrived
    (batch formation) and runs until EVERY member finishes (per-sequence
    budgets mask early finishers, but their rows cannot be reused), then the
    next group starts.  Prompts within a group must share one length."""
    reqs = sorted(requests, key=lambda r: (r.arrival, r.req_id))
    results = {}
    t0 = time.perf_counter()

    def now():
        return time.perf_counter() - t0

    for i in range(0, len(reqs), batch):
        group = reqs[i:i + batch]
        wait = max(r.arrival for r in group) - now()
        if wait > 0:
            time.sleep(wait)
        prompts = np.stack([np.asarray(r.tokens, np.int32) for r in group])
        budgets = np.asarray([r.n_tokens for r in group], np.int32)
        t_admit = now()
        out, stats = engine.generate({"tokens": prompts}, budgets, eos=eos)
        if out.ndim == 1:                     # B=1 tail group
            out = out[None]
        t_fin = now()
        for j, r in enumerate(group):
            n = int(stats["n_emitted"][j])
            results[r.req_id] = RequestResult(
                req_id=r.req_id, tokens=out[j, :n].copy(), n_emitted=n,
                arrival=r.arrival, t_admit=t_admit, t_finish=t_fin)

    makespan = now()
    ordered = [results[r.req_id] for r in requests]
    stats = _aggregate(ordered, makespan)
    stats.update(batch=batch)
    return ordered, stats


def poisson_arrivals(n: int, rate: float, *, seed: int = 0) -> np.ndarray:
    """Cumulative Poisson-process arrival times (rate = requests/second)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))
