"""Continuous-batching scheduler: iteration-level admission/eviction on top
of the chunked engines (Orca-style scheduling, vLLM-style slot reuse).

The engines decode a fixed bank of B rows device-resident, K steps per host
sync.  This module turns those rows into *slots* a request stream flows
through:

  queue --admit--> slot b --chunks--> done --evict--> slot b free --admit-->

Slot lifecycle
--------------
* **admit** (chunk boundary, row free, request arrived): the prompt is
  prefilled at B=1 and the row is spliced into the resident state with the
  engine's jitted ``sched_insert`` (``cache.insert_rows``: per-row KV /
  recurrent-state write, ``pos[b]`` and ``key_pos[b]`` taken from the fresh
  prefill, done-mask cleared).  The compiled K-step scan never changes —
  admission is pure data movement, so the chunk driver is reused across the
  whole request stream.
* **decode**: every chunk runs the full bank; free/finished rows ride along
  masked by the scan's done-mask (no emission, no commit) and cost no extra
  compilation.  Chunk length is clamped to the largest remaining budget
  (power-of-two schedule, bounded compile cache).
* **evict** (chunk boundary, row done): EOS, per-request token budget, or
  KV-capacity freeze ends a sequence; its outputs are finalized and the row
  is freed.  If a queued request takes the slot at the same boundary the
  admission insert overwrites the whole row (it copies every slot of the
  fresh B=1 prefill, ``key_pos`` included); rows that stay empty are
  cleared in one batched ``sched_reset`` (``cache.reset_rows``:
  ``key_pos`` -> -1, ``pos`` -> 0, state zeroed).  With the speculative
  engine the reset is durable — masked rows commit nothing, so no stale
  KV/state outlives its request.  ``BatchEngine``'s chunk body decodes
  every row unconditionally, so a freed row re-accumulates masked scratch
  (derived from the dead request's last token) until the next admission
  overwrites it; its emission stays masked throughout.

Capacity semantics: a request whose prompt+budget exceed the engine's
``max_len`` is not rejected — the chunk driver freezes it at the capacity
boundary (see runtime/engine.py) and it returns fewer tokens, reported via
``RequestResult.n_emitted``.

Paged engines add a reservation step: admission asks ``sched_can_admit``
whether the page pool can fund ``ceil((prompt + budget + overshoot) /
page_size)`` pages and DEFERS the request while it cannot; eviction
returns the row's pages via ``sched_release`` before the device-side
reset, so a freed reservation funds the same boundary's admissions.  Pool
exhaustion therefore shows up as queueing delay, never as a failed or
corrupted request.

Admission policies
------------------
*Which* queued request a freed row takes is a pluggable
``AdmissionPolicy`` (``policy=`` — ``"fifo"`` default, ``"sjf"``,
``"lpt"``, or any object with the ``pick`` protocol):

* **fifo** — strict arrival order; a request the pool cannot fund blocks
  everything behind it (head-of-line).  Bit-compatible with the pre-policy
  scheduler: same requests, same engine calls, same outputs.
* **sjf** — shortest job first by ``engine.sched_footprint`` (reserved
  pages when paged, else slots): among ARRIVED requests, the smallest one
  the pool can fund is admitted, skipping past a deferred head-of-line
  request.  Cuts queueing delay for the short-budget bulk of a mixed
  trace.  CAVEAT: SJF is starvation-prone — a stream of small requests
  can postpone a large one indefinitely; it never *loses* the large
  request (every policy admits it once the bank drains, because an empty
  bank always funds the pool's worth), but its latency is unbounded under
  sustained load.  FIFO remains the fairness-preserving default.
* **lpt** — longest footprint first (reverse of SJF): packs big
  reservations early; same skip-past-deferred rule, same starvation
  caveat with the roles reversed.

``age_limit=N`` (0 = off) bounds SJF/LPT starvation: every boundary an
ARRIVED request is PASSED OVER — another request admitted past it, or a
free row left empty because its own reservation could not be funded —
increments its ``age`` (waiting behind a full bank ages nobody, so
ordinary saturation never triggers the bound); once ``age >= age_limit``
the oldest such request is promoted to FIFO-HEAD priority — the
size-ordered ranking is suspended and, exactly like FIFO, nothing may be
admitted past the starved request while its reservation cannot be funded
(skipping past it is what made the starvation unbounded).  A deferred
request is therefore passed over at most ``age_limit`` times before it
gets FIFO's own worst case.  FIFO ignores ``age_limit`` (strict arrival
order cannot starve).

Per-request OUTPUT is policy-independent: a policy only reorders
admission; decode math is untouched (the fuzz suite pins per-request
parity with solo B=1 runs across policies).

Chunked prefill
---------------
``prefill_chunk=N`` (0 = off) admits a long prompt PIECEWISE instead of
in one prompt-sized prefill dispatch (Sarathi/vLLM-style chunked prefill):

* admission inserts only the first N prompt tokens (the normal fused
  ``sched_admit``, reservation sized to the WHOLE prompt via
  ``reserve_len``), and the row joins the bank done-masked;
* each following chunk boundary runs ``engine.sched_extend`` once per
  prefilling row: the next N-token piece is pushed through the causal
  verify path against the row's resident cache and spliced in at the
  row's offset (``cache.write_row_at``) — paged pieces are paginated
  incrementally, so the paged path's dense prefill transient is bounded
  by the piece size, never the prompt;
* the LAST piece's final logits produce the request's first token and the
  row goes live (``done`` cleared, budget armed) — from then on the slot
  is indistinguishable from a whole-prompt admission.

The resident bank keeps decoding between pieces, so one long prompt no
longer stalls every resident sequence for a prompt-sized dispatch.  Only
attention-family engines support it (``engine.sched_chunked_ok``);
recurrent families and prompts <= N fall back to whole-prompt admission.

Adaptive speculation
--------------------
``adaptive=`` arms runtime strategy selection over a ``DecodeEngine``
bank (measured ARCA, paper §III-C run *online* instead of once at
startup — the Dovetail observation that the best width moves with the
workload).  Pass the ``{width: arca.Strategy}`` table that
``arca.choose_strategy`` returns — ideally with the MEASURED ``time_fn``
from ``arca.profile_engine`` — or a pre-built ``AdaptiveSpeculation``.
The scheduler then:

* tracks a windowed EMA of the acceptance length actually observed on the
  bank (per-step accepted counts from the chunk raw, free rows excluded);
* at an eviction/admission boundary, rescales every candidate width's
  ESTIMATED acceptance by the observed/estimated ratio of the active
  width (width 1 stays exactly 1) and switches the bank's strategy when
  the ``AL / step_time`` argmax moves (``engine.set_strategy``);
* logs every switch as a ``("switch", from_width, to_width)`` event and
  in ``stats["strategy_switches"]``.

Switching is output-neutral: greedy tree verification commits exactly the
greedy chain whatever the tree, so a mid-request width change alters speed,
never tokens (the strategy-parity tests pin this).  Candidate strategies
are registered with the engine up front (``register_strategies``), which
buckets them for compile-cache reuse and ratchets the paged reservation
overshoot to the deepest candidate tree.

Arrivals are wall-clock: a request is admissible once ``arrival`` seconds
(relative to ``serve()`` entry) have elapsed, which is how ``serve.py
--arrivals poisson`` and ``benchmarks/sched_bench.py`` replay traces.
``serve_static`` is the baseline the bench compares against: requests are
grouped into fixed batches in arrival order, each batch runs to completion
(its rows cannot be refilled) before the next one starts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.runtime.engine import _eos_scalar, _pow2_chunk


@dataclasses.dataclass
class Request:
    """One generation request in the replayed stream."""
    req_id: int
    tokens: np.ndarray           # (S,) int32 prompt
    n_tokens: int                # generation budget (includes first token)
    arrival: float = 0.0         # seconds after serve() start
    age: int = 0                 # boundaries this request was passed over
                                 # (scheduler-managed; fuels age_limit)


@dataclasses.dataclass
class RequestResult:
    req_id: int
    tokens: np.ndarray           # real emitted tokens (length n_emitted)
    n_emitted: int
    arrival: float
    t_admit: float               # when the request got a slot
    t_finish: float              # when its outputs were finalized

    @property
    def latency(self) -> float:
        return self.t_finish - self.arrival

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.arrival


def _aggregate(results: Sequence[RequestResult], makespan: float) -> dict:
    lats = np.asarray([r.latency for r in results])
    waits = np.asarray([r.queue_wait for r in results])
    total = int(sum(r.n_emitted for r in results))

    def pct(a, q):
        return float(np.percentile(a, q)) if a.size else 0.0

    # mean alone hides the tail the admission policies target: p50/p95 are
    # first-class alongside it (p90 kept for older consumers)
    return {
        "requests": len(results),
        "makespan_s": makespan,
        "emitted_total": total,
        "tok_s": total / makespan if makespan > 0 else float("inf"),
        "latency_mean_s": float(lats.mean()) if lats.size else 0.0,
        "latency_p50_s": pct(lats, 50),
        "latency_p90_s": pct(lats, 90),
        "latency_p95_s": pct(lats, 95),
        "latency_max_s": float(lats.max()) if lats.size else 0.0,
        "queue_wait_mean_s": float(waits.mean()) if waits.size else 0.0,
        "queue_wait_p50_s": pct(waits, 50),
        "queue_wait_p95_s": pct(waits, 95),
    }


# --------------------------------------------------------------------------
# Admission policies: which queued request a freed row takes.
#
# ``pick`` sees the pending list in FIFO order (sorted by (arrival,
# req_id)) and returns an index into it, or None to leave the remaining
# free rows empty this boundary.  ``can_admit(req)`` is the engine's page-
# reservation gate (always admissible when the engine is dense);
# ``footprint(req)`` is ``engine.sched_footprint`` — reserved pages when
# paged, else slots.  ``bootstrap`` is True for the very first admission
# of a serve(): the bank (and paged allocator) are rebuilt from scratch,
# so the reservation gate must not apply (a depleted allocator left by an
# aborted run cannot wedge a fresh serve, and a request larger than the
# whole pool is admitted alone and freezes with a shortfall rather than
# being lost).
# --------------------------------------------------------------------------
class AdmissionPolicy:
    """Protocol + FIFO base: strict arrival order, defer-blocks-the-line.

    ``age_limit`` (0 = off) is the starvation bound the size-ordered
    policies honour; FIFO cannot starve and ignores it."""

    name = "fifo"

    def __init__(self, age_limit: int = 0):
        if age_limit < 0:
            raise ValueError("age_limit must be >= 0")
        self.age_limit = age_limit

    def pick(self, pending: Sequence["Request"], now: float,
             can_admit: Callable, footprint: Callable,
             bootstrap: bool) -> Optional[int]:
        if pending[0].arrival > now:
            return None
        if not bootstrap and not can_admit(pending[0]):
            # pool exhausted: DEFER head-of-line until evictions free pages
            return None
        return 0


class _SizeOrderedPolicy(AdmissionPolicy):
    """Shared SJF/LPT machinery: rank ARRIVED requests by footprint and
    admit the best-ranked one the pool can fund — i.e. admission may skip
    past a deferred head-of-line request whenever a differently-sized one
    fits.  Ties break FIFO (arrival, req_id).

    Aging: a request whose ``age`` (boundaries it was passed over,
    scheduler-maintained) reaches ``age_limit`` is promoted to FIFO-head
    priority — the ranking is suspended and, like FIFO, NOTHING may be
    admitted past the starved request while it cannot be funded; skipping
    past it is exactly what made the starvation unbounded."""

    reverse = False

    def pick(self, pending, now, can_admit, footprint, bootstrap):
        if self.age_limit:
            aged = [i for i, r in enumerate(pending)
                    if r.arrival <= now and r.age >= self.age_limit]
            if aged:                  # oldest starved request, FIFO order
                i = aged[0]
                return i if (bootstrap or can_admit(pending[i])) else None
        sign = -1 if self.reverse else 1
        ranked = sorted(
            (sign * footprint(r), r.arrival, r.req_id, i)
            for i, r in enumerate(pending) if r.arrival <= now)
        for *_, i in ranked:
            if bootstrap or can_admit(pending[i]):
                return i
        return None


class SJFPolicy(_SizeOrderedPolicy):
    """Shortest reserved footprint first.  Starvation-prone under
    sustained small-request load (see module docstring) unless
    ``age_limit`` bounds the deferral."""
    name = "sjf"


class LPTPolicy(_SizeOrderedPolicy):
    """Longest footprint first (packs big reservations early)."""
    name = "lpt"
    reverse = True


POLICIES = {"fifo": AdmissionPolicy, "sjf": SJFPolicy, "lpt": LPTPolicy}


def get_policy(policy, age_limit: int = 0) -> AdmissionPolicy:
    """Resolve a policy name (constructed with ``age_limit``) or pass
    through an AdmissionPolicy instance (which keeps its own)."""
    if isinstance(policy, str):
        try:
            return POLICIES[policy](age_limit=age_limit)
        except KeyError:
            raise ValueError(f"unknown admission policy {policy!r} "
                             f"(have: {sorted(POLICIES)})") from None
    return policy


# --------------------------------------------------------------------------
# Adaptive speculation: measured-ARCA width selection at runtime.
# --------------------------------------------------------------------------
class AdaptiveSpeculation:
    """Runtime decode-strategy selection for a ``DecodeEngine`` bank.

    Wraps the ``{width: arca.Strategy}`` table ``choose_strategy`` returns
    — each entry carries the candidate tree, its ESTIMATED acceptance
    length (calibration accuracies) and a step time, ideally MEASURED via
    ``arca.profile_engine`` — plus a windowed EMA of the acceptance length
    actually observed on the bank.

    The observed signal only exists for the ACTIVE width, so candidate ALs
    are compared by rescaling every width's estimate with the
    observed/estimated ratio of the active width, anchored so width 1
    stays exactly AL=1 (``al_hat(w) = 1 + (est(w) - 1) * ratio``).  The
    ratio is only updated while a width > 1 is active — width 1 observes
    AL == 1 by construction and carries no draft-quality information, so
    while it is active the ratio instead RELAXES toward the calibration
    prior at rate ``probe`` per boundary: width 1 is never absorbing, the
    bank periodically re-probes the best drafted width and drops back if
    the observation still disagrees.

    ``pick`` (called by the scheduler at an eviction/admission boundary)
    returns the new width when the ``al_hat / step_time`` argmax moved,
    else None.  ``switch_every`` throttles how often a switch may happen;
    ``min_steps`` delays the first observation-driven switch until the
    EMA has seen that many accepted steps.  A switch resets the
    observation window (the EMA is read against the ACTIVE width's
    estimate, so stale cross-width samples would corrupt the ratio and
    flap the argmax); the normalized ratio itself persists across
    switches.
    """

    def __init__(self, strategies, *, ema: float = 0.3,
                 switch_every: int = 2, min_steps: int = 8,
                 probe: float = 0.05):
        if not strategies:
            raise ValueError("adaptive mode needs candidate strategies")
        self.strategies = {int(w): s for w, s in strategies.items()}
        self.ema, self.switch_every = ema, switch_every
        self.min_steps = min_steps
        self.probe = probe
        self.reset()

    def reset(self) -> None:
        """Back to the calibration prior: observation EMA, ratio, counters
        and the switch log all cleared.  ``serve()`` calls this on entry so
        a reused controller never carries one stream's observations (or
        switch events) into the next run's decisions and stats."""
        self.al_obs: Optional[float] = None   # EMA of observed AL
        self.ratio = 1.0                      # observed/estimated, anchored
        self.steps_seen = 0
        self.boundaries = 0
        self.switches: List[tuple] = []       # (boundary, from_w, to_w)

    def observe(self, ns, width: int) -> None:
        """Feed one chunk's per-step accepted counts (``ns (K, B)``; zeros
        = masked/free rows, dropped).  Width-1 chunks carry no signal."""
        if width <= 1 or width not in self.strategies:
            return
        ns = np.asarray(ns).ravel()
        ns = ns[ns > 0]
        if not ns.size:
            return
        al = float(ns.mean())
        self.al_obs = al if self.al_obs is None else \
            (1.0 - self.ema) * self.al_obs + self.ema * al
        est = self.strategies[width].acceptance
        self.ratio = max(self.al_obs - 1.0, 0.0) / max(est - 1.0, 1e-9)
        self.steps_seen += int(ns.size)

    def al_hat(self, width: int) -> float:
        """Rescaled acceptance estimate (width 1 is exactly 1)."""
        return 1.0 + (self.strategies[width].acceptance - 1.0) * self.ratio

    def pick(self, width: int) -> Optional[int]:
        """New width when the measured AL/step_time argmax moved, else
        None.  Call at an eviction/admission boundary only."""
        self.boundaries += 1
        if width <= 1:
            # width 1 observes AL == 1 by construction (no signal), so it
            # would be an ABSORBING state once the ratio hits 0.  Relax the
            # ratio toward the calibration prior (1.0) instead: after
            # enough signal-free boundaries the argmax re-probes the best
            # drafted width, and a still-bad observation sends it straight
            # back down — bounded-duty-cycle probing, no pinned serve.
            self.ratio += self.probe * (1.0 - self.ratio)
        elif self.steps_seen < self.min_steps:
            return None                       # EMA not warmed up yet
        if self.boundaries % self.switch_every:
            return None
        best = max(sorted(self.strategies),
                   key=lambda w: self.al_hat(w)
                   / self.strategies[w].step_time)
        if best == width:
            return None
        self.switches.append((self.boundaries, width, best))
        # fresh observation window for the new width: the AL EMA is read
        # against the ACTIVE width's estimate, so stale samples from the
        # old width would corrupt the ratio (an inflated ratio right after
        # a downswitch flips the argmax straight back — flapping).  The
        # ratio itself persists: it is the width-normalized draft-quality
        # signal and stays comparable across switches.
        self.al_obs = None
        self.steps_seen = 0
        return best


class ContinuousScheduler:
    """Per-sequence admission/eviction over an engine's B-row slot bank.

    Works with any engine implementing the slot protocol
    (``sched_prefill`` / ``sched_blank`` / ``sched_insert`` /
    ``sched_reset`` / ``sched_step`` / ``sched_emitted`` plus the paged
    reservation hooks ``sched_can_admit`` / ``sched_release`` /
    ``sched_footprint`` and, for ``prefill_chunk``, the piecewise
    admission hook ``sched_extend`` gated by ``sched_chunked_ok`` — the
    unified ``DecodeEngine`` implements all of it once; ``BatchEngine`` /
    ``SpeculativeEngine`` are its aliases).

    ``policy`` picks which queued request a freed row takes (``"fifo"`` /
    ``"sjf"`` / ``"lpt"`` or an ``AdmissionPolicy``); ``age_limit=N``
    bounds SJF/LPT starvation (a request deferred for more than N
    boundaries is promoted to FIFO-head priority); ``prefill_chunk=N``
    admits prompts longer than N in N-token pieces; ``adaptive=`` arms
    measured-ARCA runtime strategy switching (a ``{width: arca.Strategy}``
    table or an ``AdaptiveSpeculation`` — drafted engines only).  See the
    module docstring for all four.
    """

    def __init__(self, engine, *, batch: int = 8,
                 chunk: Optional[int] = None, policy="fifo",
                 prefill_chunk: int = 0, age_limit: int = 0,
                 adaptive=None):
        self.engine = engine
        self.batch = batch
        self.chunk = chunk or engine.chunk
        self.policy = get_policy(policy, age_limit)
        if prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0")
        # chunked prefill: 0 = whole-prompt admission; N = admit long
        # prompts in N-token pieces (attention-family engines only — other
        # families silently use whole-prompt admission)
        self.prefill_chunk = prefill_chunk if getattr(
            engine, "sched_chunked_ok", False) else 0
        self.adaptive: Optional[AdaptiveSpeculation] = None
        self._strategy_table = {}
        if adaptive is not None:
            if getattr(engine, "strategy", None) is None or \
                    engine.strategy.draft != "medusa":
                raise ValueError("adaptive speculation needs a drafted "
                                 "DecodeEngine (strategy.draft == 'medusa')")
            self.adaptive = adaptive if isinstance(
                adaptive, AdaptiveSpeculation) else \
                AdaptiveSpeculation(adaptive)
            # build each candidate DecodeStrategy once (switches reuse the
            # pytrees) and ratchet the paged reservation overshoot to the
            # deepest candidate tree
            self._strategy_table = engine.register_strategies(
                {w: s.tree for w, s in self.adaptive.strategies.items()})
        # introspection for tests / debugging, populated by serve()
        self.last_state = None
        self.events: List[tuple] = []

    def serve(self, requests: Sequence[Request], *, eos: Optional[int] = None
              ) -> tuple:
        """Replay ``requests`` (admitting each no earlier than its arrival)
        and return ``(results, stats)`` with results in request order."""
        eng, B, C = self.engine, self.batch, self.prefill_chunk
        eos_val = int(_eos_scalar(eos))
        # pending stays in FIFO order; policies index into it
        pending = sorted(requests, key=lambda r: (r.arrival, r.req_id))
        for r in pending:
            r.age = 0                 # aging state is per-serve()
        if self.adaptive is not None:
            self.adaptive.reset()     # so is the observation window
        slots: list = [None] * B          # per-row {req, out, t, pending}
        done_np = np.ones((B,), bool)     # free rows are masked done
        rem_np = np.zeros((B,), np.int32)
        state = None
        results = {}
        self.events = []
        max_resident = 0
        chunks = 0
        dirty = set()                     # evicted rows not yet reset
        t0 = time.perf_counter()

        def now():
            return time.perf_counter() - t0

        def can_admit(r):
            return eng.sched_can_admit(len(r.tokens), r.n_tokens)

        def footprint(r):
            return eng.sched_footprint(len(r.tokens), r.n_tokens)

        while pending or any(s is not None for s in slots):
            # ---- advance chunked prefills: one piece per row/boundary ----
            for b in range(B):
                s = slots[b]
                if s is None or s.get("pending") is None:
                    continue
                rest = s["pending"]
                piece = rest[:C]
                padded = np.zeros((1, C), np.int32)
                padded[0, :len(piece)] = piece
                state, last = eng.sched_extend(state, b, padded, len(piece))
                self.events.append(("extend", s["req"].req_id, b))
                if len(rest) > C:
                    s["pending"] = rest[C:]
                else:                     # last piece: the row goes LIVE
                    s["pending"] = None
                    s["out"] = [last]     # unsynced device scalar, like
                    done_np[b] = (eos is not None  # an admission's `first`
                                  and int(last) == eos_val)
                    rem_np[b] = max(s["req"].n_tokens - 1, 0)
                    self.events.append(("prefill_done", s["req"].req_id, b))

            # ---- admit arrived requests into free rows (policy order) ----
            # ONE arrival cutoff for the whole boundary: pick and the
            # aging filter below must agree on who was visible, or a
            # request arriving mid-dispatch would be aged (and promoted)
            # without ever having been passed over
            t_bound = now()
            admitted_n, free_rows = 0, False
            for b in range(B):
                if slots[b] is not None or not pending:
                    continue
                idx = self.policy.pick(pending, t_bound, can_admit,
                                       footprint, state is None)
                if idx is None:           # nothing arrived / nothing the
                    free_rows = True      # pool can fund: leave rows empty
                    break
                req = pending.pop(idx)
                prompt_np = np.asarray(req.tokens, np.int32)
                S = len(prompt_np)
                chunked = bool(C) and S > C
                prompt = (prompt_np[:C] if chunked else prompt_np)[None]
                if state is None:         # bootstrap the bank once
                    row = eng.sched_prefill({"tokens": prompt})
                    state = eng.sched_blank(row, B)
                    state = eng.sched_insert(state, b, row,
                                             prompt_len=S,
                                             n_tokens=req.n_tokens)
                    first = eng.sched_first(row)
                else:                     # ONE fused prefill+insert dispatch
                    state, first = eng.sched_admit(state, b,
                                                   {"tokens": prompt},
                                                   n_tokens=req.n_tokens,
                                                   reserve_len=S)
                dirty.discard(b)          # insert overwrote the whole row
                if chunked:               # rest of the prompt lands piece-
                    slots[b] = {"req": req, "out": [], "t": now(),
                                "pending": prompt_np[C:]}
                    done_np[b] = True     # masked until the last piece
                    rem_np[b] = 0
                else:
                    # `first` may be an unsynced device scalar — only force
                    # it when EOS filtering needs the value now
                    slots[b] = {"req": req, "out": [first], "t": now(),
                                "pending": None}
                    done_np[b] = eos is not None and int(first) == eos_val
                    rem_np[b] = max(req.n_tokens - 1, 0)
                admitted_n += 1
                self.events.append(("admit", req.req_id, b))
            # aging counts boundaries a request was PASSED OVER: another
            # request was admitted past it, or a free row stayed empty
            # because its own reservation could not be funded.  Waiting
            # behind a FULL bank ages nobody — otherwise ordinary
            # saturation would push every request past age_limit and
            # permanently degrade SJF/LPT to FIFO.
            if admitted_n or free_rows:
                for r in pending:
                    if r.arrival <= t_bound:
                        r.age += 1
            if dirty:                     # rows left empty: one batched reset
                state = eng.sched_reset(state, sorted(dirty))
                dirty.clear()
            occupied = [b for b in range(B) if slots[b] is not None]
            max_resident = max(max_resident, len(occupied))
            if not occupied:
                if not pending:
                    break
                wait = pending[0].arrival - now()
                if wait > 0:
                    time.sleep(wait)
                continue

            # ---- run one chunk over the whole bank -----------------------
            live = [b for b in occupied if not done_np[b] and rem_np[b] > 0]
            if live:
                K = _pow2_chunk(self.chunk, int(rem_np[live].max()))
                state, done, rem, raw = eng.sched_step(
                    state, done_np, rem_np, K, eos_val)
                done_np = np.asarray(done).copy()
                rem_np = np.asarray(rem).copy()
                per_row = eng.sched_emitted(raw)
                chunks += 1
                for b in occupied:
                    if slots[b]["pending"] is None:
                        slots[b]["out"].extend(per_row[b])
                if self.adaptive is not None:
                    # raw[1] = (K, B) per-step accepted counts; masked/free
                    # rows are 0 and dropped by the EMA
                    self.adaptive.observe(raw[1], eng.strategy.width)

            # ---- evict finished rows (EOS / budget / capacity freeze) ----
            for b in occupied:
                s = slots[b]
                if s["pending"] is not None:
                    continue              # still prefilling: not evictable
                budget = s["req"].n_tokens
                if not (done_np[b] or rem_np[b] <= 0
                        or len(s["out"]) >= budget):
                    continue
                kept = s["out"][:budget]
                results[s["req"].req_id] = RequestResult(
                    req_id=s["req"].req_id,
                    tokens=np.asarray(kept, np.int32),
                    n_emitted=len(kept),
                    arrival=s["req"].arrival,
                    t_admit=s["t"], t_finish=now())
                eng.sched_release(b)      # paged: pages back to the pool NOW
                dirty.add(b)              # reset lazily unless re-admitted
                slots[b] = None
                done_np[b] = True
                rem_np[b] = 0
                self.events.append(("evict", s["req"].req_id, b))

            # ---- adaptive: re-decide the decode strategy at the boundary -
            if self.adaptive is not None and live:
                new_w = self.adaptive.pick(eng.strategy.width)
                if new_w is not None:
                    old_w = eng.strategy.width
                    eng.set_strategy(self._strategy_table[new_w])
                    self.events.append(("switch", old_w, new_w))

        if dirty and state is not None:   # final evictions: leave rows clean
            state = eng.sched_reset(state, sorted(dirty))
            dirty.clear()
        makespan = now()
        self.last_state = state
        ordered = [results[r.req_id] for r in requests]
        stats = _aggregate(ordered, makespan)
        stats.update(admitted=len(ordered), chunks=chunks,
                     max_resident=max_resident, batch=B, chunk=self.chunk,
                     policy=self.policy.name,
                     age_limit=getattr(self.policy, "age_limit", 0),
                     prefill_chunk=self.prefill_chunk)
        if self.adaptive is not None:
            stats.update(
                strategy_switches=[
                    {"boundary": n, "from": f, "to": t}
                    for n, f, t in self.adaptive.switches],
                width_final=self.engine.strategy.width,
                al_observed=self.adaptive.al_obs)
        return ordered, stats


def serve_static(engine, requests: Sequence[Request], *, batch: int = 8,
                 eos: Optional[int] = None) -> tuple:
    """Static-batching baseline: fixed groups of ``batch`` requests in
    arrival order; a group prefills only after ALL its members have arrived
    (batch formation) and runs until EVERY member finishes (per-sequence
    budgets mask early finishers, but their rows cannot be reused), then the
    next group starts.  Prompts within a group must share one length."""
    reqs = sorted(requests, key=lambda r: (r.arrival, r.req_id))
    results = {}
    t0 = time.perf_counter()

    def now():
        return time.perf_counter() - t0

    for i in range(0, len(reqs), batch):
        group = reqs[i:i + batch]
        wait = max(r.arrival for r in group) - now()
        if wait > 0:
            time.sleep(wait)
        prompts = np.stack([np.asarray(r.tokens, np.int32) for r in group])
        budgets = np.asarray([r.n_tokens for r in group], np.int32)
        t_admit = now()
        out, stats = engine.generate({"tokens": prompts}, budgets, eos=eos)
        if out.ndim == 1:                     # B=1 tail group
            out = out[None]
        t_fin = now()
        for j, r in enumerate(group):
            n = int(stats["n_emitted"][j])
            results[r.req_id] = RequestResult(
                req_id=r.req_id, tokens=out[j, :n].copy(), n_emitted=n,
                arrival=r.arrival, t_admit=t_admit, t_finish=t_fin)

    makespan = now()
    ordered = [results[r.req_id] for r in requests]
    stats = _aggregate(ordered, makespan)
    stats.update(batch=batch)
    return ordered, stats


def poisson_arrivals(n: int, rate: float, *, seed: int = 0) -> np.ndarray:
    """Cumulative Poisson-process arrival times (rate = requests/second)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))
