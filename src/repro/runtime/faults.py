"""Deterministic, seedable fault injection for the serving stack.

Every degradation path the fault-tolerant front end must survive is
injectable here, on a fixed seed, so chaos runs replay bit-identically
in tests, CI and ``benchmarks/sched_bench.py``'s ``record["faults"]``
arm:

* **replica crash** — ``ReplicaFaults.on_boundary`` raises
  ``ReplicaCrash`` at a scheduled boundary index.  The scheduler
  propagates it out of ``boundary()``; the async server catches it,
  finalizes every in-flight request as FAILED via
  ``ContinuousScheduler.fail_all`` (releasing the rows' pages — a
  crashed replica never leaks pool pages) and marks itself unhealthy so
  the router stops routing to it.
* **chunk-step stall / latency spike** — ``on_boundary`` sleeps
  ``stall_s`` with probability ``stall_rate`` before the chunk runs,
  modelling a slow device or a preempted core.  Purely timing: outputs
  are untouched.
* **admission-time pool exhaustion** — ``block_admission`` returns True
  with probability ``exhaust_rate``; the scheduler then defers every
  queued request for that boundary exactly like a genuinely exhausted
  page pool (queueing delay, never corruption or loss).
* **client disconnect** — ``ClientFaults.disconnect_after(req_id)``
  decides, deterministically PER REQUEST ID, whether that client hangs
  up mid-stream and after how many delivered tokens.  Keying on the id
  (not arrival order or wall clock) means a retried request keeps the
  same client behavior on every replica it lands on.

Failure semantics: all injectors are host-side and deterministic given
``(seed, replica name, boundary index / request id)``.  A crash is
terminal for its replica; stalls and exhaustion are transient; a
disconnect becomes a normal ``abort(req_id)`` → CANCELLED at the next
chunk boundary.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Dict, Optional, Tuple

import numpy as np


class ReplicaCrash(RuntimeError):
    """An injected (or detected) fatal replica fault: the engine behind a
    scheduler is gone and every in-flight request on it must fail."""


def _stable_key(name: str) -> int:
    """Seed component for a replica name — stable across processes
    (``hash(str)`` is salted per interpreter, crc32 is not)."""
    return zlib.crc32(name.encode("utf-8"))


@dataclasses.dataclass
class FaultPlan:
    """One seeded chaos schedule for a whole serving deployment.

    ``crash`` maps replica names to the boundary index at which they
    raise ``ReplicaCrash``; rates are per-boundary (stall/exhaust) or
    per-request (cancel) probabilities.  ``injector(name)`` derives the
    per-replica injector, ``client()`` the client-side one; both are
    deterministic functions of ``(seed, name)`` so two runs of the same
    plan inject the same faults at the same points.
    """
    seed: int = 0
    crash: Dict[str, int] = dataclasses.field(default_factory=dict)
    stall_rate: float = 0.0
    stall_s: float = 0.02
    exhaust_rate: float = 0.0
    cancel_rate: float = 0.0
    cancel_after: Tuple[int, int] = (1, 8)   # inclusive token range

    def __post_init__(self):
        for name, rate in (("stall_rate", self.stall_rate),
                           ("exhaust_rate", self.exhaust_rate),
                           ("cancel_rate", self.cancel_rate)):
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {rate}")
        lo, hi = self.cancel_after
        if lo < 1 or hi < lo:
            raise ValueError("cancel_after must be (lo >= 1, hi >= lo)")

    def injector(self, name: str) -> "ReplicaFaults":
        return ReplicaFaults(self, name)

    def client(self) -> "ClientFaults":
        return ClientFaults(self)


class ReplicaFaults:
    """Per-replica injector, wired into ``ContinuousScheduler(faults=)``.

    ``on_boundary(i)`` runs at every boundary entry: it raises
    ``ReplicaCrash`` at the scheduled crash boundary and sleeps
    ``stall_s`` on a ``stall_rate`` draw.  ``block_admission()`` is
    consulted once per boundary by the admission loop."""

    def __init__(self, plan: FaultPlan, name: str):
        self.plan = plan
        self.name = name
        self.crash_boundary = plan.crash.get(name)
        base = [plan.seed, _stable_key(name)]
        self._stall_rng = np.random.default_rng(base + [1])
        self._exhaust_rng = np.random.default_rng(base + [2])
        self.injected: Dict[str, int] = {"stall": 0, "exhaust": 0,
                                         "crash": 0}

    def on_boundary(self, i: int) -> None:
        if self.crash_boundary is not None and i >= self.crash_boundary:
            self.injected["crash"] += 1
            raise ReplicaCrash(
                f"injected crash on {self.name} at boundary {i}")
        if self.plan.stall_rate and \
                self._stall_rng.random() < self.plan.stall_rate:
            self.injected["stall"] += 1
            time.sleep(self.plan.stall_s)

    def block_admission(self) -> bool:
        if self.plan.exhaust_rate and \
                self._exhaust_rng.random() < self.plan.exhaust_rate:
            self.injected["exhaust"] += 1
            return True
        return False


class ClientFaults:
    """Client-side injector (lives with the router, not a replica).

    ``disconnect_after(req_id)`` is a pure function of
    ``(plan.seed, req_id)``: None for a patient client, else the number
    of delivered tokens after which the client hangs up.  The router
    turns a hang-up into ``server.cancel(req_id)`` and the scheduler
    finalizes the request CANCELLED at its next boundary."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def disconnect_after(self, req_id: int) -> Optional[int]:
        if not self.plan.cancel_rate:
            return None
        rng = np.random.default_rng([self.plan.seed, 3, int(req_id)])
        if rng.random() >= self.plan.cancel_rate:
            return None
        lo, hi = self.plan.cancel_after
        return int(rng.integers(lo, hi + 1))
