"""Serving engine: batched sequential decoding + single-sample Ghidorah
speculative decoding, with jitted steps and (optional) profiling hooks that
feed ARCA's measured-time search.

The paper's setting is single-sample (end-user device); ``SpeculativeEngine``
is B=1.  ``BatchEngine`` serves batched requests with plain decode (the
Sequential baseline and the multi-request server example).
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.speculative.tree import Tree, TreeSpec
from repro.core.speculative.verify import SpecState, spec_prefill, spec_step
from repro.runtime.sampling import greedy


class BatchEngine:
    """Uniform-length batched prefill + decode (Sequential baseline)."""

    def __init__(self, model, params, *, max_len=512, window=0,
                 backend="ref"):
        self.model, self.params = model, params
        self.max_len, self.window = max_len, window
        self._decode = jax.jit(
            lambda p, c, t: model.decode(p, c, t, backend=backend))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len, window=window))

    def generate(self, batch, n_tokens: int, *, eos: Optional[int] = None):
        logits, _, cache = self._prefill(self.params, batch)
        cur = greedy(logits[:, -1])
        out = [np.asarray(cur)]
        times = []
        for _ in range(n_tokens - 1):
            t0 = time.perf_counter()
            lg, cache = self._decode(self.params, cache, cur[:, None])
            cur = greedy(lg[:, 0])
            cur.block_until_ready()
            times.append(time.perf_counter() - t0)
            out.append(np.asarray(cur))
            if eos is not None and bool(np.all(np.stack(out[-1]) == eos)):
                break
        return np.stack(out, axis=1), {"step_times": times}


class SpeculativeEngine:
    """Ghidorah speculative serving (B=1): draft -> tree-verify -> accept."""

    def __init__(self, model, heads, params, tree_spec: TreeSpec, *,
                 max_len=512, window=0, backend="ref"):
        self.model, self.heads, self.params = model, heads, params
        self.tree = Tree.from_spec(tree_spec)
        self.max_len, self.window = max_len, window
        self._step = jax.jit(
            lambda p, h, s: spec_step(model, p, h, self.tree, s,
                                      backend=backend))
        self._prefill = jax.jit(
            lambda p, h, b: spec_prefill(model, p, h, b,
                                         max_len=max_len, window=window))

    def generate(self, batch, n_tokens: int, *, eos: Optional[int] = None):
        state = self._prefill(self.params, self.heads, batch)
        out: List[int] = [int(state.cur_token[0])]
        accepts, times = [], []
        while len(out) < n_tokens:
            t0 = time.perf_counter()
            state, emitted, n = self._step(self.params, self.heads, state)
            n0 = int(n[0])
            times.append(time.perf_counter() - t0)
            toks = np.asarray(emitted[0])[:n0]
            accepts.append(n0)
            for t in toks:
                out.append(int(t))
                if eos is not None and t == eos:
                    return np.asarray(out), _stats(accepts, times)
        return np.asarray(out[:n_tokens]), _stats(accepts, times)


def _stats(accepts, times):
    return {
        "acceptance_length": float(np.mean(accepts)) if accepts else 0.0,
        "steps": len(accepts),
        "step_times": times,
    }


def measure_acceptance(model, heads, params, tree_spec: TreeSpec, prompts,
                       n_tokens=64, *, max_len=512) -> float:
    """Empirical acceptance length over a prompt set (ARCA's brute-force
    refinement evaluator + Table-I measurement)."""
    eng = SpeculativeEngine(model, heads, params, tree_spec, max_len=max_len)
    als = []
    for batch in prompts:
        _, stats = eng.generate(batch, n_tokens)
        als.append(stats["acceptance_length"])
    return float(np.mean(als))
