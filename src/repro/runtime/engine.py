"""Serving engines: batched sequential decoding and batched Ghidorah
speculative decoding, with *device-resident chunked drivers*.

Both engines run K decode/speculative steps inside a single jitted
``lax.scan`` and transfer one fixed-size token chunk back to the host —
one host sync per chunk instead of per token.  EOS is handled by a
per-sequence done-mask carried through the scan: finished sequences stop
emitting (their acceptance count drops to 0 / their token slot is padded
with EOS) while the rest of the batch keeps decoding.

``SpeculativeEngine`` accepts any batch size: each sequence accepts its own
chain length per step and the cache commit is a per-sequence masked ring
write (see runtime/cache.py), so positions diverge freely across the batch.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.speculative.tree import Tree, TreeSpec
from repro.core.speculative.verify import spec_prefill, spec_step
from repro.runtime.sampling import greedy

_NO_EOS = -1          # sentinel: no real token id is negative


def _eos_scalar(eos) -> jnp.ndarray:
    return jnp.asarray(_NO_EOS if eos is None else int(eos), jnp.int32)


class BatchEngine:
    """Uniform-length batched prefill + chunked decode (Sequential baseline).

    ``chunk`` = K decode steps fused into one device call via ``lax.scan``;
    K=1 degenerates to the per-step host-synced loop (the old behaviour).
    """

    def __init__(self, model, params, *, max_len=512, window=0,
                 backend="ref", chunk=8):
        self.model, self.params = model, params
        self.max_len, self.window = max_len, window
        self.backend, self.chunk = backend, chunk
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len, window=window))
        self._chunks = {}           # K -> jitted K-step scan

    def _chunk_fn(self, K: int):
        if K not in self._chunks:
            model, backend = self.model, self.backend

            def run(p, cache, cur, done, eos):
                def body(carry, _):
                    cache, cur, done = carry
                    lg, cache = model.decode(p, cache, cur[:, None],
                                             backend=backend)
                    nxt = greedy(lg[:, 0])
                    nxt = jnp.where(done, eos, nxt)     # pad finished seqs
                    done = done | (nxt == eos)
                    return (cache, nxt, done), nxt

                (cache, cur, done), toks = jax.lax.scan(
                    body, (cache, cur, done), None, length=K)
                return cache, cur, done, toks           # toks: (K, B)

            self._chunks[K] = jax.jit(run)
        return self._chunks[K]

    def generate(self, batch, n_tokens: int, *, eos: Optional[int] = None,
                 chunk: Optional[int] = None):
        K = chunk or self.chunk
        eos_val = _eos_scalar(eos)
        logits, _, cache = self._prefill(self.params, batch)
        cur = greedy(logits[:, -1])
        done = cur == eos_val
        out = [np.asarray(cur)]
        times = []
        produced = 1
        while produced < n_tokens and not bool(np.asarray(done).all()):
            t0 = time.perf_counter()
            cache, cur, done, toks = self._chunk_fn(K)(
                self.params, cache, cur, done, eos_val)
            toks = np.asarray(toks)              # ONE host sync per K tokens
            times.append(time.perf_counter() - t0)
            out.extend(toks[i] for i in range(toks.shape[0]))
            produced += toks.shape[0]
        return np.stack(out, axis=1)[:, :n_tokens], \
            {"step_times": times, "chunk": K}


class SpeculativeEngine:
    """Ghidorah speculative serving: draft -> tree-verify -> accept, batched
    over sequences and chunked over steps (K speculative steps per device
    call, one host transfer per chunk)."""

    def __init__(self, model, heads, params, tree_spec: TreeSpec, *,
                 max_len=512, window=0, backend="ref", chunk=8):
        self.model, self.heads, self.params = model, heads, params
        self.tree = Tree.from_spec(tree_spec)
        self.max_depth = tree_spec.max_depth
        self.max_len, self.window = max_len, window
        self.backend, self.chunk = backend, chunk
        # the tree is a jit ARGUMENT of the chunk fns (registered pytree):
        # same-shape trees share one compiled scan — ARCA sweeps many
        # same-width candidates
        self._prefill = jax.jit(
            lambda p, h, b: spec_prefill(model, p, h, b,
                                         max_len=max_len, window=window))
        self._chunks = {}           # K -> jitted K-step scan

    def set_tree(self, tree_spec: TreeSpec) -> None:
        """Swap the verification tree WITHOUT dropping compiled steps (used
        by ``measure_acceptance`` across ARCA's candidate trees)."""
        self.tree = Tree.from_spec(tree_spec)
        self.max_depth = tree_spec.max_depth

    def _chunk_fn(self, K: int):
        if K not in self._chunks:
            model, backend = self.model, self.backend

            def run(p, h, t, state, done, eos):
                def body(carry, _):
                    state, done = carry
                    state, emitted, n = spec_step(model, p, h, t, state,
                                                  backend=backend)
                    idx = jnp.arange(emitted.shape[1])[None, :]
                    valid = idx < n[:, None]
                    is_eos = valid & (emitted == eos)
                    has_eos = jnp.any(is_eos, axis=1)
                    # truncate each sequence's emission at its first EOS
                    n_cut = jnp.where(has_eos,
                                      jnp.argmax(is_eos, axis=1) + 1, n)
                    n_eff = jnp.where(done, 0, n_cut)
                    emitted = jnp.where(idx < n_eff[:, None], emitted, eos)
                    done = done | has_eos
                    return (state, done), (emitted, n_eff)

                (state, done), (toks, ns) = jax.lax.scan(
                    body, (state, done), None, length=K)
                # toks: (K, B, Dmax) eos-padded; ns: (K, B) accepted counts
                return state, done, toks, ns

            self._chunks[K] = jax.jit(run)
        return self._chunks[K]

    def generate(self, batch, n_tokens: int, *, eos: Optional[int] = None,
                 chunk: Optional[int] = None):
        K = chunk or self.chunk
        eos_val = _eos_scalar(eos)
        state = self._prefill(self.params, self.heads, batch)
        B = int(state.cur_token.shape[0])
        first = np.asarray(state.cur_token)
        outs = [[int(first[b])] for b in range(B)]
        done = state.cur_token == eos_val
        done_np = np.asarray(done)
        accepts, times = [], []

        def active(b):
            return not done_np[b] and len(outs[b]) < n_tokens

        while any(active(b) for b in range(B)):
            t0 = time.perf_counter()
            state, done, toks, ns = self._chunk_fn(K)(
                self.params, self.heads, self.tree, state, done, eos_val)
            toks_np = np.asarray(toks)           # ONE host sync per chunk
            ns_np = np.asarray(ns)
            done_np = np.asarray(done)
            times.append(time.perf_counter() - t0)
            for k in range(ns_np.shape[0]):
                for b in range(B):
                    m = int(ns_np[k, b])
                    if m and len(outs[b]) < n_tokens:
                        # count only steps whose tokens are (at least partly)
                        # kept: overshoot steps past n_tokens would bias the
                        # acceptance stats ARCA's evaluator consumes
                        accepts.append(m)
                        outs[b].extend(int(x) for x in toks_np[k, b, :m])

        stats = _stats(accepts, times)
        stats["chunk"] = K
        if B == 1:
            return np.asarray(outs[0][:n_tokens]), stats
        out = np.full((B, n_tokens), int(eos_val), np.int32)
        for b in range(B):
            seq = np.asarray(outs[b][:n_tokens], np.int32)
            out[b, :len(seq)] = seq
        return out, stats


def _stats(accepts, times):
    accepts = np.asarray(accepts)
    return {
        "acceptance_length": float(np.mean(accepts)) if accepts.size else 0.0,
        "steps": int(accepts.size),
        "step_times": times,
    }


def measure_acceptance(model, heads, params, tree_spec: TreeSpec, prompts,
                       n_tokens=64, *, max_len=512,
                       engine: Optional[SpeculativeEngine] = None) -> float:
    """Empirical acceptance length over a prompt set (ARCA's brute-force
    refinement evaluator + Table-I measurement).

    Pass ``engine`` to reuse a constructed ``SpeculativeEngine`` across
    candidate trees: the tree is swapped via ``set_tree`` and the jitted
    step is shared for same-shape trees, so ARCA's evaluator does not pay
    compile time per candidate.
    """
    if engine is None:
        engine = SpeculativeEngine(model, heads, params, tree_spec,
                                   max_len=max_len)
    else:
        engine.set_tree(tree_spec)
    als = []
    for batch in prompts:
        _, stats = engine.generate(batch, n_tokens)
        als.append(stats["acceptance_length"])
    return float(np.mean(als))
