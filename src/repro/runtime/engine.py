"""Serving engines: batched sequential decoding and batched Ghidorah
speculative decoding, with *device-resident chunked drivers*.

Both engines run K decode/speculative steps inside a single jitted
``lax.scan`` and transfer one fixed-size token chunk back to the host —
one host sync per chunk instead of per token.

Per-sequence liveness is a done-mask carried through the scan.  A row goes
(and stays) done when any of three conditions hits:

  * EOS — the sequence emitted its end token (its slot pads with EOS);
  * budget — ``rem (B,)`` tokens-still-wanted reaches 0, so a sequence that
    hit ``n_tokens`` without EOS stops burning decode steps while the rest
    of the batch finishes;
  * capacity — a full (window=0) KV cache would wrap its ring past
    ``max_len`` (``cache.capacity_left``), so near-capacity decode freezes
    instead of silently overwriting its oldest KV and corrupting attention.

Done rows commit nothing in the speculative engine (``spec_step``'s
``active`` mask zeroes their acceptance, so ``pos`` stays put); in the
sequential engine they keep stepping but their emission is masked.  The
host loop also clamps the chunk length to the largest remaining budget
(rounded up to a power of two so the compiled-scan cache stays small), so
no full K-step chunk is launched when every live sequence needs fewer.

Slot lifecycle (continuous batching, see runtime/scheduler.py): each batch
row is a *slot*.  The scheduler admits a request by prefilling it at B=1
and inserting that row into the resident state (``sched_insert``), runs
chunks over the whole bank, and at each chunk boundary evicts rows that
went done — freeing the row (``sched_reset``) for the next queued request.
Admission/eviction only ever happen between chunks, so the jitted K-step
scan is reused unchanged; inside a chunk a freed row simply rides along
fully masked.

Paged KV (``paged=True``): the bank's KV lives in one shared page pool
(runtime/cache.py ``PagedKVCache``) instead of B dense ``max_len`` rows.
Admission reserves ``ceil((prompt + budget + overshoot) / page_size)``
pages from a host-side free list, eviction returns them
(``sched_release``), and ``sched_can_admit`` lets the scheduler DEFER a
request while the pool is exhausted instead of failing it.  A row that
somehow outgrows its reservation (e.g. ``generate`` on a pool smaller than
the batch's total need — reservations are then partial) freezes exactly
like a dense row hitting ``max_len``, with the shortfall in
``stats["n_emitted"]``; its overflow writes land in the pool's trash page,
never in a neighbor's reservation.  Recurrent/cross state keeps the dense
per-row layout — only KV pages.

All state-threading jits (the K-step chunk scans, ``sched_admit``,
``sched_insert``, ``sched_reset``) DONATE the carried state, so the cache
— one large pool when paged — is updated in place instead of copied every
chunk.

``SpeculativeEngine`` accepts any batch size: each sequence accepts its own
chain length per step and the cache commit is a per-sequence masked ring
write (see runtime/cache.py), so positions diverge freely across the batch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.speculative.tree import Tree, TreeSpec, chain_spec
from repro.core.speculative.verify import SpecState, spec_prefill, spec_step
from repro.runtime.cache import (PageAllocator, blank_paged_rows,
                                 capacity_left, insert_rows, pages_for,
                                 paginate_cache, reset_rows, slice_row,
                                 tile_rows, write_row_at)
from repro.runtime.sampling import greedy

_NO_EOS = -1          # sentinel: no real token id is negative


def _eos_scalar(eos) -> jnp.ndarray:
    return jnp.asarray(_NO_EOS if eos is None else int(eos), jnp.int32)


def _budget(n_tokens, batch) -> np.ndarray:
    """Per-sequence token budgets: scalar broadcast or (B,) array."""
    b = np.broadcast_to(np.asarray(n_tokens, np.int32), (batch,)).copy()
    if np.any(b < 1):
        raise ValueError("n_tokens must be >= 1 per sequence")
    return b


def _pow2_chunk(k_max: int, need: int) -> int:
    """Smallest power-of-two chunk covering ``need`` steps, capped at
    ``k_max``: bounds the tail-chunk overshoot AND the set of compiled scan
    lengths to {1, 2, 4, ..., k_max}."""
    k = 1
    while k < need and k < k_max:
        k *= 2
    return min(k, k_max)


def _prompt_len(batch) -> int:
    """Decoder-sequence length of a prefill batch: tokens plus any VLM
    patch embeds that join the decoder sequence (encoder frames do not)."""
    n = int(batch["tokens"].shape[1])
    if "patch_embeds" in batch:
        n += int(batch["patch_embeds"].shape[1])
    return n


class _PagedPoolMixin:
    """Shared page-reservation bookkeeping for paged engines.

    The allocator is HOST state: pages move between the free list and rows
    only at admission/eviction boundaries (and once per ``generate``), so
    reservation never syncs the device.  ``_overshoot`` is the engine's
    worst-case slots written past the budget (speculative: one full
    accepted chain of ``max_depth``)."""

    def _paged_init(self, *, paged, page_size, pool_pages):
        if paged and self.window:
            raise ValueError("paged KV supports full attention only "
                             "(sliding windows stay dense: the ring IS the "
                             "window)")
        self.paged, self.page_size = paged, page_size
        self.pool_pages = pool_pages
        self.max_pages = pages_for(self.max_len, page_size) if paged else 0
        self._alloc: Optional[PageAllocator] = None      # sched-bank state
        self._row_pages = {}
        self._extends = {}          # piece width -> jitted prefill-extend

    def _need_pages(self, prompt_len: int, budget: int, n_total: int) -> int:
        return min(pages_for(prompt_len + budget + self._overshoot,
                             self.page_size),
                   self.max_pages, n_total)

    def _reserve_tables(self, batch, budget):
        """Per-row page reservations for a ``generate`` call.  When the
        pool cannot cover a row's need the reservation is PARTIAL — the row
        then freezes at ``capacity_left`` with its shortfall reported in
        ``n_emitted``, it never borrows a neighbor's pages."""
        B = int(batch["tokens"].shape[0])
        n_total = self.pool_pages or B * self.max_pages
        alloc = PageAllocator(n_total)
        prompt = _prompt_len(batch)
        tables = np.full((B, self.max_pages), -1, np.int32)
        for b in range(B):
            pages = alloc.alloc_upto(
                self._need_pages(prompt, int(budget[b]), n_total))
            tables[b, :len(pages)] = pages
        return jnp.asarray(tables), n_total

    # ---- scheduler-facing reservation hooks ------------------------------
    def sched_footprint(self, prompt_len: int, n_tokens: int) -> int:
        """Slot cost of a request — what the scheduler's size-ordered
        admission policies (SJF/LPT) rank by: reserved pages when paged,
        otherwise logical slots (prompt + budget + overshoot)."""
        need = int(prompt_len) + int(n_tokens) + self._overshoot
        if self.paged:
            return pages_for(need, self.page_size)
        return need

    @property
    def sched_chunked_ok(self) -> bool:
        """Whether this engine supports chunked prefill (piecewise
        ``sched_extend`` admission): attention-only families with full
        attention.  Recurrent families (Mamba/xLSTM/hybrid) prefill their
        state sequentially and stay on whole-prompt admission; sliding
        windows stay dense/whole for the same reason the paged path does."""
        return self.window == 0 and \
            getattr(self.model, "family", "") in ("dense", "moe", "vlm")

    def sched_can_admit(self, prompt_len: int, n_tokens: int) -> bool:
        """False while the pool cannot fund the request's reservation — the
        scheduler then DEFERS admission until evictions free pages.  A
        request bigger than the whole pool caps at the pool (admitted once
        fully free; it freezes with a shortfall, it is not rejected)."""
        if not self.paged or self._alloc is None:
            return True
        return self._alloc.available >= self._need_pages(
            prompt_len, n_tokens, self._alloc.n_pages)

    def sched_release(self, b: int) -> None:
        """Return an evicted row's pages to the pool (host-side; the row's
        device-side table is cleared by the boundary's reset/insert before
        the next chunk runs)."""
        if self.paged and self._alloc is not None:
            self._alloc.free(self._row_pages.pop(b, ()))

    def _sched_pages(self, b: int, prompt_len: int, n_tokens: int):
        """Allocate row ``b``'s reservation (gated by ``sched_can_admit``),
        -1-padded to the static ``max_pages`` table width."""
        pages = self._alloc.alloc(self._need_pages(prompt_len, n_tokens,
                                                   self._alloc.n_pages))
        self._row_pages[b] = pages
        out = np.full((self.max_pages,), -1, np.int32)
        out[:len(pages)] = pages
        return jnp.asarray(out)

    # ---- chunked-prefill hook (runtime/scheduler.py prefill_chunk) -------
    def _extend_fn(self, C: int):
        """Per-piece-width jit of the engine's ``_extend_row``."""
        if C not in self._extends:
            model, row_fn = self.model, self._extend_row
            tree = Tree.from_spec(chain_spec(C))

            def run(p, st, b, toks, nv):
                return row_fn(model, p, st, b, toks, nv, tree)

            self._extends[C] = jax.jit(run, donate_argnums=(1,))
        return self._extends[C]

    def sched_extend(self, state, b, tokens, n_valid):
        """One chunked-prefill piece: run ``tokens (1, C)`` (tail pieces
        right-padded; ``n_valid`` real entries) through the causal verify
        path against row ``b``'s existing cache and splice the piece's KVs
        in at the row's offset.  Returns (state, last-real-token device
        scalar — after the final piece that token is the request's first
        emission, and the spec engine's row additionally carries the
        drafting ``cur_token``/``hidden`` of the last real position, so the
        finished slot is indistinguishable from a whole-prompt admission).
        Compiled once per piece width C."""
        return self._extend_fn(int(tokens.shape[1]))(
            self.params, state, jnp.asarray(b, jnp.int32),
            jnp.asarray(tokens, jnp.int32), jnp.asarray(n_valid, jnp.int32))


def _extend_seq_row(model, params, state, b, tokens, n_valid, tree):
    """Chunked-prefill piece for the sequential engine: causal multi-token
    forward over row ``b``'s cache view (``tree`` is the chain spec — plain
    causal attention through the tree-verify path, ref numerics) followed by
    a partial-row KV insert at the row's current offset."""
    cache, cur = state
    row_view = slice_row(cache, b)
    logits, extras = model.verify(params, row_view, tokens, tree,
                                  backend="ref")
    k1, v1 = extras["tree_kv"]                       # (L, 1, C, Hkv, hd)
    cache = write_row_at(cache, b, k1[:, 0], v1[:, 0],
                         row_view.kv.pos[0], n_valid)
    last = greedy(jnp.take(logits[0], n_valid - 1, axis=0))
    return (cache, cur.at[b].set(last)), last


def _extend_spec_row(model, params, state, b, tokens, n_valid, tree):
    """Spec-engine chunked-prefill piece: as ``_extend_seq_row`` plus the
    drafting carry — ``cur_token``/``hidden`` track the last REAL position
    so the final piece leaves the row exactly as ``spec_prefill`` would."""
    row_view = slice_row(state.cache, b)
    logits, extras = model.verify(params, row_view, tokens, tree,
                                  backend="ref")
    k1, v1 = extras["tree_kv"]
    cache = write_row_at(state.cache, b, k1[:, 0], v1[:, 0],
                         row_view.kv.pos[0], n_valid)
    last = greedy(jnp.take(logits[0], n_valid - 1, axis=0))
    hid = jnp.take(extras["hidden"][0], n_valid - 1, axis=0)
    return SpecState(cache=cache,
                     cur_token=state.cur_token.at[b].set(last),
                     hidden=state.hidden.at[b].set(hid)), last


class BatchEngine(_PagedPoolMixin):
    """Uniform-length batched prefill + chunked decode (Sequential baseline).

    ``chunk`` = K decode steps fused into one device call via ``lax.scan``;
    K=1 degenerates to the per-step host-synced loop (the old behaviour).

    ``paged=True`` swaps the bank's dense per-row KV for the shared page
    pool (``pool_pages`` total; default ``B * ceil(max_len / page_size)``,
    the dense-equivalent capacity — shrink it to serve a larger bank at
    fixed memory).
    """

    _overshoot = 1        # decode writes 1 slot past the last emitted token
    _extend_row = staticmethod(_extend_seq_row)      # chunked-prefill piece

    def __init__(self, model, params, *, max_len=512, window=0,
                 backend="ref", chunk=8, paged=False, page_size=16,
                 pool_pages=None):
        self.model, self.params = model, params
        self.max_len, self.window = max_len, window
        self.backend, self.chunk = backend, chunk
        self._paged_init(paged=paged, page_size=page_size,
                         pool_pages=pool_pages)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len, window=window))
        self._chunks = {}           # K -> jitted K-step scan
        # state-threading jits donate their carried state: the cache (one
        # large pool when paged) is aliased in place, never copied
        self._insert = jax.jit(_insert_seq_row, donate_argnums=(0,))
        self._reset = jax.jit(_reset_seq_rows, donate_argnums=(0,))
        # fused admission: B=1 prefill + row splice in ONE device call (a
        # per-request dispatch on the scheduler's hot path)
        self._admit = jax.jit(
            lambda p, st, b, bt: _admit_seq_row(model, p, st, b, bt,
                                                max_len=max_len,
                                                window=window),
            donate_argnums=(1,))
        if paged:
            # prompt-sized dense prefill: paginated right after (generate)
            # or spliced into the paged bank (admission) — never a full
            # (B, max_len) dense transient
            self._prefill_prompt = jax.jit(
                lambda p, b: model.prefill(p, b, max_len=1, window=0))
            self._prefills_paged = {}    # n_pages -> fused prefill+paginate
            self._admit_paged = jax.jit(
                lambda p, st, b, bt, pages: _admit_seq_row_paged(
                    model, p, st, b, bt, pages),
                donate_argnums=(1,))
            self._insert_paged = jax.jit(_insert_seq_row_paged,
                                         donate_argnums=(0,))

    def _prefill_paged_fn(self, n_total: int):
        if n_total not in self._prefills_paged:
            model, ps = self.model, self.page_size

            def run(p, b, tables):
                logits, _, cache = model.prefill(p, b, max_len=1, window=0)
                return logits, paginate_cache(cache, tables, page_size=ps,
                                              n_pages=n_total)

            self._prefills_paged[n_total] = jax.jit(run)
        return self._prefills_paged[n_total]

    def _chunk_fn(self, K: int):
        if K not in self._chunks:
            model, backend = self.model, self.backend

            def run(p, cache, cur, done, rem, eos):
                def body(carry, _):
                    cache, cur, done, rem = carry
                    done = done | (rem <= 0) | (capacity_left(cache) < 1)
                    kv0 = cache.kv
                    lg, cache = model.decode(p, cache, cur[:, None],
                                             backend=backend)
                    if kv0 is not None:
                        # the sequential body decodes EVERY row, done ones
                        # included — restore their key_pos/pos so a done
                        # row's KV bookkeeping is frozen (its garbage k/v
                        # write stays invisible at key_pos -1 and is
                        # overwritten by the slot's next real write).
                        # Without this a mid-chunked-prefill row (done-
                        # masked while its prompt pieces land) would have
                        # its piece offsets corrupted between pieces.
                        kv = cache.kv
                        cache = dataclasses.replace(
                            cache, kv=dataclasses.replace(
                                kv,
                                key_pos=jnp.where(done[:, None], kv0.key_pos,
                                                  kv.key_pos),
                                pos=jnp.where(done, kv0.pos, kv.pos)))
                    nxt = greedy(lg[:, 0])
                    nxt = jnp.where(done, eos, nxt)     # pad finished seqs
                    emit = ~done
                    rem = rem - emit.astype(jnp.int32)
                    done = done | (nxt == eos)
                    return (cache, nxt, done, rem), (nxt, emit)

                (cache, cur, done, rem), (toks, emit) = jax.lax.scan(
                    body, (cache, cur, done, rem), None, length=K)
                return cache, cur, done, rem, toks, emit  # toks/emit: (K, B)

            # donate the scan carry (cache/cur/done/rem): the cache — ONE
            # pool-sized buffer in paged mode — is updated in place every
            # chunk instead of being copied (ROADMAP donation item)
            self._chunks[K] = jax.jit(run, donate_argnums=(1, 2, 3, 4))
        return self._chunks[K]

    def generate(self, batch, n_tokens, *, eos: Optional[int] = None,
                 chunk: Optional[int] = None):
        """``n_tokens``: int or (B,) per-sequence budgets.  Returns
        ``(out (B, max_budget), stats)`` — rows past their own budget /
        EOS / capacity freeze are padded with ``eos`` (-1 if None); real
        per-sequence counts are in ``stats["n_emitted"]``."""
        K = chunk or self.chunk
        eos_val = _eos_scalar(eos)
        B = int(batch["tokens"].shape[0])
        budget = _budget(n_tokens, B)
        if self.paged:
            tables, n_total = self._reserve_tables(batch, budget)
            logits, cache = self._prefill_paged_fn(n_total)(
                self.params, batch, tables)
        else:
            logits, _, cache = self._prefill(self.params, batch)
        cur = greedy(logits[:, -1])
        n_max = int(budget.max())
        done = cur == eos_val
        rem = jnp.asarray(budget - 1)
        done_np, rem_np = np.asarray(done), budget - 1
        out = [np.asarray(cur)]
        emits = []
        times = []
        while np.any(~done_np & (rem_np > 0)):
            need = int(rem_np[~done_np & (rem_np > 0)].max())
            t0 = time.perf_counter()
            cache, cur, done, rem, toks, emit = self._chunk_fn(
                _pow2_chunk(K, need))(
                self.params, cache, cur, done, rem, eos_val)
            toks = np.asarray(toks)              # ONE host sync per chunk
            emit_np = np.asarray(emit)
            done_np, rem_np = np.asarray(done), np.asarray(rem)
            times.append(time.perf_counter() - t0)
            out.extend(toks[i] for i in range(toks.shape[0]))
            emits.extend(emit_np[i] for i in range(emit_np.shape[0]))
        n_emitted = np.ones((B,), np.int64)      # prefill's first token
        if emits:
            n_emitted += np.stack(emits, axis=0).sum(axis=0)
        res = np.full((B, n_max), int(eos_val), np.int32)
        out = np.stack(out, axis=1)
        w = min(out.shape[1], n_max)
        res[:, :w] = out[:, :w]
        stats = {"step_times": times, "chunk": K,
                 "n_emitted": n_emitted.astype(np.int32),
                 "emitted_total": int(n_emitted.sum())}
        return res, stats

    # ---- continuous-batching slot protocol (runtime/scheduler.py) --------
    def sched_prefill(self, batch):
        """B=1 prefill -> opaque row state (cache, cur).  Paged engines
        prefill at prompt size (the dense row is a splice source, not a
        resident)."""
        if self.paged:
            logits, _, cache = self._prefill_prompt(self.params, batch)
        else:
            logits, _, cache = self._prefill(self.params, batch)
        return (cache, greedy(logits[:, -1]))

    @staticmethod
    def sched_first(row):
        return int(np.asarray(row[1])[0])

    def sched_blank(self, row, batch):
        cache, cur = row
        if self.paged:
            n_total = self.pool_pages or batch * self.max_pages
            self._alloc = PageAllocator(n_total)
            self._row_pages = {}
            bank = blank_paged_rows(cache, batch, page_size=self.page_size,
                                    n_pages=n_total, max_len=self.max_len)
            return (bank, jnp.repeat(cur, batch, axis=0))
        return (tile_rows(cache, batch), jnp.repeat(cur, batch, axis=0))

    def sched_insert(self, state, b, row, *, prompt_len=None, n_tokens=None):
        if self.paged:
            pages = self._sched_pages(b, prompt_len, n_tokens)
            return self._insert_paged(state, jnp.asarray(b, jnp.int32), row,
                                      pages)
        return self._insert(state, jnp.asarray(b, jnp.int32), row)

    def sched_admit(self, state, b, batch, *, n_tokens=None,
                    reserve_len=None):
        """Fused prefill+insert; returns (state, first-token device scalar —
        unsynced, the caller materializes it lazily).  ``reserve_len``
        overrides the page reservation's prompt length — chunked prefill
        admits only the FIRST piece here but must reserve for the whole
        prompt."""
        if self.paged:
            plen = reserve_len if reserve_len is not None \
                else _prompt_len(batch)
            pages = self._sched_pages(b, plen, n_tokens)
            return self._admit_paged(self.params, state,
                                     jnp.asarray(b, jnp.int32), batch, pages)
        return self._admit(self.params, state, jnp.asarray(b, jnp.int32),
                           batch)

    def sched_reset(self, state, b):
        mask = np.zeros((int(state[1].shape[0]),), bool)
        mask[b] = True
        return self._reset(state, mask)

    def sched_step(self, state, done, rem, K, eos_val):
        cache, cur = state
        cache, cur, done, rem, toks, emit = self._chunk_fn(K)(
            self.params, cache, cur, done, rem, eos_val)
        return (cache, cur), done, rem, (toks, emit)

    @staticmethod
    def sched_emitted(raw):
        toks, emit = (np.asarray(x) for x in raw)
        K, B = toks.shape
        return [[int(toks[k, b]) for k in range(K) if emit[k, b]]
                for b in range(B)]


def _insert_seq_row(state, b, row):
    cache, cur = state
    rcache, rcur = row
    return (insert_rows(cache, b, rcache), cur.at[b].set(rcur[0]))


def _insert_seq_row_paged(state, b, row, pages):
    cache, cur = state
    rcache, rcur = row
    return (insert_rows(cache, b, rcache, pages=pages),
            cur.at[b].set(rcur[0]))


def _admit_seq_row(model, params, state, b, batch, *, max_len, window):
    logits, _, cache = model.prefill(params, batch, max_len=max_len,
                                     window=window)
    cur = greedy(logits[:, -1])
    return _insert_seq_row(state, b, (cache, cur)), cur[0]


def _admit_seq_row_paged(model, params, state, b, batch, pages):
    logits, _, cache = model.prefill(params, batch, max_len=1, window=0)
    cur = greedy(logits[:, -1])
    return _insert_seq_row_paged(state, b, (cache, cur), pages), cur[0]


def _reset_seq_rows(state, mask):
    cache, cur = state
    # a freed slot must be fully inert, carry included: ``cur`` seeds the
    # next chunk's decode input, so a stale token would feed the dead
    # request's suffix back through the (masked) row until re-admission
    return (reset_rows(cache, mask),
            jnp.where(mask, jnp.zeros_like(cur), cur))


def _reset_spec_rows(state, mask):
    # cache reset alone is NOT enough: a freed speculative slot used to
    # keep its stale ``cur_token``/``hidden``, so the evicted request's
    # last state kept driving (masked) drafts — and once freed pages are
    # recycled immediately, a stale carry is one masking bug away from
    # leaking into a neighbor.  Clear the whole row.
    mask = jnp.asarray(mask)
    return type(state)(cache=reset_rows(state.cache, mask),
                       cur_token=jnp.where(mask,
                                           jnp.zeros_like(state.cur_token),
                                           state.cur_token),
                       hidden=jnp.where(mask[:, None],
                                        jnp.zeros_like(state.hidden),
                                        state.hidden))


class SpeculativeEngine(_PagedPoolMixin):
    """Ghidorah speculative serving: draft -> tree-verify -> accept, batched
    over sequences and chunked over steps (K speculative steps per device
    call, one host transfer per chunk).

    ``paged=True`` as in ``BatchEngine``; the per-row reservation carries a
    ``max_depth`` overshoot because one speculative step can commit a full
    accepted chain past the budget.
    """

    _extend_row = staticmethod(_extend_spec_row)     # chunked-prefill piece

    def __init__(self, model, heads, params, tree_spec: TreeSpec, *,
                 max_len=512, window=0, backend="ref", chunk=8, paged=False,
                 page_size=16, pool_pages=None):
        self.model, self.heads, self.params = model, heads, params
        self.tree = Tree.from_spec(tree_spec)
        self.max_depth = tree_spec.max_depth
        self.max_len, self.window = max_len, window
        self.backend, self.chunk = backend, chunk
        self._paged_init(paged=paged, page_size=page_size,
                         pool_pages=pool_pages)
        # the tree is a jit ARGUMENT of the chunk fns (registered pytree):
        # same-shape trees share one compiled scan — ARCA sweeps many
        # same-width candidates
        self._prefill = jax.jit(
            lambda p, h, b: spec_prefill(model, p, h, b,
                                         max_len=max_len, window=window))
        self._chunks = {}           # K -> jitted K-step scan
        self._insert = jax.jit(_insert_spec_row, donate_argnums=(0,))
        self._reset = jax.jit(_reset_spec_rows, donate_argnums=(0,))
        self._admit = jax.jit(
            lambda p, h, st, b, bt: _admit_spec_row(model, p, h, st, b, bt,
                                                    max_len=max_len,
                                                    window=window),
            donate_argnums=(2,))
        if paged:
            self._prefill_prompt = jax.jit(
                lambda p, h, b: spec_prefill(model, p, h, b, max_len=1,
                                             window=0))
            self._prefills_paged = {}    # n_pages -> fused prefill+paginate
            self._admit_paged = jax.jit(
                lambda p, h, st, b, bt, pages: _admit_spec_row_paged(
                    model, p, h, st, b, bt, pages),
                donate_argnums=(2,))
            self._insert_paged = jax.jit(_insert_spec_row_paged,
                                         donate_argnums=(0,))

    @property
    def _overshoot(self):
        # worst case slots written past the budget: one full accepted chain
        return self.max_depth

    def _prefill_paged_fn(self, n_total: int):
        if n_total not in self._prefills_paged:
            model, ps = self.model, self.page_size

            def run(p, h, b, tables):
                st = spec_prefill(model, p, h, b, max_len=1, window=0)
                return SpecState(
                    cache=paginate_cache(st.cache, tables, page_size=ps,
                                         n_pages=n_total),
                    cur_token=st.cur_token, hidden=st.hidden)

            self._prefills_paged[n_total] = jax.jit(run)
        return self._prefills_paged[n_total]

    def set_tree(self, tree_spec: TreeSpec) -> None:
        """Swap the verification tree WITHOUT dropping compiled steps (used
        by ``measure_acceptance`` across ARCA's candidate trees)."""
        self.tree = Tree.from_spec(tree_spec)
        self.max_depth = tree_spec.max_depth

    def _chunk_fn(self, K: int):
        if K not in self._chunks:
            model, backend = self.model, self.backend

            def run(p, h, t, state, done, rem, eos):
                def body(carry, _):
                    state, done, rem = carry
                    # capacity guard BEFORE the step: a commit may write up
                    # to max_depth tokens, so freeze once the ring cannot
                    # take a worst-case chain without wrapping
                    done = done | (rem <= 0) | \
                        (capacity_left(state.cache) < t.max_depth)
                    active = ~done
                    state, emitted, n = spec_step(model, p, h, t, state,
                                                  backend=backend,
                                                  active=active)
                    idx = jnp.arange(emitted.shape[1])[None, :]
                    valid = idx < n[:, None]
                    is_eos = valid & (emitted == eos)
                    has_eos = jnp.any(is_eos, axis=1)
                    # truncate each sequence's emission at its first EOS
                    n_cut = jnp.where(has_eos,
                                      jnp.argmax(is_eos, axis=1) + 1, n)
                    n_eff = jnp.where(active, n_cut, 0)
                    emitted = jnp.where(idx < n_eff[:, None], emitted, eos)
                    done = done | has_eos
                    rem = rem - n_eff
                    return (state, done, rem), (emitted, n_eff)

                (state, done, rem), (toks, ns) = jax.lax.scan(
                    body, (state, done, rem), None, length=K)
                # toks: (K, B, Dmax) eos-padded; ns: (K, B) accepted counts
                return state, done, rem, toks, ns

            # donate the scan carry (state incl. the KV pool, done, rem):
            # in-place chunk updates, no per-chunk cache copy
            self._chunks[K] = jax.jit(run, donate_argnums=(3, 4, 5))
        return self._chunks[K]

    def generate(self, batch, n_tokens, *, eos: Optional[int] = None,
                 chunk: Optional[int] = None):
        """``n_tokens``: int or (B,) per-sequence budgets.  B=1 returns a
        1-D token array, B>1 a (B, max_budget) array; rows past their
        budget / EOS / capacity freeze pad with ``eos`` (-1 if None) and
        ``stats["n_emitted"]`` has the real per-sequence counts."""
        K = chunk or self.chunk
        eos_val = _eos_scalar(eos)
        B = int(batch["tokens"].shape[0])
        budget = _budget(n_tokens, B)
        if self.paged:
            tables, n_total = self._reserve_tables(batch, budget)
            state = self._prefill_paged_fn(n_total)(
                self.params, self.heads, batch, tables)
        else:
            state = self._prefill(self.params, self.heads, batch)
        n_max = int(budget.max())
        first = np.asarray(state.cur_token)
        outs = [[int(first[b])] for b in range(B)]
        done = state.cur_token == eos_val
        rem = jnp.asarray(budget - 1)
        done_np, rem_np = np.asarray(done), budget - 1
        accepts, times = [], []

        while np.any(~done_np & (rem_np > 0)):
            # every live step emits >= 1 token, so the largest remaining
            # budget bounds the steps still needed — no full-K tail chunks
            need = int(rem_np[~done_np & (rem_np > 0)].max())
            t0 = time.perf_counter()
            state, done, rem, toks, ns = self._chunk_fn(
                _pow2_chunk(K, need))(
                self.params, self.heads, self.tree, state, done, rem, eos_val)
            toks_np = np.asarray(toks)           # ONE host sync per chunk
            ns_np = np.asarray(ns)
            done_np, rem_np = np.asarray(done), np.asarray(rem)
            times.append(time.perf_counter() - t0)
            for k in range(ns_np.shape[0]):
                for b in range(B):
                    m = int(ns_np[k, b])
                    if m and len(outs[b]) < budget[b]:
                        # count only steps whose tokens are (at least partly)
                        # kept: overshoot steps past n_tokens would bias the
                        # acceptance stats ARCA's evaluator consumes
                        accepts.append(m)
                        outs[b].extend(int(x) for x in toks_np[k, b, :m])

        n_emitted = np.asarray(
            [min(len(outs[b]), int(budget[b])) for b in range(B)], np.int32)
        stats = _stats(accepts, times)
        stats["chunk"] = K
        stats["n_emitted"] = n_emitted
        stats["emitted_total"] = int(n_emitted.sum())
        out = np.full((B, n_max), int(eos_val), np.int32)
        for b in range(B):
            seq = np.asarray(outs[b][:budget[b]], np.int32)
            out[b, :len(seq)] = seq
        if B == 1:
            return out[0], stats
        return out, stats

    # ---- continuous-batching slot protocol (runtime/scheduler.py) --------
    def sched_prefill(self, batch):
        """B=1 prefill -> opaque row state (a SpecState).  Paged engines
        prefill at prompt size (the dense row is a splice source)."""
        if self.paged:
            return self._prefill_prompt(self.params, self.heads, batch)
        return self._prefill(self.params, self.heads, batch)

    @staticmethod
    def sched_first(row):
        return int(np.asarray(row.cur_token)[0])

    def sched_blank(self, row, batch):
        if self.paged:
            n_total = self.pool_pages or batch * self.max_pages
            self._alloc = PageAllocator(n_total)
            self._row_pages = {}
            bank = blank_paged_rows(row.cache, batch,
                                    page_size=self.page_size,
                                    n_pages=n_total, max_len=self.max_len)
        else:
            bank = tile_rows(row.cache, batch)
        return type(row)(cache=bank,
                         cur_token=jnp.repeat(row.cur_token, batch, axis=0),
                         hidden=jnp.repeat(row.hidden, batch, axis=0))

    def sched_insert(self, state, b, row, *, prompt_len=None, n_tokens=None):
        if self.paged:
            pages = self._sched_pages(b, prompt_len, n_tokens)
            return self._insert_paged(state, jnp.asarray(b, jnp.int32), row,
                                      pages)
        return self._insert(state, jnp.asarray(b, jnp.int32), row)

    def sched_admit(self, state, b, batch, *, n_tokens=None,
                    reserve_len=None):
        """Fused prefill+insert; returns (state, first-token device scalar —
        unsynced, the caller materializes it lazily).  ``reserve_len``: see
        ``BatchEngine.sched_admit`` (chunked prefill reserves for the whole
        prompt while inserting only its first piece)."""
        if self.paged:
            plen = reserve_len if reserve_len is not None \
                else _prompt_len(batch)
            pages = self._sched_pages(b, plen, n_tokens)
            return self._admit_paged(self.params, self.heads, state,
                                     jnp.asarray(b, jnp.int32), batch, pages)
        return self._admit(self.params, self.heads, state,
                           jnp.asarray(b, jnp.int32), batch)

    def sched_reset(self, state, b):
        mask = np.zeros((int(state.cur_token.shape[0]),), bool)
        mask[b] = True
        return self._reset(state, mask)

    def sched_step(self, state, done, rem, K, eos_val):
        state, done, rem, toks, ns = self._chunk_fn(K)(
            self.params, self.heads, self.tree, state, done, rem, eos_val)
        return state, done, rem, (toks, ns)

    @staticmethod
    def sched_emitted(raw):
        toks, ns = (np.asarray(x) for x in raw)
        K, B = ns.shape
        out = [[] for _ in range(B)]
        for k in range(K):
            for b in range(B):
                m = int(ns[k, b])
                if m:
                    out[b].extend(int(x) for x in toks[k, b, :m])
        return out


def _insert_spec_row(state, b, row):
    return type(state)(cache=insert_rows(state.cache, b, row.cache),
                       cur_token=state.cur_token.at[b].set(row.cur_token[0]),
                       hidden=state.hidden.at[b].set(row.hidden[0]))


def _insert_spec_row_paged(state, b, row, pages):
    return type(state)(cache=insert_rows(state.cache, b, row.cache,
                                         pages=pages),
                       cur_token=state.cur_token.at[b].set(row.cur_token[0]),
                       hidden=state.hidden.at[b].set(row.hidden[0]))


def _admit_spec_row(model, params, heads, state, b, batch, *, max_len,
                    window):
    row = spec_prefill(model, params, heads, batch, max_len=max_len,
                       window=window)
    return _insert_spec_row(state, b, row), row.cur_token[0]


def _admit_spec_row_paged(model, params, heads, state, b, batch, pages):
    row = spec_prefill(model, params, heads, batch, max_len=1, window=0)
    return _insert_spec_row_paged(state, b, row, pages), row.cur_token[0]


def _stats(accepts, times):
    accepts = np.asarray(accepts)
    return {
        "acceptance_length": float(np.mean(accepts)) if accepts.size else 0.0,
        "steps": int(accepts.size),
        "step_times": times,
    }


def measure_acceptance(model, heads, params, tree_spec: TreeSpec, prompts,
                       n_tokens=64, *, max_len=512,
                       engine: Optional[SpeculativeEngine] = None) -> float:
    """Empirical acceptance length over a prompt set (ARCA's brute-force
    refinement evaluator + Table-I measurement).

    Pass ``engine`` to reuse a constructed ``SpeculativeEngine`` across
    candidate trees: the tree is swapped via ``set_tree`` and the jitted
    step is shared for same-shape trees, so ARCA's evaluator does not pay
    compile time per candidate.
    """
    if engine is None:
        engine = SpeculativeEngine(model, heads, params, tree_spec,
                                   max_len=max_len)
    else:
        engine.set_tree(tree_spec)
    als = []
    for batch in prompts:
        _, stats = engine.generate(batch, n_tokens)
        als.append(stats["acceptance_length"])
    return float(np.mean(als))
