"""Unified serving engine: ONE device-resident chunked decode driver
parameterized by a pluggable ``DecodeStrategy``.

A strategy is a registered pytree bundling the *verification tree* (the
PR 1 ``Tree`` machinery), its *width*, and the *draft source*:

  * ``DecodeStrategy.medusa(tree_spec)`` — Ghidorah speculative decoding:
    Medusa heads draft, the tree is verified in one forward, each sequence
    accepts its own chain (paper §III).
  * ``DecodeStrategy.sequential()`` — the degenerate ``chain_spec(width=1)``
    strategy: the tree is just the root (the last committed token), there
    is no draft source, and "verifying" the root alone IS plain one-token
    decoding — so the engine runs ``model.decode`` for it and the classic
    sequential baseline falls out of the same driver, protocol and slot
    lifecycle as speculation instead of a copy-pasted twin engine.

``BatchEngine`` and ``SpeculativeEngine`` survive as thin constructor
aliases over ``DecodeEngine`` (bit-identical outputs to the pre-unification
engines); everything below them — the K-step ``lax.scan`` chunk driver, the
``sched_*`` continuous-batching slot protocol, admission/insert/reset and
the paged-pool bookkeeping — is ONE implementation.

Because the strategy is a jit ARGUMENT of the chunk functions, it can be
swapped at runtime between chunks (``set_strategy``): same-shape strategies
(equal ``(draft, width, max_depth, n_paths)``) reuse the compiled scans, so
the scheduler's adaptive mode (runtime/scheduler.py) re-decides the
speculative width from *measured* acceptance/step-time without re-jitting,
and ARCA's measured time source (core/arca.py ``profile_engine`` ->
``time_step``) times exactly the deployed step function.

Chunked driver semantics (unchanged from the split engines): K steps run
inside a single jitted ``lax.scan`` with ONE host sync per chunk.  A row
goes (and stays) done on EOS, on its ``rem`` budget reaching 0, or on a
capacity freeze — a full (window=0) KV cache that cannot take a worst-case
accepted chain (``capacity_left < tree.max_depth``; depth 1 for the
sequential strategy) freezes instead of silently wrapping its ring.  Done
speculative rows commit nothing (``spec_step(active=...)``); done
sequential rows keep stepping with emission masked and their KV
bookkeeping (``key_pos``/``pos``) frozen, so mid-chunked-prefill rows keep
their piece offsets.  The host loop clamps the chunk length to the largest
remaining budget (power-of-two schedule, bounded compile cache).

Slot lifecycle (continuous batching, runtime/scheduler.py): each batch row
is a *slot*; admission/eviction happen only between chunks via the
``sched_*`` protocol, so the compiled scans are reused across the whole
request stream.  Paged KV (``paged=True``): the bank's KV lives in one
shared page pool (runtime/cache.py) with host-side page reservations at
admission and a trash-page redirect for overflow writes; with runtime
strategy switching the reservation overshoot is the DEEPEST registered
candidate tree (``register_strategies``), so a mid-request switch can
never outgrow a row's reservation.

All state-threading jits (chunk scans, ``sched_admit``, ``sched_insert``,
``sched_reset``) DONATE the carried state, so the cache — one large pool
when paged — is updated in place instead of copied every chunk.

HCMP executor split (``hcmp="overlap"``, core/hcmp/executors.py): the
drafted strategy's two phases run on separate executors — Medusa heads
(DraftExecutor, device 1) and the full-model tree verify + commit
(VerifyExecutor, device 0) — pipelined so drafting step t+1 overlaps
step t's KV commit, with a cross-chunk pre-draft versioned by the bank
epoch (any ``sched_*`` mutation or strategy switch bumps it; a stale
pre-draft is discarded and redrafted).  The routing happens inside
``_run_chunk`` below the ``sched_*`` protocol, so the scheduler is
unchanged and outputs stay bit-identical to the inline scan.  ARCA
times both partitions (``time_step(..., hcmp=...)`` ->
``profile_engine``) and ``Strategy.hcmp`` records the measured choice.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.speculative.tree import Tree, TreeSpec, chain_spec
from repro.core.speculative.verify import SpecState, spec_prefill, spec_step
from repro.runtime.cache import (PageAllocator, blank_paged_rows,
                                 capacity_left, insert_rows, pages_for,
                                 paginate_cache, reset_rows, slice_row,
                                 tile_rows, write_row_at)
from repro.runtime.sampling import greedy

_NO_EOS = -1          # sentinel: no real token id is negative

_KV_DTYPES = {"fp32": jnp.float32, "f32": jnp.float32,
              "float32": jnp.float32, "bf16": jnp.bfloat16,
              "bfloat16": jnp.bfloat16, "int8": jnp.int8}


def _kv_dtype(kv_dtype):
    """Normalize the engine's ``kv_dtype`` knob: None keeps the model
    dtype; a name ("fp32" | "bf16" | "int8") or any jnp dtype picks the
    paged pool's storage dtype (int8 = quantized pages, runtime/cache.py)."""
    if kv_dtype is None:
        return None
    if isinstance(kv_dtype, str):
        if kv_dtype not in _KV_DTYPES:
            raise ValueError(f"kv_dtype must be one of "
                             f"{sorted(_KV_DTYPES)} or a dtype, "
                             f"got {kv_dtype!r}")
        return _KV_DTYPES[kv_dtype]
    return jnp.dtype(kv_dtype)


def _eos_scalar(eos) -> jnp.ndarray:
    return jnp.asarray(_NO_EOS if eos is None else int(eos), jnp.int32)


def _budget(n_tokens, batch) -> np.ndarray:
    """Per-sequence token budgets: scalar broadcast or (B,) array."""
    b = np.broadcast_to(np.asarray(n_tokens, np.int32), (batch,)).copy()
    if np.any(b < 1):
        raise ValueError("n_tokens must be >= 1 per sequence")
    return b


def _pow2_chunk(k_max: int, need: int) -> int:
    """Smallest power-of-two chunk covering ``need`` steps, capped at
    ``k_max``: bounds the tail-chunk overshoot AND the set of compiled scan
    lengths to {1, 2, 4, ..., k_max}."""
    k = 1
    while k < need and k < k_max:
        k *= 2
    return min(k, k_max)


def _prompt_len(batch) -> int:
    """Decoder-sequence length of a prefill batch: tokens plus any VLM
    patch embeds that join the decoder sequence (encoder frames do not)."""
    n = int(batch["tokens"].shape[1])
    if "patch_embeds" in batch:
        n += int(batch["patch_embeds"].shape[1])
    return n


# ===========================================================================
# DecodeStrategy: the runtime-swappable (tree, width, draft-source) bundle
# ===========================================================================
@partial(jax.tree_util.register_dataclass,
         data_fields=["tree"], meta_fields=["width", "draft"])
@dataclasses.dataclass(frozen=True)
class DecodeStrategy:
    """What one decode step does: verification tree + width + draft source.

    A registered pytree, passed as a jit ARGUMENT to the engine's chunk
    scans — strategies with equal ``shape()`` share one compiled scan, so
    swapping same-shape-bucketed strategies at a chunk boundary is pure
    data movement (no re-jit).  ``draft`` is static metadata:

      * ``"medusa"`` — Medusa heads draft candidates, the tree is verified
        in one forward (requires an engine constructed with ``heads``);
      * ``"none"`` — no draft source; the tree must be the degenerate
        ``chain_spec(1)`` root and the step is plain one-token decode.
    """
    width: int
    draft: str                   # "medusa" | "none"
    tree: Tree

    @property
    def max_depth(self) -> int:
        return self.tree.max_depth

    def shape(self) -> tuple:
        """Compile-cache bucket: strategies with equal shape reuse the
        engine's compiled chunk scans."""
        return (self.draft,) + self.tree.shape()

    @staticmethod
    def sequential() -> "DecodeStrategy":
        """The degenerate width-1 strategy: tree = chain_spec(1) (root
        only), no draft — sequential decoding."""
        return DecodeStrategy(width=1, draft="none",
                              tree=Tree.from_spec(chain_spec(1)))

    @staticmethod
    def medusa(spec: TreeSpec) -> "DecodeStrategy":
        return DecodeStrategy(width=spec.width, draft="medusa",
                              tree=Tree.from_spec(spec))


# ===========================================================================
# unified engine state + row surgery (ONE implementation for both drafts)
# ===========================================================================
# The engine state is core/speculative/verify.py ``SpecState``; the
# sequential strategy carries ``hidden=None`` (an empty pytree leaf), so
# every insert/reset/admit path below handles both drafts with one body.

def _prefill_state(model, params, heads, batch, *, max_len, window):
    """Prefill -> engine state.  ``heads is None`` selects the draft-free
    path (no hidden carry)."""
    if heads is None:
        logits, _, cache = model.prefill(params, batch, max_len=max_len,
                                         window=window)
        return SpecState(cache=cache, cur_token=greedy(logits[:, -1]),
                         hidden=None)
    return spec_prefill(model, params, heads, batch, max_len=max_len,
                        window=window)


def _insert_row(state, b, row, pages=None):
    cache = insert_rows(state.cache, b, row.cache) if pages is None else \
        insert_rows(state.cache, b, row.cache, pages=pages)
    hid = None if state.hidden is None else \
        state.hidden.at[b].set(row.hidden[0])
    return type(state)(cache=cache,
                       cur_token=state.cur_token.at[b].set(row.cur_token[0]),
                       hidden=hid)


def _admit_row(model, params, heads, state, b, batch, *, max_len, window):
    row = _prefill_state(model, params, heads, batch, max_len=max_len,
                         window=window)
    return _insert_row(state, b, row), row.cur_token[0]


def _admit_row_paged(model, params, heads, state, b, batch, pages):
    row = _prefill_state(model, params, heads, batch, max_len=1, window=0)
    return _insert_row(state, b, row, pages=pages), row.cur_token[0]


def _reset_state_rows(state, mask):
    # a freed slot must be fully inert, carry included: ``cur_token`` seeds
    # the next chunk's decode input and ``hidden`` keeps driving (masked)
    # drafts, so a stale carry is one masking bug away from leaking into a
    # recycled page.  Clear the whole row.
    mask = jnp.asarray(mask)
    hid = None if state.hidden is None else \
        jnp.where(mask[:, None], jnp.zeros_like(state.hidden), state.hidden)
    return type(state)(cache=reset_rows(state.cache, mask),
                       cur_token=jnp.where(mask,
                                           jnp.zeros_like(state.cur_token),
                                           state.cur_token),
                       hidden=hid)


def _extend_row(model, params, state, b, tokens, n_valid, tree):
    """Chunked-prefill piece: run ``tokens (1, C)`` through the causal
    verify path (``tree`` = chain spec — plain causal attention at the
    row's offset, ref numerics) against row ``b``'s cache view and splice
    the piece's KVs in.  The drafting carry (``cur_token``/``hidden`` when
    present) tracks the last REAL position, so the final piece leaves the
    row exactly as a whole-prompt admission would."""
    row_view = slice_row(state.cache, b)
    logits, extras = model.verify(params, row_view, tokens, tree,
                                  backend="ref")
    k1, v1 = extras["tree_kv"]                       # (L, 1, C, Hkv, hd)
    cache = write_row_at(state.cache, b, k1[:, 0], v1[:, 0],
                         row_view.kv.pos[0], n_valid)
    last = greedy(jnp.take(logits[0], n_valid - 1, axis=0))
    hid = None if state.hidden is None else state.hidden.at[b].set(
        jnp.take(extras["hidden"][0], n_valid - 1, axis=0))
    return type(state)(cache=cache,
                       cur_token=state.cur_token.at[b].set(last),
                       hidden=hid), last


def _seq_step(model, params, state, *, backend, active):
    """One step of the degenerate ``chain_spec(width=1)`` strategy: the
    tree is just the root (the last committed token) and there is no draft,
    so verifying it IS plain one-token decode.  Interface mirrors
    ``spec_step``: returns (state, emitted (B, 1), n (B,) in {0, 1}).

    Every row decodes, done ones included — their ``key_pos``/``pos`` are
    restored afterwards so a done row's KV bookkeeping is frozen (its
    garbage k/v write stays invisible at key_pos -1 and is overwritten by
    the slot's next real write).  Without this a mid-chunked-prefill row
    (done-masked while its prompt pieces land) would have its piece offsets
    corrupted between pieces."""
    kv0 = state.cache.kv
    lg, cache = model.decode(params, state.cache, state.cur_token[:, None],
                             backend=backend)
    if kv0 is not None:
        done = ~active
        kv = cache.kv
        cache = dataclasses.replace(
            cache, kv=dataclasses.replace(
                kv,
                key_pos=jnp.where(done[:, None], kv0.key_pos, kv.key_pos),
                pos=jnp.where(done, kv0.pos, kv.pos)))
    nxt = greedy(lg[:, 0])
    cur = jnp.where(active, nxt, state.cur_token)
    return (type(state)(cache=cache, cur_token=cur, hidden=state.hidden),
            nxt[:, None], active.astype(jnp.int32))


@runtime_checkable
class SchedulableEngine(Protocol):
    """The slot protocol ``runtime/scheduler.py`` drives engines through.

    This is the declared source of truth for the scheduler/engine
    contract; reprolint's R6 cross-checks it against the scheduler's
    actual ``sched_*`` call sites, so it can never silently lag them.
    Every method below is REQUIRED (called unconditionally at chunk
    boundaries) except the last three, which the scheduler/server probe
    with ``getattr``/``hasattr``.  Two optional *properties* are part of
    the wider contract but kept out of this Protocol so it stays
    ``issubclass``-checkable (runtime_checkable Protocols with non-method
    members reject issubclass): ``sched_chunked_ok`` (chunked-prefill
    support) and ``sched_pages_held`` (pages reserved by resident rows).

    Slot-state conventions: ``state`` is the opaque resident-bank carry
    (a registered pytree, donated by every state-threading jit), ``row``
    an opaque B=1 prefill result, ``b`` a bank slot index.
    """

    # ---- admission sizing (host-side, no device work) --------------------
    def sched_footprint(self, prompt_len: int, n_tokens: int) -> int: ...
    def sched_can_admit(self, prompt_len: int, n_tokens: int) -> bool: ...

    # ---- row lifecycle ---------------------------------------------------
    def sched_prefill(self, batch): ...
    def sched_first(self, row) -> int: ...
    def sched_blank(self, row, batch): ...
    def sched_insert(self, state, b, row, *, prompt_len=None,
                     n_tokens=None): ...
    def sched_admit(self, state, b, batch, *, n_tokens=None,
                    reserve_len=None): ...
    def sched_extend(self, state, b, tokens, n_valid): ...
    def sched_reset(self, state, b): ...
    def sched_release(self, b: int) -> None: ...

    # ---- the chunk step --------------------------------------------------
    def sched_step(self, state, done, rem, K, eos_val): ...
    def sched_emitted(self, raw): ...

    # ---- optional extensions (probed with getattr/hasattr) ---------------
    def sched_abort(self, b: int) -> None: ...
    def sched_pool_conserved(self) -> bool: ...
    def sched_drained(self) -> bool: ...


class _PagedPoolMixin:
    """Shared page-reservation bookkeeping for paged engines.

    The allocator is HOST state: pages move between the free list and rows
    only at admission/eviction boundaries (and once per ``generate``), so
    reservation never syncs the device.  ``_overshoot`` is the engine's
    worst-case slots written past the budget: one full accepted chain of
    the current strategy's ``max_depth`` (1 for sequential — decode writes
    one slot past the last emitted token), ratcheted to the deepest
    registered candidate when runtime switching is armed."""

    def _paged_init(self, *, paged, page_size, pool_pages):
        if paged and self.window:
            raise ValueError("paged KV supports full attention only "
                             "(sliding windows stay dense: the ring IS the "
                             "window)")
        self.paged, self.page_size = paged, page_size
        self.pool_pages = pool_pages
        self.max_pages = pages_for(self.max_len, page_size) if paged else 0
        self._alloc: Optional[PageAllocator] = None      # sched-bank state
        self._row_pages = {}
        self._extends = {}          # piece width -> jitted prefill-extend

    def _need_pages(self, prompt_len: int, budget: int, n_total: int) -> int:
        return min(pages_for(prompt_len + budget + self._overshoot,
                             self.page_size),
                   self.max_pages, n_total)

    def _reserve_tables(self, batch, budget):
        """Per-row page reservations for a ``generate`` call.  When the
        pool cannot cover a row's need the reservation is PARTIAL — the row
        then freezes at ``capacity_left`` with its shortfall reported in
        ``n_emitted``, it never borrows a neighbor's pages."""
        B = int(batch["tokens"].shape[0])
        n_total = self.pool_pages or B * self.max_pages
        alloc = PageAllocator(n_total)
        prompt = _prompt_len(batch)
        tables = np.full((B, self.max_pages), -1, np.int32)
        for b in range(B):
            pages = alloc.alloc_upto(
                self._need_pages(prompt, int(budget[b]), n_total))
            tables[b, :len(pages)] = pages
        return jnp.asarray(tables), n_total

    # ---- scheduler-facing reservation hooks ------------------------------
    def sched_footprint(self, prompt_len: int, n_tokens: int) -> int:
        """Slot cost of a request — what the scheduler's size-ordered
        admission policies (SJF/LPT) rank by: reserved pages when paged,
        otherwise logical slots (prompt + budget + overshoot)."""
        need = int(prompt_len) + int(n_tokens) + self._overshoot
        if self.paged:
            return pages_for(need, self.page_size)
        return need

    @property
    def sched_chunked_ok(self) -> bool:
        """Whether this engine supports chunked prefill (piecewise
        ``sched_extend`` admission): attention-only families with full
        attention.  Recurrent families (Mamba/xLSTM/hybrid) prefill their
        state sequentially and stay on whole-prompt admission; sliding
        windows stay dense/whole for the same reason the paged path does."""
        return self.window == 0 and \
            getattr(self.model, "family", "") in ("dense", "moe", "vlm")

    def sched_can_admit(self, prompt_len: int, n_tokens: int) -> bool:
        """False while the pool cannot fund the request's reservation — the
        scheduler then DEFERS admission until evictions free pages.  A
        request bigger than the whole pool caps at the pool (admitted once
        fully free; it freezes with a shortfall, it is not rejected)."""
        if not self.paged or self._alloc is None:
            return True
        return self._alloc.available >= self._need_pages(
            prompt_len, n_tokens, self._alloc.n_pages)

    def sched_release(self, b: int) -> None:
        """Return an evicted row's pages to the pool (host-side; the row's
        device-side table is cleared by the boundary's reset/insert before
        the next chunk runs)."""
        if self.paged and self._alloc is not None:
            self._alloc.free(self._row_pages.pop(b, ()))

    def sched_abort(self, b: int) -> None:
        """Release a LIVE, unfinished row mid-flight (client cancellation,
        expired deadline, injected fault).  Identical to the eviction-time
        release: the allocator is host state, so returning an unfinished
        row's pages never syncs the device — but the caller MUST reset the
        row (clearing its device-side block table) before the next chunk
        runs, or a same-boundary admission could write pages the aborted
        row still references.  The scheduler's dirty-reset ordering
        guarantees exactly that."""
        self.sched_release(b)

    @property
    def sched_pages_held(self) -> int:
        """Pages currently reserved by resident rows (0 when dense)."""
        if not self.paged:
            return 0
        return sum(len(p) for p in self._row_pages.values())

    def sched_pool_conserved(self) -> bool:
        """Page-leak audit: the allocator's free+held must equal the pool
        and agree with the engine's per-row bookkeeping.  True for dense
        engines and before the first sched admission."""
        if not self.paged or self._alloc is None:
            return True
        return (self._alloc.conserved
                and self._alloc.outstanding == self.sched_pages_held)

    def sched_drained(self) -> bool:
        """True when every page is back on the free list and no row holds
        a reservation — the zero-leak postcondition every drained stream
        (including aborted/faulted ones) must satisfy."""
        if not self.paged or self._alloc is None:
            return True
        return (not self._row_pages
                and self._alloc.available == self._alloc.n_pages)

    def _sched_pages(self, b: int, prompt_len: int, n_tokens: int):
        """Allocate row ``b``'s reservation (gated by ``sched_can_admit``),
        -1-padded to the static ``max_pages`` table width."""
        pages = self._alloc.alloc(self._need_pages(prompt_len, n_tokens,
                                                   self._alloc.n_pages))
        self._row_pages[b] = pages
        out = np.full((self.max_pages,), -1, np.int32)
        out[:len(pages)] = pages
        return jnp.asarray(out)

    # ---- chunked-prefill hook (runtime/scheduler.py prefill_chunk) -------
    def _extend_fn(self, C: int):
        """Per-piece-width jit of the prefill-extend row surgery."""
        if C not in self._extends:
            model = self.model
            tree = Tree.from_spec(chain_spec(C))

            # named (not a bare lambda) so compile-log audits (`python -m
            # repro.analysis.tracecount`) bucket it distinctly
            def prefill_extend(p, st, b, toks, nv):
                return _extend_row(model, p, st, b, toks, nv, tree)

            self._extends[C] = jax.jit(prefill_extend, donate_argnums=(1,))
        return self._extends[C]

    def sched_extend(self, state, b, tokens, n_valid):
        """One chunked-prefill piece: run ``tokens (1, C)`` (tail pieces
        right-padded; ``n_valid`` real entries) through the causal verify
        path against row ``b``'s existing cache and splice the piece's KVs
        in at the row's offset.  Returns (state, last-real-token device
        scalar — after the final piece that token is the request's first
        emission, and a drafted row additionally carries the
        ``cur_token``/``hidden`` of the last real position, so the finished
        slot is indistinguishable from a whole-prompt admission).  Compiled
        once per piece width C."""
        self._touch_bank()
        return self._extend_fn(int(tokens.shape[1]))(
            self.params, state, jnp.asarray(b, jnp.int32),
            jnp.asarray(tokens, jnp.int32), jnp.asarray(n_valid, jnp.int32))


class DecodeEngine(_PagedPoolMixin):
    """ONE serving engine for every decode strategy.

    ``strategy`` picks what a step does (``DecodeStrategy.sequential()`` /
    ``DecodeStrategy.medusa(tree_spec)``); ``heads`` are required exactly
    when the strategy drafts.  ``chunk`` = K steps fused into one device
    call via ``lax.scan``; K=1 degenerates to the per-step host-synced
    loop.  ``paged=True`` swaps the bank's dense per-row KV for the shared
    page pool (``pool_pages`` total; default ``B * ceil(max_len /
    page_size)``, the dense-equivalent capacity — shrink it to serve a
    larger bank at fixed memory).

    Runtime strategy switching: ``set_strategy`` swaps the strategy between
    chunks (same draft kind only — the state carry differs); same-shape
    strategies reuse the compiled scans.  ``register_strategies`` arms a
    candidate set for the scheduler's adaptive mode and ratchets the paged
    reservation overshoot to the deepest candidate.  ``time_step`` measures
    one compiled step — ARCA's measured time source.

    ``kv_dtype`` picks the paged pool's storage dtype — ``"int8"``
    quantizes pages with per-page dequant scales (runtime/cache.py),
    shrinking bytes/token ~3.5x so the same pool bytes reserve more
    tokens.  ``tree_kernel`` picks the paged verify kernel: ``"dense"``
    (fused page walk + tree block) or ``"sparse"`` (split quantized page
    walk + block-masked tree kernel, merged by the Eq.-1 rule);
    ``set_tree_kernel`` / ``time_step(tree_kernel=...)`` let ARCA
    measure both per shape."""

    def __init__(self, model, params, *, strategy: Optional[DecodeStrategy]
                 = None, heads=None, max_len=512, window=0, backend="ref",
                 chunk=8, paged=False, page_size=16, pool_pages=None,
                 hcmp="inline", kv_dtype=None, tree_kernel="dense"):
        if strategy is None:
            if heads is not None:
                raise ValueError("an engine with draft heads needs an "
                                 "explicit DecodeStrategy.medusa(tree_spec)")
            strategy = DecodeStrategy.sequential()
        if (strategy.draft == "medusa") != (heads is not None):
            raise ValueError(f"strategy draft {strategy.draft!r} "
                             f"{'requires' if strategy.draft == 'medusa' else 'forbids'} "
                             "draft heads")
        if hcmp not in ("inline", "overlap"):
            raise ValueError(f"hcmp must be 'inline' or 'overlap', "
                             f"got {hcmp!r}")
        if hcmp == "overlap" and heads is None:
            raise ValueError("hcmp='overlap' needs a drafted strategy: the "
                             "sequential engine has no draft source to "
                             "disaggregate")
        kv_dtype = _kv_dtype(kv_dtype)
        if kv_dtype == jnp.int8 and not paged:
            raise ValueError("kv_dtype=int8 quantizes the PAGED pool "
                             "(per-page scales live on the page axis); "
                             "dense ring caches stay float — pass "
                             "paged=True")
        if tree_kernel not in ("dense", "sparse"):
            raise ValueError(f"tree_kernel must be 'dense' or 'sparse', "
                             f"got {tree_kernel!r}")
        if tree_kernel == "sparse" and not paged:
            raise ValueError("tree_kernel='sparse' splits the PAGED verify "
                             "path (quantized page walk + block-masked "
                             "tree kernel); dense caches use the fused "
                             "kernel — pass paged=True")
        self.kv_dtype = kv_dtype
        self.tree_kernel = tree_kernel
        self.model, self.params, self.heads = model, params, heads
        self.strategy = strategy
        # HCMP executor split (core/hcmp/executors.py): "overlap" routes
        # chunks through the disaggregated draft/verify runner, built
        # lazily.  The bank epoch versions the resident state: every
        # mutation (admission, reset, extend, strategy switch, a new
        # generate/time_step stream) bumps it, invalidating the runner's
        # cross-chunk pre-draft (mis-speculated overlaps are discarded
        # and redrafted -- outputs stay bit-identical either way).
        self.hcmp = hcmp
        self._hcmp_runner = None
        self._bank_epoch = 0
        self._registered: Dict[int, DecodeStrategy] = {}
        self._registered_depth = 0
        self.max_len, self.window = max_len, window
        self.backend, self.chunk = backend, chunk
        self._paged_init(paged=paged, page_size=page_size,
                         pool_pages=pool_pages)
        # every jit target below is a NAMED def (not a lambda): the
        # compile log (`jax_log_compiles`) reports the target's __name__,
        # and the tracecount audit diffs per-name compile counts against
        # the committed budget — `<lambda>` buckets would alias
        def prefill_full(p, h, b):
            return _prefill_state(model, p, h, b, max_len=max_len,
                                  window=window)

        self._prefill = jax.jit(prefill_full)
        self._chunks = {}           # K -> jitted K-step scan
        # state-threading jits donate their carried state: the cache (one
        # large pool when paged) is aliased in place, never copied
        self._insert = jax.jit(_insert_row, donate_argnums=(0,))
        self._reset = jax.jit(_reset_state_rows, donate_argnums=(0,))

        # fused admission: B=1 prefill + row splice in ONE device call (a
        # per-request dispatch on the scheduler's hot path)
        def admit_row(p, h, st, b, bt):
            return _admit_row(model, p, h, st, b, bt, max_len=max_len,
                              window=window)

        self._admit = jax.jit(admit_row, donate_argnums=(2,))
        if paged:
            # prompt-sized dense prefill: paginated right after (generate)
            # or spliced into the paged bank (admission) — never a full
            # (B, max_len) dense transient
            def prefill_prompt(p, h, b):
                return _prefill_state(model, p, h, b, max_len=1, window=0)

            def admit_paged(p, h, st, b, bt, pages):
                return _admit_row_paged(model, p, h, st, b, bt, pages)

            def insert_paged(st, b, row, pages):
                return _insert_row(st, b, row, pages=pages)

            self._prefill_prompt = jax.jit(prefill_prompt)
            self._prefills_paged = {}    # n_pages -> fused prefill+paginate
            self._admit_paged = jax.jit(admit_paged, donate_argnums=(2,))
            self._insert_paged = jax.jit(insert_paged, donate_argnums=(0,))

    # ---- strategy axis ---------------------------------------------------
    @property
    def tree(self) -> Tree:
        return self.strategy.tree

    @property
    def max_depth(self) -> int:
        return self.strategy.tree.max_depth

    @property
    def _overshoot(self) -> int:
        # worst case slots written past the budget: one full accepted chain
        # (1 for sequential); with runtime switching armed, the deepest
        # registered candidate (a switch must never outgrow a reservation)
        return max(self.strategy.tree.max_depth, self._registered_depth)

    def strategy_for(self, spec: TreeSpec) -> DecodeStrategy:
        """Build a DecodeStrategy of THIS engine's draft kind from a tree
        spec (the state carry differs across draft kinds, so an engine can
        only ever run strategies of its own kind)."""
        if self.heads is None:
            if spec.width != 1:
                raise ValueError("a draft-free engine can only run the "
                                 "degenerate width-1 strategy")
            return DecodeStrategy.sequential()
        return DecodeStrategy.medusa(spec)

    def set_strategy(self, strategy) -> None:
        """Swap the decode strategy WITHOUT dropping compiled steps (the
        strategy is a jit argument: same-shape strategies share one
        compiled scan).  Accepts a ``DecodeStrategy`` or a ``TreeSpec``;
        the draft kind must match the engine's.  Safe only at chunk
        boundaries — the scheduler's adaptive mode calls it there."""
        if isinstance(strategy, TreeSpec):
            strategy = self.strategy_for(strategy)
        if strategy.draft != self.strategy.draft:
            raise ValueError(f"cannot switch draft kind "
                             f"{self.strategy.draft!r} -> {strategy.draft!r}"
                             " (the state carry differs)")
        self.strategy = strategy
        self._touch_bank()

    # ---- HCMP executor split (core/hcmp/executors.py) --------------------
    @property
    def hcmp_capable(self) -> bool:
        """Whether this engine can run the disaggregated overlap schedule
        (it needs a draft source to put on the second executor)."""
        return self.heads is not None

    def set_hcmp(self, mode: str) -> None:
        """Switch the executor partition between chunks ("inline" |
        "overlap").  Safe only at chunk boundaries, like
        ``set_strategy``; bumps the bank epoch so a pre-draft computed
        under the other schedule is discarded."""
        if mode not in ("inline", "overlap"):
            raise ValueError(f"hcmp must be 'inline' or 'overlap', "
                             f"got {mode!r}")
        if mode == "overlap" and not self.hcmp_capable:
            raise ValueError("hcmp='overlap' needs a drafted strategy")
        self.hcmp = mode
        self._touch_bank()

    def set_tree_kernel(self, mode: str) -> None:
        """Switch the paged verify kernel between chunks ("dense" = fused
        page walk + tree block, "sparse" = split quantized page walk +
        block-masked tree kernel, merged by the Eq.-1 rule).  Safe only at
        chunk boundaries, like ``set_strategy``; the choice is a closure
        static of the compiled scans (``_chunk_fn`` keys on it) and of the
        overlap runner, which is rebuilt on change."""
        if mode not in ("dense", "sparse"):
            raise ValueError(f"tree_kernel must be 'dense' or 'sparse', "
                             f"got {mode!r}")
        if mode == "sparse" and not self.paged:
            raise ValueError("tree_kernel='sparse' needs a paged engine")
        if mode != self.tree_kernel:
            self.tree_kernel = mode
            self._hcmp_runner = None     # verify_front baked the old kernel
        self._touch_bank()

    def _touch_bank(self) -> None:
        """Version the resident bank: called by every mutation that makes
        a cross-chunk pre-draft stale (admission/insert/reset/extend, a
        strategy or partition switch, a new generate/time_step stream)."""
        self._bank_epoch += 1

    def _hcmp(self):
        if self._hcmp_runner is None:
            from repro.core.hcmp.executors import HcmpOverlapRunner
            self._hcmp_runner = HcmpOverlapRunner(
                self.model, self.heads, backend=self.backend,
                tree_kernel=self.tree_kernel)
        return self._hcmp_runner

    @property
    def hcmp_stats(self) -> Optional[dict]:
        """Overlap-runner counters (None until the runner exists)."""
        if self._hcmp_runner is None:
            return None
        return dict(self._hcmp_runner.stats, mode=self.hcmp)

    def _run_chunk(self, K, strategy, state, done, rem, eos_val):
        """Route one K-step chunk: the fused inline ``chunk_scan`` or the
        disaggregated overlap pipeline — same signature, bit-identical
        outputs (greedy verification commits the greedy chain whatever
        the draft's placement or timing)."""
        if self.hcmp == "overlap" and strategy.draft == "medusa":
            return self._hcmp().run_chunk(self.params, strategy, state,
                                          done, rem, K, eos_val,
                                          self._bank_epoch)
        return self._chunk_fn(K)(self.params, self.heads, strategy, state,
                                 done, rem, eos_val)

    def set_tree(self, tree_spec: TreeSpec) -> None:
        """Legacy alias of ``set_strategy`` (ARCA's ``measure_acceptance``
        swaps candidate trees through it)."""
        self.set_strategy(tree_spec)

    def register_strategies(self, specs) -> Dict[int, DecodeStrategy]:
        """Arm a candidate set for runtime switching: builds the
        DecodeStrategy per width ONCE (switches then reuse the same
        pytrees) and ratchets the paged reservation overshoot to the
        deepest candidate so a mid-request switch can never outgrow a
        row's page reservation.  ``specs``: {width: TreeSpec}."""
        self._registered = {int(w): self.strategy_for(sp)
                            for w, sp in specs.items()}
        self._registered_depth = max(
            [s.tree.max_depth for s in self._registered.values()],
            default=0)
        return self._registered

    # ---- the ONE chunk driver --------------------------------------------
    def _chunk_fn(self, K: int):
        # keyed by (K, tree_kernel): the verify kernel choice is baked into
        # the compiled scan (a closure static, like ``backend``), so a
        # runtime switch lands in a different compile-cache entry instead
        # of silently reusing the other kernel's scan
        key = (K, self.tree_kernel)
        if key not in self._chunks:
            model, backend = self.model, self.backend
            tree_kernel = self.tree_kernel

            def chunk_scan(p, h, strat, state, done, rem, eos):
                def body(carry, _):
                    state, done, rem = carry
                    # capacity guard BEFORE the step: a commit may write up
                    # to max_depth slots (1 for sequential), so freeze once
                    # the ring cannot take a worst case without wrapping
                    done = done | (rem <= 0) | \
                        (capacity_left(state.cache) < strat.tree.max_depth)
                    active = ~done
                    if strat.draft == "none":       # static: strategy meta
                        state, emitted, n = _seq_step(model, p, state,
                                                      backend=backend,
                                                      active=active)
                    else:
                        state, emitted, n = spec_step(model, p, h,
                                                      strat.tree, state,
                                                      backend=backend,
                                                      tree_kernel=tree_kernel,
                                                      active=active)
                    idx = jnp.arange(emitted.shape[1])[None, :]
                    valid = idx < n[:, None]
                    is_eos = valid & (emitted == eos)
                    has_eos = jnp.any(is_eos, axis=1)
                    # truncate each sequence's emission at its first EOS
                    n_cut = jnp.where(has_eos,
                                      jnp.argmax(is_eos, axis=1) + 1, n)
                    n_eff = jnp.where(active, n_cut, 0)
                    emitted = jnp.where(idx < n_eff[:, None], emitted, eos)
                    done = done | has_eos
                    rem = rem - n_eff
                    return (state, done, rem), (emitted, n_eff)

                (state, done, rem), (toks, ns) = jax.lax.scan(
                    body, (state, done, rem), None, length=K)
                # toks: (K, B, Dmax) eos-padded; ns: (K, B) accepted counts
                return state, done, rem, toks, ns

            # donate the scan carry (state incl. the KV pool, done, rem):
            # in-place chunk updates, no per-chunk cache copy
            self._chunks[key] = jax.jit(chunk_scan, donate_argnums=(3, 4, 5))
        return self._chunks[key]

    def _prefill_paged_fn(self, n_total: int):
        if n_total not in self._prefills_paged:
            model, ps = self.model, self.page_size
            kvdt = self.kv_dtype

            def prefill_paged(p, h, b, tables):
                st = _prefill_state(model, p, h, b, max_len=1, window=0)
                return type(st)(
                    cache=paginate_cache(st.cache, tables, page_size=ps,
                                         n_pages=n_total, kv_dtype=kvdt),
                    cur_token=st.cur_token, hidden=st.hidden)

            self._prefills_paged[n_total] = jax.jit(prefill_paged)
        return self._prefills_paged[n_total]

    # ---- batch generation ------------------------------------------------
    def generate(self, batch, n_tokens, *, eos: Optional[int] = None,
                 chunk: Optional[int] = None):
        """``n_tokens``: int or (B,) per-sequence budgets.  Returns
        ``(out, stats)``; rows past their budget / EOS / capacity freeze
        pad with ``eos`` (-1 if None) and ``stats["n_emitted"]`` has the
        real per-sequence counts.  Drafted engines return a 1-D token
        array at B=1 (legacy ``SpeculativeEngine`` shape); the sequential
        strategy always returns ``(B, max_budget)``."""
        K = chunk or self.chunk
        eos_val = _eos_scalar(eos)
        B = int(batch["tokens"].shape[0])
        budget = _budget(n_tokens, B)
        self._touch_bank()            # new stream: stale pre-drafts die
        if self.paged:
            tables, n_total = self._reserve_tables(batch, budget)
            state = self._prefill_paged_fn(n_total)(
                self.params, self.heads, batch, tables)
        else:
            state = self._prefill(self.params, self.heads, batch)
        n_max = int(budget.max())
        # prologue sync: materialize the prefill's first token + done mask
        # reprolint: disable=R3 (intended post-prefill sync)
        first = np.asarray(state.cur_token)
        outs = [[int(first[b])] for b in range(B)]
        done = state.cur_token == eos_val
        rem = jnp.asarray(budget - 1)
        # reprolint: disable=R3 (intended post-prefill sync)
        done_np, rem_np = np.asarray(done), budget - 1
        accepts, times = [], []

        while np.any(~done_np & (rem_np > 0)):
            # every live step emits >= 1 token, so the largest remaining
            # budget bounds the steps still needed — no full-K tail chunks
            need = int(rem_np[~done_np & (rem_np > 0)].max())
            t0 = time.perf_counter()
            state, done, rem, toks, ns = self._run_chunk(
                _pow2_chunk(K, need), self.strategy, state, done, rem,
                eos_val)
            # ONE host sync per chunk: this block is the whole budget
            toks_np = np.asarray(toks)    # reprolint: disable=R3 (chunk sync)
            ns_np = np.asarray(ns)        # reprolint: disable=R3 (chunk sync)
            # reprolint: disable=R3 (chunk sync)
            done_np, rem_np = np.asarray(done), np.asarray(rem)
            times.append(time.perf_counter() - t0)
            for k in range(ns_np.shape[0]):
                for b in range(B):
                    m = int(ns_np[k, b])
                    if m and len(outs[b]) < budget[b]:
                        # count only steps whose tokens are (at least partly)
                        # kept: overshoot steps past n_tokens would bias the
                        # acceptance stats ARCA's evaluator consumes
                        accepts.append(m)
                        outs[b].extend(int(x) for x in toks_np[k, b, :m])

        n_emitted = np.asarray(
            [min(len(outs[b]), int(budget[b])) for b in range(B)], np.int32)
        stats = _stats(accepts, times)
        stats["chunk"] = K
        stats["n_emitted"] = n_emitted
        stats["emitted_total"] = int(n_emitted.sum())
        out = np.full((B, n_max), int(eos_val), np.int32)
        for b in range(B):
            # reprolint: disable=R3 (outs is a host list, not a device array)
            seq = np.asarray(outs[b][:budget[b]], np.int32)
            out[b, :len(seq)] = seq
        if B == 1 and self.strategy.draft == "medusa":
            return out[0], stats
        return out, stats

    # ---- measured step time (ARCA's time source) -------------------------
    def time_step(self, strategy: Optional[DecodeStrategy] = None, *,
                  batch: int = 1, prompt_len: int = 16, reps: int = 3,
                  chunk: Optional[int] = None,
                  hcmp: Optional[str] = None,
                  tree_kernel: Optional[str] = None) -> float:
        """Best-of-``reps`` wall time of ONE decode step under ``strategy``
        (default: the current one), measured through the engine's COMPILED
        chunk scan on a dummy prompt — the strategy is a jit argument, so
        the timed function is exactly the deployed one.  Timed at the
        serving chunk cadence (``chunk`` steps per dispatch, divided out);
        feeds ``core/arca.py profile_engine`` -> ``choose_strategy``.

        ``hcmp`` overrides the executor partition for this measurement
        ("inline" | "overlap") — ARCA times both and picks the partition
        the same way it picks the speculative strategy.  ``tree_kernel``
        ("dense" | "sparse") likewise overrides the paged verify kernel,
        so ARCA measures the fused vs split page walk per shape instead
        of trusting an analytic crossover."""
        strategy = strategy or self.strategy
        K = chunk or self.chunk
        prev_hcmp = self.hcmp
        prev_tk = self.tree_kernel
        if hcmp is not None:
            self.set_hcmp(hcmp)
        if tree_kernel is not None:
            self.set_tree_kernel(tree_kernel)
        try:
            self._touch_bank()        # measurement stream, not the bank
            bd = {"tokens": jnp.zeros((batch, prompt_len), jnp.int32)}
            if self.paged:
                budget = np.full((batch,), self.max_len, np.int64)
                tables, n_total = self._reserve_tables(bd, budget)
                state = self._prefill_paged_fn(n_total)(
                    self.params, self.heads, bd, tables)
            else:
                state = self._prefill(self.params, self.heads, bd)
            done = jnp.zeros((batch,), bool)
            rem = jnp.full((batch,), 1 << 30, jnp.int32)
            eos = _eos_scalar(None)

            def step(st, dn, rm):
                return self._run_chunk(K, strategy, st, dn, rm, eos)

            # warm-up compiles; the donated carry is rebound from the
            # outputs
            state, done, rem, toks, _ = step(state, done, rem)
            # reprolint: disable=R3 (timing harness)
            jax.block_until_ready(toks)
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                state, done, rem, toks, _ = step(state, done, rem)
                # this IS the measurement: ARCA times the compiled step
                # reprolint: disable=R3 (timing harness)
                jax.block_until_ready(toks)
                best = min(best, time.perf_counter() - t0)
            return best / K
        finally:
            if hcmp is not None:
                self.set_hcmp(prev_hcmp)
            if tree_kernel is not None:
                self.set_tree_kernel(prev_tk)

    # ---- continuous-batching slot protocol (runtime/scheduler.py) --------
    def sched_prefill(self, batch):
        """B=1 prefill -> opaque row state.  Paged engines prefill at
        prompt size (the dense row is a splice source, not a resident)."""
        if self.paged:
            return self._prefill_prompt(self.params, self.heads, batch)
        return self._prefill(self.params, self.heads, batch)

    @staticmethod
    def sched_first(row):
        return int(np.asarray(row.cur_token)[0])

    def sched_blank(self, row, batch):
        self._touch_bank()
        if self.paged:
            n_total = self.pool_pages or batch * self.max_pages
            self._alloc = PageAllocator(n_total)
            self._row_pages = {}
            bank = blank_paged_rows(row.cache, batch,
                                    page_size=self.page_size,
                                    n_pages=n_total, max_len=self.max_len,
                                    kv_dtype=self.kv_dtype)
        else:
            bank = tile_rows(row.cache, batch)
        hid = None if row.hidden is None else \
            jnp.repeat(row.hidden, batch, axis=0)
        return type(row)(cache=bank,
                         cur_token=jnp.repeat(row.cur_token, batch, axis=0),
                         hidden=hid)

    def sched_insert(self, state, b, row, *, prompt_len=None, n_tokens=None):
        self._touch_bank()
        if self.paged:
            pages = self._sched_pages(b, prompt_len, n_tokens)
            return self._insert_paged(state, jnp.asarray(b, jnp.int32), row,
                                      pages)
        return self._insert(state, jnp.asarray(b, jnp.int32), row)

    def sched_admit(self, state, b, batch, *, n_tokens=None,
                    reserve_len=None):
        """Fused prefill+insert; returns (state, first-token device scalar —
        unsynced, the caller materializes it lazily).  ``reserve_len``
        overrides the page reservation's prompt length — chunked prefill
        admits only the FIRST piece here but must reserve for the whole
        prompt."""
        self._touch_bank()
        if self.paged:
            plen = reserve_len if reserve_len is not None \
                else _prompt_len(batch)
            pages = self._sched_pages(b, plen, n_tokens)
            return self._admit_paged(self.params, self.heads, state,
                                     jnp.asarray(b, jnp.int32), batch, pages)
        return self._admit(self.params, self.heads, state,
                           jnp.asarray(b, jnp.int32), batch)

    def sched_reset(self, state, b):
        self._touch_bank()
        mask = np.zeros((int(state.cur_token.shape[0]),), bool)
        mask[b] = True
        return self._reset(state, mask)

    def sched_step(self, state, done, rem, K, eos_val):
        # eos arrives as a Python int from the scheduler but as an int32
        # array from generate(); coerce so both paths key the SAME
        # compile-cache entry of the chunk fn (R7 retrace audit)
        state, done, rem, toks, ns = self._run_chunk(
            K, self.strategy, state, done, rem,
            jnp.asarray(eos_val, jnp.int32))
        return state, done, rem, (toks, ns)

    @staticmethod
    def sched_emitted(raw):
        # the scheduler's ONE budgeted sync per boundary: materialize the
        # chunk's token block exactly once
        # reprolint: disable=R3 (intended boundary sync)
        toks, ns = (np.asarray(x) for x in raw)
        K, B = ns.shape
        out = [[] for _ in range(B)]
        for k in range(K):
            for b in range(B):
                m = int(ns[k, b])
                if m:
                    out[b].extend(int(x) for x in toks[k, b, :m])
        return out


# ===========================================================================
# legacy entry points: thin constructor aliases over DecodeEngine
# ===========================================================================
class BatchEngine(DecodeEngine):
    """Sequential baseline = ``DecodeEngine`` pinned to the degenerate
    ``DecodeStrategy.sequential()`` (chain_spec(width=1), no draft).
    Output- and protocol-identical to the pre-unification BatchEngine."""

    def __init__(self, model, params, *, max_len=512, window=0,
                 backend="ref", chunk=8, paged=False, page_size=16,
                 pool_pages=None, kv_dtype=None):
        super().__init__(model, params,
                         strategy=DecodeStrategy.sequential(),
                         max_len=max_len, window=window, backend=backend,
                         chunk=chunk, paged=paged, page_size=page_size,
                         pool_pages=pool_pages, kv_dtype=kv_dtype)


class SpeculativeEngine(DecodeEngine):
    """Ghidorah speculative serving = ``DecodeEngine`` with a Medusa-draft
    strategy built from ``tree_spec``.  Output- and protocol-identical to
    the pre-unification SpeculativeEngine."""

    def __init__(self, model, heads, params, tree_spec: TreeSpec, *,
                 max_len=512, window=0, backend="ref", chunk=8, paged=False,
                 page_size=16, pool_pages=None, hcmp="inline",
                 kv_dtype=None, tree_kernel="dense"):
        super().__init__(model, params, heads=heads,
                         strategy=DecodeStrategy.medusa(tree_spec),
                         max_len=max_len, window=window, backend=backend,
                         chunk=chunk, paged=paged, page_size=page_size,
                         pool_pages=pool_pages, hcmp=hcmp,
                         kv_dtype=kv_dtype, tree_kernel=tree_kernel)


def _stats(accepts, times):
    accepts = np.asarray(accepts)
    return {
        "acceptance_length": float(np.mean(accepts)) if accepts.size else 0.0,
        "steps": int(accepts.size),
        "step_times": times,
    }


def measure_acceptance(model, heads, params, tree_spec: TreeSpec, prompts,
                       n_tokens=64, *, max_len=512,
                       engine: Optional[DecodeEngine] = None) -> float:
    """Empirical acceptance length over a prompt set (ARCA's brute-force
    refinement evaluator + Table-I measurement).

    Pass ``engine`` to reuse a constructed engine across candidate trees:
    the strategy is swapped via ``set_tree`` and the jitted step is shared
    for same-shape trees, so ARCA's evaluator does not pay compile time
    per candidate.
    """
    if engine is None:
        engine = SpeculativeEngine(model, heads, params, tree_spec,
                                   max_len=max_len)
    else:
        engine.set_tree(tree_spec)
    als = []
    for batch in prompts:
        _, stats = engine.generate(batch, n_tokens)
        als.append(stats["acceptance_length"])
    return float(np.mean(als))
