"""Decode-state caches: KV cache (full or sliding-window ring buffer),
Mamba2 SSM state, xLSTM states, and encoder cross-attention memory.

Conventions
-----------
- KV arrays are stacked over layers: ``(L, B, S, Hkv, hd)`` so model stacks can
  ``lax.scan`` over the leading axis.
- ``key_pos (B, S)`` holds the absolute position stored in each cache slot
  (-1 = empty), **per sequence**.  With a sliding window the cache is a ring
  buffer: slot(p) = p % S.  The attention mask is derived from ``key_pos``
  (validity + causality + window), so ring wraparound needs no special-casing.
- ``pos (B,)`` is the number of tokens processed so far **per sequence**.
  Batched speculative decoding accepts a different number of draft tokens per
  sequence each step, so positions diverge across the batch; every write and
  mask below is therefore vmapped over the batch axis.
- RoPE is applied to keys at *write* time with their absolute position.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=["k", "v", "key_pos", "pos"], meta_fields=["window"])
@dataclasses.dataclass
class KVCache:
    k: jax.Array          # (L, B, S, Hkv, hd)
    v: jax.Array          # (L, B, S, Hkv, hd)
    key_pos: jax.Array    # (B, S) int32 absolute position per slot; -1 empty
    pos: jax.Array        # (B,) int32 tokens processed so far per sequence
    window: int = 0       # static: 0 = full attention; >0 = sliding window

    @property
    def max_len(self) -> int:
        return self.k.shape[2]


@partial(jax.tree_util.register_dataclass,
         data_fields=["ssm", "conv", "pos"], meta_fields=[])
@dataclasses.dataclass
class MambaState:
    ssm: jax.Array        # (L, B, nh, hd, N) float32
    conv: jax.Array       # (L, B, K-1, C)    conv tail (C = di + 2N)
    pos: jax.Array        # (B,) int32


@partial(jax.tree_util.register_dataclass,
         data_fields=["layers", "pos"], meta_fields=[])
@dataclasses.dataclass
class XLSTMState:
    layers: tuple         # per-layer dict of state arrays (unrolled stack)
    pos: jax.Array        # (B,) int32


@partial(jax.tree_util.register_dataclass,
         data_fields=["kv", "mamba", "xlstm", "cross_k", "cross_v"],
         meta_fields=[])
@dataclasses.dataclass
class Cache:
    """Union cache for all architecture families (unused fields = None)."""
    kv: Optional[KVCache] = None            # self-attention layers
    mamba: Optional[MambaState] = None      # Mamba2 layers
    xlstm: Optional[XLSTMState] = None      # xLSTM layers
    cross_k: Optional[jax.Array] = None     # (L, B, Senc, Hkv, hd) enc-dec
    cross_v: Optional[jax.Array] = None

    @property
    def pos(self) -> jax.Array:
        for c in (self.kv, self.mamba, self.xlstm):
            if c is not None:
                return c.pos
        raise ValueError("empty cache")


# --------------------------------------------------------------------------
def init_kv_cache(n_layers, batch, max_len, n_kv, head_dim, *, window=0,
                  dtype=jnp.bfloat16) -> KVCache:
    size = min(max_len, window) if window else max_len
    return KVCache(
        k=jnp.zeros((n_layers, batch, size, n_kv, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, size, n_kv, head_dim), dtype),
        key_pos=jnp.full((batch, size), -1, jnp.int32),
        pos=jnp.zeros((batch,), jnp.int32),
        window=window,
    )


def _per_batch(start_pos, batch):
    """Broadcast a scalar start position to (B,) int32."""
    return jnp.broadcast_to(jnp.asarray(start_pos, jnp.int32), (batch,))


def _ring_match(abs_pos, valid, size):
    """Per-slot source index for a masked ring write.

    abs_pos: (D,) absolute positions being written; valid: (D,) write mask.
    Returns (written (S,), src (S,)): slot s takes entry src[s] iff
    written[s].  Expressed as gather + where rather than scatter — XLA CPU
    lowers batched dynamic scatters to a serialized loop, which dominated
    the batched commit path (see engine_bench).  Duplicate slots (a write
    run longer than the ring) resolve to the LAST write, matching scatter
    semantics.
    """
    D = abs_pos.shape[0]
    slots = abs_pos % size
    match = (jnp.arange(size, dtype=jnp.int32)[:, None] == slots[None, :]) \
        & valid[None, :]                                 # (S, D)
    written = jnp.any(match, axis=1)
    src = (D - 1) - jnp.argmax(match[:, ::-1], axis=1).astype(jnp.int32)
    return written, src


def kv_write(cache_k, cache_v, key_pos, k_new, v_new, start_pos):
    """Write S_new entries per sequence at positions [start_b, start_b+S_new).

    cache_k/v: (B, S, Hkv, hd) — per-layer slices (inside scan).
    key_pos: (B, S); k_new/v_new: (B, S_new, Hkv, hd).
    start_pos: () or (B,) — per-sequence absolute start positions.
    Ring indexing per sequence: slot = pos % S.
    Returns updated (cache_k, cache_v, key_pos).
    """
    S = cache_k.shape[1]
    s_new = k_new.shape[1]
    start = _per_batch(start_pos, cache_k.shape[0])

    def one(ck, cv, kp, kn, vn, st):
        abs_pos = st + jnp.arange(s_new, dtype=jnp.int32)
        written, src = _ring_match(abs_pos, jnp.ones((s_new,), bool), S)
        m = written[:, None, None]
        return (jnp.where(m, kn[src].astype(ck.dtype), ck),
                jnp.where(m, vn[src].astype(cv.dtype), cv),
                jnp.where(written, abs_pos[src], kp))

    return jax.vmap(one)(cache_k, cache_v, key_pos, k_new, v_new, start)


def kv_commit(kv: KVCache, k_new, v_new, accept_nodes, n_accept,
              max_depth) -> KVCache:
    """Write each sequence's accepted tree path into its ring buffer.

    k_new/v_new: (L, B, W, Hkv, hd) uncommitted tree KVs;
    accept_nodes: (B, Dmax) node ids of the accepted chain (padded);
    n_accept: (B,) accepted tokens per sequence (1..Dmax).
    Writes are masked per sequence: slots beyond n_accept[b] keep their
    previous contents, and ``pos`` advances by n_accept[b].
    """
    size = kv.max_len
    idx = jnp.arange(max_depth, dtype=jnp.int32)

    def one(ck, cv, kp, kn, vn, nodes, n, p):
        # ck/cv: (L, S, Hkv, hd); kn/vn: (L, W, Hkv, hd); kp: (S,)
        abs_pos = p + idx
        written, src = _ring_match(abs_pos, idx < n, size)
        sel_k = jnp.take(kn, nodes, axis=1)              # (L, Dmax, Hkv, hd)
        sel_v = jnp.take(vn, nodes, axis=1)
        m = written[None, :, None, None]
        return (jnp.where(m, sel_k[:, src].astype(ck.dtype), ck),
                jnp.where(m, sel_v[:, src].astype(cv.dtype), cv),
                jnp.where(written, abs_pos[src], kp))

    k2, v2, kp2 = jax.vmap(one, in_axes=(1, 1, 0, 1, 1, 0, 0, 0),
                           out_axes=(1, 1, 0))(
        kv.k, kv.v, kv.key_pos, k_new, v_new,
        accept_nodes, n_accept, kv.pos)
    return KVCache(k=k2, v=v2, key_pos=kp2,
                   pos=kv.pos + n_accept.astype(jnp.int32), window=kv.window)


# --------------------------------------------------------------------------
# Per-row slot primitives (continuous batching, runtime/scheduler.py).
#
# A batched cache is a bank of B independent rows; the scheduler treats each
# row as a slot that sequences are admitted into and evicted from at chunk
# boundaries.  Every helper below maps a function over the batched leaves of
# a ``Cache`` with the leaf's batch-axis position made explicit (KV k/v and
# Mamba/cross arrays carry batch at axis 1, key_pos/pos/xLSTM leaves at
# axis 0), so row surgery never touches the other rows.
# --------------------------------------------------------------------------
def _row_map(fn, *caches: "Cache") -> "Cache":
    """Apply ``fn(batch_axis, *leaves)`` over the batched leaves of Cache(s).

    All caches must share one structure (same model family + shapes apart
    from the batch axis).  Returns a new Cache built from fn's outputs.
    """
    c = caches[0]

    def go(axis, get):
        return fn(axis, *(get(x) for x in caches))

    kv = mamba = xl = ck = cv = None
    if c.kv is not None:
        kv = KVCache(k=go(1, lambda x: x.kv.k), v=go(1, lambda x: x.kv.v),
                     key_pos=go(0, lambda x: x.kv.key_pos),
                     pos=go(0, lambda x: x.kv.pos), window=c.kv.window)
    if c.mamba is not None:
        mamba = MambaState(ssm=go(1, lambda x: x.mamba.ssm),
                           conv=go(1, lambda x: x.mamba.conv),
                           pos=go(0, lambda x: x.mamba.pos))
    if c.xlstm is not None:
        layers = jax.tree_util.tree_map(
            lambda *ls: fn(0, *ls), *(x.xlstm.layers for x in caches))
        xl = XLSTMState(layers=layers, pos=go(0, lambda x: x.xlstm.pos))
    if c.cross_k is not None:
        ck = go(1, lambda x: x.cross_k)
        cv = go(1, lambda x: x.cross_v)
    return Cache(kv=kv, mamba=mamba, xlstm=xl, cross_k=ck, cross_v=cv)


def tile_rows(cache: Cache, batch: int) -> Cache:
    """Broadcast a batch-1 cache to ``batch`` identical rows (used once to
    bootstrap the scheduler's resident state from the first admission)."""
    return _row_map(lambda axis, a: jnp.repeat(a, batch, axis=axis), cache)


def reset_rows(cache: Cache, rows) -> Cache:
    """Clear the rows where ``rows (B,)`` is True: ``key_pos`` -> -1 (every
    attention mask rejects the slot), ``pos`` -> 0, KV/recurrent state
    zeroed.  A freed row is inert until ``insert_rows`` installs a freshly
    prefilled sequence — reset guarantees no stale KV survives eviction, it
    does not produce a decodable initial state (e.g. xLSTM stabilizer
    offsets are re-established by the admission prefill)."""
    rows = jnp.asarray(rows, bool)

    def f(axis, a):
        shape = [1] * a.ndim
        shape[axis] = rows.shape[0]
        return jnp.where(rows.reshape(shape), jnp.zeros_like(a), a)

    out = _row_map(f, cache)
    if out.kv is not None:
        out.kv.key_pos = jnp.where(rows[:, None], jnp.int32(-1),
                                   cache.kv.key_pos)
    return out


def insert_rows(cache: Cache, row, src: Cache) -> Cache:
    """Copy row 0 of a batch-1 cache ``src`` into row ``row`` of ``cache``
    (admission: the new request's B=1 prefilled state takes over the slot).
    ``row`` may be a traced scalar, so one jitted insert serves every slot."""
    row = jnp.asarray(row, jnp.int32)

    def f(axis, big, small):
        upd = jax.lax.index_in_dim(small, 0, axis, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(
            big, upd.astype(big.dtype), row, axis)

    return _row_map(f, cache, src)


_UNBOUNDED = 1 << 30


def capacity_left(cache: Cache) -> jax.Array:
    """(B,) decode slots left before a full (window=0) KV ring would wrap
    past capacity and silently overwrite its oldest entries.

    Sliding-window caches wrap by design and recurrent state is O(1) in
    context, so those report an effectively unbounded budget.  The chunk
    drivers fold this into the scan done-mask: a sequence freezes (stops
    emitting/committing) instead of corrupting its own attention."""
    pos = cache.pos
    kv = cache.kv
    if kv is None or kv.window:
        return jnp.full(pos.shape, _UNBOUNDED, jnp.int32)
    return jnp.int32(kv.max_len) - kv.pos


def decode_mask(key_pos, q_pos, window):
    """Validity mask (T,) for one query at absolute position q_pos.

    key_pos: (T,) absolute positions in one sequence's cache (-1 empty).
    """
    ok = (key_pos >= 0) & (key_pos <= q_pos)
    if window:
        ok &= key_pos > q_pos - window
    return ok


def batched_decode_mask(key_pos, q_pos, window):
    """Per-batch validity mask (B, W, S).

    key_pos: (B, S) absolute positions per slot; q_pos: (B, W) absolute query
    positions (they differ per sequence once acceptance lengths diverge).
    """
    kp = key_pos[:, None, :]                             # (B, 1, S)
    qp = q_pos[:, :, None]                               # (B, W, 1)
    ok = (kp >= 0) & (kp <= qp)
    if window:
        ok &= kp > qp - window
    return ok


def prefill_mask(seq_len, window, q_offset=0, dtype=bool):
    """Causal (optionally windowed) (S, S) mask for prefill."""
    q = jnp.arange(seq_len)[:, None] + q_offset
    k = jnp.arange(seq_len)[None, :] + q_offset
    m = k <= q
    if window:
        m &= k > q - window
    return m
