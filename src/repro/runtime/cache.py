"""Decode-state caches: dense and paged KV caches, Mamba2 SSM state, xLSTM
states, and encoder cross-attention memory.

Two KV layouts share one logical addressing convention:

Dense (``KVCache``)
-------------------
- KV arrays are stacked over layers: ``(L, B, S, Hkv, hd)`` so model stacks
  can ``lax.scan`` over the leading axis.  Every sequence owns a full
  ``S = max_len`` row; with a sliding window the row is a ring buffer:
  slot(p) = p % S.
- Still the layout for sliding-window caches (the ring IS the window) and
  the parity baseline for the paged path (``paged=False`` engines).

Paged (``PagedKVCache``)
------------------------
- One shared pool of fixed-size pages ``(L, n_pages + 1, page_size, Hkv,
  hd)``; **page ``n_pages`` is a trash page** — every masked, unreserved, or
  overflowing write is redirected there, so a row can never scribble on a
  page another row owns.
- A per-sequence ``block_table (B, max_pages)`` maps *logical* page
  ``s // page_size`` to a physical pool page (-1 = unreserved).  Logical
  slot ``s = pos % (max_pages * page_size)`` — the same ring arithmetic as
  the dense path, so masks/kernels are layout-agnostic.
- Reservation is page-grained: admission allocates
  ``ceil((prompt + budget + overshoot) / page_size)`` pages from a host-side
  free list (``PageAllocator``), eviction frees them.  ``capacity_left`` =
  reserved slots minus ``pos``; a row that outgrows its reservation freezes
  (shortfall reported in ``n_emitted``) instead of corrupting a neighbor.
- Diverged-length sequences therefore share one slot pool: a short request
  reserves 2-3 pages while a long one reserves dozens, and resident batch at
  fixed pool memory is bounded by actual context, not ``B * max_len``.

Shared conventions
------------------
- ``key_pos (B, S_logical)`` holds the absolute position stored in each
  logical slot (-1 = empty), **per sequence**.  The attention mask is
  derived from ``key_pos`` (validity + causality + window), so ring
  wraparound and unreserved paged slots need no special-casing.
- ``pos (B,)`` is the number of tokens processed so far **per sequence**.
  Batched speculative decoding accepts a different number of draft tokens
  per sequence each step, so positions diverge across the batch; every
  write and mask below is per-sequence.
- RoPE is applied to keys at *write* time with their absolute position.

Quantized int8 pages (``kv_dtype=int8``)
----------------------------------------
The paged pool may store KV in **symmetric per-page int8**: ``pool_k`` /
``pool_v`` become int8 and the cache carries ``scale_k`` / ``scale_v``
float32 tensors of shape ``(L, n_pages + 1, Hkv)`` — one scale per (layer,
pool page, kv head), trash page included.  Format and error model:

- **Arming.** A page's scale starts at the 0.0 *unarmed* sentinel.  The
  first write into the page arms it: scale = amax(|x| over that write's
  entries landing in the page, per (layer, head)) / 127.  The scale is
  then FROZEN while the page is resident — re-arming on later writes
  would silently re-scale entries already quantized under the old scale.
- **Saturation.** Later writes quantize with the frozen scale and clamp:
  ``q = clip(round(x / scale), -127, 127)`` (an unarmed 0.0 scale stores
  0).  K/V magnitudes are close to position-stationary per (layer, head),
  so the first-write amax is a good page-lifetime range estimate; an
  outlier later in the page saturates instead of corrupting neighbors.
- **Dequant.** ``x' = q * scale``, fused into the Pallas page walk (the
  scale rides the scalar-prefetched block-table path next to the page
  index) and mirrored by ``gather_pages_dequant`` on the ref backend.
- **Error bound.** Within the armed range the absolute error per element
  is <= scale/2 = amax/254 (relative ~0.4% of the page's per-head peak).
  End-to-end the engines hold attention outputs to the tolerances
  documented in ``tests/test_kernels.py`` / ``tests/test_paged.py``.
- **Recycling.** A page's scale is zeroed-then-re-armed exactly when a
  fresh reservation installs it (``_paged_insert_row`` / admission).
  ``reset_rows`` leaves pool scales alone: a freed page's armed scale is
  unreachable garbage (like its int8 payload), and the dead row's block
  table is stale by the time the scheduler batches resets — the page may
  already carry a same-boundary admission whose scale must survive.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=["k", "v", "key_pos", "pos"], meta_fields=["window"])
@dataclasses.dataclass
class KVCache:
    k: jax.Array          # (L, B, S, Hkv, hd)
    v: jax.Array          # (L, B, S, Hkv, hd)
    key_pos: jax.Array    # (B, S) int32 absolute position per slot; -1 empty
    pos: jax.Array        # (B,) int32 tokens processed so far per sequence
    window: int = 0       # static: 0 = full attention; >0 = sliding window

    @property
    def max_len(self) -> int:
        return self.k.shape[2]


@partial(jax.tree_util.register_dataclass,
         data_fields=["pool_k", "pool_v", "block_table", "key_pos", "pos",
                      "scale_k", "scale_v"],
         meta_fields=["page_size", "window"])
@dataclasses.dataclass
class PagedKVCache:
    """Block-table KV cache: one shared page pool + per-sequence tables.

    ``pool_k/pool_v`` carry ``n_pages`` real pages plus one trailing *trash*
    page; writes whose logical slot is masked or falls on an unreserved
    table entry land in the trash page (see ``_pool_scatter``), never in a
    page another sequence reserved.  ``window`` is kept for interface parity
    with ``KVCache`` but must be 0 — sliding-window caches stay dense (the
    ring IS the window).

    When the pool dtype is int8 the cache is *quantized*: ``scale_k`` /
    ``scale_v (L, n_pages + 1, Hkv)`` hold the symmetric per-page dequant
    scales (see the module docstring for the arming/freezing error model);
    for float pools they are None and every code path below is unchanged.
    """
    pool_k: jax.Array       # (L, n_pages + 1, page_size, Hkv, hd)
    pool_v: jax.Array       # (L, n_pages + 1, page_size, Hkv, hd)
    block_table: jax.Array  # (B, max_pages) int32 physical page id; -1 free
    key_pos: jax.Array      # (B, max_pages * page_size) int32; -1 empty
    pos: jax.Array          # (B,) int32 tokens processed so far per sequence
    scale_k: Optional[jax.Array] = None   # (L, n_pages + 1, Hkv) f32 | None
    scale_v: Optional[jax.Array] = None   # (L, n_pages + 1, Hkv) f32 | None
    page_size: int = 16     # static: slots per page
    window: int = 0         # static: always 0 (full attention only)

    @property
    def quantized(self) -> bool:
        return self.pool_k.dtype == jnp.int8

    @property
    def max_len(self) -> int:
        """Logical row length (ring size) — max_pages * page_size."""
        return self.key_pos.shape[1]

    @property
    def n_pages(self) -> int:
        """Real (reservable) pages — excludes the trash page."""
        return self.pool_k.shape[1] - 1

    @property
    def max_pages(self) -> int:
        return self.block_table.shape[1]


@partial(jax.tree_util.register_dataclass,
         data_fields=["ssm", "conv", "pos"], meta_fields=[])
@dataclasses.dataclass
class MambaState:
    ssm: jax.Array        # (L, B, nh, hd, N) float32
    conv: jax.Array       # (L, B, K-1, C)    conv tail (C = di + 2N)
    pos: jax.Array        # (B,) int32


@partial(jax.tree_util.register_dataclass,
         data_fields=["layers", "pos"], meta_fields=[])
@dataclasses.dataclass
class XLSTMState:
    layers: tuple         # per-layer dict of state arrays (unrolled stack)
    pos: jax.Array        # (B,) int32


@partial(jax.tree_util.register_dataclass,
         data_fields=["kv", "mamba", "xlstm", "cross_k", "cross_v"],
         meta_fields=[])
@dataclasses.dataclass
class Cache:
    """Union cache for all architecture families (unused fields = None)."""
    kv: Optional[KVCache] = None            # self-attention layers
    mamba: Optional[MambaState] = None      # Mamba2 layers
    xlstm: Optional[XLSTMState] = None      # xLSTM layers
    cross_k: Optional[jax.Array] = None     # (L, B, Senc, Hkv, hd) enc-dec
    cross_v: Optional[jax.Array] = None

    @property
    def pos(self) -> jax.Array:
        for c in (self.kv, self.mamba, self.xlstm):
            if c is not None:
                return c.pos
        raise ValueError("empty cache")


# --------------------------------------------------------------------------
def init_kv_cache(n_layers, batch, max_len, n_kv, head_dim, *, window=0,
                  dtype=jnp.bfloat16) -> KVCache:
    size = min(max_len, window) if window else max_len
    return KVCache(
        k=jnp.zeros((n_layers, batch, size, n_kv, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, size, n_kv, head_dim), dtype),
        key_pos=jnp.full((batch, size), -1, jnp.int32),
        pos=jnp.zeros((batch,), jnp.int32),
        window=window,
    )


def init_paged_kv_cache(n_layers, batch, max_len, n_kv, head_dim, *,
                        page_size, n_pages,
                        dtype=jnp.bfloat16) -> PagedKVCache:
    """Empty paged bank: zeroed pool (+1 trash page), all tables unreserved.

    ``max_len`` is the *logical* per-row capacity (rounded up to whole
    pages); the physical pool holds ``n_pages`` reservable pages shared by
    all ``batch`` rows.  ``dtype=jnp.int8`` builds a quantized pool with
    zeroed (unarmed) per-page scale tensors.
    """
    max_pages = pages_for(max_len, page_size)
    quantized = jnp.dtype(dtype) == jnp.int8

    def _scale():
        # one DISTINCT buffer per call: scale_k/scale_v sharing one array
        # would donate the same buffer twice in the state-threading jits
        return (jnp.zeros((n_layers, n_pages + 1, n_kv), jnp.float32)
                if quantized else None)

    return PagedKVCache(
        pool_k=jnp.zeros((n_layers, n_pages + 1, page_size, n_kv, head_dim),
                         dtype),
        pool_v=jnp.zeros((n_layers, n_pages + 1, page_size, n_kv, head_dim),
                         dtype),
        block_table=jnp.full((batch, max_pages), -1, jnp.int32),
        key_pos=jnp.full((batch, max_pages * page_size), -1, jnp.int32),
        pos=jnp.zeros((batch,), jnp.int32),
        scale_k=_scale(),
        scale_v=_scale(),
        page_size=page_size,
    )


def pages_for(n_tokens, page_size) -> int:
    """Pages needed to hold ``n_tokens`` slots."""
    return -(-int(n_tokens) // int(page_size))


def page_bytes(n_layers, page_size, n_kv, head_dim, kv_dtype) -> int:
    """Device bytes one pool page costs across all layers, K+V, INCLUDING
    the per-page scale overhead when quantized — the honest denominator for
    fixed-pool-bytes comparisons (sched_bench, admission sizing)."""
    elt = jnp.dtype(kv_dtype).itemsize
    data = 2 * n_layers * page_size * n_kv * head_dim * elt
    scale = 2 * n_layers * n_kv * 4 if jnp.dtype(kv_dtype) == jnp.int8 else 0
    return data + scale


def kv_bytes_per_token(n_layers, n_kv, head_dim, kv_dtype, page_size) -> float:
    """Bytes per reservable token slot (K+V, all layers, amortized scale)."""
    return page_bytes(n_layers, page_size, n_kv, head_dim, kv_dtype) \
        / page_size


def pages_at_fixed_bytes(budget_bytes, n_layers, page_size, n_kv, head_dim,
                         kv_dtype) -> int:
    """Reservable pages a byte budget funds at ``kv_dtype`` — the engine
    admission-sizing hook that turns the int8 bytes-per-token saving into
    extra reservable tokens at FIXED pool memory."""
    return int(budget_bytes) // page_bytes(n_layers, page_size, n_kv,
                                           head_dim, kv_dtype)


class PageAllocator:
    """Host-side free list over the pool's reservable page ids.

    Alloc/free happen only at admission/eviction/abort boundaries (and once
    per ``generate`` call), so this never syncs the device.  Pages are
    handed out lowest-id-first so runs are deterministic and reuse after
    fragmented frees is exercised by the unit tests.

    Every page handed out is tracked in a held set, so ``outstanding`` /
    ``conserved`` give the leak audit the fault paths rely on: after any
    mix of evictions, mid-flight aborts and replica-crash cleanups,
    ``available + outstanding == n_pages`` must hold at every step and a
    drained bank must return to ``available == n_pages`` — a page that is
    neither free nor held by a row is a leak.  Freeing a page that is not
    currently held (double free, foreign page) raises instead of
    corrupting the free list.
    """

    def __init__(self, n_pages: int):
        self.n_pages = int(n_pages)
        self._free = list(range(self.n_pages))   # kept sorted
        self._held = set()                        # pages currently reserved

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def outstanding(self) -> int:
        """Pages currently reserved by rows (the held side of the audit)."""
        return len(self._held)

    @property
    def conserved(self) -> bool:
        """free + held == pool, with no page on both sides — the invariant
        every admission/eviction/abort sequence must preserve."""
        return (len(self._free) + len(self._held) == self.n_pages
                and not self._held.intersection(self._free))

    def alloc(self, n: int) -> list:
        """Take exactly ``n`` pages; raises if the pool cannot supply them
        (callers gate on ``available`` / ``alloc_upto`` for partial)."""
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {len(self._free)}")
        pages, self._free = self._free[:n], self._free[n:]
        self._held.update(pages)
        return pages

    def alloc_upto(self, n: int) -> list:
        """Take ``min(n, available)`` pages (partial reservations freeze at
        ``capacity_left`` instead of failing)."""
        return self.alloc(min(n, len(self._free)))

    def free(self, pages) -> None:
        for p in pages:
            p = int(p)
            if p < 0:
                continue
            if p not in self._held:
                raise RuntimeError(f"bad page free: {p}")
            self._held.discard(p)
            self._free.append(p)
        self._free.sort()


def _arm_and_quantize(src_flat, scale, flat_page, P):
    """Quantize one operand's writes under frozen-first-write page scales.

    src_flat: (L, N, Hkv, hd) float sources; scale: (L, P, Hkv) with 0.0 =
    unarmed; flat_page: (N,) destination pool page per write.  Pages
    UNARMED before this op arm to amax(|writes into the page|)/127 per
    (layer, head); already-armed pages keep their scale and later writes
    saturate (module docstring: re-arming would mis-scale entries already
    stored under the old scale).  Returns (q (L, N, Hkv, hd) int8,
    new_scale (L, P, Hkv)).
    """
    src_flat = src_flat.astype(jnp.float32)
    amax = jnp.max(jnp.abs(src_flat), axis=-1)               # (L, N, Hkv)
    page_amax = jax.ops.segment_max(jnp.moveaxis(amax, 1, 0), flat_page,
                                    num_segments=P)          # (P, L, Hkv)
    page_amax = jnp.maximum(jnp.moveaxis(page_amax, 0, 1), 0.0)
    new_scale = jnp.where(scale > 0.0, scale, page_amax / 127.0)
    s_w = new_scale[:, flat_page]                            # (L, N, Hkv)
    s_w = s_w[..., None]
    q = jnp.where(s_w > 0.0,
                  jnp.clip(jnp.round(src_flat
                                     / jnp.where(s_w > 0.0, s_w, 1.0)),
                           -127.0, 127.0),
                  0.0)
    return q.astype(jnp.int8), new_scale


def _pool_scatter(pool_k, pool_v, tables, k_src, v_src, abs_pos, valid,
                  scale_k=None, scale_v=None):
    """Scatter per-sequence writes through block tables into the shared pool.

    pool_k/pool_v: (L, P, ps, Hkv, hd) with P = n_pages + 1 (trash last);
    tables: (B, max_pages); k_src/v_src: (L, B, W, Hkv, hd);
    abs_pos/valid: (B, W) absolute positions and write mask;
    scale_k/scale_v: (L, P, Hkv) per-page dequant scales when the pool is
    int8 (None = float pool, stored verbatim).

    Masked writes, and writes whose logical page is unreserved (table entry
    -1 — e.g. a partially-reserved row that outgrew its pages), are
    redirected to the trash page: a row can NEVER overwrite a page it does
    not own (a rejected write's magnitude only ever arms the never-read
    trash scale).  Returns (pool_k, pool_v, scale_k, scale_v, ok (B, W))
    where ``ok`` marks the writes that landed in real pages (callers mark
    only those in key_pos).
    """
    L, P, ps, Hkv, hd = pool_k.shape
    s_log = tables.shape[1] * ps
    logical = abs_pos % s_log                                # (B, W)
    page = jnp.take_along_axis(tables, logical // ps, axis=1)
    ok = valid & (page >= 0)
    phys = jnp.where(ok, page * ps + logical % ps, P * ps - 1)
    flat = phys.reshape(-1)                                  # (B*W,)
    k_flat = k_src.reshape(L, -1, Hkv, hd)
    v_flat = v_src.reshape(L, -1, Hkv, hd)
    if scale_k is not None:
        k_flat, scale_k = _arm_and_quantize(k_flat, scale_k, flat // ps, P)
        v_flat, scale_v = _arm_and_quantize(v_flat, scale_v, flat // ps, P)
    pk = pool_k.reshape(L, P * ps, Hkv, hd)
    pv = pool_v.reshape(L, P * ps, Hkv, hd)
    pk = pk.at[:, flat].set(k_flat.astype(pk.dtype))
    pv = pv.at[:, flat].set(v_flat.astype(pv.dtype))
    return (pk.reshape(pool_k.shape), pv.reshape(pool_v.shape),
            scale_k, scale_v, ok)


def _zero_page_scales(scale, pages, mask):
    """Zero (un-arm) the per-page scales of the pool pages in ``pages``
    where ``mask`` holds.  scale: (L, P, Hkv); pages: int page ids (-1 =
    unreserved); mask broadcastable to pages.  Non-targets redirect to the
    trash page, whose scale is never read."""
    P = scale.shape[1]
    tgt = jnp.where(mask & (pages >= 0), pages, P - 1).reshape(-1)
    return scale.at[:, tgt].set(0.0)


def _keypos_scatter(key_pos, abs_pos, ok):
    """Mark ``abs_pos`` at its logical slot where ``ok``; rejected writes go
    to a shed column past the row (key_pos: (B, S_logical))."""
    B, s_log = key_pos.shape
    col = jnp.where(ok, abs_pos % s_log, s_log)
    kp = jnp.pad(key_pos, ((0, 0), (0, 1)), constant_values=-1)
    kp = kp.at[jnp.arange(B)[:, None], col].set(
        jnp.where(ok, abs_pos, -1))
    return kp[:, :s_log]


def paged_kv_write(kv: PagedKVCache, ks, vs, start) -> PagedKVCache:
    """Write S_new entries per sequence at [start_b, start_b + S_new)
    through the block table (the paged analog of ``_bulk_write``/
    ``kv_write``).  ks/vs: (L, B, S_new, Hkv, hd).  A write run longer than
    one logical ring keeps only the tail (matching the dense ring), so
    scatter targets stay duplicate-free."""
    B, s_new = ks.shape[1], ks.shape[2]
    start = _per_batch(start, B)
    s_log = kv.max_len
    if s_new >= s_log:
        ks, vs = ks[:, :, -s_log:], vs[:, :, -s_log:]
        start = start + (s_new - s_log)
        s_new = s_log
    abs_pos = start[:, None] + jnp.arange(s_new, dtype=jnp.int32)[None, :]
    valid = jnp.ones(abs_pos.shape, bool)
    pool_k, pool_v, sk, sv, ok = _pool_scatter(
        kv.pool_k, kv.pool_v, kv.block_table, ks, vs, abs_pos, valid,
        scale_k=kv.scale_k, scale_v=kv.scale_v)
    return dataclasses.replace(
        kv, pool_k=pool_k, pool_v=pool_v, scale_k=sk, scale_v=sv,
        key_pos=_keypos_scatter(kv.key_pos, abs_pos, ok),
        pos=start + s_new)


def paged_kv_commit(kv: PagedKVCache, k_new, v_new, accept_nodes, n_accept,
                    max_depth) -> PagedKVCache:
    """Paged analog of ``kv_commit``: write each sequence's accepted tree
    path through its block table.  Writes past ``n_accept[b]`` (and any
    write a frozen row would make past its reservation) hit the trash page."""
    idx = jnp.arange(max_depth, dtype=jnp.int32)
    sel = jax.vmap(lambda kn, nd: jnp.take(kn, nd, axis=1),
                   in_axes=(1, 0), out_axes=1)
    sel_k = sel(k_new, accept_nodes)                 # (L, B, Dmax, Hkv, hd)
    sel_v = sel(v_new, accept_nodes)
    abs_pos = kv.pos[:, None] + idx[None, :]
    valid = idx[None, :] < n_accept[:, None]
    pool_k, pool_v, sk, sv, ok = _pool_scatter(
        kv.pool_k, kv.pool_v, kv.block_table, sel_k, sel_v, abs_pos, valid,
        scale_k=kv.scale_k, scale_v=kv.scale_v)
    return dataclasses.replace(
        kv, pool_k=pool_k, pool_v=pool_v, scale_k=sk, scale_v=sv,
        key_pos=_keypos_scatter(kv.key_pos, abs_pos, ok),
        pos=kv.pos + n_accept.astype(jnp.int32))


def gather_pages(pool_layer, block_table):
    """Materialize one layer's logical (B, S_logical, Hkv, hd) view through
    the block table (the ref-backend read path; the Pallas kernel instead
    DMAs pages via scalar-prefetch).  Unreserved entries read the trash
    page — their slots are key_pos == -1, so every mask rejects them."""
    P, ps = pool_layer.shape[0], pool_layer.shape[1]
    t = jnp.where(block_table < 0, P - 1, block_table)
    ck = jnp.take(pool_layer, t, axis=0)      # (B, max_pages, ps, Hkv, hd)
    B, maxp = block_table.shape
    return ck.reshape((B, maxp * ps) + pool_layer.shape[2:])


def gather_pages_dequant(pool_layer, scale_layer, block_table):
    """``gather_pages`` for an int8 pool: dequantize while materializing the
    logical (B, S_logical, Hkv, hd) float32 view.  ``scale_layer (P, Hkv)``
    is one layer's per-page scales; the ref-backend mirror of the fused
    dequant inside the Pallas page walk.  ``scale_layer=None`` falls back to
    the verbatim gather (float pool)."""
    if scale_layer is None:
        return gather_pages(pool_layer, block_table)
    P, ps = pool_layer.shape[0], pool_layer.shape[1]
    t = jnp.where(block_table < 0, P - 1, block_table)
    ck = jnp.take(pool_layer, t, axis=0).astype(jnp.float32)
    sc = jnp.take(scale_layer, t, axis=0)     # (B, max_pages, Hkv)
    ck = ck * sc[:, :, None, :, None]
    B, maxp = block_table.shape
    return ck.reshape((B, maxp * ps) + pool_layer.shape[2:])


def paginate_cache(cache: "Cache", tables, *, page_size, n_pages,
                   kv_dtype=None) -> "Cache":
    """Convert a freshly-prefilled DENSE cache into the paged layout.

    ``tables (B, max_pages)`` comes from the host-side allocator.  Runs
    inside the engines' fused prefill jit; the dense cache is a transient
    (sized to the prompt, not max_len).  Entries older than one logical
    ring (an over-long prompt on a small reservation) are dropped — the
    row then freezes at its first capacity check, same as the dense path.

    ``kv_dtype`` picks the POOL dtype (default: the dense cache's own) —
    ``jnp.int8`` quantizes the prompt KV on the way in, arming each
    destination page's scale from the prefill write.
    """
    kv = cache.kv
    if kv is None or isinstance(kv, PagedKVCache):
        return cache
    if kv.window:
        raise ValueError("paged KV supports full attention only (window=0)")
    L, B, S, Hkv, hd = kv.k.shape
    pool_dtype = kv.k.dtype if kv_dtype is None else jnp.dtype(kv_dtype)
    s_log = tables.shape[1] * page_size
    pool_k = jnp.zeros((L, n_pages + 1, page_size, Hkv, hd), pool_dtype)
    pool_v = jnp.zeros_like(pool_k)
    scale = (jnp.zeros((L, n_pages + 1, Hkv), jnp.float32)
             if pool_dtype == jnp.int8 else None)
    abs_pos = kv.key_pos                                     # (B, S)
    valid = (abs_pos >= 0) & (abs_pos >= kv.pos[:, None] - s_log)
    pool_k, pool_v, sk, sv, ok = _pool_scatter(
        pool_k, pool_v, tables, kv.k, kv.v, abs_pos, valid,
        scale_k=scale, scale_v=scale)
    key_pos = _keypos_scatter(jnp.full((B, s_log), -1, jnp.int32),
                              abs_pos, ok)
    return dataclasses.replace(cache, kv=PagedKVCache(
        pool_k=pool_k, pool_v=pool_v, block_table=tables,
        key_pos=key_pos, pos=kv.pos, scale_k=sk, scale_v=sv,
        page_size=page_size))


def _per_batch(start_pos, batch):
    """Broadcast a scalar start position to (B,) int32."""
    return jnp.broadcast_to(jnp.asarray(start_pos, jnp.int32), (batch,))


def _ring_match(abs_pos, valid, size):
    """Per-slot source index for a masked ring write.

    abs_pos: (D,) absolute positions being written; valid: (D,) write mask.
    Returns (written (S,), src (S,)): slot s takes entry src[s] iff
    written[s].  Expressed as gather + where rather than scatter — XLA CPU
    lowers batched dynamic scatters to a serialized loop, which dominated
    the batched commit path (see engine_bench).  Duplicate slots (a write
    run longer than the ring) resolve to the LAST write, matching scatter
    semantics.
    """
    D = abs_pos.shape[0]
    slots = abs_pos % size
    match = (jnp.arange(size, dtype=jnp.int32)[:, None] == slots[None, :]) \
        & valid[None, :]                                 # (S, D)
    written = jnp.any(match, axis=1)
    src = (D - 1) - jnp.argmax(match[:, ::-1], axis=1).astype(jnp.int32)
    return written, src


def kv_write(cache_k, cache_v, key_pos, k_new, v_new, start_pos):
    """Write S_new entries per sequence at positions [start_b, start_b+S_new).

    cache_k/v: (B, S, Hkv, hd) — per-layer slices (inside scan).
    key_pos: (B, S); k_new/v_new: (B, S_new, Hkv, hd).
    start_pos: () or (B,) — per-sequence absolute start positions.
    Ring indexing per sequence: slot = pos % S.
    Returns updated (cache_k, cache_v, key_pos).
    """
    S = cache_k.shape[1]
    s_new = k_new.shape[1]
    start = _per_batch(start_pos, cache_k.shape[0])

    def one(ck, cv, kp, kn, vn, st):
        abs_pos = st + jnp.arange(s_new, dtype=jnp.int32)
        written, src = _ring_match(abs_pos, jnp.ones((s_new,), bool), S)
        m = written[:, None, None]
        return (jnp.where(m, kn[src].astype(ck.dtype), ck),
                jnp.where(m, vn[src].astype(cv.dtype), cv),
                jnp.where(written, abs_pos[src], kp))

    return jax.vmap(one)(cache_k, cache_v, key_pos, k_new, v_new, start)


def kv_commit(kv, k_new, v_new, accept_nodes, n_accept,
              max_depth):
    """Write each sequence's accepted tree path into its ring buffer (dense)
    or through its block table (paged).

    k_new/v_new: (L, B, W, Hkv, hd) uncommitted tree KVs;
    accept_nodes: (B, Dmax) node ids of the accepted chain (padded);
    n_accept: (B,) accepted tokens per sequence (1..Dmax).
    Writes are masked per sequence: slots beyond n_accept[b] keep their
    previous contents, and ``pos`` advances by n_accept[b].
    """
    if isinstance(kv, PagedKVCache):
        return paged_kv_commit(kv, k_new, v_new, accept_nodes, n_accept,
                               max_depth)
    size = kv.max_len
    idx = jnp.arange(max_depth, dtype=jnp.int32)

    def one(ck, cv, kp, kn, vn, nodes, n, p):
        # ck/cv: (L, S, Hkv, hd); kn/vn: (L, W, Hkv, hd); kp: (S,)
        abs_pos = p + idx
        written, src = _ring_match(abs_pos, idx < n, size)
        sel_k = jnp.take(kn, nodes, axis=1)              # (L, Dmax, Hkv, hd)
        sel_v = jnp.take(vn, nodes, axis=1)
        m = written[None, :, None, None]
        return (jnp.where(m, sel_k[:, src].astype(ck.dtype), ck),
                jnp.where(m, sel_v[:, src].astype(cv.dtype), cv),
                jnp.where(written, abs_pos[src], kp))

    k2, v2, kp2 = jax.vmap(one, in_axes=(1, 1, 0, 1, 1, 0, 0, 0),
                           out_axes=(1, 1, 0))(
        kv.k, kv.v, kv.key_pos, k_new, v_new,
        accept_nodes, n_accept, kv.pos)
    return KVCache(k=k2, v=v2, key_pos=kp2,
                   pos=kv.pos + n_accept.astype(jnp.int32), window=kv.window)


# --------------------------------------------------------------------------
# Per-row slot primitives (continuous batching, runtime/scheduler.py).
#
# A batched cache is a bank of B independent rows; the scheduler treats each
# row as a slot that sequences are admitted into and evicted from at chunk
# boundaries.  Every helper below maps a function over the batched leaves of
# a ``Cache`` with the leaf's batch-axis position made explicit (KV k/v and
# Mamba/cross arrays carry batch at axis 1, key_pos/pos/xLSTM leaves at
# axis 0), so row surgery never touches the other rows.
# --------------------------------------------------------------------------
def _row_map(fn, *caches: "Cache") -> "Cache":
    """Apply ``fn(batch_axis, *leaves)`` over the batched leaves of Cache(s).

    All caches must share one structure (same model family + shapes apart
    from the batch axis).  Returns a new Cache built from fn's outputs.
    """
    c = caches[0]

    def go(axis, get):
        return fn(axis, *(get(x) for x in caches))

    kv = mamba = xl = ck = cv = None
    if c.kv is not None:
        kv = KVCache(k=go(1, lambda x: x.kv.k), v=go(1, lambda x: x.kv.v),
                     key_pos=go(0, lambda x: x.kv.key_pos),
                     pos=go(0, lambda x: x.kv.pos), window=c.kv.window)
    if c.mamba is not None:
        mamba = MambaState(ssm=go(1, lambda x: x.mamba.ssm),
                           conv=go(1, lambda x: x.mamba.conv),
                           pos=go(0, lambda x: x.mamba.pos))
    if c.xlstm is not None:
        layers = jax.tree_util.tree_map(
            lambda *ls: fn(0, *ls), *(x.xlstm.layers for x in caches))
        xl = XLSTMState(layers=layers, pos=go(0, lambda x: x.xlstm.pos))
    if c.cross_k is not None:
        ck = go(1, lambda x: x.cross_k)
        cv = go(1, lambda x: x.cross_v)
    return Cache(kv=kv, mamba=mamba, xlstm=xl, cross_k=ck, cross_v=cv)


def _without_kv(cache: Cache) -> Cache:
    return dataclasses.replace(cache, kv=None)


def tile_rows(cache: Cache, batch: int) -> Cache:
    """Broadcast a batch-1 cache to ``batch`` identical rows (used once to
    bootstrap the scheduler's resident state from the first admission)."""
    return _row_map(lambda axis, a: jnp.repeat(a, batch, axis=axis), cache)


def blank_paged_rows(row: Cache, batch: int, *, page_size, n_pages,
                     max_len, kv_dtype=None) -> Cache:
    """Paged bootstrap of the scheduler's resident bank from the first B=1
    dense-prefilled admission: non-KV leaves are tiled (masked rows never
    read them), the KV field becomes an EMPTY shared pool — blank rows hold
    no reservation, so unlike the dense ``tile_rows`` bootstrap no slot
    memory is spent on rows that are still free.  ``kv_dtype`` picks the
    pool dtype (default: the prefill's own; ``int8`` = quantized pool)."""
    dkv = row.kv
    if dkv is None:                       # recurrent-only family (xLSTM)
        return tile_rows(row, batch)
    out = _row_map(lambda axis, a: jnp.repeat(a, batch, axis=axis),
                   _without_kv(row))
    L, _, _, Hkv, hd = dkv.k.shape
    return dataclasses.replace(out, kv=init_paged_kv_cache(
        L, batch, max_len, Hkv, hd, page_size=page_size, n_pages=n_pages,
        dtype=dkv.k.dtype if kv_dtype is None else kv_dtype))


def reset_rows(cache: Cache, rows) -> Cache:
    """Clear the rows where ``rows (B,)`` is True: ``key_pos`` -> -1 (every
    attention mask rejects the slot), ``pos`` -> 0, KV/recurrent state
    zeroed.  A freed row is inert until ``insert_rows`` installs a freshly
    prefilled sequence — reset guarantees no stale KV survives eviction, it
    does not produce a decodable initial state (e.g. xLSTM stabilizer
    offsets are re-established by the admission prefill).

    Paged KV: the row's ``block_table`` entries drop to -1 (its pool pages
    go back to the allocator host-side; their contents are unreachable once
    no table references them) and any write the dead row still issues from
    inside a chunk redirects to the trash page.  Quantized pools
    deliberately do NOT touch the freed pages' scales here: the dead row's
    table is STALE bookkeeping — the scheduler releases pages host-side at
    completion and batches row resets to the END of the boundary, so by
    reset time a "freed" page may already carry a new resident admitted
    earlier in the SAME boundary, and zeroing its just-armed scale would
    let the next decode write re-arm it from the wrong amax (silent dequant
    corruption of the resident's already-quantized prompt).  A freed page's
    stale armed scale is unreachable garbage, exactly like its int8
    payload; ``_paged_insert_row`` un-arms the reservation at the only
    sound point — reserve time, zero-then-arm."""
    rows = jnp.asarray(rows, bool)

    def f(axis, a):
        shape = [1] * a.ndim
        shape[axis] = rows.shape[0]
        return jnp.where(rows.reshape(shape), jnp.zeros_like(a), a)

    if isinstance(cache.kv, PagedKVCache):
        kv = cache.kv
        out = _row_map(f, _without_kv(cache))
        return dataclasses.replace(out, kv=dataclasses.replace(
            kv,
            block_table=jnp.where(rows[:, None], jnp.int32(-1),
                                  kv.block_table),
            key_pos=jnp.where(rows[:, None], jnp.int32(-1), kv.key_pos),
            pos=jnp.where(rows, jnp.int32(0), kv.pos)))

    out = _row_map(f, cache)
    if out.kv is not None:
        out.kv.key_pos = jnp.where(rows[:, None], jnp.int32(-1),
                                   cache.kv.key_pos)
    return out


def insert_rows(cache: Cache, row, src: Cache, *, pages=None) -> Cache:
    """Copy row 0 of a batch-1 cache ``src`` into row ``row`` of ``cache``
    (admission: the new request's B=1 prefilled state takes over the slot).
    ``row`` may be a traced scalar, so one jitted insert serves every slot.

    When ``cache`` is paged, ``src`` is still DENSE (admission prefills at
    B=1 in the dense layout) and ``pages (max_pages,)`` — the row's fresh
    reservation, -1-padded — must be supplied; the prompt KV is scattered
    through it into the shared pool."""
    row = jnp.asarray(row, jnp.int32)

    def f(axis, big, small):
        upd = jax.lax.index_in_dim(small, 0, axis, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(
            big, upd.astype(big.dtype), row, axis)

    if isinstance(cache.kv, PagedKVCache):
        if pages is None:
            raise ValueError("paged insert_rows needs the row's pages")
        out = _row_map(f, _without_kv(cache), _without_kv(src))
        return dataclasses.replace(
            out, kv=_paged_insert_row(cache.kv, row, src.kv, pages))
    return _row_map(f, cache, src)


def _paged_insert_row(kv: PagedKVCache, row, dkv: KVCache, pages
                      ) -> PagedKVCache:
    """Scatter a dense B=1 prefill into ``row``'s fresh page reservation.

    Quantized pools un-arm the fresh reservation's scales FIRST, so the
    prompt write re-arms them from the new resident's own amax.  This is
    the ONLY place recycled-page scales are cleared: an evicted page keeps
    its stale armed scale until re-reserved (``reset_rows`` must not touch
    pool scales — its view of the dead row's pages is stale by the time
    the scheduler batches the reset; see its docstring)."""
    pages = jnp.asarray(pages, jnp.int32)
    s_log = kv.max_len
    abs_pos = dkv.key_pos[0]                              # (S_dense,)
    valid = (abs_pos >= 0) & (abs_pos >= dkv.pos[0] - s_log)
    sk, sv = kv.scale_k, kv.scale_v
    if sk is not None:
        sk = _zero_page_scales(sk, pages, jnp.ones(pages.shape, bool))
        sv = _zero_page_scales(sv, pages, jnp.ones(pages.shape, bool))
    pool_k, pool_v, sk, sv, ok = _pool_scatter(
        kv.pool_k, kv.pool_v, pages[None, :], dkv.k, dkv.v,
        abs_pos[None, :], valid[None, :], scale_k=sk, scale_v=sv)
    kp_row = _keypos_scatter(jnp.full((1, s_log), -1, jnp.int32),
                             abs_pos[None, :], ok)[0]
    return dataclasses.replace(
        kv, pool_k=pool_k, pool_v=pool_v, scale_k=sk, scale_v=sv,
        block_table=kv.block_table.at[row].set(pages),
        key_pos=kv.key_pos.at[row].set(kp_row),
        pos=kv.pos.at[row].set(dkv.pos[0]))


def slice_row(cache: Cache, row) -> Cache:
    """B=1 view of one bank row (the attention context a chunked-prefill
    piece extends).  ``row`` may be a traced scalar.  KV-only caches: the
    chunked-prefill path is gated to attention families, so recurrent /
    cross state never reaches here.

    Paged caches share the pool by reference — only the row's table,
    ``key_pos`` and ``pos`` are sliced, so the view costs O(max_pages), not
    a pool copy."""
    if cache.mamba is not None or cache.xlstm is not None \
            or cache.cross_k is not None:
        raise ValueError("slice_row supports KV-only caches "
                         "(chunked prefill is attention-family only)")
    row = jnp.asarray(row, jnp.int32)
    kv = cache.kv

    def rows(a, axis):
        return jax.lax.dynamic_slice_in_dim(a, row, 1, axis)

    if isinstance(kv, PagedKVCache):
        return Cache(kv=dataclasses.replace(
            kv, block_table=rows(kv.block_table, 0),
            key_pos=rows(kv.key_pos, 0), pos=rows(kv.pos, 0)))
    return Cache(kv=KVCache(k=rows(kv.k, 1), v=rows(kv.v, 1),
                            key_pos=rows(kv.key_pos, 0),
                            pos=rows(kv.pos, 0), window=kv.window))


def write_row_at(cache: Cache, row, ks, vs, start, n_valid) -> Cache:
    """Partial-row insert at an offset (chunked prefill): write the first
    ``n_valid`` of ``ks/vs (L, W, Hkv, hd)`` into row ``row`` at absolute
    positions [start, start + n_valid) and advance only that row's ``pos``.

    The complement of ``insert_rows`` (which replaces a whole row): pieces
    of one prompt land incrementally — dense rows via a masked ring scatter
    on the row, paged rows via ``_pool_scatter`` through the row's block
    table (each piece is paginated as it arrives; entries past ``n_valid``
    — tail-piece padding — are dropped, paged ones into the trash page).
    Requires W <= the row's logical length (piece slots must not alias).
    KV-only caches, same gate as ``slice_row``."""
    if cache.mamba is not None or cache.xlstm is not None \
            or cache.cross_k is not None:
        raise ValueError("write_row_at supports KV-only caches "
                         "(chunked prefill is attention-family only)")
    row = jnp.asarray(row, jnp.int32)
    kv = cache.kv
    W = ks.shape[1]
    idx = jnp.arange(W, dtype=jnp.int32)
    valid = idx < n_valid
    abs_pos = jnp.asarray(start, jnp.int32) + idx
    new_pos = kv.pos.at[row].set(abs_pos[0] + n_valid)

    if isinstance(kv, PagedKVCache):
        table_row = jax.lax.dynamic_slice_in_dim(kv.block_table, row, 1, 0)
        pool_k, pool_v, sk, sv, ok = _pool_scatter(
            kv.pool_k, kv.pool_v, table_row, ks[:, None], vs[:, None],
            abs_pos[None, :], valid[None, :],
            scale_k=kv.scale_k, scale_v=kv.scale_v)
        kp_row = _keypos_scatter(
            jax.lax.dynamic_slice_in_dim(kv.key_pos, row, 1, 0),
            abs_pos[None, :], ok)
        return dataclasses.replace(cache, kv=dataclasses.replace(
            kv, pool_k=pool_k, pool_v=pool_v, scale_k=sk, scale_v=sv,
            key_pos=jax.lax.dynamic_update_slice_in_dim(
                kv.key_pos, kp_row, row, 0),
            pos=new_pos))

    S = kv.max_len
    slots = abs_pos % S
    # masked scatter: invalid (padding) entries re-write the slot's current
    # contents — a gather of W slots, cheap next to the piece itself
    k_cur = kv.k[:, row, slots]
    v_cur = kv.v[:, row, slots]
    m = valid[:, None, None]
    return dataclasses.replace(cache, kv=dataclasses.replace(
        kv,
        k=kv.k.at[:, row, slots].set(
            jnp.where(m, ks.astype(kv.k.dtype), k_cur)),
        v=kv.v.at[:, row, slots].set(
            jnp.where(m, vs.astype(kv.v.dtype), v_cur)),
        key_pos=kv.key_pos.at[row, slots].set(
            jnp.where(valid, abs_pos, kv.key_pos[row, slots])),
        pos=new_pos))


_UNBOUNDED = 1 << 30


def capacity_left(cache: Cache) -> jax.Array:
    """(B,) decode slots left before a full (window=0) KV ring would wrap
    past capacity and silently overwrite its oldest entries.

    Sliding-window caches wrap by design and recurrent state is O(1) in
    context, so those report an effectively unbounded budget.  The chunk
    drivers fold this into the scan done-mask: a sequence freezes (stops
    emitting/committing) instead of corrupting its own attention.

    Paged caches count slots inside the row's page RESERVATION — a
    partially-reserved row (pool was short at admission) freezes when its
    last reserved page fills, exactly like a dense row hitting ``max_len``;
    the trash-page redirect below it is defense in depth, not the contract."""
    pos = cache.pos
    kv = cache.kv
    if isinstance(kv, PagedKVCache):
        n_alloc = jnp.sum(kv.block_table >= 0, axis=1).astype(jnp.int32)
        return n_alloc * jnp.int32(kv.page_size) - kv.pos
    if kv is None or kv.window:
        return jnp.full(pos.shape, _UNBOUNDED, jnp.int32)
    return jnp.int32(kv.max_len) - kv.pos


def decode_mask(key_pos, q_pos, window):
    """Validity mask (T,) for one query at absolute position q_pos.

    key_pos: (T,) absolute positions in one sequence's cache (-1 empty).
    """
    ok = (key_pos >= 0) & (key_pos <= q_pos)
    if window:
        ok &= key_pos > q_pos - window
    return ok


def batched_decode_mask(key_pos, q_pos, window):
    """Per-batch validity mask (B, W, S).

    key_pos: (B, S) absolute positions per slot; q_pos: (B, W) absolute query
    positions (they differ per sequence once acceptance lengths diverge).
    """
    kp = key_pos[:, None, :]                             # (B, 1, S)
    qp = q_pos[:, :, None]                               # (B, W, 1)
    ok = (kp >= 0) & (kp <= qp)
    if window:
        ok &= kp > qp - window
    return ok


def prefill_mask(seq_len, window, q_offset=0, dtype=bool):
    """Causal (optionally windowed) (S, S) mask for prefill."""
    q = jnp.arange(seq_len)[:, None] + q_offset
    k = jnp.arange(seq_len)[None, :] + q_offset
    m = k <= q
    if window:
        m &= k > q - window
    return m
