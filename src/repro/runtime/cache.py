"""Decode-state caches: KV cache (full or sliding-window ring buffer),
Mamba2 SSM state, xLSTM states, and encoder cross-attention memory.

Conventions
-----------
- KV arrays are stacked over layers: ``(L, B, S, Hkv, hd)`` so model stacks can
  ``lax.scan`` over the leading axis.
- ``key_pos (S,)`` holds the absolute position stored in each cache slot
  (-1 = empty).  With a sliding window the cache is a ring buffer: slot(p) =
  p % S.  The attention mask is derived from ``key_pos`` (validity + causality
  + window), so ring wraparound needs no special-casing.
- ``pos ()`` is the number of tokens processed so far (uniform across the
  batch; the serving engine schedules uniform-length batches and pads).
- RoPE is applied to keys at *write* time with their absolute position.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=["k", "v", "key_pos", "pos"], meta_fields=["window"])
@dataclasses.dataclass
class KVCache:
    k: jax.Array          # (L, B, S, Hkv, hd)
    v: jax.Array          # (L, B, S, Hkv, hd)
    key_pos: jax.Array    # (S,) int32 absolute position per slot; -1 empty
    pos: jax.Array        # ()  int32 tokens processed so far
    window: int = 0       # static: 0 = full attention; >0 = sliding window

    @property
    def max_len(self) -> int:
        return self.k.shape[2]


@partial(jax.tree_util.register_dataclass,
         data_fields=["ssm", "conv", "pos"], meta_fields=[])
@dataclasses.dataclass
class MambaState:
    ssm: jax.Array        # (L, B, nh, hd, N) float32
    conv: jax.Array       # (L, B, K-1, C)    conv tail (C = di + 2N)
    pos: jax.Array        # () int32


@partial(jax.tree_util.register_dataclass,
         data_fields=["layers", "pos"], meta_fields=[])
@dataclasses.dataclass
class XLSTMState:
    layers: tuple         # per-layer dict of state arrays (unrolled stack)
    pos: jax.Array


@partial(jax.tree_util.register_dataclass,
         data_fields=["kv", "mamba", "xlstm", "cross_k", "cross_v"],
         meta_fields=[])
@dataclasses.dataclass
class Cache:
    """Union cache for all architecture families (unused fields = None)."""
    kv: Optional[KVCache] = None            # self-attention layers
    mamba: Optional[MambaState] = None      # Mamba2 layers
    xlstm: Optional[XLSTMState] = None      # xLSTM layers
    cross_k: Optional[jax.Array] = None     # (L, B, Senc, Hkv, hd) enc-dec
    cross_v: Optional[jax.Array] = None

    @property
    def pos(self) -> jax.Array:
        for c in (self.kv, self.mamba, self.xlstm):
            if c is not None:
                return c.pos
        raise ValueError("empty cache")


# --------------------------------------------------------------------------
def init_kv_cache(n_layers, batch, max_len, n_kv, head_dim, *, window=0,
                  dtype=jnp.bfloat16) -> KVCache:
    size = min(max_len, window) if window else max_len
    return KVCache(
        k=jnp.zeros((n_layers, batch, size, n_kv, head_dim), dtype),
        v=jnp.zeros((n_layers, batch, size, n_kv, head_dim), dtype),
        key_pos=jnp.full((size,), -1, jnp.int32),
        pos=jnp.zeros((), jnp.int32),
        window=window,
    )


def kv_write(cache_k, cache_v, key_pos, k_new, v_new, start_pos):
    """Write S_new entries at absolute positions [start, start+S_new).

    cache_k/v: (B, S, Hkv, hd) — per-layer slices (inside scan).
    k_new/v_new: (B, S_new, Hkv, hd).  Ring indexing: slot = pos % S.
    Returns updated (cache_k, cache_v, key_pos).
    """
    S = cache_k.shape[1]
    s_new = k_new.shape[1]
    abs_pos = start_pos + jnp.arange(s_new, dtype=jnp.int32)
    slots = abs_pos % S
    ck = cache_k.at[:, slots].set(k_new)
    cv = cache_v.at[:, slots].set(v_new)
    kp = key_pos.at[slots].set(abs_pos)
    return ck, cv, kp


def decode_mask(key_pos, q_pos, window):
    """Validity mask (T,) for one query at absolute position q_pos.

    key_pos: (T,) absolute positions in cache (-1 empty).
    """
    ok = (key_pos >= 0) & (key_pos <= q_pos)
    if window:
        ok &= key_pos > q_pos - window
    return ok


def prefill_mask(seq_len, window, q_offset=0, dtype=bool):
    """Causal (optionally windowed) (S, S) mask for prefill."""
    q = jnp.arange(seq_len)[:, None] + q_offset
    k = jnp.arange(seq_len)[None, :] + q_offset
    m = k <= q
    if window:
        m &= k > q - window
    return m
