"""Sampling utilities (greedy is the paper's acceptance rule)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(rng, logits, temperature=1.0, top_k=0):
    if temperature <= 0:
        return greedy(logits)
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        cut = vals[..., -1:]
        logits = jnp.where(logits < cut, -1e30, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)
