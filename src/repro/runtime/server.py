"""Asyncio streaming front end over one ``ContinuousScheduler`` replica.

One ``AsyncEngineServer`` owns one scheduler (one engine bank) and runs
its boundary loop on a dedicated worker thread; the asyncio side talks
to it through thread-safe inbox/cancel queues and receives per-request
token streams flushed once per chunk boundary (the chunked scan's one
host sync per chunk is the natural streaming granularity — tokens
cannot be observed any earlier without breaking the compiled K-step
scan).

Failure semantics
-----------------
* **Cancellation** (``cancel(req_id)`` or a client dropping the stream)
  is *boundary-asynchronous*: it is recorded immediately but takes
  effect at the scheduler's NEXT chunk boundary, where the request is
  finalized CANCELLED with the tokens emitted so far and — mid-flight —
  its row and reserved pages are released for the same boundary's
  admissions.
* **Deadlines** (``submit(..., deadline_s=)``) are measured on the
  replica's serve clock from submission; the first boundary past the
  deadline finalizes the request TIMED_OUT (queued requests time out
  without ever being admitted).
* **Backpressure**: ``queue_limit`` bounds queued-not-yet-admitted
  requests.  A submit over the limit (or to an unhealthy replica)
  resolves immediately with a typed REJECTED result — load is shed with
  a first-class answer, never an unbounded queue.
* **Replica crash** (injected ``ReplicaCrash`` or any unexpected engine
  fault): the worker finalizes every in-flight and queued request as
  FAILED via ``scheduler.fail_all`` (pages released — a dead replica
  leaks nothing), resolves their handles, and marks the server
  unhealthy; subsequent submits are REJECTED.  Recovery is the router's
  job (retry on another replica), not the replica's.

Every request therefore ends in exactly one typed terminal state
(DONE / CANCELLED / TIMED_OUT / FAILED / REJECTED) and every handle's
``result()`` future resolves — a consumer can never hang on a request
the scheduler forgot.
"""
from __future__ import annotations

import asyncio
import collections
import threading
import time
from typing import Optional

import numpy as np

from repro.runtime.scheduler import (CANCELLED, FAILED, QUEUED, REJECTED,
                                     ContinuousScheduler, Request,
                                     RequestResult)


class RequestHandle:
    """Consumer view of one submitted request: a token stream plus the
    final typed result.  ``stream()`` yields lists of tokens (one list
    per chunk-boundary flush) and ends when the request reaches a
    terminal state; ``result()`` resolves to the ``RequestResult``."""

    def __init__(self, req_id: int, loop: asyncio.AbstractEventLoop):
        self.req_id = req_id
        self.state = QUEUED
        self._loop = loop
        self._chunks: asyncio.Queue = asyncio.Queue()
        self._result: asyncio.Future = loop.create_future()

    # ---- worker-thread side (always via call_soon_threadsafe) ----------
    def _push_threadsafe(self, tokens) -> None:
        self._loop.call_soon_threadsafe(self._chunks.put_nowait,
                                        list(tokens))

    def _finish_threadsafe(self, result: RequestResult) -> None:
        def _finish():
            self.state = result.state
            if not self._result.done():
                self._result.set_result(result)
            self._chunks.put_nowait(None)          # stream sentinel
        self._loop.call_soon_threadsafe(_finish)

    def _reject_local(self, result: RequestResult) -> None:
        """Resolve on the event-loop thread (backpressure path)."""
        self.state = result.state
        if not self._result.done():
            self._result.set_result(result)
        self._chunks.put_nowait(None)

    # ---- consumer side --------------------------------------------------
    async def stream(self):
        while True:
            item = await self._chunks.get()
            if item is None:
                return
            yield item

    async def result(self) -> RequestResult:
        return await asyncio.shield(self._result)


def _typed_result(req: Request, state: str, now: float) -> RequestResult:
    return RequestResult(req_id=req.req_id,
                         tokens=np.zeros((0,), np.int32), n_emitted=0,
                         arrival=now, t_admit=now, t_finish=now,
                         state=state)


class AsyncEngineServer:
    """One serving replica: a scheduler boundary loop on a worker thread,
    bridged to asyncio.  See the module docstring for failure semantics.

    The worker thread OWNS the scheduler — the asyncio side never calls
    scheduler methods directly; submissions and cancels go through
    thread-safe queues and are drained between boundaries, so the
    scheduler itself needs no locking."""

    def __init__(self, scheduler: ContinuousScheduler, *,
                 name: str = "replica0", eos: Optional[int] = None,
                 queue_limit: int = 64, poll_s: float = 0.005,
                 stall_timeout_s: float = 0.0):
        if queue_limit < 1:
            raise ValueError("queue_limit must be >= 1")
        if stall_timeout_s < 0:
            raise ValueError("stall_timeout_s must be >= 0")
        self.scheduler = scheduler
        self.name = name
        self._eos = eos
        self.queue_limit = queue_limit
        self.poll_s = poll_s
        self._lock = threading.Lock()
        self._inbox: collections.deque = collections.deque()
        self._cancel_box: collections.deque = collections.deque()
        self._handles: dict = {}
        self._work = threading.Event()
        self._stopping = False
        self._crashed: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._load = 0                      # queued + resident (approx.)
        self.completed = 0
        self.rejected = 0
        # worker-published engine snapshots: the event-loop side (health,
        # router audits) must never touch the worker-owned scheduler, so
        # the worker refreshes these under the lock at every publish
        self._pool_ok = True
        self._drained = True
        # boundary-progress heartbeat: the worker refreshes the timestamp
        # at every ingest (loop liveness) and every publish (boundary
        # progress).  A replica with work whose heartbeat goes stale past
        # ``stall_timeout_s`` is STALLED — alive but stuck (a hung device
        # call, an injected stall) — and the router's liveness watcher
        # drains it proactively (``drain_stalled``) instead of letting
        # clients wait on a wedged worker.  0 disables stall detection.
        self.stall_timeout_s = stall_timeout_s
        self._beat_boundary = 0
        self._beat_t = time.perf_counter()
        self._stalled_out = False           # sticky: drained as stalled
        self.stall_drains = 0               # handles failed over by drains
        self._t0 = time.perf_counter()      # serve clock (loop-side twin
        #                                     of scheduler.now())

    # ---- lifecycle -------------------------------------------------------
    async def start(self) -> None:
        if self._thread is not None:
            raise RuntimeError(f"{self.name} already started")
        self._loop = asyncio.get_running_loop()
        self.scheduler.start(eos=self._eos)
        self._t0 = time.perf_counter()
        with self._lock:
            self._beat_t = time.perf_counter()   # heartbeat epoch
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"engine-{self.name}")
        self._thread.start()

    async def stop(self) -> None:
        """Graceful drain: the worker exits once nothing is in flight."""
        self._stopping = True
        self._work.set()
        if self._thread is not None:
            await asyncio.get_running_loop().run_in_executor(
                None, self._thread.join)

    @property
    def healthy(self) -> bool:
        with self._lock:
            stalled_out = self._stalled_out
        return (self._thread is not None and self._thread.is_alive()
                and self._crashed is None and not self._stopping
                and not stalled_out)

    @property
    def stalled(self) -> bool:
        """True when the worker is alive, has work, and its heartbeat is
        older than ``stall_timeout_s`` — no ingest and no boundary
        completed for that long.  Idle replicas never read as stalled
        (nothing obliges their heartbeat to move)."""
        if not self.stall_timeout_s or self._thread is None \
                or not self._thread.is_alive() or self._crashed is not None:
            return False
        with self._lock:
            busy = (self._load + len(self._inbox)) > 0
            age = time.perf_counter() - self._beat_t
        return busy and age > self.stall_timeout_s

    def heartbeat(self) -> dict:
        """Loop-side view of the worker's progress beat."""
        with self._lock:
            return {"boundary": self._beat_boundary,
                    "age_s": time.perf_counter() - self._beat_t}

    def drain_stalled(self) -> int:
        """Liveness drain of a stalled-but-alive replica, called from the
        EVENT LOOP (the stuck worker cannot run its own crash path):
        every outstanding handle resolves FAILED so the router retries it
        elsewhere, queued-but-not-ingested requests included, and the
        replica is marked unhealthy (sticky — it stays out of rotation
        even if the wedged worker later limps on; its late publishes land
        on popped handles and are dropped).  Returns the number of
        handles failed over."""
        with self._lock:
            handles = list(self._handles.values())
            self._handles.clear()
            self._inbox.clear()
            self._stalled_out = True
            self.stall_drains += len(handles)
        now = self._now()
        for h in handles:
            h._reject_local(RequestResult(
                req_id=h.req_id, tokens=np.zeros((0,), np.int32),
                n_emitted=0, arrival=now, t_admit=now, t_finish=now,
                state=FAILED))
        return len(handles)

    @property
    def load(self) -> int:
        with self._lock:
            return self._load + len(self._inbox)

    def _now(self) -> float:
        """Event-loop-side serve clock.  ``scheduler.now()`` belongs to
        the worker thread; the loop side keeps its own epoch (set when
        the scheduler starts) for timestamps on rejected requests."""
        return time.perf_counter() - self._t0

    def health(self) -> dict:
        stalled = self.stalled              # takes the lock itself
        with self._lock:
            completed, rejected = self.completed, self.rejected
            load = self._load + len(self._inbox)
            pool_ok = self._pool_ok
            beat_boundary = self._beat_boundary
            beat_age = time.perf_counter() - self._beat_t
            stall_drains = self.stall_drains
        return {"name": self.name, "healthy": self.healthy,
                "load": load, "completed": completed,
                "rejected": rejected,
                "crashed": repr(self._crashed) if self._crashed else None,
                "pool_conserved": pool_ok,
                "stalled": stalled, "boundary": beat_boundary,
                "beat_age_s": beat_age, "stall_drains": stall_drains}

    def pool_conserved(self) -> bool:
        """Engine page-leak audit, as of the last boundary (worker
        snapshot — safe to call from the event loop)."""
        with self._lock:
            return self._pool_ok

    def drained(self) -> bool:
        """True iff the engine pool was fully free at the last boundary
        (worker snapshot — safe to call from the event loop)."""
        with self._lock:
            return self._drained

    # ---- request plane ---------------------------------------------------
    async def submit(self, request: Request, *,
                     deadline_s: Optional[float] = None) -> RequestHandle:
        """Queue a request; returns its handle.  An unhealthy replica or a
        full admission queue resolves the handle REJECTED immediately."""
        handle = RequestHandle(request.req_id, self._loop)
        if not self.healthy or self.load >= self.queue_limit:
            with self._lock:
                self.rejected += 1
            handle._reject_local(
                _typed_result(request, REJECTED, self._now()))
            return handle
        with self._lock:
            self._handles[request.req_id] = handle
            self._inbox.append((request, deadline_s))
        self._work.set()
        return handle

    async def cancel(self, req_id: int) -> None:
        """Client cancellation: effective at the next chunk boundary."""
        with self._lock:
            self._cancel_box.append(req_id)
        self._work.set()

    # ---- worker thread ---------------------------------------------------
    def _ingest(self) -> None:
        sched = self.scheduler
        with self._lock:
            subs = list(self._inbox)
            self._inbox.clear()
            cans = list(self._cancel_box)
            self._cancel_box.clear()
            # keep drained submissions counted in ``load`` until the next
            # _publish recomputes it from the scheduler — otherwise a
            # burst of submits between ingest and publish reads load 0
            # and sails past queue_limit
            self._load += len(subs)
            self._beat_t = time.perf_counter()   # worker loop is spinning
        for req, deadline_s in subs:
            # arrivals/deadlines live on the replica's serve clock
            req.arrival = sched.now()
            req.deadline = None if deadline_s is None else \
                req.arrival + float(deadline_s)
            sched.submit(req)
        for req_id in cans:
            sched.abort(req_id, CANCELLED)

    def _publish(self, emitted, finished, boundary=None) -> None:
        # engine audits run here, on the worker thread that owns the
        # scheduler; the loop side reads the published snapshot
        eng = self.scheduler.engine
        pool_ok = eng.sched_pool_conserved() \
            if hasattr(eng, "sched_pool_conserved") else True
        drained = eng.sched_drained() \
            if hasattr(eng, "sched_drained") else True
        with self._lock:
            for req_id, toks in emitted.items():
                h = self._handles.get(req_id)
                if h is not None:
                    h._push_threadsafe(toks)
            for res in finished:
                h = self._handles.pop(res.req_id, None)
                if h is not None:
                    h._finish_threadsafe(res)
                self.completed += 1
            self._load = self.scheduler.load
            self._pool_ok = pool_ok
            self._drained = drained
            self._beat_t = time.perf_counter()   # boundary progressed
            if boundary is not None:
                self._beat_boundary = boundary

    def _run(self) -> None:
        sched = self.scheduler
        try:
            while True:
                self._ingest()
                if not sched.has_work:
                    if self._stopping:
                        break
                    self._work.clear()
                    # re-check after clearing: a submit may have landed
                    # between has_work and clear (classic lost wakeup)
                    with self._lock:
                        empty = not self._inbox and not self._cancel_box
                    if empty and not self._stopping:
                        self._work.wait(timeout=0.25)
                    continue
                report = sched.boundary()   # faults stall/crash inside
                self._publish(report.emitted, report.finished,
                              boundary=report.boundary)
                if report.idle:
                    # resident bank empty but requests queued (injected
                    # pool exhaustion / future arrivals): don't hot-spin
                    self._work.wait(timeout=self.poll_s)
        except BaseException as e:          # noqa: BLE001 — crash path
            self._crashed = e
            failed = sched.fail_all(e)
            self._publish({}, failed)
        finally:
            # whatever is left (post-crash stragglers in the inbox, or
            # handles a racing submit added) must still resolve: nobody
            # may await a dead replica forever
            with self._lock:
                leftovers = list(self._handles.values())
                self._handles.clear()
                inbox = list(self._inbox)
                self._inbox.clear()
                self._load = 0
            now = sched.now()
            for req, _ in inbox:
                h = next((x for x in leftovers if x.req_id == req.req_id),
                         None)
                if h is not None and not h._result.done():
                    h._finish_threadsafe(_typed_result(
                        req, REJECTED, now))
            for h in leftovers:
                if not h._result.done():
                    res = self.scheduler._results.get(h.req_id)
                    if res is not None:
                        h._finish_threadsafe(res)
