"""Multi-replica router: load balancing, retry with backoff, idempotent
re-streaming over ``AsyncEngineServer`` replicas.

The router is the client-facing plane: it picks the least-loaded healthy
replica for each request, streams its tokens, and absorbs replica
failures so the client sees exactly one typed terminal result per
request.

Failure semantics
-----------------
* **Routing / health**: every attempt goes to the least-loaded replica
  whose ``healthy`` flag is up (ties break by replica order); an
  optional background health watcher snapshots ``health()`` for
  observability.  With no healthy replica left the request resolves
  REJECTED without running.
* **Liveness probes**: the same watcher reads each replica's
  boundary-progress heartbeat.  A replica that is alive but stuck — no
  ingest and no boundary completed for ``stall_timeout_s`` while it has
  work — is drained proactively (``drain_stalled``): its outstanding
  handles resolve FAILED, which feeds straight into the retry path
  below, and the replica is marked unhealthy so routing skips it.
  Clients never wait out a wedged worker.
* **Retry**: a FAILED attempt (replica crashed mid-request) or a
  REJECTED one (backpressure) is retried up to ``max_retries`` times
  with exponential backoff plus deterministic per-(request, attempt)
  jitter, preferring a different replica than the one that just failed.
  DONE / CANCELLED / TIMED_OUT are terminal — a client cancellation or
  an expired deadline is never retried.
* **Idempotency guard**: the router counts tokens already delivered to
  the client; a retried request re-decodes from scratch on the new
  replica (decode is greedy, hence deterministic per prompt) and the
  router SKIPS the already-delivered prefix, so a retry never
  double-emits and the client's stream is a clean continuation.  The
  final result's tokens always equal the delivered stream.
* **Client disconnect injection**: with ``client_faults``
  (``faults.ClientFaults``), a request whose client is scheduled to
  hang up is cancelled on its replica once that many tokens were
  delivered — exercising the CANCELLED path end to end.

``replay()`` drives an open-loop arrival trace through the router
(arrival times honoured on the router's own clock) and aggregates
router-level stats: per-state counts, retries, goodput (tokens of DONE
requests per second of makespan) and latency percentiles.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
import zlib
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.runtime.scheduler import (DONE, FAILED, REJECTED,
                                     TERMINAL_STATES, Request,
                                     RequestResult)
from repro.runtime.server import AsyncEngineServer


class ReplicaRouter:
    """Route requests across replicas; retry faults; never double-emit."""

    def __init__(self, replicas: Sequence[AsyncEngineServer], *,
                 max_retries: int = 2, backoff_base: float = 0.05,
                 backoff_cap: float = 1.0, jitter: float = 0.5,
                 seed: int = 0, client_faults=None):
        if not replicas:
            raise ValueError("router needs at least one replica")
        if max_retries < 0 or backoff_base < 0 or jitter < 0:
            raise ValueError("max_retries/backoff_base/jitter must be >= 0")
        self.replicas = list(replicas)
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.jitter = jitter
        self.seed = seed
        self.client_faults = client_faults
        self.retries = 0
        self.routed: Dict[str, int] = {r.name: 0 for r in self.replicas}
        self.health_log: List[list] = []
        self.stall_drains = 0               # handles failed over by probes
        self._health_task: Optional[asyncio.Task] = None

    # ---- replica plane ---------------------------------------------------
    async def start(self, *, health_every_s: float = 0.0) -> None:
        for r in self.replicas:
            await r.start()
        if health_every_s > 0:
            self._health_task = asyncio.ensure_future(
                self._watch(health_every_s))

    async def stop(self) -> None:
        if self._health_task is not None:
            self._health_task.cancel()
            self._health_task = None
        for r in self.replicas:
            await r.stop()

    async def _watch(self, every_s: float) -> None:
        """Health snapshots + liveness probes.  Runs on the event loop:
        ``stalled`` reads only loop-side state and ``drain_stalled``
        resolves handles loop-side, so the stuck worker thread is never
        touched — its late publishes land on popped handles."""
        try:
            while True:
                self.health_log.append(self.health())
                for r in self.replicas:
                    if r.stalled:
                        self.stall_drains += r.drain_stalled()
                await asyncio.sleep(every_s)
        except asyncio.CancelledError:
            pass

    def health(self) -> list:
        return [r.health() for r in self.replicas]

    def pages_conserved(self) -> bool:
        """Fleet-wide page-leak audit (True for dense engines).  Reads
        each replica's worker-published snapshot — the router runs on the
        event loop and must never touch a worker-owned scheduler."""
        return all(r.pool_conserved() for r in self.replicas)

    def drained(self) -> bool:
        """After everything terminal: every replica's pool fully free
        (as of each worker's last boundary snapshot)."""
        return all(r.drained() for r in self.replicas)

    def _pick(self, avoid=None) -> Optional[AsyncEngineServer]:
        healthy = [r for r in self.replicas if r.healthy]
        if not healthy:
            return None
        preferred = [r for r in healthy if r is not avoid] or healthy
        return min(preferred,
                   key=lambda r: (r.load, self.replicas.index(r)))

    def _backoff(self, req_id, attempt: int) -> float:
        delay = min(self.backoff_cap,
                    self.backoff_base * (2.0 ** (attempt - 1)))
        # deterministic per (seed, request, attempt): jitter decorrelates
        # retry bursts without making chaos runs unreplayable.  Request
        # ids are application-chosen and not necessarily integers, so
        # seed from a stable digest of the id's string form (crc32 is
        # stable across processes, unlike hash())
        rid = zlib.crc32(str(req_id).encode("utf-8"))
        rng = np.random.default_rng([self.seed, rid, attempt])
        return delay * (1.0 + self.jitter * float(rng.random()))

    # ---- request plane ---------------------------------------------------
    async def generate(self, request: Request, *,
                       deadline_s: Optional[float] = None) -> tuple:
        """Run one request to a terminal state; returns
        ``(delivered_tokens, RequestResult)``.  Tokens are delivered
        exactly once across all retry attempts (idempotency guard)."""
        delivered: List[int] = []
        disconnect_after = None
        if self.client_faults is not None:
            disconnect_after = self.client_faults.disconnect_after(
                request.req_id)
        attempt = 0
        avoid = None
        result = None
        while True:
            replica = self._pick(avoid=avoid)
            if replica is None:
                result = RequestResult(
                    req_id=request.req_id,
                    # reprolint: disable=R3 (host list, not a device array)
                    tokens=np.asarray(delivered, np.int32),
                    n_emitted=len(delivered), arrival=0.0, t_admit=0.0,
                    t_finish=0.0, state=REJECTED)
                break
            self.routed[replica.name] += 1
            # the scheduler mutates Request in place (arrival, deadline,
            # age): every attempt gets a fresh copy so a retry replays the
            # original request, not the previous attempt's leftovers
            handle = await replica.submit(
                dataclasses.replace(request), deadline_s=deadline_s)
            seen = 0
            cancelled = False
            async for toks in handle.stream():
                for t in toks:
                    seen += 1
                    if seen > len(delivered):   # skip re-decoded prefix
                        delivered.append(int(t))
                if (disconnect_after is not None and not cancelled
                        and len(delivered) >= disconnect_after):
                    cancelled = True
                    await replica.cancel(handle.req_id)
            result = await handle.result()
            assert result.state in TERMINAL_STATES
            if result.state not in (REJECTED, FAILED):
                break                           # DONE/CANCELLED/TIMED_OUT
            if attempt >= self.max_retries:
                break
            attempt += 1
            self.retries += 1
            avoid = replica
            await asyncio.sleep(self._backoff(request.req_id, attempt))
        return delivered, result


async def replay(router: ReplicaRouter, requests: Sequence[Request], *,
                 deadline_s: Optional[float] = None) -> tuple:
    """Open-loop arrival replay through the router: each request is
    submitted at its ``arrival`` offset on the router's clock; returns
    ``(results_in_request_order, stats)``."""
    t0 = time.perf_counter()
    lat: Dict[int, float] = {}
    out: Dict[int, RequestResult] = {}
    tokens: Dict[int, list] = {}

    async def one(req: Request):
        wait = req.arrival - (time.perf_counter() - t0)
        if wait > 0:
            await asyncio.sleep(wait)
        t_sub = time.perf_counter()
        toks, res = await router.generate(req, deadline_s=deadline_s)
        lat[req.req_id] = time.perf_counter() - t_sub
        out[req.req_id] = res
        tokens[req.req_id] = toks

    await asyncio.gather(*(one(r) for r in requests))
    makespan = time.perf_counter() - t0
    ordered = [out[r.req_id] for r in requests]
    states: Dict[str, int] = {}
    for r in ordered:
        states[r.state] = states.get(r.state, 0) + 1
    total = sum(len(tokens[r.req_id]) for r in requests)
    good = sum(r.n_emitted for r in ordered if r.state == DONE)
    lats = np.asarray([lat[r.req_id] for r in requests])

    def pct(q):
        return float(np.percentile(lats, q)) if lats.size else 0.0

    stats = {
        "requests": len(ordered),
        "makespan_s": makespan,
        "delivered_total": total,
        "tok_s": total / makespan if makespan > 0 else float("inf"),
        "goodput_tok_s": good / makespan if makespan > 0 else float("inf"),
        "states": states,
        "terminal": all(r.state in TERMINAL_STATES for r in ordered),
        "retries": router.retries,
        "routed": dict(router.routed),
        "latency_mean_s": float(lats.mean()) if lats.size else 0.0,
        "latency_p50_s": pct(50),
        "latency_p95_s": pct(95),
        "latency_max_s": float(lats.max()) if lats.size else 0.0,
    }
    return ordered, stats
