"""AdamW in pure JAX (no optax dependency)."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=["mu", "nu", "step"], meta_fields=[])
@dataclasses.dataclass
class AdamWState:
    mu: object
    nu: object
    step: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros),
                      step=jnp.zeros((), jnp.int32))


def adamw_update(grads, state: AdamWState, params, *, lr=3e-4, b1=0.9,
                 b2=0.95, eps=1e-8, weight_decay=0.01):
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    # separate tree_maps (tuple-packing leaves would break on pytrees that
    # use tuples as containers, e.g. the xLSTM layer stack); XLA CSEs the
    # recomputed moment updates under jit.
    tm = jax.tree_util.tree_map
    new_mu = tm(lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32),
                grads, state.mu)
    new_nu = tm(lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                grads, state.nu)

    def upd(p, m, v):
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps) \
            + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = tm(upd, params, new_mu, new_nu)
    return new_params, AdamWState(mu=new_mu, nu=new_nu, step=step)
