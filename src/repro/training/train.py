"""LM training step (next-token CE + MoE aux loss) and Medusa-head training.

``train_step`` is the function the train_4k dry-run shapes lower; it is a
full forward + backward + AdamW update.  ``medusa_step`` trains drafting
heads against offset targets with the base model frozen (the end-to-end
example uses it to produce *real* acceptance-length measurements).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.speculative.medusa import medusa_logits
from repro.training.optimizer import adamw_update


def lm_loss(cfg, model, params, batch):
    """batch: tokens (B,S) int32, labels (B,S) int32 (-100 = ignore)."""
    logits, extras, _ = model.prefill(params, batch, return_cache=False)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:
        # VLM: logits cover [patch_embeds; tokens] — loss on the text tail
        logits = logits[:, -labels.shape[1]:]
    valid = labels >= 0
    labels = jnp.maximum(labels, 0)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    n = jnp.maximum(jnp.sum(valid), 1)
    ce = -jnp.sum(jnp.where(valid, ll, 0.0)) / n
    return ce + extras["aux_loss"], ce


def train_step(cfg, model, params, opt_state, batch, *, lr=3e-4):
    """One optimizer step.  Returns (params, opt_state, metrics)."""
    if cfg.remat:
        loss_fn = jax.checkpoint(lambda p: lm_loss(cfg, model, p, batch))
    else:
        loss_fn = lambda p: lm_loss(cfg, model, p, batch)
    (loss, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    params, opt_state = adamw_update(grads, opt_state, params, lr=lr)
    return params, opt_state, {"loss": loss, "ce": ce}


# --------------------------------------------------------------------------
# Medusa head training (base model frozen)
# --------------------------------------------------------------------------
def medusa_loss(cfg, model, params, heads, batch):
    """Head h is trained to predict the token at offset h+1."""
    _, extras, _ = model.prefill(params, batch, return_cache=False)
    hidden = extras["hidden"]                                # (B,S,d)
    logits = medusa_logits(cfg, heads, hidden)               # (B,S,H,V)
    tokens = batch["tokens"]
    B, S = tokens.shape
    H = cfg.medusa_heads
    total = 0.0
    count = 0
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    for h in range(H):
        off = h + 2                       # hidden at t predicts t+h+2 for head h+1
        if off >= S:
            break
        tgt = tokens[:, off:]
        pred = lp[:, : S - off, h]
        ll = jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
        total = total - jnp.mean(ll)
        count += 1
    return total / max(count, 1)


def medusa_step(cfg, model, params, heads, opt_state, batch, *, lr=1e-3):
    loss, grads = jax.value_and_grad(
        lambda h: medusa_loss(cfg, model, params, h, batch))(heads)
    heads, opt_state = adamw_update(grads, opt_state, heads, lr=lr,
                                    weight_decay=0.0)
    return heads, opt_state, {"loss": loss}
