"""Flat .npz checkpointing for arbitrary param pytrees."""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, tree) -> None:
    leaves, treedef = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, treedef=np.frombuffer(str(treedef).encode(), np.uint8),
             **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)})


def restore(path: str, like):
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path)
    leaves, treedef = _flatten(like)
    new = [np.asarray(data[f"leaf_{i}"]).astype(np.asarray(l).dtype)
           for i, l in enumerate(leaves)]
    for old, n in zip(leaves, new):
        assert old.shape == n.shape, (old.shape, n.shape)
    return jax.tree_util.tree_unflatten(treedef, new)
