"""ARCA — Architecture-aware profiling (paper §III-C).

Determines the *speculative strategy* (verification width + tree) and the
*partitioning strategy* (per-unit ratio), balancing acceptance length
against hardware parallelism and memory contention.

Two time sources feed the same search:

  * ``Soc`` — an analytic model of a unified-memory CPU+GPU SoC, calibrated
    to the paper's Jetson Xavier NX testbed (GPU @204 MHz, 6-core ARM
    @1.9 GHz, shared LPDDR4x).  Used to reproduce Fig. 9 / Fig. 10.
  * ``roofline_time`` — the TPU-mesh roofline (compute/HBM/ICI terms from
    the dry-run artifacts).  Used by the serving launcher on the pod.

On real hardware the same ``choose_strategy`` runs over measured step times:
``profile_engine(engine, widths)`` times the engine's COMPILED per-width
step functions (``DecodeEngine.time_step`` — the strategy is a jit
argument, so the timed function is exactly the deployed one) and returns
the ``time_fn`` the search consumes.  The search is identical, only the
timer changes; the scheduler's adaptive mode
(runtime/scheduler.py ``AdaptiveSpeculation``) re-runs the argmax online
from the measured table plus the observed acceptance EMA.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.core.speculative import tree as T

WIDTHS = (1, 2, 4, 8, 16, 32, 64)       # powers of two (§III-C2, wave quant)


# ===========================================================================
# workload model (per decode step)
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class Workload:
    weight_bytes: float          # active weight bytes read once per step
    linear_flops: float          # 2 * N_active * W
    attn_dense_flops: float      # W x ctx (the KV-cache part)
    attn_sparse_flops: float     # tree-mask nnz part
    kv_bytes: float              # KV cache bytes read
    sync_points: int             # layer-boundary synchronizations


def decode_workload(cfg, width: int, ctx: int,
                    spec: Optional[T.TreeSpec] = None,
                    dtype_bytes: int = 2) -> Workload:
    n_active = cfg.active_param_count()
    L = cfg.num_layers
    H, hd, Hkv = cfg.num_heads, cfg.head_dim, cfg.num_kv_heads
    nnz = int(spec.mask.sum()) if spec is not None else width * (width + 1) // 2
    return Workload(
        weight_bytes=n_active * dtype_bytes,
        linear_flops=2.0 * n_active * width,
        attn_dense_flops=2.0 * 2 * width * ctx * H * hd * L,
        attn_sparse_flops=2.0 * 2 * nnz * H * hd * L,
        kv_bytes=2.0 * ctx * Hkv * hd * L * dtype_bytes,
        sync_points=2 * L,
    )


# ===========================================================================
# unified-memory SoC model (Jetson NX calibration)
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class Unit:
    name: str
    flops: float                 # peak FLOP/s (fp16)
    gemm_eff: float              # achieved fraction on dense GEMM (linears)
    sparse_eff: float            # achieved fraction on tree-sparse work
    attn_eff: float = 0.5        # achieved fraction on dense KV-cache
                                 # attention (streaming, smaller GEMMs; CPUs
                                 # are disproportionately bad here — the
                                 # paper's computing-affinity argument)
    bw_frac: float = 0.6         # fraction of shared DRAM bw one unit can
                                 # pull alone (a single engine cannot
                                 # saturate unified LPDDR — the reason
                                 # hetero parallelism beats the 1-unit
                                 # memory floor)


@dataclasses.dataclass(frozen=True)
class Soc:
    units: Sequence[Unit]
    dram_bw: float               # shared bytes/s (both units together)
    sync_latency: float          # per cross-unit sync (unified-memory page)
    contention: float = 1.08     # concurrent-access DRAM efficiency loss
    em_ratio_err: float = 0.03   # EdgeNN's solo-profiled (contention-
                                 # UNAWARE) partition ratio misallocation —
                                 # what ARCA's contention-aware refinement
                                 # fixes (paper SIII-C3)

    @property
    def gpu(self):
        return self.units[0]

    @property
    def cpu(self):
        return self.units[1]


# Jetson Xavier NX, clocks locked per paper §IV-A (GPU 204 MHz, CPU 1.9 GHz).
# flops: 48 Volta tensor cores x 64 FMA x 2 x 204 MHz ~ 1.25e12 fp16;
# 6 Carmel cores x 1.9 GHz x 2x128-bit NEON fp16 FMA ~ 0.18e12.
# gemm_eff / bw_frac calibrated against Fig. 9 in benchmarks/throughput.py;
# fitted values are recorded in EXPERIMENTS.md.
JETSON_NX = Soc(
    units=(
        Unit("volta-384c@204MHz", flops=1.25e12, gemm_eff=0.62,
             sparse_eff=0.05, attn_eff=0.55, bw_frac=0.55),
        Unit("carmel-6c@1.9GHz", flops=182e9, gemm_eff=0.50,
             sparse_eff=0.35, attn_eff=0.12, bw_frac=0.50),
    ),
    dram_bw=59.7e9,
    sync_latency=1e-4,           # <0.1 ms page sync (paper §II-D)
)


def _mem_time(soc: Soc, bytes_, concurrent: bool, unit: "Unit" = None) -> float:
    if concurrent:
        bw = soc.dram_bw / soc.contention
    else:
        bw = soc.dram_bw * (unit or soc.gpu).bw_frac
    return bytes_ / bw


def step_time_sequential(soc: Soc, cfg, ctx: int) -> float:
    """1-token decode on the GPU (the paper's Sequential baseline)."""
    wl = decode_workload(cfg, 1, ctx)
    g = soc.gpu
    t_c = (wl.linear_flops + wl.attn_dense_flops) / (g.flops * g.gemm_eff)
    t_m = _mem_time(soc, wl.weight_bytes + wl.kv_bytes, concurrent=False)
    return max(t_c, t_m)


def step_time_medusa_gpu(soc: Soc, cfg, width: int, ctx: int,
                         spec=None) -> float:
    """Medusa on the GPU only; sparse part executed as dense-with-mask."""
    wl = decode_workload(cfg, width, ctx, spec)
    g = soc.gpu
    dense_as_sparse = 2.0 * 2 * width * width * cfg.num_heads * cfg.head_dim \
        * cfg.num_layers                      # full WxW, mask applied after
    t_c = (wl.linear_flops + wl.attn_dense_flops + dense_as_sparse) \
        / (g.flops * g.gemm_eff)
    t_m = _mem_time(soc, wl.weight_bytes + wl.kv_bytes, concurrent=False)
    return max(t_c, t_m)


def _split_compute(soc: Soc, flops: float, ratio: float) -> float:
    """Column-split GEMM time when GPU takes ``ratio`` of the columns."""
    g, c = soc.gpu, soc.cpu
    return max(flops * ratio / (g.flops * g.gemm_eff),
               flops * (1 - ratio) / (c.flops * c.gemm_eff))


def optimal_ratio(soc: Soc) -> float:
    g, c = soc.gpu, soc.cpu
    eg, ec = g.flops * g.gemm_eff, c.flops * c.gemm_eff
    return eg / (eg + ec)


def step_time_megatron(soc: Soc, cfg, width: int, ctx: int, spec=None,
                       ratio: Optional[float] = None) -> float:
    """Medusa+EM baseline: Megatron (col,row) TP across CPU+GPU with an
    AllReduce every two linears (extra read+write of activations), attention
    split by heads (both units run dense AND masked-sparse work), zero-copy
    sync at every boundary."""
    wl = decode_workload(cfg, width, ctx, spec)
    if ratio is None:
        ratio = max(0.05, optimal_ratio(soc) - soc.em_ratio_err)
    dense_as_sparse = 2.0 * 2 * width * width * cfg.num_heads * cfg.head_dim \
        * cfg.num_layers
    t_c = _split_compute(soc, wl.linear_flops, ratio)
    # head-split attention: the EdgeNN ratio comes from LINEAR-layer solo
    # times, but each unit also gets that share of dense + masked-sparse
    # attention, where the CPU's achievable efficiency is far lower — the
    # affinity miss Ghidorah fixes (paper SIII-B2)
    g, c = soc.gpu, soc.cpu
    attn_work = wl.attn_dense_flops + dense_as_sparse
    t_attn = max(attn_work * ratio / (g.flops * g.attn_eff),
                 attn_work * (1 - ratio) / (c.flops * c.attn_eff))
    # AllReduce: read both partials + write combined (3x activation traffic)
    act_bytes = 2.0 * width * cfg.d_model * cfg.num_layers * 2
    t_m = _mem_time(soc, wl.weight_bytes + wl.kv_bytes + 3 * act_bytes,
                    concurrent=True)
    t_sync = soc.sync_latency * wl.sync_points
    return max(t_c + t_attn, t_m) + t_sync


def step_time_ghidorah(soc: Soc, cfg, width: int, ctx: int, spec=None,
                       ratio: Optional[float] = None) -> float:
    """HCMP: column-only splits (no AllReduce traffic), dense attention to
    the GPU, tree-sparse attention to the CPU (optimized SpMM), online-
    softmax merge fused into the reduce (paper: 'almost no overhead')."""
    wl = decode_workload(cfg, width, ctx, spec)
    ratio = optimal_ratio(soc) if ratio is None else ratio
    g, c = soc.gpu, soc.cpu
    t_lin = _split_compute(soc, wl.linear_flops, ratio)
    t_attn = max(wl.attn_dense_flops / (g.flops * g.attn_eff),
                 wl.attn_sparse_flops / (c.flops * c.sparse_eff))
    t_m = _mem_time(soc, wl.weight_bytes + wl.kv_bytes, concurrent=True)
    t_sync = soc.sync_latency * (wl.sync_points / 2)   # one sync per layer
    return max(t_lin + t_attn, t_m) + t_sync


def contention_aware_ratio(soc: Soc, cfg, width: int, ctx: int,
                           iters: int = 12) -> float:
    """§III-C3: start from solo execution times, refine by bisection on the
    bottleneck unit under the contention model."""
    lo, hi = 0.05, 0.95
    wl = decode_workload(cfg, width, ctx)
    g, c = soc.gpu, soc.cpu
    for _ in range(iters):
        r = 0.5 * (lo + hi)
        tg = wl.linear_flops * r / (g.flops * g.gemm_eff)
        tc = wl.linear_flops * (1 - r) / (c.flops * c.gemm_eff)
        if tg > tc:
            hi = r
        else:
            lo = r
    return 0.5 * (lo + hi)


# ===========================================================================
# strategy search (speculative + partitioning)
# ===========================================================================
@dataclasses.dataclass
class Strategy:
    width: int
    tree: T.TreeSpec
    ratio: float
    acceptance: float
    step_time: float
    throughput: float            # tokens/s
    hcmp: str = "inline"         # measured executor partition for this
                                 # width: "inline" (fused draft+verify) or
                                 # "overlap" (disaggregated draft/verify,
                                 # core/hcmp/executors.py) — set from
                                 # profile_engine's dual-mode timings
    tree_kernel: str = "dense"   # measured paged verify kernel for this
                                 # width: "dense" (fused page walk + tree
                                 # block) or "sparse" (split page walk +
                                 # block-masked tree kernel) — set from
                                 # profile_engine's per-kernel timings


def choose_strategy(cfg, accs: np.ndarray, ctx: int = 256,
                    soc: Soc = JETSON_NX,
                    time_fn: Optional[Callable] = None,
                    widths: Sequence[int] = WIDTHS,
                    evaluator=None) -> Dict[int, Strategy]:
    """For every candidate width: build the tree (greedy + refine), estimate
    acceptance, time the step, compute tokens/s.  Returns {width: Strategy};
    the deployment choice is the argmax."""
    out = {}
    for w in widths:
        spec = T.candidate_spec(accs, w, evaluator=evaluator)
        al = T.expected_acceptance_length(spec, accs)
        ratio = contention_aware_ratio(soc, cfg, w, ctx)
        hcmp = "inline"
        tkern = "dense"
        if time_fn is not None:
            t = time_fn(cfg, w, ctx, spec)
            # a measured time_fn from profile_engine also knows which
            # executor partition / verify kernel its best time came from:
            # both are chosen exactly the way the speculative strategy is
            part = getattr(time_fn, "partition_for", None)
            if part is not None:
                hcmp = part(spec)
            kern = getattr(time_fn, "kernel_for", None)
            if kern is not None:
                tkern = kern(spec)
        elif w == 1:
            t = step_time_sequential(soc, cfg, ctx)
        else:
            t = step_time_ghidorah(soc, cfg, w, ctx, spec, ratio)
        out[w] = Strategy(width=w, tree=spec, ratio=ratio, acceptance=al,
                          step_time=t, throughput=al / t, hcmp=hcmp,
                          tree_kernel=tkern)
    return out


def best(strategies: Dict[int, Strategy]) -> Strategy:
    return max(strategies.values(), key=lambda s: s.throughput)


def profile_engine(engine, widths: Optional[Sequence[int]] = None, *,
                   accs: Optional[np.ndarray] = None, batch: int = 1,
                   prompt_len: int = 16, reps: int = 3,
                   hcmp_modes: Optional[Sequence[str]] = None,
                   tree_kernels: Optional[Sequence[str]] = None) -> Callable:
    """Measured time source for ``choose_strategy``: returns a
    ``time_fn(cfg, width, ctx, spec)`` that times the engine's COMPILED
    step for the given tree through ``DecodeEngine.time_step`` (one
    measurement per tree SHAPE and serving batch — ``(width, max_depth,
    n_paths, batch)`` — cached, so the search never re-times a same-shape
    candidate and switching back to a profiled width is free).

    ``batch`` must be the SERVING batch (the adaptive scheduler's bank
    width B): per-step cost is strongly batch-dependent, so a width
    ranked at batch=1 can be the wrong pick at B=8 — the batch is part
    of the timing cache key for the same reason.

    ``hcmp_modes`` names the executor partitions to time per candidate
    ("inline" / "overlap", core/hcmp/executors.py).  Default: both when
    the engine is already running the disaggregated schedule, else
    inline only.  The returned ``time_fn`` reports each shape's BEST
    partition time, and ``time_fn.partition_for(spec)`` names the
    winning partition — ``choose_strategy`` stamps it on the
    ``Strategy`` so the partition is chosen the same way the speculative
    strategy is.

    ``tree_kernels`` names the paged verify kernels to time per candidate
    and partition ("dense" / "sparse", see ``DecodeEngine.time_step``).
    Default: both when the engine already runs the split kernel, else
    dense only.  ``time_fn.times[skey + (mode,)]`` stays each
    partition's BEST kernel time (the key existing consumers read);
    per-kernel times land at ``skey + (mode, kernel)`` and
    ``time_fn.kernel_for(spec)`` names the overall winner, which
    ``choose_strategy`` stamps on the ``Strategy``.

    ``widths`` pre-measures those candidates up front (trees built from
    ``accs``, default: the engine model's calibration table shape), which
    also pre-compiles each width's chunk scan — the serve launcher calls
    this once at startup so the adaptive scheduler's first switch to any
    candidate width hits a warm compile cache.  Unseen shapes are measured
    lazily on first use.
    """
    if hcmp_modes is None:
        hcmp_modes = ("inline", "overlap") \
            if getattr(engine, "hcmp", "inline") == "overlap" else ("inline",)
    hcmp_modes = tuple(hcmp_modes)
    for m in hcmp_modes:
        if m == "overlap" and not getattr(engine, "hcmp_capable", False):
            raise ValueError("cannot profile the overlap partition: the "
                             "engine has no draft source to disaggregate")
    if tree_kernels is None:
        tree_kernels = ("dense", "sparse") \
            if getattr(engine, "tree_kernel", "dense") == "sparse" \
            else ("dense",)
    tree_kernels = tuple(tree_kernels)
    for tk in tree_kernels:
        if tk == "sparse" and not getattr(engine, "paged", False):
            raise ValueError("cannot profile the sparse tree kernel: the "
                             "split verify path is paged-only")
    times: Dict[tuple, float] = {}
    partition: Dict[tuple, str] = {}
    kernel: Dict[tuple, str] = {}

    def _measure(spec) -> tuple:
        skey = (spec.width, spec.max_depth, spec.n_paths, batch)
        if skey not in partition:
            strategy = engine.strategy_for(spec)
            per = {}
            for mode in hcmp_modes:
                for tk in tree_kernels:
                    per[(mode, tk)] = engine.time_step(
                        strategy, batch=batch, prompt_len=prompt_len,
                        reps=reps, hcmp=mode, tree_kernel=tk)
                    if len(tree_kernels) > 1:
                        times[skey + (mode, tk)] = per[(mode, tk)]
                # the (mode,) key existing consumers read: the
                # partition's best kernel time
                times[skey + (mode,)] = min(
                    per[(mode, tk)] for tk in tree_kernels)
            mode, tk = min(per, key=per.get)
            partition[skey], kernel[skey] = mode, tk
        return skey

    def time_fn(cfg, width, ctx, spec) -> float:
        skey = _measure(spec)
        return times[skey + (partition[skey],)]

    def partition_for(spec) -> str:
        return partition[_measure(spec)]

    def kernel_for(spec) -> str:
        return kernel[_measure(spec)]

    time_fn.partition_for = partition_for
    time_fn.kernel_for = kernel_for
    time_fn.batch = batch
    time_fn.hcmp_modes = hcmp_modes
    time_fn.tree_kernels = tree_kernels
    time_fn.times = times

    if widths:
        table = accs
        if table is None:
            mcfg = engine.model.cfg
            table = T.default_accs(mcfg.medusa_heads, mcfg.medusa_top_k)
        for w in widths:
            time_fn(None, w, prompt_len, T.candidate_spec(table, w))
    return time_fn


# ===========================================================================
# TPU-mesh roofline time source (per-device quantities from the dry-run)
# ===========================================================================
def roofline_time(flops_per_dev: float, hbm_bytes_per_dev: float,
                  coll_bytes_per_dev: float, *, peak=197e12, hbm=819e9,
                  ici=50e9) -> dict:
    t_c = flops_per_dev / peak
    t_m = hbm_bytes_per_dev / hbm
    t_x = coll_bytes_per_dev / ici
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "bound": dom[1], "step_s": max(t_c, t_m, t_x)}
