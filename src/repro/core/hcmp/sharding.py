"""HCMP sharding rules (paper §III-B) and the Megatron baseline, as
PartitionSpec pytrees for pjit.

Hetero-core model parallelism in this repo has TWO independent layers,
split across two modules:

* **Intra-step tensor parallelism (this module)**: how one forward pass
  is partitioned over the `model` mesh axis — the paper's column-only
  HCMP split vs the Megatron baseline, as PartitionSpec rule tables.
  Everything here is static layout metadata consumed by pjit; nothing
  in this file runs at decode time.
* **Inter-step executor disaggregation (``executors.py``)**: how the
  speculative decode LOOP is partitioned across executors — the tree
  verifier + KV commit pinned to the verify device, the Medusa draft
  heads pinned to the draft device, with draft(t+1) dispatched into the
  window where commit(t) is still in flight and a cross-chunk pre-draft
  carried over quiet scheduler boundaries.  Ownership and ordering
  rules (who may touch the cache, why the verify read may precede the
  donated commit, when a pre-draft must be discarded) are documented on
  ``HcmpOverlapRunner`` — runtime code reading this file for the
  sharding tables does not need them, and vice versa.

The two compose: an overlap executor pair can run a tensor-sharded
model on each side, because the executor split is made at jit-dispatch
granularity (whole ``verify_front`` / ``draft_step`` / ``commit_step``
calls), never inside a pjit'd computation.

Two tensor-parallel modes over the `model` mesh axis:

  hcmp      column-only split of EVERY linear (paper §III-B1).  Activations
            come out feature-sharded and are re-gathered at the next
            consumer — the collective-minimal translation of "each unit
            writes its own slice to its memory region; consumers read both"
            to a discrete-memory TPU mesh.  For decode (W<=64 tokens) the
            gathered activations are tiny vs the Megatron AllReduce pattern
            which moves the same bytes TWICE (reduce + broadcast semantics).
  megatron  the paper's baseline (Medusa+EM): (column, row) pairs with an
            AllReduce closing every two linears.

``fsdp=True`` additionally shards the non-`model` weight dim on `data`
(needed for >=30B weights).  MoE experts shard on `model` (expert
parallelism); the KV cache shards its *sequence* dim on `model` — GQA
kv-head counts (2..8) don't divide a 16-way axis, and sequence sharding is
what enables the paper's online-softmax partial merge across shards.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

# leaf-name rule tables ----------------------------------------------------
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "up", "w", "out", "lm_head",
        "in_z", "in_x", "conv_wx"}   # mamba z/x paths stay model-sharded
_ROW = {"wo", "w_down", "down", "out_proj"}          # row-split in megatron
_SHARD_1D = {"bq", "bk", "bv", "conv_bx", "norm_mamba"}  # follow column shards
_MOE = {"w_gate", "w_up", "w_down"}                  # 3D (E, ., .)


def _names(path):
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(p.key)
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(p.name)
    return out


def _leaf_spec(cfg, names, shape, mode, fsdp):
    name = names[-1]
    stacked = names and any(n in ("layers", "encoder", "decoder") for n in names)
    moe = "moe" in names
    xlstm_block = "block" in names                 # xLSTM internals: replicate
    nd = len(shape)
    lead = (None,) if stacked else ()
    core = nd - len(lead)

    def spec(*axes):
        return P(*(lead + axes + (None,) * (core - len(axes))))

    if xlstm_block:
        return spec()
    if moe and nd - len(lead) == 3 and name in _MOE:
        # experts on model; fsdp shards the d/f dim on data
        return spec("model", "data" if fsdp else None, None)
    if name == "router":
        return spec()
    if name == "embed":
        # (V, d): vocab column-shard; fsdp shards d
        return P("model", "data" if fsdp else None)
    if nd - len(lead) == 2 and name in _COL:
        return spec("data" if fsdp else None, "model")
    if nd - len(lead) == 2 and name in _ROW:
        if mode == "megatron":
            return spec("model", "data" if fsdp else None)
        return spec("data" if fsdp else None, "model")   # hcmp: column again
    if nd - len(lead) == 1 and name in _SHARD_1D:
        return spec("model")
    return spec()                                   # norms, scalars: replicated


def param_specs(cfg, params, mode="hcmp"):
    """params: pytree (or eval_shape struct) -> matching PartitionSpec tree."""
    assert mode in ("hcmp", "megatron")
    fsdp = cfg.fsdp

    def rule(path, leaf):
        return _leaf_spec(cfg, _names(path), leaf.shape, mode, fsdp)

    return jax.tree_util.tree_map_with_path(rule, params)


# ---------------------------------------------------------------------------
# cache + activation specs
# ---------------------------------------------------------------------------
def cache_specs(cfg, cache, *, batch_axes=("pod", "data"), seq_axis="model"):
    """KV cache: batch on data axes, SEQUENCE on `model` (HCMP online-softmax
    shard merge).  Recurrent states: batch on data axes, heads on `model`
    where divisible."""
    dp = batch_axes

    def rule(path, leaf):
        names = _names(path)
        name = names[-1]

        def bax(bdim):
            # batch=1 (long_500k single-sample decode) cannot shard: replicate
            return dp if leaf.shape[bdim] > 1 else None

        if name in ("k", "v"):            # (L, B, S, Hkv, hd)
            return P(None, bax(1), seq_axis, None, None)
        if name in ("cross_k", "cross_v"):  # (L, B, Senc, Hkv, hd)
            hkv = leaf.shape[3]
            head_ax = "model" if hkv % 16 == 0 else None
            return P(None, bax(1), None, head_ax, None)
        if name == "ssm":                 # (L, B, nh, hd, N)
            nh = leaf.shape[2]
            return P(None, bax(1), "model" if nh % 16 == 0 else None, None, None)
        if name == "conv":                # (L, B, K-1, C) — tiny, replicate C
            return P(None, bax(1), None, None)
        if name == "key_pos":             # (B, S): follow k/v batch + seq
            return P(bax(0), seq_axis)
        if name == "pos":                 # (B,) per-sequence positions
            return P() if leaf.ndim == 0 else P(bax(0))
        # xlstm layer states (B, ...) — batch only
        if leaf.ndim >= 1 and "layers" in names:
            return P(bax(0), *(None,) * (leaf.ndim - 1))
        return P(*(None,) * leaf.ndim)

    return jax.tree_util.tree_map_with_path(rule, cache)


def batch_specs(batch, batch_axes=("pod", "data")):
    """Input batches: shard dim0 (global batch) across the data axes;
    batch=1 shapes fall back to replication."""
    def rule(leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] == 1:
            return P(*(None,) * leaf.ndim)
        return P(batch_axes, *(None,) * (leaf.ndim - 1))

    return jax.tree_util.tree_map(rule, batch)
