"""Runtime HCMP: the draft/verify executor split (paper §III-B at runtime).

``core/hcmp/sharding.py`` is the lowering study — how HCMP partitions a
single forward across a mesh.  This module is HCMP as the *serving
runtime* sees it: the ``DecodeStrategy``'s two compute phases live on
separate executors and the step pipeline overlaps them.

Executor split (Dovetail's affinity argument):

  * **VerifyExecutor** (device 0) — the full-model tree forward
    (``model.verify`` + ``accept_walk``) and the KV commit.  Weight- and
    bandwidth-heavy; owns the KV cache.
  * **DraftExecutor** (device 1) — the Medusa heads
    (``draft_candidates`` + ``expand_tree_tokens``).  A few small
    matmuls over one hidden vector per row; owns a private copy of the
    heads, placed once at construction.

Pipeline (PEARL-style overlap, adapted to Medusa's self-drafting):
Medusa drafts from the VERIFIER's hidden state, so draft(t+1) cannot
start before verify(t)'s forward finishes — the true overlap window is
the verifier's *commit*: step t's KV commit (device 0) runs concurrently
with drafting step t+1 (device 1), and across chunk boundaries the next
chunk's first draft is computed ahead of time ("pre-draft") while the
host does its boundary bookkeeping.  A pre-draft is tagged with the
engine's bank epoch + strategy shape; any bank mutation between chunks
(admission, reset, strategy switch) bumps the epoch, the stale pre-draft
is DISCARDED and redrafted from the committed state.  Greedy tree
verification commits the greedy chain whatever the draft proposes, so a
discarded-vs-reused pre-draft can never change emitted tokens: the
overlap engine is bit-identical to the inline ``chunk_scan`` driver.

Ownership rules (single-threaded host, two async device streams —
documented here and in ``src/repro/analysis/README.md``; there are no
host locks, so reprolint's R4 has nothing to guard):

  * device 0 owns ``state.cache`` — only ``verify_front`` reads it and
    only ``commit_step`` (donated) writes it, both on device 0's FIFO
    stream, so read-before-donate is ordered by the stream itself;
  * device 1 owns the runner's heads copy — placed once, never written;
  * the host runner owns the pre-draft slot and the hit/discard
    counters — it is only ever entered from the engine's single-threaded
    ``sched_step``/``generate`` callers.

On this CPU container the two executors are the two XLA host devices
requested via ``--xla_force_host_platform_device_count=2``
(``ensure_host_devices``); with one device the runner degrades to a
serial schedule on device 0 — still bit-identical, just no overlap.
When an accelerator is attached the same placement logic lands verify on
the accelerator and draft on host CPU (Dovetail's split).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.speculative.medusa import draft_candidates, expand_tree_tokens
from repro.core.speculative.verify import SpecState, accept_walk
from repro.runtime.cache import capacity_left

_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_devices(n: int = 2) -> int:
    """Best-effort request for ``n`` XLA host CPU devices.

    Only effective BEFORE the jax backend initializes (serve.py calls it
    first thing in ``main``); afterwards it is a no-op probe.  Returns
    the number of devices actually visible — callers must tolerate 1
    (the runner then runs both executors on device 0, serially)."""
    if _DEVICE_FLAG not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + f" {_DEVICE_FLAG}={n}").strip()
    return len(jax.devices())


def executor_pair():
    """(verify_device, draft_device): the first two local devices, or the
    single device twice (serial fallback)."""
    devs = jax.devices()
    return devs[0], devs[1] if len(devs) > 1 else devs[0]


class HcmpOverlapRunner:
    """Disaggregated chunk driver: same signature and bit-identical
    outputs as the engine's inline ``chunk_scan``, with the step split
    across the two executors.

    Per step: ``verify_front`` (device 0) runs the tree forward, the
    acceptance walk and the whole emission/EOS/budget fold of the inline
    scan body; the accepted-chain operands then fan out — ``draft_step``
    for t+1 is dispatched to device 1 *before* ``commit_step`` is
    dispatched to device 0, so XLA's async streams execute the draft
    concurrently with the commit.  The final iteration's draft becomes
    the next chunk's pre-draft."""

    def __init__(self, model, heads, *, backend: str = "ref",
                 tree_kernel: str = "dense"):
        self.verify_dev, self.draft_dev = executor_pair()
        # DraftExecutor owns its heads copy: placed once, read-only
        self.heads = jax.device_put(heads, self.draft_dev)
        cfg = model.cfg

        # NAMED jit targets (not lambdas): the tracecount audit buckets
        # compile counts per __name__ against compile_budget.json
        def draft_step(h, strat, cur, hidden):
            cands, _ = draft_candidates(cfg, h, hidden, cfg.medusa_top_k)
            return expand_tree_tokens(strat.tree, cur, cands)

        def verify_front(p, strat, cache, cur, hidden, tree_tokens, done,
                         rem, eos):
            # identical semantics to the inline chunk_scan body with
            # spec_step split open (verify/accept here, commit deferred)
            done = done | (rem <= 0) | \
                (capacity_left(cache) < strat.tree.max_depth)
            active = ~done
            tree = strat.tree
            logits, extras = model.verify(p, cache, tree_tokens, tree,
                                          backend=backend,
                                          tree_kernel=tree_kernel)
            acc = accept_walk(tree, tree_tokens, logits)
            n_accept = jnp.where(active, acc["n_accept"], 0)
            path_idx = tree.node_path[acc["last_node"]]
            new_hidden = jnp.take_along_axis(
                extras["hidden"],
                acc["last_node"][:, None, None].astype(jnp.int32),
                axis=1)[:, 0]
            cur_token = jnp.where(active, acc["bonus"], cur)
            new_hidden = jnp.where(active[:, None], new_hidden, hidden)
            # emission: accepted children then the bonus (spec_step), then
            # the chunk driver's EOS truncation + budget fold
            idx = jnp.arange(tree.max_depth)[None, :]
            chain_tokens = jnp.take_along_axis(tree_tokens, acc["chain"],
                                               axis=1)
            child_shift = jnp.concatenate(
                [chain_tokens[:, 1:], chain_tokens[:, -1:]], axis=1)
            emitted = jnp.where(idx < (acc["n_accept"] - 1)[:, None],
                                child_shift, 0)
            emitted = jnp.where(idx == (acc["n_accept"] - 1)[:, None],
                                acc["bonus"][:, None], emitted)
            valid = idx < n_accept[:, None]
            is_eos = valid & (emitted == eos)
            has_eos = jnp.any(is_eos, axis=1)
            n_cut = jnp.where(has_eos, jnp.argmax(is_eos, axis=1) + 1,
                              n_accept)
            n_eff = jnp.where(active, n_cut, 0)
            emitted = jnp.where(idx < n_eff[:, None], emitted, eos)
            done = done | has_eos
            rem = rem - n_eff
            return (done, rem, cur_token, new_hidden, emitted, n_eff,
                    acc["chain"], n_accept, path_idx, extras)

        def commit_step(cache, extras, strat, chain, n_accept, path_idx):
            return model.commit(cache, extras, strat.tree, chain, n_accept,
                                path_idx)

        self._draft = jax.jit(draft_step)
        # the cache is NOT donated here: commit_step (below) is the sole
        # writer and donates it; verify_front's read strictly precedes
        # that commit on device 0's FIFO stream
        # reprolint: disable=R2 (read-only cache; commit_step donates it)
        self._verify = jax.jit(verify_front)
        self._commit = jax.jit(commit_step, donate_argnums=(0,))

        # pre-draft slot: (epoch, strategy shape, batch) -> tree_tokens
        self._predraft: Optional[tuple] = None
        self.chunks = 0
        self.steps = 0
        self.predraft_hits = 0
        self.predraft_discards = 0

    # ---- pre-draft lifecycle ---------------------------------------------
    def _take_predraft(self, epoch, strategy, B):
        """Consume the stored pre-draft if it matches the bank's current
        epoch/strategy/width; count a hit or a mis-speculation discard."""
        slot, self._predraft = self._predraft, None
        if slot is None:
            return None
        tag_epoch, tag_shape, tag_b, tokens = slot
        if tag_epoch == epoch and tag_shape == strategy.shape() \
                and tag_b == B:
            self.predraft_hits += 1
            return tokens
        self.predraft_discards += 1
        return None

    def run_chunk(self, params, strategy, state, done, rem, K, eos, epoch):
        """K overlapped steps; returns ``(state, done, rem, toks (K, B,
        Dmax), ns (K, B))`` — the inline ``chunk_scan`` signature.  Pure
        async dispatch: no host sync in this loop (the caller's boundary
        sync materializes the outputs, same budget as inline)."""
        assert strategy.draft == "medusa", "overlap needs a drafted strategy"
        B = int(state.cur_token.shape[0])
        cache, cur, hidden = state.cache, state.cur_token, state.hidden
        tree_tokens = self._take_predraft(epoch, strategy, B)
        strat_d = jax.device_put(strategy, self.draft_dev)
        if tree_tokens is None:
            tree_tokens = self._draft(
                self.heads, strat_d,
                jax.device_put(cur, self.draft_dev),
                jax.device_put(hidden, self.draft_dev))
        toks, ns = [], []
        for _ in range(K):
            (done, rem, cur, hidden, emitted, n_eff, chain, n_accept,
             path_idx, extras) = self._verify(
                params, strategy, cache,
                cur, hidden, jax.device_put(tree_tokens, self.verify_dev),
                done, rem, eos)
            # dispatch the NEXT draft to device 1 BEFORE the commit to
            # device 0: the transfer waits on verify(t), then draft(t+1)
            # executes concurrently with commit(t) — the overlap window
            tree_tokens = self._draft(
                self.heads, strat_d,
                jax.device_put(cur, self.draft_dev),
                jax.device_put(hidden, self.draft_dev))
            cache = self._commit(cache, extras, strategy, chain, n_accept,
                                 path_idx)
            toks.append(emitted)
            ns.append(n_eff)
            self.steps += 1
        # the dangling draft is next chunk's pre-draft (valid while the
        # bank is untouched between chunks; any mutation bumps the epoch)
        self._predraft = (epoch, strategy.shape(), B, tree_tokens)
        self.chunks += 1
        state = SpecState(cache=cache, cur_token=cur, hidden=hidden)
        return state, done, rem, jnp.stack(toks), jnp.stack(ns)

    @property
    def stats(self) -> dict:
        return {
            "verify_device": str(self.verify_dev),
            "draft_device": str(self.draft_dev),
            "devices": len(jax.devices()),
            "chunks": self.chunks,
            "steps": self.steps,
            "predraft_hits": self.predraft_hits,
            "predraft_discards": self.predraft_discards,
        }
