"""Verification trees (paper §III-C1, Fig. 8).

A verification tree of width W decides which combinations of Medusa head
candidates are verified in one step.  Node 0 is the root (the last committed
token — always correct); a node at depth d (1..H) holds head d's rank-r
candidate.  Construction:

  1. *Accuracy-based estimation*: per-head top-k calibration accuracies
     acc[h][r]; a candidate sequence's probability is the product of its
     node accuracies; expected acceptance length = 1 + sum of path products
     over all non-root nodes.  Greedy: repeatedly add the frontier node with
     the highest path product until W nodes.
  2. *Brute-force refinement*: local search over leaf swaps (and same-level
     alternatives), scored by a pluggable evaluator — the estimator by
     default, or empirical acceptance on calibration data (ARCA runtime).

Everything here is preprocessing: plain numpy, producing a static
``TreeSpec`` whose arrays the jitted verify step consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


# Node = (parent_index, depth, rank); root = (-1, 0, 0).
@dataclasses.dataclass(frozen=True)
class TreeSpec:
    width: int
    max_depth: int                    # deepest node depth + 1 (committed slots)
    depth: np.ndarray                 # (W,) int32 — node depth (root=0)
    parent: np.ndarray                # (W,) int32 — parent index (root=-1)
    rank: np.ndarray                  # (W,) int32 — head candidate rank
    mask: np.ndarray                  # (W,W) bool — ancestor-or-self
    paths: np.ndarray                 # (P,D) int32 — root->leaf chains (padded
                                      #   by repeating the leaf)
    node_path: np.ndarray             # (W,) int32 — a path through each node
    node_depth: np.ndarray            # (W,) int32 — == depth
    n_paths: int

    def shape(self) -> tuple:
        """Compile-cache bucket ``(width, max_depth, n_paths)``: trees with
        equal shape share one compiled verify/chunk step (``Tree`` is a jit
        ARGUMENT), so strategy switches inside a bucket never re-jit."""
        return (self.width, self.max_depth, self.n_paths)

    def jnp_arrays(self):
        import jax.numpy as jnp
        return {
            "depth": jnp.asarray(self.depth),
            "mask": jnp.asarray(self.mask),
            "paths": jnp.asarray(self.paths),
            "node_path": jnp.asarray(self.node_path),
            "node_depth": jnp.asarray(self.node_depth),
        }


def _register_tree(cls):
    """Register Tree as a pytree so it can be passed as a jit ARGUMENT: the
    serving engine then shares one compiled ``spec_step`` across all trees of
    the same shape (width, max_depth, n_paths) instead of re-jitting per tree
    — ARCA's brute-force evaluator sweeps many same-width candidates."""
    import jax
    from functools import partial as _p
    return _p(jax.tree_util.register_dataclass,
              data_fields=["depth", "mask", "paths", "node_path",
                           "node_depth", "parent", "rank"],
              meta_fields=["width", "max_depth"])(cls)


@_register_tree
@dataclasses.dataclass(frozen=True)
class Tree:
    """jit-friendly view of TreeSpec (jnp arrays) used by model.verify."""
    width: int
    max_depth: int
    depth: object
    mask: object
    paths: object
    node_path: object
    node_depth: object
    parent: object
    rank: object

    def shape(self) -> tuple:
        """Compile-cache bucket, mirroring ``TreeSpec.shape``."""
        return (self.width, self.max_depth, int(self.paths.shape[0]))

    @staticmethod
    def from_spec(spec: "TreeSpec") -> "Tree":
        import jax.numpy as jnp
        return Tree(width=spec.width, max_depth=spec.max_depth,
                    depth=jnp.asarray(spec.depth),
                    mask=jnp.asarray(spec.mask),
                    paths=jnp.asarray(spec.paths),
                    node_path=jnp.asarray(spec.node_path),
                    node_depth=jnp.asarray(spec.node_depth),
                    parent=jnp.asarray(spec.parent),
                    rank=jnp.asarray(spec.rank))


def spec_from_nodes(nodes: Sequence[Tuple[int, int, int]]) -> TreeSpec:
    """nodes: list of (parent, depth, rank); nodes[0] must be the root."""
    W = len(nodes)
    parent = np.array([n[0] for n in nodes], np.int32)
    depth = np.array([n[1] for n in nodes], np.int32)
    rank = np.array([n[2] for n in nodes], np.int32)
    assert parent[0] == -1 and depth[0] == 0
    assert all(parent[i] < i for i in range(1, W)), "nodes must be topo-ordered"
    # ancestor-or-self mask
    mask = np.zeros((W, W), bool)
    for i in range(W):
        j = i
        while j >= 0:
            mask[i, j] = True
            j = parent[j]
    # root->leaf paths
    children = [[] for _ in range(W)]
    for i in range(1, W):
        children[parent[i]].append(i)
    leaves = [i for i in range(W) if not children[i]]
    D = int(depth.max()) + 1
    paths = np.zeros((len(leaves), D), np.int32)
    for p, leaf in enumerate(leaves):
        chain = []
        j = leaf
        while j >= 0:
            chain.append(j)
            j = parent[j]
        chain = chain[::-1]
        chain += [leaf] * (D - len(chain))       # pad by repeating the leaf
        paths[p] = chain
    node_path = np.zeros((W,), np.int32)
    for p in range(len(leaves)):
        for d_i in range(D):
            node_path[paths[p, d_i]] = p
    return TreeSpec(width=W, max_depth=D, depth=depth, parent=parent,
                    rank=rank, mask=mask, paths=paths, node_path=node_path,
                    node_depth=depth, n_paths=len(leaves))


def chain_spec(length: int) -> TreeSpec:
    """Degenerate single-path tree: node i at depth i under node i-1, so
    ``depth = arange(length)`` and the ancestor mask is lower-triangular.
    ``verify`` over it is plain causal attention at the cache's offset —
    the chunked-prefill pieces (runtime/engine.py ``sched_extend``) reuse
    the tree-verification path with this spec instead of growing a second
    multi-token forward."""
    return spec_from_nodes([(-1, 0, 0)]
                           + [(i - 1, i, 0) for i in range(1, length)])


# --------------------------------------------------------------------------
# expected acceptance length (the paper's estimator)
# --------------------------------------------------------------------------
def path_products(spec: TreeSpec, accs: np.ndarray) -> np.ndarray:
    """accs: (H, K) per-head top-k accuracies -> (W,) path product per node
    (root = 1)."""
    prods = np.ones((spec.width,), np.float64)
    for i in range(1, spec.width):
        h = spec.depth[i] - 1
        prods[i] = prods[spec.parent[i]] * accs[h, spec.rank[i]]
    return prods


def expected_acceptance_length(spec: TreeSpec, accs: np.ndarray) -> float:
    """E[AL] = 1 (bonus token) + sum of per-node acceptance probabilities."""
    return float(1.0 + path_products(spec, accs)[1:].sum())


# --------------------------------------------------------------------------
# greedy construction (estimation step of Fig. 8)
# --------------------------------------------------------------------------
def build_tree_greedy(accs: np.ndarray, width: int,
                      max_depth: Optional[int] = None) -> TreeSpec:
    """Add the highest-path-probability candidate node until ``width`` nodes."""
    H, K = accs.shape
    max_depth = min(max_depth or H, H)
    nodes: List[Tuple[int, int, int]] = [(-1, 0, 0)]
    prods = [1.0]
    # frontier: candidate (prob, parent_idx, depth, rank)
    import heapq
    heap: list = []

    def push_children(idx):
        d = nodes[idx][1] + 1
        if d > max_depth:
            return
        for r in range(K):
            heapq.heappush(heap, (-prods[idx] * accs[d - 1, r],
                                  len(heap), idx, d, r))

    used = set()                                  # (parent, rank) pairs
    push_children(0)
    while len(nodes) < width and heap:
        negp, _, parent, d, r = heapq.heappop(heap)
        if (parent, r) in used:
            continue
        used.add((parent, r))
        nodes.append((parent, d, r))
        prods.append(-negp)
        push_children(len(nodes) - 1)
    return spec_from_nodes(nodes)


# --------------------------------------------------------------------------
# brute-force refinement (search step of Fig. 8)
# --------------------------------------------------------------------------
def refine_tree(spec: TreeSpec, accs: np.ndarray,
                evaluator: Optional[Callable[[TreeSpec], float]] = None,
                max_rounds: int = 4) -> TreeSpec:
    """Local search: try replacing each leaf with an alternative candidate
    (sibling ranks and children of other nodes at the same level), keep any
    strict improvement.  ``evaluator`` defaults to the estimator but ARCA can
    pass an empirical acceptance measurer (paper compares *real* acceptance
    lengths)."""
    H, K = accs.shape
    if evaluator is None:
        evaluator = lambda s: expected_acceptance_length(s, accs)

    best = spec
    best_score = evaluator(spec)
    for _ in range(max_rounds):
        improved = False
        nodes = list(zip(best.parent.tolist(), best.depth.tolist(),
                         best.rank.tolist()))
        children = [[] for _ in nodes]
        for i in range(1, len(nodes)):
            children[nodes[i][0]].append(i)
        leaves = [i for i in range(1, len(nodes)) if not children[i]]
        used = {(p, r) for (p, _, r) in nodes[1:]}
        # alternatives: any (parent, rank) not in the tree; parent index must
        # precede the leaf (keeps topo order, prevents ancestor cycles)
        for leaf in leaves:
            for parent in range(leaf):
                d = nodes[parent][1] + 1
                if d > H:
                    continue
                for r in range(K):
                    if (parent, r) in used:
                        continue
                    cand = list(nodes)
                    cand[leaf] = (parent, d, r)
                    # replacing a leaf keeps all other parent links valid
                    try:
                        cspec = spec_from_nodes(cand)
                    except AssertionError:
                        continue
                    s = evaluator(cspec)
                    if s > best_score + 1e-12:
                        best, best_score, improved = cspec, s, True
                        nodes = cand
                        used = {(p, r2) for (p, _, r2) in nodes[1:]}
                        break
                if improved:
                    break
            if improved:
                break
        if not improved:
            break
    return best


def build_tree(accs: np.ndarray, width: int,
               evaluator: Optional[Callable[[TreeSpec], float]] = None,
               refine: bool = True) -> TreeSpec:
    spec = build_tree_greedy(accs, width)
    if refine and width > 2:
        spec = refine_tree(spec, accs, evaluator)
    return spec


def candidate_spec(accs: np.ndarray, width: int,
                   evaluator: Optional[Callable[[TreeSpec], float]] = None
                   ) -> TreeSpec:
    """The candidate tree ARCA considers at a given width: the degenerate
    root-only spec at width 1 (acceptance is exactly 1, nothing to draft
    or refine), else greedy construction + refinement.  The ONE place the
    width-1 special case lives — choose_strategy, profile_engine and the
    serve/bench candidate sets all build through here."""
    if width == 1:
        return spec_from_nodes([(-1, 0, 0)])
    return build_tree(accs, width, evaluator=evaluator)


# --------------------------------------------------------------------------
# default calibration accuracies
# --------------------------------------------------------------------------
def default_accs(H: int = 4, K: int = 10, a1: float = 0.72, head_decay: float = 0.82,
                 rank_decay: float = 0.42) -> np.ndarray:
    """Synthetic per-head top-k accuracy table in the regime Medusa reports
    (head-1 top-1 ~0.6-0.75, decaying with head index and rank).  The exact
    values used for Table-I validation are fitted in benchmarks/acceptance.py."""
    h = np.arange(H)[:, None]
    r = np.arange(K)[None, :]
    return a1 * (head_decay ** h) * (rank_decay ** r)
