"""Greedy tree acceptance (predict-then-verify fallback to the longest
validated prefix) and the full Ghidorah speculative decoding step.

Acceptance walk (jit-friendly, fixed shapes): start at the root; at each
depth pick the child whose token equals the argmax of the current node's
logits; stop when none matches.  The last accepted node's argmax becomes the
*bonus* token — tokens emitted per step = (accepted chain - root) + 1 bonus
= the paper's acceptance length.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.speculative.medusa import draft_candidates, expand_tree_tokens


def accept_walk(tree, tree_tokens, logits):
    """tree_tokens: (B, W); logits: (B, W, V).

    Returns dict(n_accept (B,) total accepted incl. root, chain (B, Dmax)
    node ids padded with the last accepted node, bonus (B,) next token,
    last_node (B,)).
    """
    B, W, V = logits.shape
    targets = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B, W)
    parent = tree.parent                                        # (W,)

    def body(d, state):
        cur, n_acc, alive, chain = state
        # child of `cur` whose token matches target[cur]
        tgt = jnp.take_along_axis(targets, cur[:, None], axis=1)[:, 0]  # (B,)
        is_child = parent[None, :] == cur[:, None]                      # (B,W)
        match = is_child & (tree_tokens == tgt[:, None]) & (tree.depth[None, :] == d)
        any_match = jnp.any(match, axis=1)
        nxt = jnp.argmax(match, axis=1).astype(jnp.int32)
        step_ok = alive & any_match
        cur = jnp.where(step_ok, nxt, cur)
        n_acc = n_acc + step_ok.astype(jnp.int32)
        chain = chain.at[:, d].set(jnp.where(step_ok, nxt, chain[:, d - 1]))
        return cur, n_acc, step_ok, chain

    cur0 = jnp.zeros((B,), jnp.int32)
    chain0 = jnp.zeros((B, tree.max_depth), jnp.int32)
    alive0 = jnp.ones((B,), bool)
    n0 = jnp.ones((B,), jnp.int32)                               # root counts
    cur, n_acc, _, chain = jax.lax.fori_loop(
        1, tree.max_depth, body, (cur0, n0, alive0, chain0))
    bonus = jnp.take_along_axis(targets, cur[:, None], axis=1)[:, 0]
    return {"n_accept": n_acc, "chain": chain, "bonus": bonus,
            "last_node": cur}


@partial(jax.tree_util.register_dataclass,
         data_fields=["cache", "cur_token", "hidden"], meta_fields=[])
@dataclasses.dataclass
class SpecState:
    """Carry between decode steps (any batch size B).

    Also the unified ``DecodeEngine`` state: a draft-free (sequential)
    strategy carries ``hidden=None`` — an empty pytree leaf — since there
    is no drafting input to thread."""
    cache: Any
    cur_token: jax.Array     # (B,) last committed token (next root)
    hidden: Any              # (B, d) hidden at that token (drafting
                             # input), or None for draft-free strategies


def spec_step(model, params, heads, tree, state: SpecState, *, backend="ref",
              tree_kernel="dense", active=None):
    """One Ghidorah speculative decoding step, batched over sequences.

    Each sequence accepts its own chain length; the commit is a per-sequence
    masked ring write, so positions diverge across the batch.
    Returns (new_state, out_tokens (B, Dmax) emitted tokens padded with the
    bonus, n_out (B,) = acceptance length this step).

    ``active (B,) bool`` freezes the rows where it is False: their
    acceptance count is forced to 0 (nothing committed, ``pos`` does not
    advance) and their carry (``cur_token``/``hidden``) is left untouched.
    The chunk driver uses this to stop finished / capacity-exhausted / free
    slots from writing into their cache rows while the rest of the batch
    keeps decoding (runtime/scheduler.py evicts them at the chunk boundary).
    """
    cfg = model.cfg
    cands, _ = draft_candidates(cfg, heads, state.hidden, cfg.medusa_top_k)
    tree_tokens = expand_tree_tokens(tree, state.cur_token, cands)
    logits, extras = model.verify(params, state.cache, tree_tokens, tree,
                                  backend=backend, tree_kernel=tree_kernel)
    acc = accept_walk(tree, tree_tokens, logits)

    # batched commit: per-sequence accepted chain / length / path
    n_accept = acc["n_accept"]
    if active is not None:
        n_accept = jnp.where(active, n_accept, 0)
    path_idx = tree.node_path[acc["last_node"]]              # (B,)
    cache = model.commit(state.cache, extras, tree, acc["chain"],
                         n_accept, path_idx)

    hidden = extras["hidden"]                       # (B, W, d)
    new_hidden = jnp.take_along_axis(
        hidden, acc["last_node"][:, None, None].astype(jnp.int32), axis=1)[:, 0]
    cur_token = acc["bonus"]
    if active is not None:
        cur_token = jnp.where(active, cur_token, state.cur_token)
        new_hidden = jnp.where(active[:, None], new_hidden, state.hidden)
    new_state = SpecState(cache=cache, cur_token=cur_token,
                          hidden=new_hidden)

    # emitted tokens: accepted children (chain[1:n]) then the bonus token.
    # position j < n-1 emits tree_tokens[chain[j+1]]; position n-1 emits bonus.
    idx = jnp.arange(tree.max_depth)[None, :]
    chain_tokens = jnp.take_along_axis(tree_tokens, acc["chain"], axis=1)
    child_shift = jnp.concatenate(
        [chain_tokens[:, 1:], chain_tokens[:, -1:]], axis=1)
    emitted = jnp.where(idx < (acc["n_accept"] - 1)[:, None], child_shift, 0)
    emitted = jnp.where(idx == (acc["n_accept"] - 1)[:, None],
                        acc["bonus"][:, None], emitted)
    return new_state, emitted, n_accept


def spec_prefill(model, params, heads, batch, *, max_len, window=0):
    """Prefill + initial draft state."""
    logits, extras, cache = model.prefill(batch=batch, params=params,
                                          max_len=max_len, window=window)
    last = logits[:, -1]
    cur = jnp.argmax(last, axis=-1).astype(jnp.int32)
    hidden = extras["hidden"][:, -1]
    return SpecState(cache=cache, cur_token=cur, hidden=hidden)
