"""Medusa drafting heads (paper's default speculative approach, §III-A).

Each head h predicts the token at offset h+1 from the current hidden state:
  head_h(x) = (x + silu(x @ W_h)) @ O_h        (ResBlock + linear)

Heads are separate from base-model params (they're trained post-hoc; the
end-to-end example trains them with the base model frozen).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


def init_medusa(cfg, rng):
    ks = jax.random.split(rng, cfg.medusa_heads)
    dt = jnp.dtype(cfg.dtype)

    def head_init(k):
        k1, k2 = jax.random.split(k)
        return {
            "w": cm.dense_init(k1, cfg.d_model, cfg.d_model, dt, scale=0.02),
            "out": cm.dense_init(k2, cfg.d_model, cfg.padded_vocab, dt),
        }

    return cm.stack_init(rng, cfg.medusa_heads, head_init)


def medusa_logits(cfg, heads, hidden):
    """hidden: (..., d) -> (..., H, V) — vmapped over stacked heads."""
    def one(hp):
        h = hidden + jax.nn.silu(hidden @ hp["w"])
        return h @ hp["out"]

    out = jax.vmap(one)(heads)                     # (H, ..., Vp)
    return jnp.moveaxis(out, 0, -2)[..., :cfg.vocab_size]


def draft_candidates(cfg, heads, hidden, top_k):
    """hidden: (B, d) -> candidate tokens (B, H, K) + probs (B, H, K)."""
    logits = medusa_logits(cfg, heads, hidden)     # (B, H, V)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(probs, top_k)
    return idx.astype(jnp.int32), vals


def head_accuracies(cfg, model, params, heads, token_batches):
    """REAL per-head top-k accuracy table (replaces the fitted calibration
    table): accs[h, k] = P(head h's rank-k candidate is the target), the
    quantity ARCA's tree construction and expected-acceptance estimator
    consume.  ``token_batches``: iterable of (B, S) int32 token arrays
    (calibration prompts).  Used by the end-to-end example and the
    trained-heads arm of ``benchmarks/engine_bench.py``."""
    import numpy as np

    H, K = cfg.medusa_heads, cfg.medusa_top_k
    hits = np.zeros((H, K))
    counts = 0
    for toks in token_batches:
        toks = jnp.asarray(np.asarray(toks, np.int32))
        seq = int(toks.shape[1])
        _, extras, _ = model.prefill(params, {"tokens": toks},
                                     return_cache=False)
        logits = medusa_logits(cfg, heads, extras["hidden"])  # (B,S,H,V)
        _, top = jax.lax.top_k(logits, K)                     # (B,S,H,K)
        top = np.asarray(top)
        tk = np.asarray(toks)
        for h in range(H):
            off = h + 2       # hidden at t drives head h toward token t+h+2
            if off >= seq:
                continue
            tgt = tk[:, off:]                                 # (B, S-off)
            pred = top[:, :seq - off, h]                      # (B, S-off, K)
            for k in range(K):
                hits[h, k] += float(np.mean(pred[..., k] == tgt))
        counts += 1
    return hits / max(counts, 1)


def expand_tree_tokens(tree, cur_token, candidates):
    """Fill tree slots: node 0 = cur committed token; node n (depth d>0) =
    head (d-1)'s rank[n] candidate.

    cur_token: (B,), candidates: (B, H, K) -> (B, W) int32.
    """
    B = cur_token.shape[0]
    head_idx = jnp.maximum(tree.depth - 1, 0)          # (W,)
    cand = candidates[:, head_idx, tree.rank]          # (B, W)
    root = jnp.zeros_like(tree.depth) == tree.depth    # depth==0 mask
    return jnp.where(root[None, :], cur_token[:, None], cand)
