"""Synthetic data pipeline: deterministic, seedable token streams with
enough structure for a tiny model (and Medusa heads) to learn.

The generator is a small order-2 Markov chain over the vocabulary with a
skewed transition table — learnable by a ~100M model in a few hundred steps,
which is what the end-to-end example needs to show real acceptance-length
gains.  Batches are (tokens, labels) with labels = next token.
"""
from __future__ import annotations

import numpy as np


class MarkovDataset:
    def __init__(self, vocab_size: int, seed: int = 0, branch: int = 4):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        # each (prev, cur) context maps to `branch` likely next tokens with
        # skewed probabilities -> predictable continuations (good for heads)
        self.table = self.rng.integers(0, vocab_size,
                                       size=(vocab_size, branch))
        p = np.array([0.7, 0.18, 0.08, 0.04][:branch], np.float64)
        self.p = p / p.sum()
        self.branch = branch

    def sample(self, batch: int, seq_len: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        toks = np.empty((batch, seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq_len):
            nxt = self.table[toks[:, t]]                       # (B, branch)
            choice = rng.choice(self.branch, size=batch, p=self.p)
            # occasional uniform noise keeps entropy non-zero
            noise = rng.random(batch) < 0.02
            rand = rng.integers(0, self.vocab, size=batch)
            toks[:, t + 1] = np.where(noise, rand,
                                      nxt[np.arange(batch), choice])
        return toks

    def batches(self, batch: int, seq_len: int, steps: int, seed: int = 0):
        for i in range(steps):
            toks = self.sample(batch, seq_len, seed=seed + i)
            yield {"tokens": toks[:, :-1].astype(np.int32),
                   "labels": toks[:, 1:].astype(np.int32)}
