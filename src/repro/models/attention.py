"""GQA attention block: prefill / decode / tree-verify paths.

The tree-verify path is the heart of the Ghidorah reproduction: the W
speculative tokens attend to (a) the long KV cache — the *dense* part — and
(b) the W fresh tree KVs under the ancestor mask — the *sparse* part.  The two
parts are computed as separate online-softmax partials and merged (paper
§III-B2, Eq. 1).  On the real mesh the dense part is additionally sequence-
sharded across the `model` axis (core/hcmp/attention.py) and the same merge
combines the shards; the Pallas kernels in kernels/ implement the same math
with VMEM tiling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.runtime.cache import batched_decode_mask, prefill_mask


def attn_init(cfg, rng):
    ks = jax.random.split(rng, 8)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": cm.dense_init(ks[0], d, cfg.num_heads * hd, _dt(cfg)),
        "wk": cm.dense_init(ks[1], d, cfg.num_kv_heads * hd, _dt(cfg)),
        "wv": cm.dense_init(ks[2], d, cfg.num_kv_heads * hd, _dt(cfg)),
        "wo": cm.dense_init(ks[3], cfg.num_heads * hd, d, _dt(cfg)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), _dt(cfg))
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), _dt(cfg))
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), _dt(cfg))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), _dt(cfg))
        p["k_norm"] = jnp.ones((hd,), _dt(cfg))
    return p


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _qkv(cfg, p, x, positions):
    """x: (B, S, d) -> roped q (B,S,Hq,hd), k (B,S,Hkv,hd), v (B,S,Hkv,hd)."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = cm.rmsnorm(q, p["q_norm"], cfg.rmsnorm_eps)
        k = cm.rmsnorm(k, p["k_norm"], cfg.rmsnorm_eps)
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


BLOCKED_PREFILL_THRESHOLD = 4096      # S above which prefill uses tiling
PREFILL_BLOCK = 1024


def attn_prefill(cfg, p, x, *, start_pos=0, window=0, causal=True):
    """Full-sequence (optionally causal/windowed) attention.  Returns
    (out, (k, v)) — k/v are the rope'd cache entries for [start, start+S).

    Long sequences use the blocked online-softmax path (§Perf hillclimb A2):
    the naive form materializes (B, H, S, S) scores — at 32k prefill that
    single tensor dominates HBM traffic and the TP collectives."""
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)[None, :] + start_pos
    q, k, v = _qkv(cfg, p, x, positions)
    if causal and S >= BLOCKED_PREFILL_THRESHOLD and \
            S % PREFILL_BLOCK == 0:
        o = _blocked_causal_attend(q, k, v, cfg.head_dim ** -0.5,
                                   window=window, block=PREFILL_BLOCK)
    else:
        if causal:
            mask = prefill_mask(S, window)[None, None]
        else:                                      # bidirectional (encoder)
            mask = jnp.ones((1, 1, S, S), bool)
        o = cm.gqa_attend(q, k, v, mask, cfg.head_dim ** -0.5)
    out = o.reshape(B, S, -1) @ p["wo"]
    return out, (k, v)


def _blocked_causal_attend(q, k, v, scale, *, window=0, block=1024):
    """Tiled causal attention with an online-softmax carry — (Cq, Ck) score
    tiles instead of the (S, S) matrix.  Masked (above-diagonal) tiles are
    still computed (2x FLOP waste vs a triangular schedule — a candidate A3
    iteration); memory/collective footprint is what this targets."""
    B, S, Hq, hd = q.shape
    nq = S // block
    qs = jnp.swapaxes(q.reshape(B, nq, block, Hq, hd), 0, 1)   # (nq,B,C,H,hd)
    ks = jnp.swapaxes(k.reshape(B, nq, block, k.shape[2], hd), 0, 1)
    vs = jnp.swapaxes(v.reshape(B, nq, block, v.shape[2], hd), 0, 1)
    base = jnp.arange(block)

    def q_step(_, qi_blk):
        i, qi = qi_blk
        qpos = i * block + base

        def kv_step(carry, kv_blk):
            j, kj, vj = kv_blk
            kpos = j * block + base
            ok = kpos[None, :] <= qpos[:, None]
            if window:
                ok &= kpos[None, :] > qpos[:, None] - window
            o, m, l = cm.gqa_attend_partial(qi, kj, vj, ok[None, None], scale)
            return (cm.merge_partials_carry(carry, (o, m, l))), None

        init = (jnp.zeros((B, block, Hq, hd), jnp.float32),
                jnp.full((B, Hq, block), cm.NEG_INF, jnp.float32),
                jnp.zeros((B, Hq, block), jnp.float32))
        (o, m, l), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nq), ks, vs))
        l = jnp.maximum(l, 1e-30)
        out = o * (1.0 / jnp.transpose(l, (0, 2, 1)))[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    return jnp.swapaxes(outs, 0, 1).reshape(B, S, Hq, hd)


def attn_cross(cfg, p, x, enc_k, enc_v, *, pos=None, tree_depth=None):
    """Encoder-decoder cross-attention: queries over fixed encoder memory.

    enc_k/enc_v: (B, Senc, Hkv, hd) — precomputed, un-rope'd (absolute
    encoder positions are baked in at encode time via rope on k).
    """
    B, S, _ = x.shape
    hd = cfg.head_dim
    # cross-attn queries are not rotary-shifted against encoder memory
    q = (x @ p["wq"]).reshape(B, S, cfg.num_heads, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(cfg.num_heads, hd)
    if cfg.qk_norm:
        q = cm.rmsnorm(q, p["q_norm"], cfg.rmsnorm_eps)
    mask = jnp.ones((1, 1, S, enc_k.shape[1]), bool)
    o = cm.gqa_attend(q, enc_k, enc_v, mask, hd ** -0.5)
    return o.reshape(B, S, -1) @ p["wo"]


def cross_kv_init(cfg, p, enc_out):
    """Precompute the cross-attention K/V memory from encoder outputs."""
    B, S, _ = enc_out.shape
    hd = cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qkv_bias:
        k = k + p["bk"].reshape(cfg.num_kv_heads, hd)
        v = v + p["bv"].reshape(cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        k = cm.rmsnorm(k, p["k_norm"], cfg.rmsnorm_eps)
    return k, v


def attn_verify(cfg, p, x, *, ck, cv, key_pos, pos, tree_depth, tree_mask,
                window=0, backend="ref", block_table=None,
                scale_k=None, scale_v=None, tree_kernel="dense"):
    """Tree-verification attention over W draft tokens (decode = W=1 case).

    x: (B, W, d); tree_depth: (W,) node depth (0 = first new token);
    tree_mask: (W, W) ancestor-or-self mask.
    ``pos`` and ``key_pos`` are per-sequence — () or (B,), and (S,) or (B, S)
    — because batched speculative decoding leaves each sequence at its own
    absolute position after a commit.

    Cache layout: dense (``block_table=None``) reads ck/cv as per-sequence
    rows (B, S, Hkv, hd); paged passes ONE layer's shared page pool
    ``(n_pages + 1, ps, Hkv, hd)`` plus ``block_table (B, max_pages)`` —
    the ref path gathers the logical view through the table, the Pallas
    path walks the table inside the kernel (scalar prefetch).  A quantized
    pool also passes ``scale_k/scale_v (n_pages + 1, Hkv)``; dequant is
    fused into the kernel's page walk (ref: ``gather_pages_dequant``).

    ``tree_kernel="sparse"`` (paged + pallas only) splits the verify into
    the cache-only page walk plus the block-masked W×W sparse tree kernel,
    merged by the Eq.-1 online-softmax rule; other layouts/backends fall
    back to their fused path (the split exists for the paged walk).  The
    mask math is layout-agnostic: ``key_pos`` is already logical.
    Returns (out (B, W, d), (k_new, v_new)) — fresh KVs NOT yet committed.
    """
    B, W, _ = x.shape
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos_b[:, None] + tree_depth[None, :]           # (B, W)
    q, k_new, v_new = _qkv(cfg, p, x, positions)
    scale = cfg.head_dim ** -0.5

    if block_table is not None and backend == "pallas":
        from repro.kernels import ops as kops
        if tree_kernel == "sparse":
            cache_part = kops.paged_cache_attention(
                q, ck, cv, block_table, key_pos, pos_b, tree_depth,
                scale_k=scale_k, scale_v=scale_v)
            tree_part = kops.sparse_tree_attention_partial(
                q, k_new, v_new, tree_mask)
            o = cm.merge_partials([cache_part, tree_part]).astype(x.dtype)
        else:
            o = kops.paged_tree_attention(
                q, ck, cv, k_new, v_new, block_table, key_pos, pos_b,
                tree_depth, tree_mask, scale_k=scale_k, scale_v=scale_v)
    else:
        if block_table is not None:
            from repro.runtime.cache import gather_pages_dequant
            ck = gather_pages_dequant(ck, scale_k, block_table)
            cv = gather_pages_dequant(cv, scale_v, block_table)
        key_pos_b = jnp.broadcast_to(key_pos, (B, ck.shape[1]))
        if backend == "pallas":
            from repro.kernels import ops as kops
            o = kops.tree_attention(q, ck, cv, k_new, v_new, key_pos_b,
                                    pos_b, tree_depth, tree_mask,
                                    window=window)
        else:
            # dense part: W queries vs the KV cache (per-batch/query mask)
            cache_ok = batched_decode_mask(key_pos_b, positions, window)
            dense = cm.gqa_attend_partial(q, ck, cv, cache_ok[:, None], scale)
            # sparse part: W queries vs W fresh tree KVs, ancestor mask
            sparse = cm.gqa_attend_partial(q, k_new, v_new,
                                           tree_mask[None, None], scale)
            o = cm.merge_partials([dense, sparse]).astype(x.dtype)

    out = o.reshape(B, W, -1) @ p["wo"]
    return out, (k_new, v_new)


def attn_decode(cfg, p, x, *, ck, cv, key_pos, pos, window=0, backend="ref"):
    """Single-token decode: W=1 tree with a trivial mask.

    Note: the new token's K/V is returned for the caller to commit; attention
    includes it via the sparse part (self-attention to itself).
    """
    return attn_verify(
        cfg, p, x,
        ck=ck, cv=cv, key_pos=key_pos, pos=pos,
        tree_depth=jnp.zeros((1,), jnp.int32),
        tree_mask=jnp.ones((1, 1), bool),
        window=window, backend=backend)
