"""Encoder-decoder stack (SeamlessM4T-style audio->text backbone).

The audio frontend (mel + conv codec) is STUBBED per the carve-out: the
encoder consumes precomputed frame embeddings (B, frames, d).  Cross-attn
K/V memory is computed once at prefill and stored in the cache; decoder
self-attention supports full / sliding-window caches and tree verification.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.attention import (attn_cross, attn_init, attn_prefill,
                                    attn_verify, cross_kv_init)
from repro.models.mlp import mlp_apply, mlp_init
from repro.runtime.cache import Cache, KVCache, PagedKVCache, init_kv_cache


def init_params(cfg, rng):
    k_emb, k_enc, k_dec, k_out = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.dtype)

    def enc_layer(k):
        ka, km = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), dt), "attn": attn_init(cfg, ka),
                "ln2": jnp.ones((cfg.d_model,), dt), "mlp": mlp_init(cfg, km)}

    def dec_layer(k):
        ka, kc, km = jax.random.split(k, 3)
        return {"ln1": jnp.ones((cfg.d_model,), dt), "attn": attn_init(cfg, ka),
                "ln_c": jnp.ones((cfg.d_model,), dt), "cross": attn_init(cfg, kc),
                "ln2": jnp.ones((cfg.d_model,), dt), "mlp": mlp_init(cfg, km)}

    return {
        "embed": cm.embed_init(k_emb, cfg.padded_vocab, cfg.d_model, dt),
        "encoder": cm.stack_init(k_enc, cfg.num_encoder_layers, enc_layer),
        "decoder": cm.stack_init(k_dec, cfg.num_layers, dec_layer),
        "ln_enc": jnp.ones((cfg.d_model,), dt),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "lm_head": cm.dense_init(k_out, cfg.d_model, cfg.padded_vocab, dt),
    }


def _logits(cfg, params, x):
    return (cm.rmsnorm(x, params["ln_f"], cfg.rmsnorm_eps)
            @ params["lm_head"])[..., :cfg.vocab_size]


def encode(cfg, params, frame_embeds):
    """frame_embeds: (B, Senc, d) stubbed frontend output -> encoder memory."""
    def body(x, lp):
        a, _ = attn_prefill(cfg, lp["attn"],
                            cm.rmsnorm(x, lp["ln1"], cfg.rmsnorm_eps),
                            causal=False)
        x = x + a
        x = x + mlp_apply(cfg, lp["mlp"],
                          cm.rmsnorm(x, lp["ln2"], cfg.rmsnorm_eps))
        return x, None

    x, _ = cm.layer_scan(cfg, body, frame_embeds, params["encoder"])
    return cm.rmsnorm(x, params["ln_enc"], cfg.rmsnorm_eps)


def _cross_memory(cfg, params, enc_out):
    """Precompute per-decoder-layer cross K/V: (L, B, Senc, Hkv, hd)."""
    def one(lp):
        return cross_kv_init(cfg, lp["cross"], enc_out)
    ks, vs = jax.vmap(one)(params["decoder"])
    return ks, vs


def prefill(cfg, params, tokens=None, embeds=None, *, enc_out=None,
            frame_embeds=None, cache=None, window=0, max_len=None,
            return_cache=True, last_logits=False):
    """Decoder prefill.  Either ``enc_out`` or ``frame_embeds`` must be given
    on the first call (cross memory is then cached)."""
    x = params["embed"][tokens] if embeds is None else embeds
    B, S, _ = x.shape
    if cache is None or cache.cross_k is None:
        if enc_out is None:
            enc_out = encode(cfg, params, frame_embeds)
        cross_k, cross_v = _cross_memory(cfg, params, enc_out)
    else:
        cross_k, cross_v = cache.cross_k, cache.cross_v
    if cache is None:
        size = max(S, max_len or 0) if return_cache else 1
        kv = init_kv_cache(cfg.num_layers, B, size,
                           cfg.num_kv_heads, cfg.head_dim, window=window,
                           dtype=jnp.dtype(cfg.dtype))
    else:
        kv = cache.kv

    def body(xc, xs):
        lp, ck, cv = xs
        a, (k, v) = attn_prefill(cfg, lp["attn"],
                                 cm.rmsnorm(xc, lp["ln1"], cfg.rmsnorm_eps),
                                 window=window)
        xc = xc + a
        xc = xc + attn_cross(cfg, lp["cross"],
                             cm.rmsnorm(xc, lp["ln_c"], cfg.rmsnorm_eps), ck, cv)
        xc = xc + mlp_apply(cfg, lp["mlp"],
                            cm.rmsnorm(xc, lp["ln2"], cfg.rmsnorm_eps))
        return xc, (k, v)

    x, (ks, vs) = cm.layer_scan(cfg, body, x,
                                (params["decoder"], cross_k, cross_v))

    from repro.models.transformer import _bulk_write
    kv = _bulk_write(kv, ks, vs, start=0)
    cache_out = Cache(kv=kv, cross_k=cross_k, cross_v=cross_v)
    return (_logits(cfg, params, x[:, -1:] if last_logits else x),
            {"aux_loss": jnp.zeros((), jnp.float32), "hidden": x},
            cache_out if return_cache else None)


def verify(cfg, params, cache: Cache, tree_tokens, tree_depth, tree_mask,
           *, backend="ref", **_):
    x = params["embed"][tree_tokens]
    kv = cache.kv
    paged = isinstance(kv, PagedKVCache)
    table = kv.block_table if paged else None

    def body(xc, xs):
        lp, ck, cv, xk, xv = xs
        a, (k1, v1) = attn_verify(
            cfg, lp["attn"], cm.rmsnorm(xc, lp["ln1"], cfg.rmsnorm_eps),
            ck=ck, cv=cv, key_pos=kv.key_pos, pos=kv.pos,
            tree_depth=tree_depth, tree_mask=tree_mask, window=kv.window,
            backend=backend, block_table=table)
        xc = xc + a
        xc = xc + attn_cross(cfg, lp["cross"],
                             cm.rmsnorm(xc, lp["ln_c"], cfg.rmsnorm_eps), xk, xv)
        xc = xc + mlp_apply(cfg, lp["mlp"],
                            cm.rmsnorm(xc, lp["ln2"], cfg.rmsnorm_eps))
        return xc, (k1, v1)

    kv_scan = (kv.pool_k, kv.pool_v) if paged else (kv.k, kv.v)
    x, (k_new, v_new) = cm.layer_scan(
        cfg, body, x,
        (params["decoder"],) + kv_scan + (cache.cross_k, cache.cross_v))
    return _logits(cfg, params, x), {"tree_kv": (k_new, v_new), "hidden": x}


def decode(cfg, params, cache: Cache, tokens, *, backend="ref"):
    logits, extras = verify(
        cfg, params, cache, tokens,
        tree_depth=jnp.zeros((1,), jnp.int32),
        tree_mask=jnp.ones((1, 1), bool), backend=backend)
    from repro.models.transformer import _bulk_write
    k1, v1 = extras["tree_kv"]
    kv = _bulk_write(cache.kv, k1, v1, start=cache.kv.pos)
    return logits, Cache(kv=kv, cross_k=cache.cross_k, cross_v=cache.cross_v)


def commit(cfg, cache: Cache, extras, accept_nodes, n_accept, max_depth):
    from repro.models import transformer as tf
    base = tf.commit(cfg, Cache(kv=cache.kv), extras, accept_nodes,
                     n_accept, max_depth)
    return Cache(kv=base.kv, cross_k=cache.cross_k, cross_v=cache.cross_v)
