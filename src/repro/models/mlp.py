"""SwiGLU MLP and GShard-style top-k MoE with grouped one-hot dispatch.

MoE dispatch uses the capacity-factor one-hot einsum form (GShard/MaxText
style): it lowers to MXU-friendly einsums whose expert dimension shards
cleanly on the `model` mesh axis (all-to-all appears in SPMD HLO).  Dropped
tokens (over capacity) pass through on the residual path — standard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


def mlp_init(cfg, rng):
    ks = jax.random.split(rng, 3)
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    return {
        "w_gate": cm.dense_init(ks[0], d, f, dt),
        "w_up": cm.dense_init(ks[1], d, f, dt),
        "w_down": cm.dense_init(ks[2], f, d, dt),
    }


def mlp_apply(cfg, p, x):
    return cm.swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------
def moe_init(cfg, rng):
    ks = jax.random.split(rng, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.dtype(cfg.dtype)

    def einit(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) * fan_in ** -0.5).astype(dt)

    return {
        "router": cm.dense_init(ks[0], d, e, jnp.dtype(jnp.float32)),
        "w_gate": einit(ks[1], (e, d, f), d),
        "w_up": einit(ks[2], (e, d, f), d),
        "w_down": einit(ks[3], (e, f, d), f),
    }


def moe_apply(cfg, p, x, *, capacity_factor=1.25, group_size=256):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Tokens are split into groups of <=``group_size`` before the one-hot
    dispatch.  Grouping bounds the dispatch einsum's FLOPs and memory at
    O(g * E * cap) per group (cap = g*K/E*cf) instead of O(S^2)-scaling when
    the whole sequence is one group — the same reason GShard dispatches per
    group.  Groups align with the token sharding, so the expert einsum (whose
    E axis shards on `model`) carries the all-to-all.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    g = min(group_size, T)
    while T % g:                                   # largest divisor <= group_size
        g -= 1
    G = T // g
    # small groups (decode / tree-verify) run DROPLESS (cap = g) so cached
    # serving is bit-consistent with prefill; large training groups use the
    # standard capacity factor (dropped tokens ride the residual).
    if g <= 32:
        cap = g
    else:
        cap = max(K, int(g * K / E * capacity_factor))
    xg = x.reshape(G, g, d)

    logits = xg.astype(jnp.float32) @ p["router"]              # (G,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # (G,g,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)      # renormalize

    # rank of each (token, k) choice inside its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)      # (G,g,K,E)
    flat = onehot.reshape(G, g * K, E)
    rank = (jnp.cumsum(flat, axis=1) - flat)                   # (G,g*K,E)
    rank = jnp.sum(rank * flat, axis=-1).reshape(G, g, K)
    keep = (rank < cap).astype(x.dtype)                        # capacity drop

    oh_e = jax.nn.one_hot(gate_idx, E, dtype=x.dtype) * keep[..., None]
    oh_c = jax.nn.one_hot(rank, cap, dtype=x.dtype)
    disp = jnp.einsum("gske,gskc->gsec", oh_e, oh_c)           # (G,g,E,cap)
    comb = jnp.einsum("gske,gskc,gsk->gsec", oh_e, oh_c,
                      gate_vals.astype(x.dtype))

    xe = jnp.einsum("gsec,gsd->gecd", disp, xg)                # (G,E,cap,d)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])          # (G,E,cap,d)
    out = jnp.einsum("gsec,gecd->gsd", comb, ye).reshape(B, S, d)

    # load-balance auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_coef
    return out, aux
