"""Decoder-only transformer stack (dense / MoE / VLM) with layer-scan.

Per-layer params are stacked on a leading L axis and the stack is traversed
with ``lax.scan`` (compile-time sanity for 94-layer configs).  Three paths:

  prefill  tokens/embeds (B,S)   -> logits (B,S,V), filled Cache
  decode   token (B,1) + Cache   -> logits (B,1,V), updated Cache
  verify   tree tokens (B,W)+Cache -> logits (B,W,V), uncommitted tree KVs

``commit`` scatters the accepted tree path's KVs into the cache (Ghidorah's
accept-then-fallback step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.attention import attn_decode, attn_init, attn_prefill, attn_verify
from repro.models.mlp import mlp_apply, mlp_init, moe_apply, moe_init
from repro.runtime.cache import (Cache, KVCache, PagedKVCache, _ring_match,
                                 init_kv_cache, kv_commit, paged_kv_write)


def init_params(cfg, rng):
    k_embed, k_layers, k_out = jax.random.split(rng, 3)
    dt = jnp.dtype(cfg.dtype)

    def layer_init(k):
        ka, km = jax.random.split(k)
        p = {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "attn": attn_init(cfg, ka),
        }
        p["moe" if cfg.num_experts else "mlp"] = (
            moe_init(cfg, km) if cfg.num_experts else mlp_init(cfg, km))
        return p

    params = {
        "embed": cm.embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dt),
        "layers": cm.stack_init(k_layers, cfg.num_layers, layer_init),
        "ln_f": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(k_out, cfg.d_model, cfg.padded_vocab, dt)
    return params


def _mix(cfg, lp, h):
    if cfg.num_experts:
        return moe_apply(cfg, lp["moe"], h)
    return mlp_apply(cfg, lp["mlp"], h), jnp.zeros((), jnp.float32)


def _logits(cfg, params, x):
    x = cm.rmsnorm(x, params["ln_f"], cfg.rmsnorm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head)[..., :cfg.vocab_size]


def embed_tokens(cfg, params, tokens):
    return params["embed"][tokens]


# --------------------------------------------------------------------------
def prefill(cfg, params, tokens=None, embeds=None, *, cache=None, window=0,
            max_len=None, return_cache=True, last_logits=False):
    """Returns (logits (B,S,V), extras, Cache).  ``embeds`` overrides token
    embedding (VLM path: pre-projected patch embeds + token embeds).
    ``max_len`` sets cache capacity (>= S + expected new tokens).
    ``return_cache=False`` (training) skips all KV-cache work."""
    x = embed_tokens(cfg, params, tokens) if embeds is None else embeds
    B, S, _ = x.shape
    eff_window = window                 # 0 = full attention; engine decides

    def body(xc, lp):
        a, (k, v) = attn_prefill(cfg, lp["attn"],
                                 cm.rmsnorm(xc, lp["ln1"], cfg.rmsnorm_eps),
                                 window=eff_window)
        xc = xc + a
        m, aux = _mix(cfg, lp, cm.rmsnorm(xc, lp["ln2"], cfg.rmsnorm_eps))
        kv_out = (k, v) if return_cache else ()
        return xc + m, (kv_out, aux)

    x, (kvs, auxs) = cm.layer_scan(cfg, body, x, params["layers"])
    # serving only needs the last position's next-token distribution; the
    # full (B, S, V) logits tensor (and its vocab-sharded collectives) is a
    # training-only cost.  See EXPERIMENTS.md SPerf hillclimb A.
    logits = _logits(cfg, params, x[:, -1:] if last_logits else x)
    extras = {"aux_loss": jnp.sum(auxs), "hidden": x}

    if not return_cache:
        return logits, extras, None
    ks, vs = kvs
    if cache is None:
        cache = Cache(kv=init_kv_cache(
            cfg.num_layers, B, max(S, max_len or 0), cfg.num_kv_heads,
            cfg.head_dim, window=eff_window, dtype=jnp.dtype(cfg.dtype)))
    kv = _bulk_write(cache.kv, ks, vs, start=0)
    return logits, extras, Cache(kv=kv)


def _bulk_write(kv, ks, vs, start):
    """Write (L,B,S,Hkv,hd) KVs at [start_b, start_b + S) per sequence.

    ``start`` is a scalar (prefill: uniform positions) or (B,) per-sequence
    positions (decode after speculative steps, where positions diverge).
    Ring buffer keeps the tail when S exceeds the cache size.  Paged caches
    route through the block table (cache.paged_kv_write).
    """
    if isinstance(kv, PagedKVCache):
        return paged_kv_write(kv, ks, vs, start)
    B, S = ks.shape[1], ks.shape[2]
    size = kv.max_len
    off = 0
    if S >= size:                     # only the last `size` entries survive
        ks, vs = ks[:, :, -size:], vs[:, :, -size:]
        off, S = S - size, size
    start = jnp.asarray(start, jnp.int32)

    if start.ndim == 0:
        # uniform positions: one contiguous O(S_new) ring scatter shared by
        # the whole batch (prefill can be long — the per-sequence
        # gather+where path below would be O(S_cache * S_new))
        abs_pos = start + off + jnp.arange(S, dtype=jnp.int32)
        slots = abs_pos % size
        return KVCache(
            k=kv.k.at[:, :, slots].set(ks.astype(kv.k.dtype)),
            v=kv.v.at[:, :, slots].set(vs.astype(kv.v.dtype)),
            key_pos=kv.key_pos.at[:, slots].set(abs_pos),
            pos=jnp.full((B,), start + off + S, jnp.int32),
            window=kv.window)

    # diverged per-sequence positions: one ring-match per sequence, applied
    # to every layer's K and V and to key_pos (see cache._ring_match)
    def one(ck, cv, kp, kn, vn, st):
        # ck/cv: (L, S_cache, Hkv, hd); kn/vn: (L, S, Hkv, hd) one sequence
        abs_pos = st + off + jnp.arange(S, dtype=jnp.int32)
        written, src = _ring_match(abs_pos, jnp.ones((S,), bool), size)
        m = written[None, :, None, None]
        return (jnp.where(m, kn[:, src].astype(ck.dtype), ck),
                jnp.where(m, vn[:, src].astype(cv.dtype), cv),
                jnp.where(written, abs_pos[src], kp))

    k2, v2, kp2 = jax.vmap(one, in_axes=(1, 1, 0, 1, 1, 0),
                           out_axes=(1, 1, 0))(kv.k, kv.v, kv.key_pos,
                                               ks, vs, start)
    return KVCache(k=k2, v=v2, key_pos=kp2,
                   pos=start + off + S, window=kv.window)


# --------------------------------------------------------------------------
def verify(cfg, params, cache: Cache, tree_tokens, tree_depth, tree_mask,
           *, backend="ref", tree_kernel="dense"):
    """Tree-verification forward: W draft tokens vs cache + tree mask.

    Returns (logits (B,W,V), tree_kv (k,v each (L,B,W,Hkv,hd))).
    KVs are NOT committed — call ``commit`` with the accepted path.
    Quantized paged caches scan the per-layer scale slices alongside the
    pool so dequant happens inside each layer's attention; ``tree_kernel``
    selects the fused vs split (sparse tree kernel) paged verify path.
    """
    x = embed_tokens(cfg, params, tree_tokens)
    kv = cache.kv
    paged = isinstance(kv, PagedKVCache)
    table = kv.block_table if paged else None
    quantized = paged and kv.scale_k is not None

    def body(xc, xs):
        lp, ck, cv = xs[0], xs[1], xs[2]
        sk, sv = (xs[3], xs[4]) if len(xs) == 5 else (None, None)
        a, (k1, v1) = attn_verify(
            cfg, lp["attn"], cm.rmsnorm(xc, lp["ln1"], cfg.rmsnorm_eps),
            ck=ck, cv=cv, key_pos=kv.key_pos, pos=kv.pos,
            tree_depth=tree_depth, tree_mask=tree_mask,
            window=kv.window, backend=backend, block_table=table,
            scale_k=sk, scale_v=sv, tree_kernel=tree_kernel)
        xc = xc + a
        m, _ = _mix(cfg, lp, cm.rmsnorm(xc, lp["ln2"], cfg.rmsnorm_eps))
        return xc + m, (k1, v1)

    if paged:
        kv_scan = (kv.pool_k, kv.pool_v)
        if quantized:
            kv_scan += (kv.scale_k, kv.scale_v)
    else:
        kv_scan = (kv.k, kv.v)
    x, (k_new, v_new) = cm.layer_scan(cfg, body, x,
                                  (params["layers"],) + kv_scan)
    extras = {"tree_kv": (k_new, v_new), "hidden": x}
    return _logits(cfg, params, x), extras


def decode(cfg, params, cache: Cache, tokens, *, backend="ref"):
    """Plain 1-token decode (the Sequential baseline step).

    tokens: (B, 1).  Returns (logits (B,1,V), updated Cache).
    """
    logits, extras = verify(
        cfg, params, cache, tokens,
        tree_depth=jnp.zeros((1,), jnp.int32),
        tree_mask=jnp.ones((1, 1), bool),
        backend=backend)
    k1, v1 = extras["tree_kv"]
    kv = _bulk_write(cache.kv, k1, v1, start=cache.kv.pos)
    return logits, Cache(kv=kv)


def commit(cfg, cache: Cache, extras, accept_nodes, n_accept, max_depth):
    """Scatter each sequence's accepted tree path at [pos_b, pos_b + n_b).

    accept_nodes: (B, Dmax) node indices of the accepted paths (padded);
    n_accept: (B,) accepted tokens per sequence (1..Dmax).
    Writes are masked per sequence: slots beyond n_accept[b] keep their
    previous contents (the vmapped ring scatter lives in cache.kv_commit).
    """
    tree_kv = extras["tree_kv"] if isinstance(extras, dict) else extras
    k_new, v_new = tree_kv                                   # (L,B,W,Hkv,hd)
    return Cache(kv=kv_commit(cache.kv, k_new, v_new, accept_nodes,
                              n_accept, max_depth))
