"""Uniform model API across architecture families.

``get_model(cfg)`` returns a ``Model`` namespace with:

  init_params(rng)                               -> params
  prefill(params, batch, max_len, window)        -> logits, aux, cache
  decode(params, cache, tokens)                  -> logits, cache
  verify(params, cache, tree_tokens, spec)       -> logits, extras
    (kw: backend, tree_kernel — "sparse" splits the paged verify into a
     quantized page walk + block-masked tree kernel merged by Eq.-1;
     families without that path accept and ignore it)
  commit(cache, extras, spec,
         accept_nodes (B, Dmax), n_accept (B,),
         path_idx (B,))                          -> cache

``commit`` is batched: every sequence commits its own accepted chain length,
so cache positions diverge per sequence (see runtime/cache.py).

``batch`` for prefill is a dict: {"tokens": (B,S)} and, for modality archs,
{"frame_embeds" | "patch_embeds": (B,T,d)}.  The VLM path concatenates
patch embeddings before the token embeddings (pre-projected, stub frontend).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from repro.models import encdec, hybrid, transformer, xlstm_model


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init_params: Callable
    prefill: Callable            # (params, batch, *, max_len, window) -> (logits, aux, cache)
    decode: Callable             # (params, cache, tokens, *, backend) -> (logits, cache)
    verify: Callable             # (params, cache, tree_tokens, spec, *, backend) -> (logits, extras)
    commit: Callable             # (cache, extras, spec, accept_nodes, n_accept, path_idx) -> cache
    family: str


def _dense_like(cfg, family):
    def prefill(params, batch, *, max_len=None, window=0, return_cache=True,
                last_logits=False):
        tokens = batch["tokens"]
        embeds = None
        if cfg.frontend == "vision" and "patch_embeds" in batch:
            tok_e = transformer.embed_tokens(cfg, params, tokens)
            embeds = jnp.concatenate(
                [batch["patch_embeds"].astype(tok_e.dtype), tok_e], axis=1)
        return transformer.prefill(cfg, params, tokens, embeds,
                                   max_len=max_len, window=window,
                                   return_cache=return_cache,
                                   last_logits=last_logits)

    def verify(params, cache, tree_tokens, spec, *, backend="ref",
               tree_kernel="dense"):
        return transformer.verify(cfg, params, cache, tree_tokens,
                                  spec.depth, spec.mask, backend=backend,
                                  tree_kernel=tree_kernel)

    def commit(cache, extras, spec, accept_nodes, n_accept, path_idx):
        return transformer.commit(cfg, cache, extras, accept_nodes, n_accept,
                                  spec.max_depth)

    return Model(cfg=cfg,
                 init_params=lambda rng: transformer.init_params(cfg, rng),
                 prefill=prefill,
                 decode=lambda params, cache, tokens, backend="ref":
                     transformer.decode(cfg, params, cache, tokens, backend=backend),
                 verify=verify, commit=commit, family=family)


def _hybrid(cfg):
    def prefill(params, batch, *, max_len=None, window=0, return_cache=True,
                last_logits=False):
        return hybrid.prefill(cfg, params, batch["tokens"],
                              max_len=max_len, window=window,
                              return_cache=return_cache,
                              last_logits=last_logits)

    def verify(params, cache, tree_tokens, spec, *, backend="ref",
               tree_kernel="dense"):
        del tree_kernel              # no paged tree-verify split here
        return hybrid.verify(cfg, params, cache, tree_tokens, spec.depth,
                             spec.mask, paths=spec.paths,
                             node_path=spec.node_path,
                             node_depth=spec.node_depth, backend=backend)

    def commit(cache, extras, spec, accept_nodes, n_accept, path_idx):
        return hybrid.commit(cfg, cache, extras, accept_nodes, n_accept,
                             path_idx, spec.max_depth)

    return Model(cfg=cfg,
                 init_params=lambda rng: hybrid.init_params(cfg, rng),
                 prefill=prefill,
                 decode=lambda params, cache, tokens, backend="ref":
                     hybrid.decode(cfg, params, cache, tokens, backend=backend),
                 verify=verify, commit=commit, family="hybrid")


def _xlstm(cfg):
    def prefill(params, batch, *, max_len=None, window=0, return_cache=True,
                last_logits=False):
        return xlstm_model.prefill(cfg, params, batch["tokens"],
                                   last_logits=last_logits)

    def verify(params, cache, tree_tokens, spec, *, backend="ref",
               tree_kernel="dense"):
        del tree_kernel              # no paged tree-verify split here
        return xlstm_model.verify(cfg, params, cache, tree_tokens, spec.depth,
                                  spec.mask, paths=spec.paths,
                                  node_path=spec.node_path,
                                  node_depth=spec.node_depth)

    def commit(cache, extras, spec, accept_nodes, n_accept, path_idx):
        return xlstm_model.commit(cfg, cache, extras, accept_nodes, n_accept,
                                  path_idx, spec.max_depth)

    return Model(cfg=cfg,
                 init_params=lambda rng: xlstm_model.init_params(cfg, rng),
                 prefill=prefill,
                 decode=lambda params, cache, tokens, backend="ref":
                     xlstm_model.decode(cfg, params, cache, tokens),
                 verify=verify, commit=commit, family="ssm")


def _encdec(cfg):
    def prefill(params, batch, *, max_len=None, window=0, return_cache=True,
                last_logits=False):
        return encdec.prefill(cfg, params, batch["tokens"],
                              frame_embeds=batch.get("frame_embeds"),
                              enc_out=batch.get("enc_out"),
                              max_len=max_len, window=window,
                              return_cache=return_cache,
                              last_logits=last_logits)

    def verify(params, cache, tree_tokens, spec, *, backend="ref",
               tree_kernel="dense"):
        del tree_kernel              # no paged tree-verify split here
        return encdec.verify(cfg, params, cache, tree_tokens, spec.depth,
                             spec.mask, backend=backend)

    def commit(cache, extras, spec, accept_nodes, n_accept, path_idx):
        return encdec.commit(cfg, cache, extras, accept_nodes, n_accept,
                             spec.max_depth)

    return Model(cfg=cfg,
                 init_params=lambda rng: encdec.init_params(cfg, rng),
                 prefill=prefill,
                 decode=lambda params, cache, tokens, backend="ref":
                     encdec.decode(cfg, params, cache, tokens, backend=backend),
                 verify=verify, commit=commit, family="audio")


def get_model(cfg) -> Model:
    if cfg.is_encoder_decoder:
        return _encdec(cfg)
    if cfg.arch_type == "hybrid":
        return _hybrid(cfg)
    if cfg.arch_type == "ssm":
        return _xlstm(cfg)
    return _dense_like(cfg, cfg.arch_type)       # dense | moe | vlm
