"""Tree verification through recurrent (SSM/xLSTM) blocks.

A state-space recurrence cannot attend sparsely to a token *tree* the way
attention can (DESIGN.md §Arch-applicability): instead the tree's paths are
verified by replicating the state per path and stepping each path's tokens.
Node outputs are recovered from (path, depth) coordinates — identical across
paths sharing the prefix, so any covering path works.

This is the Ghidorah compute/acceptance trade-off in recurrent form: the
draft costs P×D steps instead of W tree slots; ARCA's cost model accounts
for it when choosing the verification width for these architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def expand_paths(x_nodes, paths):
    """x_nodes: (B, W, d); paths: (P, D) node ids -> (D, B, P, d)."""
    xp = jnp.take(x_nodes, paths, axis=1)          # (B, P, D, d)
    return jnp.transpose(xp, (2, 0, 1, 3))


def collapse_nodes(y_steps, node_path, node_depth):
    """y_steps: (D, B, P, d) -> node outputs (B, W, d)."""
    y = y_steps[node_depth, :, node_path]           # (W, B, d)
    return jnp.transpose(y, (1, 0, 2))


def replicate_state(state, P):
    """Tile each (B, ...) state leaf to (B*P, ...)."""
    def rep(s):
        return jnp.broadcast_to(s[:, None], (s.shape[0], P) + s.shape[1:]) \
                  .reshape((s.shape[0] * P,) + s.shape[1:])
    return jax.tree_util.tree_map(rep, state)


def path_verify(step_fn, x_nodes, state, paths, node_path, node_depth):
    """Run ``step_fn`` over every tree path with per-path state.

    step_fn(x_t (B*P, d), state) -> (y (B*P, d), state)
    Returns (y_nodes (B, W, d), per_depth_states) where each state leaf is
    stacked (D, B*P, ...) — states AFTER processing each depth, used by
    ``select_committed_state`` once the accepted path is known.
    """
    B, W, d = x_nodes.shape
    P, D = paths.shape
    xs = expand_paths(x_nodes, paths).reshape(D, B * P, d)
    st0 = replicate_state(state, P)

    def step(st, x_t):
        y, st = step_fn(x_t, st)
        return st, (y, st)

    _, (ys, sts) = jax.lax.scan(step, st0, xs)
    y_nodes = collapse_nodes(ys.reshape(D, B, P, d), node_path, node_depth)
    return y_nodes, sts


def select_committed_state(per_depth_states, path_idx, n_accept, batch, P):
    """State after accepting ``n_accept[b]`` tokens along path ``path_idx[b]``
    for each sequence b.

    per_depth_states leaves: (D, B*P, ...); path_idx/n_accept: (B,).
    Returns leaves (B, ...).
    """
    def sel(s):
        sbp = s.reshape((s.shape[0], batch, P) + s.shape[2:])  # (D, B, P, ...)

        def one(sb, n, pi):
            # sb: (D, P, ...) for one sequence
            d_state = jax.lax.dynamic_index_in_dim(sb, n - 1, 0, False)
            return jax.lax.dynamic_index_in_dim(d_state, pi, 0, False)

        return jax.vmap(one, in_axes=(1, 0, 0), out_axes=0)(
            sbp, n_accept, path_idx)
    return jax.tree_util.tree_map(sel, per_depth_states)
