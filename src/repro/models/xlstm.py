"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with recurrent gate connections) — arXiv:2405.04517.

Both expose ``*_step`` (decode) and ``*_prefill`` (time scan).  States are
fp32.  These blocks carry their own projections (cfg.d_ff == 0 for xLSTM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


# --------------------------------------------------------------------------
# mLSTM: per-head matrix memory C (hd x hd), normalizer n (hd,), max-state m
# --------------------------------------------------------------------------
def mlstm_dims(cfg):
    di = 2 * cfg.d_model
    nh = cfg.num_heads
    hd = di // nh
    return di, nh, hd


def mlstm_init(cfg, rng):
    d = cfg.d_model
    di, nh, hd = mlstm_dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 8)
    return {
        "up": cm.dense_init(ks[0], d, 2 * di, dt),          # [x_in, gate]
        "wq": cm.dense_init(ks[1], di, di, dt),
        "wk": cm.dense_init(ks[2], di, di, dt),
        "wv": cm.dense_init(ks[3], di, di, dt),
        "wi": cm.dense_init(ks[4], di, nh, jnp.dtype(jnp.float32)),
        "wf": cm.dense_init(ks[5], di, nh, jnp.dtype(jnp.float32)),
        "skip": jnp.ones((di,), dt),
        "norm": jnp.ones((di,), dt),
        "down": cm.dense_init(ks[6], di, d, dt),
    }


def _mlstm_gates(p, xi):
    i_raw = xi.astype(jnp.float32) @ p["wi"]                # (..., nh)
    f_raw = xi.astype(jnp.float32) @ p["wf"]
    return i_raw, jax.nn.log_sigmoid(f_raw)


def _mlstm_qkv(cfg, p, xi):
    di, nh, hd = mlstm_dims(cfg)
    shp = xi.shape[:-1] + (nh, hd)
    q = (xi @ p["wq"]).reshape(shp)
    k = (xi @ p["wk"]).reshape(shp) * hd ** -0.5
    v = (xi @ p["wv"]).reshape(shp)
    return q, k, v


def mlstm_step(cfg, p, x_t, state):
    """x_t: (B, d); state: dict(C (B,nh,hd,hd), n (B,nh,hd), m (B,nh))."""
    di, nh, hd = mlstm_dims(cfg)
    up = x_t @ p["up"]
    xi, gate = up[..., :di], up[..., di:]
    q, k, v = _mlstm_qkv(cfg, p, xi)
    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    i_raw, f_log = _mlstm_gates(p, xi)

    m_new = jnp.maximum(f_log + state["m"], i_raw)           # (B,nh)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(f_log + state["m"] - m_new)
    C = f_g[..., None, None] * state["C"] + i_g[..., None, None] * (
        v[..., :, None] * k[..., None, :])                   # (B,nh,hd,hd)
    n = f_g[..., None] * state["n"] + i_g[..., None] * k
    h_num = jnp.einsum("bhvk,bhk->bhv", C, q)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h = (h_num / h_den[..., None]).reshape(x_t.shape[0], di)

    y = cm.rmsnorm(h.astype(x_t.dtype), p["norm"], cfg.rmsnorm_eps)
    y = y + xi * p["skip"]
    y = y * jax.nn.silu(gate)
    return y @ p["down"], {"C": C, "n": n, "m": m_new}


def mlstm_prefill_scan(cfg, p, x, state=None):
    """Per-step recurrence (the correctness baseline — O(S) sequential)."""
    B, S, d = x.shape
    if state is None:
        state = mlstm_init_state(cfg, B)

    def step(st, x_t):
        out, st = mlstm_step(cfg, p, x_t, st)
        return st, out

    state, ys = jax.lax.scan(step, state, jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(ys, 0, 1), state


def _mlstm_chunk(cfg, p, xi_c, state):
    """Closed-form parallel evaluation of one chunk (exact unroll of the
    stabilized recurrence):

      m_t = max_{s<=t}( F_t - F_s + i_s , F_t + m_0 )
      C_t = sum_s e^{F_t-F_s+i_s-m_t} v_s k_s^T + e^{F_t+m_0-m_t} C_0

    Within-chunk work is one (T,T) masked matmul per head — MXU-shaped,
    removing the T-step scan (EXPERIMENTS §Perf hillclimb B).
    xi_c: (B, T, di) post-up-projection inner activations.
    """
    di, nh, hd = mlstm_dims(cfg)
    B, T, _ = xi_c.shape
    q, k, v = _mlstm_qkv(cfg, p, xi_c)
    q, k, v = (jnp.swapaxes(t.astype(jnp.float32), 1, 2) for t in (q, k, v))
    i_raw, f_log = _mlstm_gates(p, xi_c)                   # (B,T,nh)
    i_raw = jnp.swapaxes(i_raw, 1, 2)                      # (B,nh,T)
    f_log = jnp.swapaxes(f_log, 1, 2)
    F = jnp.cumsum(f_log, axis=-1)                         # (B,nh,T)

    # decay/inject matrix D~ (B,nh,T,T): F_t - F_s + i_s for s<=t
    Dm = F[..., :, None] - F[..., None, :] + i_raw[..., None, :]
    causal = jnp.tril(jnp.ones((T, T), bool))
    Dm = jnp.where(causal, Dm, -jnp.inf)
    m_state = F + state["m"][..., None]                    # (B,nh,T)
    m = jnp.maximum(jnp.max(Dm, axis=-1), m_state)         # (B,nh,T)

    S = jnp.exp(Dm - m[..., None]) * jnp.einsum("bhtd,bhsd->bhts", q, k)
    carry_w = jnp.exp(m_state - m)                         # (B,nh,T)
    num = jnp.einsum("bhts,bhsd->bhtd", S, v) \
        + carry_w[..., None] * jnp.einsum("bhvk,bhtk->bhtv", state["C"], q)
    den = jnp.sum(S, axis=-1) \
        + carry_w * jnp.einsum("bhk,bhtk->bht", state["n"], q)
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]    # (B,nh,T,hd)
    h = jnp.swapaxes(h, 1, 2).reshape(B, T, di)

    # chunk-end state (t = T-1)
    wT = jnp.exp(Dm[..., -1, :] - m[..., -1:])             # (B,nh,T)
    C_T = jnp.einsum("bhs,bhsv,bhsk->bhvk", wT, v, k) \
        + carry_w[..., -1, None, None] * state["C"]
    n_T = jnp.einsum("bhs,bhsk->bhk", wT, k) \
        + carry_w[..., -1, None] * state["n"]
    m_T = m[..., -1]
    return h, {"C": C_T, "n": n_T, "m": m_T}


def mlstm_prefill(cfg, p, x, state=None, chunk=256):
    """Chunked-parallel prefill (exact vs the scan baseline; falls back to
    the scan when cfg.mlstm_chunked is False)."""
    B, S, d = x.shape
    if not getattr(cfg, "mlstm_chunked", True):
        return mlstm_prefill_scan(cfg, p, x, state)
    if state is None:
        state = mlstm_init_state(cfg, B)
    di, nh, hd = mlstm_dims(cfg)

    up = x @ p["up"]
    xi, gate = up[..., :di], up[..., di:]

    T = min(chunk, S)
    n_full = S // T
    rem = S - n_full * T
    if n_full > 1:
        xs = jnp.swapaxes(xi[:, :n_full * T].reshape(B, n_full, T, di), 0, 1)

        def step(st, xi_c):
            h, st = _mlstm_chunk(cfg, p, xi_c, st)
            return st, h

        state, hs = jax.lax.scan(step, state, xs)
        h_main = jnp.swapaxes(hs, 0, 1).reshape(B, n_full * T, di)
    else:
        h_main, state = _mlstm_chunk(cfg, p, xi[:, :n_full * T], state)
    if rem:
        h_rem, state = _mlstm_chunk(cfg, p, xi[:, n_full * T:], state)
        h_flat = jnp.concatenate([h_main, h_rem], axis=1)
    else:
        h_flat = h_main

    y = cm.rmsnorm(h_flat.astype(x.dtype), p["norm"], cfg.rmsnorm_eps)
    y = y + xi * p["skip"]
    y = y * jax.nn.silu(gate)
    return y @ p["down"], state


def mlstm_init_state(cfg, batch):
    di, nh, hd = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


# --------------------------------------------------------------------------
# sLSTM: scalar memory per unit, recurrent gate connections (inherently
# sequential — the reason xLSTM keeps only a few sLSTM layers)
# --------------------------------------------------------------------------
def slstm_init(cfg, rng):
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 9)
    p = {"norm": jnp.ones((d,), dt), "down": cm.dense_init(ks[8], d, d, dt)}
    for i, g in enumerate(("i", "f", "z", "o")):
        p["w" + g] = cm.dense_init(ks[i], d, d, dt)
        p["r" + g] = cm.dense_init(ks[4 + i], d, d, dt, scale=0.0)  # zero-init recurrence
        p["b" + g] = jnp.zeros((d,), jnp.float32)
    return p


def slstm_step(cfg, p, x_t, state):
    """x_t: (B, d); state: dict(c, n, h, m) each (B, d) fp32."""
    h_prev = state["h"].astype(x_t.dtype)

    def gate(g):
        return (x_t @ p["w" + g] + h_prev @ p["r" + g]).astype(jnp.float32) + p["b" + g]

    i_raw, f_raw, z_raw, o_raw = gate("i"), gate("f"), gate("z"), gate("o")
    f_log = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(f_log + state["m"], i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(f_log + state["m"] - m_new)
    c = f_g * state["c"] + i_g * jnp.tanh(z_raw)
    n = f_g * state["n"] + i_g
    h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1.0)
    y = cm.rmsnorm(h.astype(x_t.dtype), p["norm"], cfg.rmsnorm_eps)
    return y @ p["down"], {"c": c, "n": n, "h": h, "m": m_new}


def slstm_prefill(cfg, p, x, state=None):
    B, S, d = x.shape
    if state is None:
        state = slstm_init_state(cfg, B, d)

    def step(st, x_t):
        out, st = slstm_step(cfg, p, x_t, st)
        return st, out

    state, ys = jax.lax.scan(step, state, jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(ys, 0, 1), state


def slstm_init_state(cfg, batch, d=None):
    d = d or cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30, jnp.float32)}
