"""Zamba2-style hybrid stack: Mamba2 backbone + one weight-SHARED attention
block applied every ``shared_attention_every`` layers (each application site
has its own KV cache slice).

Layer scan carries (x, shared-attn KV cache); Mamba params are stacked and
scanned; the shared attention block's params are closure constants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import mamba2 as mb
from repro.models import recurrent_verify as rv
from repro.models.attention import attn_init, attn_prefill, attn_verify
from repro.models.mlp import mlp_apply, mlp_init
from repro.runtime.cache import (Cache, KVCache, MambaState, PagedKVCache,
                                 init_kv_cache, kv_commit)


def n_sites(cfg):
    # at least one cache slot so both lax.cond branches trace (a clone with
    # zero firing sites still indexes site 0 in the dead branch)
    return max(cfg.num_layers // cfg.shared_attention_every, 1)


def init_params(cfg, rng):
    k_embed, k_layers, k_attn, k_mlp, k_out = jax.random.split(rng, 5)
    dt = jnp.dtype(cfg.dtype)

    def layer_init(k):
        return {"ln": jnp.ones((cfg.d_model,), dt), "mamba": mb.mamba_init(cfg, k)}

    return {
        "embed": cm.embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dt),
        "layers": cm.stack_init(k_layers, cfg.num_layers, layer_init),
        "shared": {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "attn": attn_init(cfg, k_attn),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "mlp": mlp_init(cfg, k_mlp),
        },
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "lm_head": cm.dense_init(k_out, cfg.d_model, cfg.padded_vocab, dt),
    }


def _logits(cfg, params, x):
    return (cm.rmsnorm(x, params["ln_f"], cfg.rmsnorm_eps)
            @ params["lm_head"])[..., :cfg.vocab_size]


def _site_pred(cfg, idx):
    every = cfg.shared_attention_every
    site = idx // every
    fires = jnp.logical_and((idx % every) == every - 1, site < n_sites(cfg))
    return site, fires


def _shared_attn_tree(cfg, sp, x, ak, av, key_pos, pos, tree_depth, tree_mask,
                      window, backend="ref", block_table=None):
    """Shared attn + MLP on node-form hiddens.  Returns (x', (k_new, v_new))."""
    h = cm.rmsnorm(x, sp["ln1"], cfg.rmsnorm_eps)
    a, (k1, v1) = attn_verify(cfg, sp["attn"], h, ck=ak, cv=av,
                              key_pos=key_pos, pos=pos, tree_depth=tree_depth,
                              tree_mask=tree_mask, window=window,
                              backend=backend, block_table=block_table)
    x = x + a
    x = x + mlp_apply(cfg, sp["mlp"], cm.rmsnorm(x, sp["ln2"], cfg.rmsnorm_eps))
    return x, (k1, v1)


# --------------------------------------------------------------------------
def _group_params(cfg, layers):
    """Split the stacked layer params into (n_groups, every, ...) site groups
    plus an ungrouped tail.  The shared-attn KV cache is then touched ONLY at
    group boundaries instead of riding every layer's scan carry/cond — the
    scan-carry accounting (and real loop-state plumbing) scales with sites,
    not layers (EXPERIMENTS §Perf iteration D2)."""
    every = cfg.shared_attention_every
    ns = cfg.num_layers // every
    main = ns * every
    tm = jax.tree_util.tree_map
    grouped = (tm(lambda a: a[:main].reshape((ns, every) + a.shape[1:]),
                  layers) if ns else None)
    tail = tm(lambda a: a[main:], layers)
    tail_len = cfg.num_layers - main
    return ns, grouped, tail, tail_len


def _tslice(tree, g):
    return jax.tree_util.tree_map(lambda a: a[g], tree)


def prefill(cfg, params, tokens=None, embeds=None, *, cache=None, window=0,
            max_len=None, return_cache=True, last_logits=False):
    x = params["embed"][tokens] if embeds is None else embeds
    B, S, _ = x.shape
    sp = params["shared"]
    if cache is None:
        # training (return_cache=False): 1-slot dummy KV cache, writes are noise
        size = max(S, max_len or 0) if return_cache else 1
        cache = init_cache(cfg, B, size, window=window)
    kv = cache.kv

    def mamba_seg(x, seg):
        def body(xc, lp):
            out, st = mb.mamba_prefill(
                cfg, lp["mamba"], cm.rmsnorm(xc, lp["ln"], cfg.rmsnorm_eps))
            return xc + out, st
        return cm.layer_scan(cfg, body, x, seg)

    size = kv.max_len
    if S >= size:
        k_slots = (S - size + jnp.arange(size)) % size
        abs_pos = S - size + jnp.arange(size, dtype=jnp.int32)
    else:
        k_slots = jnp.arange(S) % size
        abs_pos = jnp.arange(S, dtype=jnp.int32)

    ns, grouped, tail, tail_len = _group_params(cfg, params["layers"])
    ak, av = kv.k, kv.v
    seg_states = []
    for g in range(ns):                        # python loop over attn sites
        x, st_g = mamba_seg(x, _tslice(grouped, g))
        seg_states.append(st_g)
        h = cm.rmsnorm(x, sp["ln1"], cfg.rmsnorm_eps)
        a, (k1, v1) = attn_prefill(cfg, sp["attn"], h, window=window)
        x = x + a
        x = x + mlp_apply(cfg, sp["mlp"],
                          cm.rmsnorm(x, sp["ln2"], cfg.rmsnorm_eps))
        if S >= size:
            k1, v1 = k1[:, -size:], v1[:, -size:]
        # note: [g, :, slots] would trigger advanced-indexing axis moving;
        # update the site slice in place instead
        ak = ak.at[g].set(ak[g].at[:, k_slots].set(k1.astype(ak.dtype)))
        av = av.at[g].set(av[g].at[:, k_slots].set(v1.astype(av.dtype)))
    if tail_len:
        x, st_t = mamba_seg(x, tail)
        seg_states.append(st_t)
    states = jax.tree_util.tree_map(
        lambda *a: jnp.concatenate(a, axis=0), *seg_states)

    key_pos = kv.key_pos.at[:, k_slots].set(abs_pos)       # same row per seq
    pos = jnp.full((B,), S, jnp.int32)
    new_cache = Cache(
        kv=KVCache(k=ak, v=av, key_pos=key_pos, pos=pos, window=kv.window),
        mamba=MambaState(ssm=states["ssm"], conv=states["conv"], pos=pos))
    return (_logits(cfg, params, x[:, -1:] if last_logits else x),
            {"aux_loss": jnp.zeros((), jnp.float32), "hidden": x},
            new_cache if return_cache else None)


# --------------------------------------------------------------------------
def verify(cfg, params, cache: Cache, tree_tokens, tree_depth, tree_mask,
           *, paths=None, node_path=None, node_depth=None, backend="ref"):
    """Tree verify: Mamba layers verify per-path (state replication);
    shared-attn sites verify in node form with the tree mask.

    Returns (logits (B,W,V), extras dict for ``commit``).
    """
    x = params["embed"][tree_tokens]
    B, W, _ = x.shape
    P, D = paths.shape
    kv, ms = cache.kv, cache.mamba
    sp = params["shared"]
    every = cfg.shared_attention_every

    def mamba_seg(x, seg, ssm_seg, conv_seg):
        def body(xc, xs):
            lp, ssm_l, conv_l = xs

            def step_fn(x_t, st):
                return mb.mamba_step(cfg, lp["mamba"], x_t, st)

            h = cm.rmsnorm(xc, lp["ln"], cfg.rmsnorm_eps)
            y_nodes, depth_states = rv.path_verify(
                step_fn, h, {"ssm": ssm_l, "conv": conv_l},
                paths, node_path, node_depth)
            return xc + y_nodes, depth_states
        return cm.layer_scan(cfg, body, x, (seg, ssm_seg, conv_seg))

    ns, grouped, tail, tail_len = _group_params(cfg, params["layers"])
    paged = isinstance(kv, PagedKVCache)
    table = kv.block_table if paged else None
    seg_states, site_k, site_v = [], [], []
    for g in range(ns):
        lo, hi = g * every, (g + 1) * every
        x, dst = mamba_seg(x, _tslice(grouped, g),
                           ms.ssm[lo:hi], ms.conv[lo:hi])
        seg_states.append(dst)
        ak, av = (kv.pool_k[g], kv.pool_v[g]) if paged else (kv.k[g], kv.v[g])
        x, (k1, v1) = _shared_attn_tree(
            cfg, sp, x, ak, av, kv.key_pos, kv.pos,
            tree_depth, tree_mask, kv.window, backend, block_table=table)
        site_k.append(k1)
        site_v.append(v1)
    if tail_len:
        x, dst = mamba_seg(x, tail, ms.ssm[ns * every:], ms.conv[ns * every:])
        seg_states.append(dst)
    depth_states = jax.tree_util.tree_map(
        lambda *a: jnp.concatenate(a, axis=0), *seg_states)
    if not site_k:                    # degenerate clones (no firing site)
        z = jnp.zeros((B, W, cfg.num_kv_heads, cfg.head_dim), x.dtype)
        site_k, site_v = [z], [z]
    extras = {"depth_states": depth_states,       # leaves (L, D, B*P, ...)
              "tree_k": jnp.stack(site_k),         # (n_sites, B, W, Hkv, hd)
              "tree_v": jnp.stack(site_v),
              "P": P, "hidden": x}
    return _logits(cfg, params, x), extras


def decode(cfg, params, cache: Cache, tokens, *, backend="ref"):
    """1-token decode via the W=1 tree."""
    B = tokens.shape[0]
    logits, extras = verify(
        cfg, params, cache, tokens,
        tree_depth=jnp.zeros((1,), jnp.int32),
        tree_mask=jnp.ones((1, 1), bool),
        paths=jnp.zeros((1, 1), jnp.int32),
        node_path=jnp.zeros((1,), jnp.int32),
        node_depth=jnp.zeros((1,), jnp.int32),
        backend=backend)
    cache = commit(cfg, cache, extras,
                   accept_nodes=jnp.zeros((B, 1), jnp.int32),
                   n_accept=jnp.ones((B,), jnp.int32),
                   path_idx=jnp.zeros((B,), jnp.int32), max_depth=1)
    return logits, cache


def commit(cfg, cache: Cache, extras, accept_nodes, n_accept, path_idx,
           max_depth):
    """Commit accepted paths: select each sequence's recurrent state at its
    (path, depth) and scatter its accepted tree KVs into the shared-attn
    cache sites.  accept_nodes (B, Dmax); n_accept/path_idx (B,)."""
    kv, ms = cache.kv, cache.mamba
    B = kv.pos.shape[0]
    P = extras["P"]

    # recurrent states: (L, D, B*P, ...) -> (L, B, ...), per-sequence indices
    def sel(s):
        sbp = s.reshape(s.shape[:2] + (B, P) + s.shape[3:])    # (L,D,B,P,...)

        def one(sb, n, pi):
            # sb: (L, D, P, ...) for one sequence
            d_state = jax.lax.dynamic_index_in_dim(sb, n - 1, 1, False)
            return jax.lax.dynamic_index_in_dim(d_state, pi, 1, False)

        return jax.vmap(one, in_axes=(2, 0, 0), out_axes=1)(
            sbp, n_accept, path_idx)

    new_ssm = sel(extras["depth_states"]["ssm"])
    new_conv = sel(extras["depth_states"]["conv"])
    # n_accept == 0 (a frozen row, see spec_step's `active` mask) commits
    # NOTHING: the depth select above would clamp n-1 = -1 to depth 0, so
    # keep the previous recurrent state instead
    keep = n_accept > 0
    new_ssm = jnp.where(keep[None, :, None, None, None], new_ssm, ms.ssm)
    new_conv = jnp.where(keep[None, :, None, None], new_conv, ms.conv)

    # shared-attn KV scatter (vmapped masked ring write, as transformer.commit)
    new_kv = kv_commit(kv, extras["tree_k"], extras["tree_v"],
                       accept_nodes, n_accept, max_depth)
    return Cache(
        kv=new_kv,
        mamba=MambaState(ssm=new_ssm, conv=new_conv, pos=new_kv.pos))


def init_cache(cfg, batch, max_len, *, window=0):
    di, nh, hd, N = mb.dims(cfg)
    kv = init_kv_cache(n_sites(cfg), batch, max_len, cfg.num_kv_heads,
                       cfg.head_dim, window=window, dtype=jnp.dtype(cfg.dtype))
    return Cache(
        kv=kv,
        mamba=MambaState(
            ssm=jnp.zeros((cfg.num_layers, batch, nh, hd, N), jnp.float32),
            conv=jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1, di + 2 * N),
                           jnp.dtype(cfg.dtype)),
            pos=jnp.zeros((batch,), jnp.int32)))
