"""Mamba2 (SSD) block: scalar-decay state-space recurrence with heads.

State per layer: ssm (B, nh, hd, N) fp32 + causal-conv tail (B, K-1, C)
where C = di + 2N conv channels.  Prefill runs a time scan; decode is a
single step.  Tree verification for recurrent blocks replicates state per
tree path (see core/speculative/verify.py) — recorded in DESIGN.md
§Arch-applicability as the honest adaptation of attention-tree sparsity.

Projections are SPLIT per semantic component (z / x / BC / dt) rather than
one fused in_proj: slicing a fused projection whose output dim is
column-sharded forces XLA SPMD to regather/rematerialize the whole tensor
(observed: ~70x HBM amplification on the zamba2 decode dry-run).  With the
split, z/x stay cleanly `model`-sharded and B/C/dt stay replicated.
EXPERIMENTS.md §Perf iteration D records the before/after.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


def dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    nh = cfg.ssm_heads or max(di // 64, 1)
    hd = di // nh
    return di, nh, hd, cfg.ssm_state


def mamba_init(cfg, rng):
    di, nh, hd, N = dims(cfg)
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 8)
    return {
        "in_z": cm.dense_init(ks[0], d, di, dt),
        "in_x": cm.dense_init(ks[1], d, di, dt),
        "in_bc": cm.dense_init(ks[2], d, 2 * N, dt),
        "in_dt": cm.dense_init(ks[3], d, nh, dt),
        "conv_wx": (jax.random.normal(ks[4], (cfg.ssm_conv, di), jnp.float32)
                    * cfg.ssm_conv ** -0.5).astype(dt),
        "conv_wbc": (jax.random.normal(ks[5], (cfg.ssm_conv, 2 * N), jnp.float32)
                     * cfg.ssm_conv ** -0.5).astype(dt),
        "conv_bx": jnp.zeros((di,), dt),
        "conv_bbc": jnp.zeros((2 * N,), dt),
        "A_log": jnp.zeros((nh,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((di,), dt),
        "out_proj": cm.dense_init(ks[6], di, d, dt),
    }


def _ssd_step(cfg, p, x_conv, bc_conv, dt_raw, state):
    """One recurrence step after the conv.  x_conv: (B, di), bc_conv: (B, 2N)."""
    di, nh, hd, N = dims(cfg)
    x = x_conv.astype(jnp.float32).reshape(-1, nh, hd)
    Bm = bc_conv[..., :N].astype(jnp.float32)                  # (B,N)
    Cm = bc_conv[..., N:].astype(jnp.float32)                  # (B,N)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,nh)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dtv)                    # (B,nh)
    upd = jnp.einsum("bhp,bn->bhpn", x * dtv[..., None], Bm)
    state = a[..., None, None] * state + upd                   # (B,nh,hd,N)
    y = jnp.einsum("bhpn,bn->bhp", state, Cm) + p["D"][None, :, None] * x
    return y.reshape(-1, di), state


def _conv_split(cfg, p, hist):
    """hist: (B, K, C) with C = di + 2N (x part sharded, bc part replicated).
    Returns silu'd (x_c (B, di), bc_c (B, 2N))."""
    di = cfg.ssm_expand * cfg.d_model
    x_c = jnp.einsum("bkc,kc->bc", hist[..., :di].astype(jnp.float32),
                     p["conv_wx"].astype(jnp.float32)) \
        + p["conv_bx"].astype(jnp.float32)
    bc_c = jnp.einsum("bkc,kc->bc", hist[..., di:].astype(jnp.float32),
                      p["conv_wbc"].astype(jnp.float32)) \
        + p["conv_bbc"].astype(jnp.float32)
    return jax.nn.silu(x_c), jax.nn.silu(bc_c)


def mamba_step(cfg, p, x_t, state):
    """x_t: (B, d); state: dict(ssm (B,nh,hd,N) fp32, conv (B,K-1,C))."""
    z = x_t @ p["in_z"]
    xin = x_t @ p["in_x"]
    bc = x_t @ p["in_bc"]
    dt_raw = x_t @ p["in_dt"]
    xbc = jnp.concatenate([xin, bc], axis=-1)
    hist = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # (B,K,C)
    x_c, bc_c = _conv_split(cfg, p, hist)
    y, ssm = _ssd_step(cfg, p, x_c, bc_c, dt_raw, state["ssm"])
    y = cm.rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype),
                   p["norm"], cfg.rmsnorm_eps)
    out = y @ p["out_proj"]
    new_state = {"ssm": ssm, "conv": hist[:, 1:, :]}
    return out, new_state


def _ssd_chunk(cfg, p, x_c, bc_c, dt_raw, S0):
    """Closed-form parallel evaluation of one SSD chunk (exact unroll of the
    scalar-decay recurrence — no stabilizer needed since decay <= 1):

      S_t = a_t S_{t-1} + (dt_t x_t) (x) B_t ,  a_t = exp(-exp(A_log) dt_t)
      y_t = C_t . S_t + D x_t
         = sum_{s<=t} e^{L_t - L_s} (B_s . C_t)(dt_s x_s) + e^{L_t} (C_t . S_0)

    with L_t = cumsum log a.  Within-chunk work is (T,T) matmuls per head —
    MXU-shaped, replacing the T-step time scan (EXPERIMENTS §Perf iter. F).

    x_c: (B,T,di) conv'd; bc_c: (B,T,2N); dt_raw: (B,T,nh); S0 fp32.
    Returns (y (B,T,di), S_T).
    """
    di, nh, hd, N = dims(cfg)
    B, T, _ = x_c.shape
    xh = x_c.astype(jnp.float32).reshape(B, T, nh, hd)
    Bm = bc_c[..., :N].astype(jnp.float32)                 # (B,T,N)
    Cm = bc_c[..., N:].astype(jnp.float32)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,T,nh)
    log_a = -jnp.exp(p["A_log"]) * dtv                     # (B,T,nh), <= 0
    L = jnp.cumsum(log_a, axis=1)                          # (B,T,nh)

    # decay matrix W_ts = exp(L_t - L_s) for s <= t  -> (B,nh,T,T)
    Lh = jnp.swapaxes(L, 1, 2)                             # (B,nh,T)
    W = jnp.exp(Lh[..., :, None] - Lh[..., None, :])
    W = jnp.where(jnp.tril(jnp.ones((T, T), bool)), W, 0.0)
    scores = jnp.einsum("btn,bsn->bts", Cm, Bm)            # (B,T,T) shared
    G = scores[:, None] * W                                # (B,nh,T,T)
    xdt = xh * dtv[..., None]                              # (B,T,nh,hd)
    y = jnp.einsum("bhts,bshp->bthp", G, xdt)
    # carried initial-state contribution
    y = y + jnp.exp(Lh)[..., None].swapaxes(1, 2) \
        * jnp.einsum("bhpn,btn->bthp", S0, Cm)
    y = y + p["D"][None, None, :, None] * xh
    # chunk-end state
    wT = jnp.exp(Lh[..., -1:] - Lh)                        # (B,nh,T)
    S_T = jnp.exp(Lh[..., -1])[..., None, None] * S0 \
        + jnp.einsum("bht,bthp,btn->bhpn", wT, xdt, Bm)
    return y.reshape(B, T, di), S_T


def mamba_prefill(cfg, p, x, state=None, chunk=256):
    """x: (B, S, d).  Chunked SSD prefill (exact vs the time scan; falls
    back to the scan when cfg.mamba_chunked is False)."""
    B, S, d = x.shape
    di, nh, hd, N = dims(cfg)
    if state is None:
        state = init_state(cfg, B, dtype=x.dtype)
    if not getattr(cfg, "mamba_chunked", True):
        return _mamba_prefill_scan(cfg, p, x, state)

    z = x @ p["in_z"]
    xin = x @ p["in_x"]
    bc = x @ p["in_bc"]
    dt_raw = x @ p["in_dt"]
    xbc = jnp.concatenate([xin, bc], axis=-1)

    K = cfg.ssm_conv
    hist = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)
    conv_w = jnp.concatenate([p["conv_wx"], p["conv_wbc"]], axis=-1)
    conv_b = jnp.concatenate([p["conv_bx"], p["conv_bbc"]], axis=-1)
    wins = jnp.stack([hist[:, i:i + S] for i in range(K)], axis=2)
    xbc_c = jnp.einsum("bskc,kc->bsc", wins.astype(jnp.float32),
                       conv_w.astype(jnp.float32)) + conv_b.astype(jnp.float32)
    xbc_c = jax.nn.silu(xbc_c)

    T = min(chunk, S)
    n_full = S // T
    rem = S - n_full * T
    if n_full > 1:
        def seg(a):
            return jnp.swapaxes(
                a[:, :n_full * T].reshape(B, n_full, T, a.shape[-1]), 0, 1)

        def step(S0, inp):
            xc, dtr = inp
            y, S_T = _ssd_chunk(cfg, p, xc[..., :di], xc[..., di:], dtr, S0)
            return S_T, y

        ssm, ys = jax.lax.scan(step, state["ssm"],
                               (seg(xbc_c), seg(dt_raw)))
        y_main = jnp.swapaxes(ys, 0, 1).reshape(B, n_full * T, di)
    else:
        y_main, ssm = _ssd_chunk(cfg, p, xbc_c[:, :n_full * T, :di],
                                 xbc_c[:, :n_full * T, di:],
                                 dt_raw[:, :n_full * T], state["ssm"])
    if rem:
        y_rem, ssm = _ssd_chunk(cfg, p, xbc_c[:, n_full * T:, :di],
                                xbc_c[:, n_full * T:, di:],
                                dt_raw[:, n_full * T:], ssm)
        y = jnp.concatenate([y_main, y_rem], axis=1)
    else:
        y = y_main

    y = cm.rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                   p["norm"], cfg.rmsnorm_eps)
    out = y @ p["out_proj"]
    return out, {"ssm": ssm,
                 "conv": hist[:, -(K - 1):, :] if K > 1 else hist[:, :0, :]}


def _mamba_prefill_scan(cfg, p, x, state):
    """Time-scan prefill (the correctness baseline)."""
    B, S, d = x.shape
    di, nh, hd, N = dims(cfg)

    z = x @ p["in_z"]                                           # (B,S,di)
    xin = x @ p["in_x"]
    bc = x @ p["in_bc"]
    dt_raw = x @ p["in_dt"]
    xbc = jnp.concatenate([xin, bc], axis=-1)

    # causal depthwise conv along time (parallel, not scanned)
    K = cfg.ssm_conv
    hist = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)
    conv_w = jnp.concatenate([p["conv_wx"], p["conv_wbc"]], axis=-1)
    conv_b = jnp.concatenate([p["conv_bx"], p["conv_bbc"]], axis=-1)
    wins = jnp.stack([hist[:, i:i + S] for i in range(K)], axis=2)  # (B,S,K,C)
    xbc_c = jnp.einsum("bskc,kc->bsc", wins.astype(jnp.float32),
                       conv_w.astype(jnp.float32)) + conv_b.astype(jnp.float32)
    xbc_c = jax.nn.silu(xbc_c)

    def step(ssm, inp):
        xbc_t, dt_t = inp
        y, ssm = _ssd_step(cfg, p, xbc_t[..., :di], xbc_t[..., di:],
                           dt_t, ssm)
        return ssm, y

    ssm, ys = jax.lax.scan(step, state["ssm"],
                           (jnp.swapaxes(xbc_c, 0, 1), jnp.swapaxes(dt_raw, 0, 1)))
    y = jnp.swapaxes(ys, 0, 1)                                  # (B,S,di)
    y = cm.rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                   p["norm"], cfg.rmsnorm_eps)
    out = y @ p["out_proj"]
    return out, {"ssm": ssm, "conv": hist[:, -(K - 1):, :] if K > 1 else hist[:, :0, :]}


def init_state(cfg, batch, dtype=jnp.bfloat16):
    di, nh, hd, N = dims(cfg)
    return {
        "ssm": jnp.zeros((batch, nh, hd, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), dtype),
    }
