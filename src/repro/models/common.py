"""Shared numeric building blocks: norms, RoPE, inits, online-softmax merge.

Everything is pure-functional jnp; params are nested dicts of arrays.
Per-layer parameter stacks (leading L axis) are built with vmap'd inits so
model stacks can ``lax.scan`` over layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # mask value (finite: avoids NaN from (-inf) - (-inf))


# --------------------------------------------------------------------------
# inits
# --------------------------------------------------------------------------
def dense_init(rng, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(rng, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(rng, vocab, d, dtype):
    return (jax.random.normal(rng, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def rmsnorm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention math (pure-jnp reference; Pallas kernels mirror this in kernels/)
# --------------------------------------------------------------------------
def gqa_scores(q, k):
    """q: (B, S, Hq, hd), k: (B, T, Hkv, hd) -> scores (B, Hq, S, T)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, S, Hkv, g, hd)
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    return s.reshape(B, Hq, S, k.shape[1])


def gqa_attend(q, k, v, mask, scale):
    """Reference masked attention.  mask: broadcastable (B, 1|Hq, S, T) bool."""
    s = gqa_scores(q, k) * scale
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    B, Hq, S, T = s.shape
    Hkv = v.shape[2]
    g = Hq // Hkv
    pg = p.reshape(B, Hkv, g, S, T)
    o = jnp.einsum("bkgst,btkd->bskgd", pg, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, v.shape[-1]).astype(v.dtype)


def gqa_attend_partial(q, k, v, mask, scale):
    """Attention partials for online-softmax merging (the paper's Eq.-1 split).

    Returns (o_unnormalized, m, l):
      m (B,Hq,S): running max; l (B,Hq,S): sum exp(s-m); o: sum exp(s-m) @ v.
    Merging partials from different units/shards:
      m* = max(m_i); l* = sum l_i e^{m_i-m*}; o* = sum o_i e^{m_i-m*}; out = o*/l*.
    """
    s = gqa_scores(q, k) * scale
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # (B,Hq,S)
    # all-masked rows: keep m finite
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    B, Hq, S, T = s.shape
    Hkv = v.shape[2]
    g = Hq // Hkv
    pg = p.reshape(B, Hkv, g, S, T)
    o = jnp.einsum("bkgst,btkd->bskgd", pg, v.astype(jnp.float32))
    o = o.reshape(B, S, Hq, v.shape[-1])
    return o, m_safe, l


def merge_partials_carry(carry, part):
    """Fold one (o, m, l) partial into an accumulator (blocked attention)."""
    o0, m0, l0 = carry
    o1, m1, l1 = part
    m_new = jnp.maximum(m0, m1)
    c0 = jnp.exp(m0 - m_new)
    c1 = jnp.exp(m1 - m_new)
    l_new = l0 * c0 + l1 * c1
    o_new = (o0 * jnp.transpose(c0, (0, 2, 1))[..., None]
             + o1 * jnp.transpose(c1, (0, 2, 1))[..., None])
    return o_new, m_new, l_new


def merge_partials(parts):
    """Merge a list of (o, m, l) online-softmax partials -> normalized output.

    o: (B,S,Hq,hd) fp32 unnormalized, m/l: (B,Hq,S).
    """
    ms = jnp.stack([m for _, m, _ in parts])                  # (P,B,Hq,S)
    m_star = jnp.max(ms, axis=0)
    o_star = 0.0
    l_star = 0.0
    for o, m, l in parts:
        corr = jnp.exp(m - m_star)                            # (B,Hq,S)
        l_star = l_star + l * corr
        o_star = o_star + o * jnp.transpose(corr, (0, 2, 1))[..., None]
    l_star = jnp.maximum(l_star, 1e-30)
    return o_star * (1.0 / jnp.transpose(l_star, (0, 2, 1))[..., None])


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def stack_init(rng, n, init_fn):
    """vmap an init over n layer rngs -> stacked params (leading axis n)."""
    return jax.vmap(init_fn)(jax.random.split(rng, n))


def layer_scan(cfg, body, carry, xs):
    """lax.scan over stacked layers, or a Python unroll when
    ``cfg.unroll_layers`` (dry-run cost-correction lowers: XLA's
    cost_analysis counts a while-loop body ONCE, so scanned stacks
    under-report FLOPs/bytes by ~L; launch/dryrun.py lowers unrolled L=1/L=2
    variants and extrapolates — see EXPERIMENTS.md §Roofline methodology)."""
    if not getattr(cfg, "unroll_layers", False):
        return jax.lax.scan(body, carry, xs)
    length = len(jax.tree_util.tree_leaves(xs)[0])
    ys = []
    for i in range(length):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    return carry, ys
