"""xLSTM model stack (sLSTM + mLSTM mix, unrolled — small configs only).

Pure recurrent: no KV cache; long_500k decode is O(1) in context length.
Tree verification uses per-path state replication (recurrent_verify).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLSTM, SLSTM
from repro.models import common as cm
from repro.models import recurrent_verify as rv
from repro.models import xlstm as xl
from repro.runtime.cache import Cache, XLSTMState


def init_params(cfg, rng):
    ks = jax.random.split(rng, cfg.num_layers + 2)
    dt = jnp.dtype(cfg.dtype)
    layers = []
    for i, kind in enumerate(cfg.blocks()):
        init = xl.slstm_init if kind == SLSTM else xl.mlstm_init
        layers.append({"ln": jnp.ones((cfg.d_model,), dt),
                       "block": init(cfg, ks[i])})
    return {
        "embed": cm.embed_init(ks[-2], cfg.padded_vocab, cfg.d_model, dt),
        "layers": tuple(layers),
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "lm_head": cm.dense_init(ks[-1], cfg.d_model, cfg.padded_vocab, dt),
    }


def _logits(cfg, params, x):
    return (cm.rmsnorm(x, params["ln_f"], cfg.rmsnorm_eps)
            @ params["lm_head"])[..., :cfg.vocab_size]


def init_cache(cfg, batch, max_len=0, *, window=0) -> Cache:
    sts = []
    for kind in cfg.blocks():
        if kind == SLSTM:
            sts.append(xl.slstm_init_state(cfg, batch))
        else:
            sts.append(xl.mlstm_init_state(cfg, batch))
    return Cache(xlstm=XLSTMState(layers=tuple(sts),
                                  pos=jnp.zeros((batch,), jnp.int32)))


def prefill(cfg, params, tokens=None, embeds=None, *, cache=None, window=0,
            max_len=None, return_cache=True, last_logits=False):
    x = params["embed"][tokens] if embeds is None else embeds
    B, S, _ = x.shape
    if cache is None:
        cache = init_cache(cfg, B)
    new_states = []
    for lp, kind, st in zip(params["layers"], cfg.blocks(),
                            cache.xlstm.layers):
        h = cm.rmsnorm(x, lp["ln"], cfg.rmsnorm_eps)
        fn = xl.slstm_prefill if kind == SLSTM else xl.mlstm_prefill
        y, st = fn(cfg, lp["block"], h, st)
        x = x + y
        new_states.append(st)
    pos = cache.xlstm.pos + S
    return (_logits(cfg, params, x[:, -1:] if last_logits else x),
            {"aux_loss": jnp.zeros((), jnp.float32), "hidden": x},
            Cache(xlstm=XLSTMState(layers=tuple(new_states), pos=pos)))


def verify(cfg, params, cache: Cache, tree_tokens, tree_depth, tree_mask,
           *, paths=None, node_path=None, node_depth=None, backend="ref"):
    x = params["embed"][tree_tokens]
    B, W, _ = x.shape
    P, D = paths.shape
    depth_states = []
    for lp, kind, st in zip(params["layers"], cfg.blocks(),
                            cache.xlstm.layers):
        step = xl.slstm_step if kind == SLSTM else xl.mlstm_step

        def step_fn(x_t, s, _p=lp["block"], _step=step):
            return _step(cfg, _p, x_t, s)

        h = cm.rmsnorm(x, lp["ln"], cfg.rmsnorm_eps)
        y_nodes, sts = rv.path_verify(step_fn, h, st, paths,
                                      node_path, node_depth)
        x = x + y_nodes
        depth_states.append(sts)
    return _logits(cfg, params, x), {"depth_states": tuple(depth_states),
                                     "P": P, "B": B, "hidden": x}


def decode(cfg, params, cache: Cache, tokens, *, backend="ref"):
    B = tokens.shape[0]
    logits, extras = verify(
        cfg, params, cache, tokens,
        tree_depth=jnp.zeros((1,), jnp.int32),
        tree_mask=jnp.ones((1, 1), bool),
        paths=jnp.zeros((1, 1), jnp.int32),
        node_path=jnp.zeros((1,), jnp.int32),
        node_depth=jnp.zeros((1,), jnp.int32))
    cache = commit(cfg, cache, extras,
                   accept_nodes=jnp.zeros((B, 1), jnp.int32),
                   n_accept=jnp.ones((B,), jnp.int32),
                   path_idx=jnp.zeros((B,), jnp.int32), max_depth=1)
    return logits, cache


def commit(cfg, cache: Cache, extras, accept_nodes, n_accept, path_idx,
           max_depth):
    """n_accept/path_idx: (B,) per-sequence acceptance and accepted path.

    n_accept == 0 (a frozen row, see spec_step's ``active`` mask) commits
    nothing: the depth select clamps n-1 = -1 to depth 0, so those rows
    keep their previous state instead."""
    B, P = extras["B"], extras["P"]
    keep = n_accept > 0

    def _freeze(new, old):
        return jax.tree_util.tree_map(
            lambda n_, o_: jnp.where(
                keep.reshape((B,) + (1,) * (n_.ndim - 1)), n_, o_),
            new, old)

    new_layers = tuple(
        _freeze(rv.select_committed_state(sts, path_idx, n_accept, B, P),
                old)
        for sts, old in zip(extras["depth_states"], cache.xlstm.layers))
    return Cache(xlstm=XLSTMState(layers=new_layers,
                                  pos=cache.xlstm.pos + n_accept))
