"""Pallas TPU kernel: fused tree-verification attention (dense and paged).

The Ghidorah dense/sparse split, TPU-native (DESIGN.md §2): W draft queries
attend to the KV cache (dense part, tiled over KV blocks in VMEM) and to the
W fresh tree KVs under the ancestor mask (sparse part, VMEM-resident), with
a single online-softmax accumulator carried across the grid — the kernel
form of the paper's Eq.-1 online-softmax merge.

Layout: one (batch, kv-head) pair per grid row; queries are grouped
(G = Hq/Hkv rows per kv head) so the score matmul is (G*W, hd) x (hd, BS) —
MXU-aligned when BS and hd are multiples of 128 and G*W of 8.

Grid: (B, Hkv, nblocks+1); the last block handles the tree part and the
normalization + writeback.  Scratch (o, m, l) persists across the KV-block
axis (sequential minor-most grid dimension on TPU).

Paged variant (``paged_tree_attention``): the KV blocks live in a SHARED
page pool ``(n_pages + 1, page_size, Hkv, hd)`` instead of per-sequence
rows.  The grid's KV axis loops over a sequence's *logical* pages and the
block table rides in as a scalar-prefetch argument, so the index map DMAs
physical page ``table[b, i]`` for grid step ``i`` — unreserved entries are
pre-clamped to the trailing trash page, whose slots carry ``key_pos == -1``
and mask to zero weight.  The kernel body is byte-for-byte the dense one;
only the BlockSpec index maps change.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_S = 512


def _kernel(q_ref, ck_ref, cv_ref, kn_ref, vn_ref, kpos_ref, qpos_ref,
            lo_ref, mask_ref, o_ref, o_acc, m_acc, l_acc, *, nblocks, scale,
            sk_ref=None, sv_ref=None):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)

    q = q_ref[0, 0].astype(jnp.float32)            # (GW, hd)
    GW = q.shape[0]
    W = qpos_ref.shape[1]
    G = GW // W

    def online_update(s, v, valid):
        """s: (GW, T) scores; v: (T, hd); valid: (GW, T) bool."""
        s = jnp.where(valid, s * scale, NEG_INF)
        m_new = jnp.maximum(m_acc[...], jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_acc[...] - m_new)
        l_acc[...] = l_acc[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        o_acc[...] = o_acc[...] * corr + p @ v
        m_acc[...] = m_new

    @pl.when(i < nblocks)
    def _cache_block():
        k = ck_ref[0, :, 0].astype(jnp.float32)    # (BS, hd)
        v = cv_ref[0, :, 0].astype(jnp.float32)
        if sk_ref is not None:
            # fused dequant: this page's per-(layer, head) scale arrived on
            # the same scalar-prefetch walk as the page index (1.0 for
            # float pools, so the multiply is exact there)
            k = k * sk_ref[0, 0]
            v = v * sv_ref[0, 0]
        kpos = kpos_ref[0]                         # (BS,) this sequence's row
        qpos = qpos_ref[0]                         # (W,)
        lo = lo_ref[0]
        ok = ((kpos[None, :] >= 0)
              & (kpos[None, :] <= qpos[:, None])
              & (kpos[None, :] > lo[:, None]))     # (W, BS)
        ok = jnp.broadcast_to(ok[None], (G, W, ok.shape[1])).reshape(GW, -1)
        online_update(q @ k.T, v, ok)

    @pl.when(i == nblocks)
    def _tree_block():
        k = kn_ref[0, :, 0].astype(jnp.float32)    # (W, hd)
        v = vn_ref[0, :, 0].astype(jnp.float32)
        tm = mask_ref[...]                         # (W, W) bool
        ok = jnp.broadcast_to(tm[None], (G,) + tm.shape).reshape(GW, -1)
        online_update(q @ k.T, v, ok)
        l_safe = jnp.maximum(l_acc[...], 1e-30)
        o_ref[0, 0] = (o_acc[...] / l_safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def tree_attention(q, ck, cv, k_new, v_new, key_pos, q_pos, lo, tree_mask,
                   *, block_s=DEFAULT_BLOCK_S, interpret=True):
    """See ref.tree_attention_ref for semantics.  q: (B, W, Hq, hd);
    key_pos: (B, S); q_pos/lo: (B, W) — per-sequence position rows (batched
    speculative decoding leaves each sequence at its own absolute position)."""
    B, W, Hq, hd = q.shape
    S, Hkv = ck.shape[1], ck.shape[2]
    G = Hq // Hkv

    # pad cache length to a block multiple; padded slots get key_pos = -1
    bs = min(block_s, max(S, 1))
    pad = (-S) % bs
    if pad:
        ck = jnp.pad(ck, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(cv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        key_pos = jnp.pad(key_pos, ((0, 0), (0, pad)), constant_values=-1)
    nblocks = (S + pad) // bs

    # regroup queries: (B, Hkv, G*W, hd)
    qg = q.reshape(B, W, Hkv, G, hd).transpose(0, 2, 3, 1, 4).reshape(
        B, Hkv, G * W, hd)
    # cache: (B, S, Hkv, hd) kept as-is; block over S
    kn = k_new                                      # (B, W, Hkv, hd)

    grid = (B, Hkv, nblocks + 1)
    out = pl.pallas_call(
        functools.partial(_kernel, nblocks=nblocks, scale=hd ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G * W, hd), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, i, _n=nblocks: (b, jnp.minimum(i, _n - 1), h, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda b, h, i, _n=nblocks: (b, jnp.minimum(i, _n - 1), h, 0)),
            pl.BlockSpec((1, W, 1, hd), lambda b, h, i: (b, 0, h, 0)),
            pl.BlockSpec((1, W, 1, hd), lambda b, h, i: (b, 0, h, 0)),
            pl.BlockSpec((1, bs),
                         lambda b, h, i, _n=nblocks: (b, jnp.minimum(i, _n - 1))),
            pl.BlockSpec((1, W), lambda b, h, i: (b, 0)),
            pl.BlockSpec((1, W), lambda b, h, i: (b, 0)),
            pl.BlockSpec((W, W), lambda b, h, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G * W, hd), lambda b, h, i: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G * W, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * W, hd), jnp.float32),   # o accumulator
            pltpu.VMEM((G * W, 1), jnp.float32),    # running max m
            pltpu.VMEM((G * W, 1), jnp.float32),    # running sum l
        ],
        interpret=interpret,
    )(qg, ck, cv, kn, v_new, key_pos, q_pos, lo, tree_mask)
    # regroup back: (B, W, Hq, hd)
    return out.reshape(B, Hkv, G, W, hd).transpose(0, 3, 1, 2, 4).reshape(
        B, W, Hq, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_tree_attention(q, pool_k, pool_v, scale_k, scale_v, k_new, v_new,
                         block_table, key_pos, q_pos, lo, tree_mask, *,
                         interpret=True):
    """Paged tree-verification attention: the KV-block grid axis walks a
    sequence's block table instead of a dense row.

    q: (B, W, Hq, hd); pool_k/pool_v: (n_pages + 1, ps, Hkv, hd) one
    layer's shared pool, trash page last; scale_k/scale_v: (n_pages + 1,
    Hkv) per-page dequant scales — all-ones for float pools, so the fused
    multiply is exact there; block_table: (B, max_pages) int32 (-1 =
    unreserved); key_pos: (B, max_pages * ps); q_pos/lo: (B, W).
    One KV "block" is one page (block_s == page_size): grid step i of row b
    fetches physical page ``table[b, i]`` via scalar prefetch, and the
    page's (1, 1) scale block rides the same table-driven index map.
    """
    B, W, Hq, hd = q.shape
    P, ps, Hkv = pool_k.shape[0], pool_k.shape[1], pool_k.shape[2]
    maxp = block_table.shape[1]
    G = Hq // Hkv
    # unreserved logical pages fetch the trash page; their slots are
    # key_pos == -1, so the validity mask zeroes them
    tbl = jnp.where(block_table < 0, P - 1, block_table).astype(jnp.int32)

    qg = q.reshape(B, W, Hkv, G, hd).transpose(0, 2, 3, 1, 4).reshape(
        B, Hkv, G * W, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, maxp + 1),
        in_specs=[
            pl.BlockSpec((1, 1, G * W, hd), lambda b, h, i, t: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, t, _n=maxp:
                         (t[b, jnp.minimum(i, _n - 1)], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, t, _n=maxp:
                         (t[b, jnp.minimum(i, _n - 1)], 0, h, 0)),
            pl.BlockSpec((1, 1),
                         lambda b, h, i, t, _n=maxp:
                         (t[b, jnp.minimum(i, _n - 1)], h)),
            pl.BlockSpec((1, 1),
                         lambda b, h, i, t, _n=maxp:
                         (t[b, jnp.minimum(i, _n - 1)], h)),
            pl.BlockSpec((1, W, 1, hd), lambda b, h, i, t: (b, 0, h, 0)),
            pl.BlockSpec((1, W, 1, hd), lambda b, h, i, t: (b, 0, h, 0)),
            pl.BlockSpec((1, ps),
                         lambda b, h, i, t, _n=maxp:
                         (b, jnp.minimum(i, _n - 1))),
            pl.BlockSpec((1, W), lambda b, h, i, t: (b, 0)),
            pl.BlockSpec((1, W), lambda b, h, i, t: (b, 0)),
            pl.BlockSpec((W, W), lambda b, h, i, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G * W, hd),
                               lambda b, h, i, t: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G * W, hd), jnp.float32),
            pltpu.VMEM((G * W, 1), jnp.float32),
            pltpu.VMEM((G * W, 1), jnp.float32),
        ],
    )

    def kernel(tbl_ref, q_ref, ck_ref, cv_ref, sk_ref, sv_ref, kn_ref,
               vn_ref, kpos_ref, qpos_ref, lo_ref, mask_ref, o_ref,
               o_acc, m_acc, l_acc):
        # table only drives the index maps; the body is the dense kernel
        # with the per-page dequant scales threaded in
        _kernel(q_ref, ck_ref, cv_ref, kn_ref, vn_ref, kpos_ref, qpos_ref,
                lo_ref, mask_ref, o_ref, o_acc, m_acc, l_acc,
                nblocks=maxp, scale=hd ** -0.5,
                sk_ref=sk_ref, sv_ref=sv_ref)

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G * W, hd), q.dtype),
        interpret=interpret,
    )(tbl, qg, pool_k, pool_v, scale_k.astype(jnp.float32),
      scale_v.astype(jnp.float32), k_new, v_new, key_pos, q_pos,
      lo, tree_mask)
    return out.reshape(B, Hkv, G, W, hd).transpose(0, 3, 1, 2, 4).reshape(
        B, W, Hq, hd)


def _cache_partial_kernel(q_ref, ck_ref, cv_ref, sk_ref, sv_ref, kpos_ref,
                          qpos_ref, lo_ref, o_ref, o_acc, m_acc, l_acc, *,
                          nblocks, scale):
    """Cache-only half of the verify attention, emitting UNNORMALIZED
    online-softmax partials packed into one (G*W, hd + 2) block — o in
    [:, :hd], running max m at [:, hd], sum l at [:, hd + 1].  Packing into
    a single output keeps the wrapper a one-``pallas_call``/one-BlockSpec
    shape the R8 bounds extractor can verify; the wrapper unpacks to the
    ``cm.merge_partials`` layout so the sparse tree half (or a sequence
    shard) merges with the usual Eq.-1 rule."""
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)

    q = q_ref[0, 0].astype(jnp.float32)            # (GW, hd)
    GW = q.shape[0]
    W = qpos_ref.shape[1]
    G = GW // W
    k = ck_ref[0, :, 0].astype(jnp.float32) * sk_ref[0, 0]
    v = cv_ref[0, :, 0].astype(jnp.float32) * sv_ref[0, 0]
    kpos = kpos_ref[0]
    qpos = qpos_ref[0]
    lo = lo_ref[0]
    ok = ((kpos[None, :] >= 0)
          & (kpos[None, :] <= qpos[:, None])
          & (kpos[None, :] > lo[:, None]))         # (W, ps)
    ok = jnp.broadcast_to(ok[None], (G, W, ok.shape[1])).reshape(GW, -1)
    s = jnp.where(ok, (q @ k.T) * scale, NEG_INF)
    m_new = jnp.maximum(m_acc[...], jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_acc[...] - m_new)
    l_acc[...] = l_acc[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    o_acc[...] = o_acc[...] * corr + p @ v
    m_acc[...] = m_new

    @pl.when(i == nblocks - 1)
    def _emit():
        # all-masked rows: clamp m like the oracle's m_safe so partials
        # compare exactly (l stays 0, so the merge ignores them anyway)
        m_safe = jnp.maximum(m_acc[...], NEG_INF / 2)
        o_ref[0, 0] = jnp.concatenate([o_acc[...], m_safe, l_acc[...]],
                                      axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_cache_attention(q, pool_k, pool_v, scale_k, scale_v, block_table,
                          key_pos, q_pos, lo, *, interpret=True):
    """Cache-only paged page walk (the dense half of the verify split when
    the W×W tree half runs as ``sparse_tree_attention_partial``).

    Same operands as ``paged_tree_attention`` minus the tree ones; the grid
    is (B, Hkv, max_pages) — no trailing tree block.  Returns merge
    partials ``(o (B, W, Hq, hd) f32 unnormalized, m (B, Hq, W),
    l (B, Hq, W))`` in the ``cm.merge_partials`` layout.
    """
    B, W, Hq, hd = q.shape
    P, ps, Hkv = pool_k.shape[0], pool_k.shape[1], pool_k.shape[2]
    maxp = block_table.shape[1]
    G = Hq // Hkv
    tbl = jnp.where(block_table < 0, P - 1, block_table).astype(jnp.int32)
    qg = q.reshape(B, W, Hkv, G, hd).transpose(0, 2, 3, 1, 4).reshape(
        B, Hkv, G * W, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, G * W, hd), lambda b, h, i, t: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, t: (t[b, i], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, i, t: (t[b, i], 0, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, i, t: (t[b, i], h)),
            pl.BlockSpec((1, 1), lambda b, h, i, t: (t[b, i], h)),
            pl.BlockSpec((1, ps), lambda b, h, i, t: (b, i)),
            pl.BlockSpec((1, W), lambda b, h, i, t: (b, 0)),
            pl.BlockSpec((1, W), lambda b, h, i, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G * W, hd + 2),
                               lambda b, h, i, t: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G * W, hd), jnp.float32),
            pltpu.VMEM((G * W, 1), jnp.float32),
            pltpu.VMEM((G * W, 1), jnp.float32),
        ],
    )

    def kernel(tbl_ref, *refs):
        _cache_partial_kernel(*refs, nblocks=maxp, scale=hd ** -0.5)

    packed = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G * W, hd + 2), jnp.float32),
        interpret=interpret,
    )(tbl, qg, pool_k, pool_v, scale_k.astype(jnp.float32),
      scale_v.astype(jnp.float32), key_pos, q_pos, lo)
    pk = packed.reshape(B, Hkv, G, W, hd + 2)
    o = pk[..., :hd].transpose(0, 3, 1, 2, 4).reshape(B, W, Hq, hd)
    m = pk[..., hd].reshape(B, Hkv * G, W)
    l = pk[..., hd + 1].reshape(B, Hkv * G, W)
    return o, m, l
