"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; TPU is the
compile target).  Set ``repro.kernels.ops.INTERPRET = False`` on real TPU.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import sparse_tree as _sparse
from repro.kernels import tree_attention as _tree

INTERPRET = True


def tree_attention(q, ck, cv, k_new, v_new, key_pos, pos, tree_depth,
                   tree_mask, *, window=0, block_s=None):
    """Signature used by models/attention.py (backend="pallas").

    ``pos`` is () or (B,) and ``key_pos`` (S,) or (B, S): sequences sit at
    different absolute positions once batched speculative commits diverge,
    so the kernel takes per-batch ``q_pos``/``lo`` rows.
    """
    B = q.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    key_pos_b = jnp.broadcast_to(key_pos, (B, ck.shape[1]))
    q_pos = pos_b[:, None] + tree_depth[None, :].astype(jnp.int32)  # (B, W)
    if window:
        lo = q_pos - window
    else:
        lo = jnp.full_like(q_pos, -1)
    kwargs = {"interpret": INTERPRET}
    if block_s:
        kwargs["block_s"] = block_s
    return _tree.tree_attention(q, ck, cv, k_new, v_new, key_pos_b, q_pos,
                                lo, tree_mask, **kwargs)


def _pool_scales(pool_k, scale_k, scale_v):
    """Resolve the per-page dequant scale operands: the caller's tensors
    for a quantized pool, all-ones for a float pool (exact multiply), so
    the kernels keep ONE pallas_call shape either way."""
    if scale_k is None:
        ones = jnp.ones((pool_k.shape[0], pool_k.shape[2]), jnp.float32)
        return ones, ones
    return scale_k, scale_v


def paged_tree_attention(q, pool_k, pool_v, k_new, v_new, block_table,
                         key_pos, pos, tree_depth, tree_mask, *,
                         scale_k=None, scale_v=None):
    """Paged-cache verification path (models/attention.py, paged engines).

    pool_k/pool_v are ONE layer's shared page pool ``(n_pages + 1, ps,
    Hkv, hd)`` (trash page last); block_table/key_pos/pos are the
    per-sequence rows.  ``scale_k/scale_v (n_pages + 1, Hkv)`` are the
    int8 pool's per-page dequant scales (None = float pool).  Windowed
    attention is dense-only (the ring IS the window), so there is no
    ``window`` here.
    """
    B = q.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q_pos = pos_b[:, None] + tree_depth[None, :].astype(jnp.int32)  # (B, W)
    lo = jnp.full_like(q_pos, -1)
    sk, sv = _pool_scales(pool_k, scale_k, scale_v)
    return _tree.paged_tree_attention(q, pool_k, pool_v, sk, sv, k_new,
                                      v_new, block_table, key_pos, q_pos,
                                      lo, tree_mask, interpret=INTERPRET)


def paged_cache_attention(q, pool_k, pool_v, block_table, key_pos, pos,
                          tree_depth, *, scale_k=None, scale_v=None):
    """Cache-only half of the paged verify split (``tree_kernel=sparse``):
    the quantized page walk WITHOUT the tree block.  Returns ``(o, m, l)``
    merge partials; the caller merges them with the
    ``sparse_tree_attention_partial`` tree half."""
    B = q.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q_pos = pos_b[:, None] + tree_depth[None, :].astype(jnp.int32)  # (B, W)
    lo = jnp.full_like(q_pos, -1)
    sk, sv = _pool_scales(pool_k, scale_k, scale_v)
    return _tree.paged_cache_attention(q, pool_k, pool_v, sk, sv,
                                       block_table, key_pos, q_pos, lo,
                                       interpret=INTERPRET)


def decode_attention(q, ck, cv, k_new, v_new, key_pos, pos, *, window=0):
    """Plain decode = W=1 tree."""
    return tree_attention(q, ck, cv, k_new, v_new, key_pos, pos,
                          jnp.zeros((1,), jnp.int32),
                          jnp.ones((1, 1), bool), window=window)


def sparse_tree_attention(q, k_new, v_new, tree_mask, *, backend="pallas",
                          interpret=None):
    """W×W tree-correlation attention (sparse part only).

    Dispatches per ``backend`` like ``attn_verify`` does — ``"ref"`` runs
    the jnp oracle, ``"pallas"`` the block-masked kernel — instead of
    hardcoding the kernel's interpret default; ``interpret=None`` resolves
    to the module-level ``INTERPRET`` platform switch.
    """
    if backend == "ref":
        from repro.kernels import ref as _ref
        return _ref.sparse_tree_ref(q, k_new, v_new, tree_mask)
    return _sparse.sparse_tree_attention(
        q, k_new, v_new, tree_mask,
        interpret=INTERPRET if interpret is None else interpret)


def sparse_tree_attention_partial(q, k_new, v_new, tree_mask):
    """Tree half of the split verify path: UNNORMALIZED ``(o, m, l)``
    merge partials of the W×W masked tree attention (merged with the
    ``paged_cache_attention`` page walk by the caller)."""
    return _sparse.sparse_tree_attention_partial(q, k_new, v_new, tree_mask,
                                                 interpret=INTERPRET)
