"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; TPU is the
compile target).  Set ``repro.kernels.ops.INTERPRET = False`` on real TPU.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import sparse_tree as _sparse
from repro.kernels import tree_attention as _tree

INTERPRET = True


def tree_attention(q, ck, cv, k_new, v_new, key_pos, pos, tree_depth,
                   tree_mask, *, window=0, block_s=None):
    """Signature used by models/attention.py (backend="pallas").

    ``pos`` is () or (B,) and ``key_pos`` (S,) or (B, S): sequences sit at
    different absolute positions once batched speculative commits diverge,
    so the kernel takes per-batch ``q_pos``/``lo`` rows.
    """
    B = q.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    key_pos_b = jnp.broadcast_to(key_pos, (B, ck.shape[1]))
    q_pos = pos_b[:, None] + tree_depth[None, :].astype(jnp.int32)  # (B, W)
    if window:
        lo = q_pos - window
    else:
        lo = jnp.full_like(q_pos, -1)
    kwargs = {"interpret": INTERPRET}
    if block_s:
        kwargs["block_s"] = block_s
    return _tree.tree_attention(q, ck, cv, k_new, v_new, key_pos_b, q_pos,
                                lo, tree_mask, **kwargs)


def paged_tree_attention(q, pool_k, pool_v, k_new, v_new, block_table,
                         key_pos, pos, tree_depth, tree_mask):
    """Paged-cache verification path (models/attention.py, paged engines).

    pool_k/pool_v are ONE layer's shared page pool ``(n_pages + 1, ps,
    Hkv, hd)`` (trash page last); block_table/key_pos/pos are the
    per-sequence rows.  Windowed attention is dense-only (the ring IS the
    window), so there is no ``window`` here.
    """
    B = q.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q_pos = pos_b[:, None] + tree_depth[None, :].astype(jnp.int32)  # (B, W)
    lo = jnp.full_like(q_pos, -1)
    return _tree.paged_tree_attention(q, pool_k, pool_v, k_new, v_new,
                                      block_table, key_pos, q_pos, lo,
                                      tree_mask, interpret=INTERPRET)


def decode_attention(q, ck, cv, k_new, v_new, key_pos, pos, *, window=0):
    """Plain decode = W=1 tree."""
    return tree_attention(q, ck, cv, k_new, v_new, key_pos, pos,
                          jnp.zeros((1,), jnp.int32),
                          jnp.ones((1, 1), bool), window=window)


def sparse_tree_attention(q, k_new, v_new, tree_mask):
    return _sparse.sparse_tree_attention(q, k_new, v_new, tree_mask,
                                         interpret=INTERPRET)
