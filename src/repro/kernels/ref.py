"""Pure-jnp oracles for the Pallas kernels (the allclose targets).

These mirror the models/attention.py reference math but with the exact
argument layout the kernels take, so tests can sweep shapes/dtypes and
assert kernel == oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm


def tree_attention_ref(q, ck, cv, k_new, v_new, key_pos, q_pos, lo,
                       tree_mask):
    """Fused dense(cache)+sparse(tree) verification attention.

    q:        (B, W, Hq, hd)
    ck, cv:   (B, S, Hkv, hd)   KV cache
    k_new:    (B, W, Hkv, hd)   fresh tree KVs
    key_pos:  (B, S) int32      absolute position per cache slot (-1 empty)
    q_pos:    (B, W) int32      absolute position per query node
    lo:       (B, W) int32      window lower bound per query (-1 = no window)
    tree_mask:(W, W) bool       ancestor-or-self
    returns   (B, W, Hq, hd) in q.dtype

    1-D ``key_pos``/``q_pos``/``lo`` (shared across the batch) are broadcast.
    """
    B, W = q.shape[:2]
    key_pos = jnp.broadcast_to(key_pos, (B, ck.shape[1]))
    q_pos = jnp.broadcast_to(q_pos, (B, W))
    lo = jnp.broadcast_to(lo, (B, W))
    scale = q.shape[-1] ** -0.5
    cache_ok = ((key_pos[:, None, :] >= 0)
                & (key_pos[:, None, :] <= q_pos[:, :, None])
                & (key_pos[:, None, :] > lo[:, :, None]))      # (B, W, S)
    dense = cm.gqa_attend_partial(q, ck, cv, cache_ok[:, None], scale)
    sparse = cm.gqa_attend_partial(q, k_new, v_new,
                                   tree_mask[None, None], scale)
    return cm.merge_partials([dense, sparse]).astype(q.dtype)


def paged_tree_attention_ref(q, pool_k, pool_v, scale_k, scale_v, k_new,
                             v_new, block_table, key_pos, q_pos, lo,
                             tree_mask):
    """Paged oracle: gather each sequence's pages into the logical
    (B, S_logical, Hkv, hd) view (dequantizing through the per-page scales
    — all-ones for float pools), then run the dense oracle.

    pool_k/pool_v: (n_pages + 1, ps, Hkv, hd) ONE layer's pool (trash page
    last); scale_k/scale_v: (n_pages + 1, Hkv) per-page dequant scales (or
    None for a verbatim float gather); block_table: (B, max_pages) with -1
    = unreserved (reads the trash page; those slots carry key_pos == -1 so
    every mask rejects them); key_pos: (B, max_pages * ps).
    """
    from repro.runtime.cache import gather_pages_dequant
    ck = gather_pages_dequant(pool_k, scale_k, block_table)
    cv = gather_pages_dequant(pool_v, scale_v, block_table)
    return tree_attention_ref(q, ck, cv, k_new, v_new, key_pos, q_pos, lo,
                              tree_mask)


def paged_cache_attention_ref(q, pool_k, pool_v, scale_k, scale_v,
                              block_table, key_pos, q_pos, lo):
    """Cache-only-half oracle: the paged gather + dense partial, returning
    the same ``(o, m, l)`` merge partials as the kernel wrapper."""
    from repro.runtime.cache import gather_pages_dequant
    ck = gather_pages_dequant(pool_k, scale_k, block_table)
    cv = gather_pages_dequant(pool_v, scale_v, block_table)
    B, W = q.shape[:2]
    key_pos = jnp.broadcast_to(key_pos, (B, ck.shape[1]))
    q_pos = jnp.broadcast_to(q_pos, (B, W))
    lo = jnp.broadcast_to(lo, (B, W))
    scale = q.shape[-1] ** -0.5
    cache_ok = ((key_pos[:, None, :] >= 0)
                & (key_pos[:, None, :] <= q_pos[:, :, None])
                & (key_pos[:, None, :] > lo[:, :, None]))       # (B, W, S)
    return cm.gqa_attend_partial(q, ck, cv, cache_ok[:, None], scale)


def decode_attention_ref(q, ck, cv, k_new, v_new, key_pos, q_pos, lo):
    """W=1 special case (plain decode)."""
    W = q.shape[1]
    assert W == 1
    return tree_attention_ref(q, ck, cv, k_new, v_new, key_pos, q_pos, lo,
                              jnp.ones((1, 1), bool))


def sparse_tree_ref(q, k_new, v_new, tree_mask):
    """Sparse-part-only oracle (paper Fig. 10b comparisons): masked softmax
    attention among the W tree tokens.  Returns normalized output."""
    scale = q.shape[-1] ** -0.5
    return cm.gqa_attend(q, k_new, v_new, tree_mask[None, None], scale)


def sparse_tree_attention_partial_ref(q, k_new, v_new, tree_mask):
    """Tree-half oracle for the split verify path: UNNORMALIZED ``(o, m,
    l)`` merge partials of the W×W masked tree attention."""
    scale = q.shape[-1] ** -0.5
    return cm.gqa_attend_partial(q, k_new, v_new, tree_mask[None, None],
                                 scale)
