"""Pallas kernel: sparse-part-only tree attention (block-masked).

TPU-native counterpart of the paper's ARM COO SpMM (§III-B3): instead of
scalar gather/FMA over COO indices (which would idle the MXU), the W×W tree
correlation is computed as one VMEM-resident masked matmul.  Benchmarked in
benchmarks/sparse.py against (a) the naive per-element oracle and (b) the
dense-with-mask-over-everything strategy, mirroring Fig. 10b.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, scale):
    q = q_ref[0, 0].astype(jnp.float32)            # (GW, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (W, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    tm = mask_ref[...]                             # (W, W)
    GW = q.shape[0]
    W = tm.shape[0]
    G = GW // W
    ok = jnp.broadcast_to(tm[None], (G, W, W)).reshape(GW, W)
    s = jnp.where(ok, (q @ k.T) * scale, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(ok, jnp.exp(s - m), 0.0)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o_ref[0, 0] = ((p @ v) / l).astype(o_ref.dtype)


def _partial_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, scale):
    """Same masked matmul, emitting UNNORMALIZED online-softmax partials
    packed into one (G*W, hd + 2) block — o in [:, :hd], running max m at
    [:, hd], sum l at [:, hd + 1] — so the tree half merges with the paged
    cache walk (``tree_attention.paged_cache_attention``) via the Eq.-1
    rule instead of being its own softmax island."""
    q = q_ref[0, 0].astype(jnp.float32)            # (GW, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (W, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)
    tm = mask_ref[...]                             # (W, W)
    GW = q.shape[0]
    W = tm.shape[0]
    G = GW // W
    ok = jnp.broadcast_to(tm[None], (G, W, W)).reshape(GW, W)
    s = jnp.where(ok, (q @ k.T) * scale, NEG_INF)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), NEG_INF / 2)
    p = jnp.where(ok, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.concatenate([p @ v, m, l], axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_tree_attention_partial(q, k_new, v_new, tree_mask, *,
                                  interpret=True):
    """q: (B, W, Hq, hd); returns merge partials ``(o (B, W, Hq, hd) f32
    unnormalized, m (B, Hq, W), l (B, Hq, W))`` in the
    ``cm.merge_partials`` layout (the W×W tree half of the split verify
    path)."""
    B, W, Hq, hd = q.shape
    Hkv = k_new.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, W, Hkv, G, hd).transpose(0, 2, 3, 1, 4).reshape(
        B, Hkv, G * W, hd)
    packed = pl.pallas_call(
        functools.partial(_partial_kernel, scale=hd ** -0.5),
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, G * W, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, W, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, W, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((W, W), lambda b, h: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G * W, hd + 2),
                               lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G * W, hd + 2), jnp.float32),
        interpret=interpret,
    )(qg, k_new, v_new, tree_mask)
    pk = packed.reshape(B, Hkv, G, W, hd + 2)
    o = pk[..., :hd].transpose(0, 3, 1, 2, 4).reshape(B, W, Hq, hd)
    m = pk[..., hd].reshape(B, Hkv * G, W)
    l = pk[..., hd + 1].reshape(B, Hkv * G, W)
    return o, m, l


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_tree_attention(q, k_new, v_new, tree_mask, *, interpret=True):
    """q: (B, W, Hq, hd); returns (B, W, Hq, hd) — sparse part only."""
    B, W, Hq, hd = q.shape
    Hkv = k_new.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, W, Hkv, G, hd).transpose(0, 2, 3, 1, 4).reshape(
        B, Hkv, G * W, hd)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=hd ** -0.5),
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, G * W, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, W, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, W, 1, hd), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((W, W), lambda b, h: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G * W, hd), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G * W, hd), q.dtype),
        interpret=interpret,
    )(qg, k_new, v_new, tree_mask)
    return out.reshape(B, Hkv, G, W, hd).transpose(0, 3, 1, 2, 4).reshape(
        B, W, Hq, hd)
