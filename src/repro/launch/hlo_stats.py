"""Parse collective-communication bytes out of (post-SPMD) HLO text.

cost_analysis() has no collective term, so §Roofline's collective_bytes
comes from summing the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute in the compiled module.
The compiled module is per-device, so byte counts are per-device.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[2,1024,320]{2,1,0} all-gather(...)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op_name: bytes, ..., 'total': bytes} (per device)."""
    out = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=", 1)[0]:
            continue                      # avoid double-counting async pairs
        shape_str, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(shape_str)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def hlo_op_histogram(hlo_text: str, top: int = 15) -> dict:
    """Rough opcode histogram (perf-iteration diffing aid)."""
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z0-9-]+)\(", line)
        if m:
            counts[m.group(1)] += 1
    return dict(sorted(counts.items(), key=lambda kv: -kv[1])[:top])
