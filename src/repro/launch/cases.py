"""Dry-run case construction: (arch × input-shape × TP-mode) -> a jittable
step function + ShapeDtypeStruct arguments + NamedShardings.

No device memory is ever allocated here: params/caches/batches are
``jax.eval_shape`` structs (weak-type-correct, shardable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.core.hcmp import sharding as shd
from repro.launch.mesh import data_axes
from repro.models import encdec, hybrid, xlstm_model
from repro.models.api import get_model
from repro.runtime.cache import Cache, init_kv_cache
from repro.training.optimizer import AdamWState, adamw_init
from repro.training.train import train_step


def decode_window(cfg, shape) -> int:
    """Sliding window is engaged only for the long-context decode shape."""
    if shape.seq_len > 32_768 and cfg.sliding_window:
        return cfg.sliding_window
    if cfg.name.startswith("llava"):
        return cfg.sliding_window          # Mistral's window is native
    return 0


def supports(cfg, shape) -> tuple[bool, str]:
    """long_500k requires sub-quadratic decode (window or recurrent state)."""
    if shape.name == "long_500k":
        if cfg.is_pure_recurrent or cfg.is_recurrent or cfg.sliding_window:
            return True, ""
        return False, "full-attention arch without sliding window"
    return True, ""


# --------------------------------------------------------------------------
def _batch_struct(cfg, shape):
    B = shape.global_batch
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        S = shape.seq_len - (cfg.num_frontend_tokens if cfg.frontend == "vision" else 0)
        b = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
             "labels": jax.ShapeDtypeStruct((B, S), i32)}
    elif shape.kind == "prefill":
        S = shape.seq_len - (cfg.num_frontend_tokens if cfg.frontend == "vision" else 0)
        b = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:                                  # decode: one new token
        b = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.frontend == "vision" and shape.kind != "decode":
        b["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_frontend_tokens, cfg.d_model), dt)
    if cfg.frontend == "audio" and shape.kind != "decode":
        b["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq_len, cfg.d_model), dt)
    return b


def _cache_struct(cfg, shape):
    B = shape.global_batch
    window = decode_window(cfg, shape)
    size = min(shape.seq_len, window) if window else shape.seq_len

    def build():
        if cfg.is_encoder_decoder:
            kv = init_kv_cache(cfg.num_layers, B, size, cfg.num_kv_heads,
                               cfg.head_dim, window=window,
                               dtype=jnp.dtype(cfg.dtype))
            ck = jnp.zeros((cfg.num_layers, B, cfg.encoder_seq_len,
                            cfg.num_kv_heads, cfg.head_dim), jnp.dtype(cfg.dtype))
            return Cache(kv=kv, cross_k=ck, cross_v=ck)
        if cfg.arch_type == "hybrid":
            return hybrid.init_cache(cfg, B, size, window=window)
        if cfg.arch_type == "ssm":
            return xlstm_model.init_cache(cfg, B)
        return Cache(kv=init_kv_cache(cfg.num_layers, B, size,
                                      cfg.num_kv_heads, cfg.head_dim,
                                      window=window, dtype=jnp.dtype(cfg.dtype)))

    return jax.eval_shape(build)


def shallow_clone(cfg, L: int, *, with_site: bool = False):
    """Full-width config with L UNROLLED layers — used by the dry-run's
    cost-correction lowers (XLA cost_analysis counts a scan body once, so the
    scanned stack under-reports per-layer cost; see dryrun.corrected_costs).

    ``with_site`` (hybrid): include exactly one shared-attention site."""
    import dataclasses
    kw = dict(num_layers=L, unroll_layers=True, remat=False)
    if cfg.block_pattern is not None:
        kw["block_pattern"] = tuple([cfg.block_pattern[0]] * L)
    if cfg.shared_attention_every:
        kw["shared_attention_every"] = L if with_site else L + 1
    if cfg.is_encoder_decoder:
        kw["num_encoder_layers"] = L
    return dataclasses.replace(cfg, **kw)


def build_case(arch: str, shape_name: str, mesh, *, mode: str = "hcmp",
               cfg_override=None, variant: str = "baseline"):
    """Returns dict(step, args (structs), in_shardings, label).

    variants:
      baseline     — train_step / full-logits prefill / 1-token decode
      last_logits  — prefill computing only the final position's logits
                     (serving semantics; EXPERIMENTS §Perf hillclimb A)
      verify16     — Ghidorah W=16 tree-verification step instead of the
                     sequential decode step (the paper's technique at pod
                     scale; §Perf hillclimb C)
      remat        — train_step with activation checkpointing (§Perf
                     iteration E: recover the peak-memory cost of blocked
                     attention's saved tiles)
    """
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    if variant == "remat":
        import dataclasses
        cfg = dataclasses.replace(cfg, remat=True)
    shape = INPUT_SHAPES[shape_name]
    ok, why = supports(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} x {shape_name} unsupported: {why}")
    model = get_model(cfg)
    dp = data_axes(mesh)

    params_struct = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    pspecs = shd.param_specs(cfg, params_struct, mode=mode)
    ns = lambda spec_tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
    batch = _batch_struct(cfg, shape)
    bspecs = shd.batch_specs(batch, batch_axes=dp)

    if shape.kind == "train":
        opt_struct = jax.eval_shape(adamw_init, params_struct)
        ospecs = AdamWState(mu=pspecs, nu=pspecs, step=P())

        def step(params, opt_state, batch):
            return train_step(cfg, model, params, opt_state, batch)

        return {
            "cfg": cfg, "label": f"{arch}/{shape_name}/{mode}",
            "step": step,
            "args": (params_struct, opt_struct, batch),
            "in_shardings": (ns(pspecs), ns(ospecs), ns(bspecs)),
        }

    if shape.kind == "prefill":
        window = cfg.sliding_window if cfg.name.startswith("llava") else 0
        last = variant == "last_logits"

        def step(params, batch):
            return model.prefill(params, batch, max_len=shape.seq_len,
                                 window=window, last_logits=last)

        return {
            "cfg": cfg, "label": f"{arch}/{shape_name}/{mode}/{variant}",
            "step": step,
            "args": (params_struct, batch),
            "in_shardings": (ns(pspecs), ns(bspecs)),
        }

    # decode
    cache_struct = _cache_struct(cfg, shape)
    cspecs = shd.cache_specs(cfg, cache_struct, batch_axes=dp)

    if variant == "verify16":
        from repro.core.speculative import tree as T
        spec = T.build_tree(T.default_accs(cfg.medusa_heads,
                                           cfg.medusa_top_k), 16)
        tr = T.Tree.from_spec(spec)
        batch = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 16),
                                                jnp.int32)}
        bspecs = shd.batch_specs(batch, batch_axes=dp)

        def step(params, cache, batch):
            return model.verify(params, cache, batch["tokens"], tr)
    else:
        def step(params, cache, batch):
            return model.decode(params, cache, batch["tokens"])

    return {
        "cfg": cfg, "label": f"{arch}/{shape_name}/{mode}/{variant}",
        "step": step,
        "args": (params_struct, cache_struct, batch),
        "in_shardings": (ns(pspecs), ns(cspecs), ns(bspecs)),
    }
