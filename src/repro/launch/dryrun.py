import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape) on the
production meshes, collect memory/cost/collective stats.

The two lines above MUST stay first: jax locks the device count at first
init, and the dry-run needs 512 placeholder host devices to build the
(2, 16, 16) production mesh.  Do not set that flag anywhere global.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
Results are cached as JSON under results/dryrun/ (one file per case).
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch.cases import build_case, shallow_clone, supports
from repro.launch.hlo_stats import collective_bytes, hlo_op_histogram
from repro.launch.mesh import make_production_mesh


def _lower_costs(arch, shape_name, mesh, mode, cfg, variant="baseline"):
    """(flops, hlo_bytes, collective_bytes) for one lowered variant."""
    case = build_case(arch, shape_name, mesh, mode=mode, cfg_override=cfg,
                      variant=variant)
    with mesh:
        compiled = jax.jit(case["step"],
                           in_shardings=case["in_shardings"]) \
            .lower(*case["args"]).compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0),
            float(coll["total"]))


def corrected_costs(arch, shape_name, mesh, mode="hcmp", variant="baseline"):
    """Scan-trip-count-corrected per-device costs.

    XLA's cost_analysis counts a while-loop body ONCE, so the scanned layer
    stack under-reports FLOPs/bytes/collectives by ~num_layers.  We lower
    UNROLLED full-width clones with L=1 and L=2 layers and extrapolate:
        total = c1 + (L-1) * (c2 - c1)
    (hybrid: + n_sites * site_cost from a third with-site clone;
     xlstm stacks are already unrolled — no correction needed).
    """
    cfg = get_config(arch)
    if cfg.arch_type == "ssm":
        return None                        # python-unrolled already
    import numpy as np
    L = cfg.num_layers
    c1 = np.array(_lower_costs(arch, shape_name, mesh, mode,
                               shallow_clone(cfg, 1), variant))
    c2 = np.array(_lower_costs(arch, shape_name, mesh, mode,
                               shallow_clone(cfg, 2), variant))
    body = c2 - c1
    total = c1 + (L - 1) * body
    if cfg.shared_attention_every:
        from repro.models.hybrid import n_sites
        c2s = np.array(_lower_costs(arch, shape_name, mesh, mode,
                                    shallow_clone(cfg, 2, with_site=True),
                                    variant))
        site = c2s - c2
        total = total + n_sites(cfg) * site
    total = np.maximum(total, 0.0)
    return {"flops": float(total[0]), "hlo_bytes_accessed": float(total[1]),
            "collective_total": float(total[2])}

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def run_case(arch, shape_name, *, multi_pod=False, mode="hcmp",
             variant="baseline", out_dir=None, force=False, verbose=True):
    out_dir = out_dir or os.path.abspath(RESULTS)
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "multi" if multi_pod else "single"
    vtag = "" if variant == "baseline" else f"__{variant}"
    fname = os.path.join(out_dir,
                         f"{arch}__{shape_name}__{mesh_tag}__{mode}{vtag}.json")
    if os.path.exists(fname) and not force:
        with open(fname) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = supports(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "mode": mode, "variant": variant}
    if not ok:
        rec.update(status="skipped", reason=why)
        _save(fname, rec)
        return rec

    t0 = time.perf_counter()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        case = build_case(arch, shape_name, mesh, mode=mode, variant=variant)
        with mesh:
            jitted = jax.jit(case["step"], in_shardings=case["in_shardings"])
            lowered = jitted.lower(*case["args"])
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
                              + (getattr(mem, "temp_size_in_bytes", 0) or 0)
                              + (getattr(mem, "output_size_in_bytes", 0) or 0),
            },
            flops=cost.get("flops") if cost else None,
            hlo_bytes_accessed=cost.get("bytes accessed") if cost else None,
            collectives=coll,
            op_histogram=hlo_op_histogram(hlo),
            n_devices=mesh.devices.size,
            model_params=cfg.param_count(),
            model_params_active=cfg.active_param_count(),
        )
        try:
            rec["corrected"] = corrected_costs(arch, shape_name, mesh, mode,
                                               variant)
        except Exception as e:  # noqa: BLE001
            rec["corrected"] = None
            rec["corrected_error"] = f"{type(e).__name__}: {e}"
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _save(fname, rec)
    if verbose:
        s = rec["status"]
        extra = (f" flops={rec.get('flops'):.3e}"
                 f" coll={rec.get('collectives', {}).get('total', 0):.3e}"
                 if s == "ok" else rec.get("reason", rec.get("error", "")))
        print(f"[dryrun] {arch} x {shape_name} x {mesh_tag}/{mode}: {s}{extra}",
              flush=True)
    return rec


def _save(fname, rec):
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="hcmp", choices=["hcmp", "megatron"])
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "last_logits", "verify16", "remat"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs(include_paper_model=False)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if not (args.all or (args.arch and args.shape)):
        ap.error("pass --arch and --shape, or --all")

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_case(arch, shape, multi_pod=mp, mode=args.mode,
                               variant=args.variant,
                               out_dir=args.out, force=args.force)
                if rec["status"] == "error":
                    n_fail += 1
                else:
                    n_ok += 1
    print(f"[dryrun] done: {n_ok} ok/skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
