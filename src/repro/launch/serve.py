"""Serving launcher: batched Ghidorah speculative serving or batched
sequential serving on the local device(s), with the device-resident chunked
decode loop (one host sync per ``--chunk`` steps).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b-smoke \
      --mode ghidorah --width 8 --tokens 64 --batch 4 --chunk 8

Two serving shapes:

* default (``--arrivals none``): one fixed batch of ``--batch`` prompts is
  prefilled together and decoded to the token budget.  Throughput counts
  REAL emitted tokens (``stats["emitted_total"]``), not the EOS padding in
  the output buffer.
* replay (``--arrivals poisson --rate R --requests N``): N requests arrive
  as a rate-R Poisson process and flow through ``runtime/scheduler.py`` —
  ``--sched continuous`` admits/evicts per sequence at chunk boundaries
  (a freed cache row is immediately refilled from the queue),
  ``--sched static`` is the fixed-group baseline.  Reports aggregate
  tokens/sec plus per-request latency mean/p50/p95 (the tail is what the
  admission policies move — mean alone hides it).  ``--policy
  fifo|sjf|lpt`` picks the admission order (sjf/lpt may admit a small
  fundable request past a page-deferred head-of-line one;
  ``--age-limit N`` bounds their starvation by promoting a request
  deferred more than N boundaries to FIFO-head priority) and
  ``--prefill-chunk N`` admits prompts longer than N piecewise so one
  long prompt cannot stall the resident bank (attention families).

``--spec-width auto`` (ghidorah + continuous replay) switches ARCA from
the analytic SoC model to MEASURED profiling: the engine's compiled
per-width step functions are timed on this machine
(``arca.profile_engine``), ``choose_strategy`` picks the starting width
from measured tokens/sec, and the scheduler's adaptive mode keeps
re-deciding the width at chunk boundaries from the observed-acceptance
EMA (strategy switches are logged).

Capacity: the KV cache is sized so the full token budget fits
(prompt + tokens + tree depth of speculative overshoot).  An undersized
cache no longer wraps silently — the engines freeze a sequence at the
capacity boundary and ``n_emitted`` reports the shortfall.

``--paged`` swaps the dense per-row KV for the shared page pool
(runtime/cache.py): each sequence reserves only the pages its
prompt+budget needs, so ``--pool-pages`` bounds total KV memory instead of
``batch * max_len`` — shrink it below the dense equivalent to serve a
larger ``--batch`` at fixed memory (the sched_bench paged record measures
exactly this trade).  ``--kv-dtype int8`` quantizes the pool's pages with
per-page dequant scales (~3.5x fewer bytes/token — the same pool bytes
reserve more resident tokens); ``--tree-kernel sparse|auto`` splits the
paged verify into the quantized page walk + the block-masked tree kernel
(auto = ARCA measures both and picks per shape).

Fault-tolerant serving (``--replicas N``, ``--deadline-s``,
``--cancel-rate``, ``--inject-faults SEED``): the replay runs through the
async front end instead of in-process — N engine replicas behind
``runtime/router.py`` with retry+backoff, per-request deadlines, client
cancellations and (with ``--inject-faults``) the seeded chaos harness
(replica crash, chunk stalls, admission-time pool exhaustion).  The run
exits non-zero unless EVERY request reaches a typed terminal state and
every replica's page pool drains leak-free — the CI chaos smoke gate.
"""
from __future__ import annotations

import argparse
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import arca
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.data.pipeline import MarkovDataset
from repro.models.api import get_model
from repro.runtime.engine import BatchEngine, SpeculativeEngine
from repro.runtime.faults import FaultPlan
from repro.runtime.router import ReplicaRouter, replay as router_replay
from repro.runtime.scheduler import (ContinuousScheduler, Request,
                                     poisson_arrivals, serve_static)
from repro.runtime.server import AsyncEngineServer
from repro.training import checkpoint


def _requests(args, data):
    prompts = data.sample(args.requests, args.prompt_len, seed=11)[:, :-1]
    arrivals = poisson_arrivals(args.requests, args.rate, seed=args.seed)
    return [Request(req_id=i, tokens=prompts[i].astype(np.int32),
                    n_tokens=args.tokens, arrival=float(arrivals[i]))
            for i in range(args.requests)]


def _once_then(prebuilt, build):
    """Engine factory that hands out the already-built engine first (its
    compiles are paid), then builds fresh replicas."""
    first = [prebuilt]

    def factory():
        if first:
            return first.pop()
        return build()
    return factory


def _fault_tolerant(args) -> bool:
    """Whether the replay must go through the async server/router plane."""
    return (args.replicas > 1 or args.deadline_s is not None
            or args.cancel_rate > 0 or args.inject_faults is not None)


def _replay_async(args, data, build_engine, adaptive=None):
    """Fault-tolerant replay: the arrival stream flows through N replica
    servers behind the router; with ``--inject-faults`` the seeded chaos
    plan crashes replica r0, stalls chunks and blocks admissions.  Exits
    non-zero unless every request is terminal and no replica leaked
    pages."""
    reqs = _requests(args, data)
    plan = None
    if args.inject_faults is not None:
        crash = {"r0": 6} if args.replicas > 1 else {}
        plan = FaultPlan(seed=args.inject_faults, crash=crash,
                         stall_rate=0.05, stall_s=0.01, exhaust_rate=0.05,
                         cancel_rate=args.cancel_rate)
    elif args.cancel_rate > 0:
        plan = FaultPlan(seed=args.seed, cancel_rate=args.cancel_rate)

    servers = []
    for i in range(args.replicas):
        name = f"r{i}"
        sched = ContinuousScheduler(
            build_engine(), batch=args.batch, chunk=args.chunk,
            policy=args.policy, prefill_chunk=args.prefill_chunk,
            age_limit=args.age_limit, adaptive=adaptive,
            faults=plan.injector(name) if plan is not None else None)
        servers.append(AsyncEngineServer(sched, name=name,
                                         queue_limit=args.queue_limit))
    router = ReplicaRouter(
        servers, seed=args.seed,
        client_faults=plan.client() if plan is not None else None)

    async def run():
        await router.start(health_every_s=0.2)
        try:
            return await router_replay(router, reqs,
                                       deadline_s=args.deadline_s)
        finally:
            await router.stop()

    results, stats = asyncio.run(run())
    drained = router.drained()
    faulty = "faults on" if args.inject_faults is not None else "faults off"
    print(f"[serve] router x{args.requests} reqs over {args.replicas} "
          f"replica(s) ({faulty}): {stats['delivered_total']} tokens in "
          f"{stats['makespan_s']:.2f}s ({stats['tok_s']:.1f} tok/s, "
          f"goodput {stats['goodput_tok_s']:.1f} tok/s), "
          f"states {stats['states']}, {stats['retries']} retried, "
          f"routed {stats['routed']}, "
          f"latency mean {stats['latency_mean_s']:.2f}s "
          f"p95 {stats['latency_p95_s']:.2f}s, "
          f"pages drained: {drained}")
    if not stats["terminal"] or not drained:
        raise SystemExit(
            f"[serve] FAULT-TOLERANCE VIOLATION: terminal="
            f"{stats['terminal']} drained={drained}")
    return results, stats


def _hcmp_gate(args, data, eng_overlap, results, build_inline,
               adaptive=None):
    """--hcmp overlap acceptance gate (the CI smoke): re-serve the SAME
    arrival stream on an inline twin engine and require bit-identical
    per-request tokens, plus a leak-free drained pool on the overlap
    engine.  Exits non-zero on any parity or leak failure."""
    leak = not (eng_overlap.sched_pool_conserved()
                and eng_overlap.sched_drained())
    if args.sched == "continuous":
        ref, _ = ContinuousScheduler(
            build_inline(), batch=args.batch, chunk=args.chunk,
            policy=args.policy, prefill_chunk=args.prefill_chunk,
            age_limit=args.age_limit, adaptive=adaptive).serve(
                _requests(args, data))
    else:
        ref, _ = serve_static(build_inline(), _requests(args, data),
                              batch=args.batch)
    bad = [r.req_id for r, s in zip(results, ref)
           if not np.array_equal(r.tokens, s.tokens)]
    hs = eng_overlap.hcmp_stats or {}
    print(f"[serve] hcmp overlap gate: parity "
          f"{'OK' if not bad else 'FAIL ' + str(bad)}, "
          f"pages {'LEAKED' if leak else 'OK'}; "
          f"predraft hits {hs.get('predraft_hits', 0)} / discards "
          f"{hs.get('predraft_discards', 0)} over {hs.get('chunks', 0)} "
          f"chunks on {hs.get('devices', 1)} device(s)")
    if bad or leak:
        raise SystemExit(f"[serve] HCMP OVERLAP VIOLATION: overlapped "
                         f"draft/verify diverged from the inline engine "
                         f"(mismatched req ids {bad}, leaked pages: "
                         f"{leak})")


def _replay(eng, args, data, cfg, adaptive=None):
    """Arrival-replay mode: Poisson request stream through the scheduler."""
    reqs = _requests(args, data)
    if args.sched == "continuous":
        results, stats = ContinuousScheduler(
            eng, batch=args.batch, chunk=args.chunk, policy=args.policy,
            prefill_chunk=args.prefill_chunk, age_limit=args.age_limit,
            adaptive=adaptive).serve(reqs)
        label = f"{args.sched}/{stats['policy']}"
        if stats["prefill_chunk"]:
            label += f"+pc{stats['prefill_chunk']}"
        if adaptive is not None:
            label += "/adaptive"
            sw = stats["strategy_switches"]
            print(f"[serve] adaptive: width {stats['width_final']} at drain, "
                  f"{len(sw)} switch(es)"
                  + (f" {[(s['from'], s['to']) for s in sw]}" if sw else ""))
    else:
        results, stats = serve_static(eng, reqs, batch=args.batch)
        label = args.sched
    print(f"[serve] {label} x{args.requests} reqs "
          f"(poisson rate {args.rate}/s, B={args.batch}): "
          f"{stats['emitted_total']} tokens in {stats['makespan_s']:.2f}s "
          f"({stats['tok_s']:.1f} tok/s aggregate), "
          f"latency mean {stats['latency_mean_s']:.2f}s "
          f"p50 {stats['latency_p50_s']:.2f}s "
          f"p95 {stats['latency_p95_s']:.2f}s, "
          f"queue wait mean {stats['queue_wait_mean_s']:.2f}s "
          f"p95 {stats['queue_wait_p95_s']:.2f}s")
    return results, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b-smoke")
    ap.add_argument("--mode", default="ghidorah",
                    choices=["ghidorah", "sequential"])
    ap.add_argument("--width", type=int, default=0,
                    help="verification width (0 = let ARCA choose "
                         "analytically)")
    ap.add_argument("--spec-width", default=None,
                    help="verification width: an int (same as --width, "
                         "takes precedence) or 'auto' — MEASURED ARCA: the "
                         "compiled per-width steps are profiled on this "
                         "machine (arca.profile_engine), choose_strategy "
                         "runs over the measured times, and the continuous "
                         "scheduler keeps re-deciding the width at chunk "
                         "boundaries from the observed acceptance EMA "
                         "(needs --mode ghidorah --arrivals poisson "
                         "--sched continuous)")
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--chunk", type=int, default=8,
                    help="device-resident steps per host sync")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--arrivals", default="none", choices=["none", "poisson"],
                    help="replay a request-arrival process instead of one "
                         "fixed batch")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="poisson arrival rate, requests/sec")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of requests in the replayed stream")
    ap.add_argument("--sched", default="continuous",
                    choices=["continuous", "static"],
                    help="scheduler for --arrivals replay")
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "sjf", "lpt"],
                    help="admission policy for --sched continuous: fifo "
                         "(arrival order), sjf (smallest reserved "
                         "footprint first; may admit past a page-deferred "
                         "head-of-line request — starvation-prone under "
                         "sustained small-request load), lpt (largest "
                         "first)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="admit prompts longer than N in N-token pieces "
                         "(0 = whole-prompt admission; attention-family "
                         "engines only)")
    ap.add_argument("--age-limit", type=int, default=0,
                    help="starvation bound for --policy sjf/lpt: a request "
                         "deferred for more than N chunk boundaries is "
                         "promoted to FIFO-head priority (0 = off)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV: sequences share one page pool and "
                         "reserve pages for prompt+budget instead of a "
                         "dense max_len row each")
    ap.add_argument("--page-size", type=int, default=16,
                    help="slots per KV page (--paged)")
    ap.add_argument("--kv-dtype", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="paged pool storage dtype (--paged): fp32 keeps "
                         "the model-dtype float pool; int8 quantizes KV "
                         "pages with per-page dequant scales "
                         "(runtime/cache.py) — ~3.5x fewer bytes/token, "
                         "so the same pool bytes reserve more tokens")
    ap.add_argument("--tree-kernel", default="dense",
                    choices=["dense", "sparse", "auto"],
                    help="paged verify kernel (ghidorah + --paged): dense "
                         "= fused page walk + tree block; sparse = split "
                         "quantized page walk + block-masked tree kernel "
                         "merged by the Eq.-1 rule (forces the pallas "
                         "backend — the split is kernel-only); auto = "
                         "ARCA times both per shape and picks the faster")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="total reservable pages in the shared pool "
                         "(0 = dense-equivalent: batch * pages(max_len)); "
                         "shrink to serve a larger --batch at fixed memory")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--heads-ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the async router "
                         "(>1 switches the replay to the fault-tolerant "
                         "server/router plane)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline (seconds, replica serve "
                         "clock); expired requests finalize TIMED_OUT at "
                         "the next chunk boundary")
    ap.add_argument("--cancel-rate", type=float, default=0.0,
                    help="fraction of clients that disconnect mid-stream "
                         "(deterministic per request id); cancelled "
                         "requests finalize CANCELLED")
    ap.add_argument("--inject-faults", type=int, default=None,
                    metavar="SEED",
                    help="arm the seeded chaos harness: replica r0 crash "
                         "(when --replicas > 1), chunk stalls, "
                         "admission-time pool exhaustion, plus "
                         "--cancel-rate disconnects; exits non-zero on "
                         "any leaked page or non-terminal request")
    ap.add_argument("--queue-limit", type=int, default=64,
                    help="bounded admission queue per replica; submits "
                         "over it are REJECTED (backpressure)")
    ap.add_argument("--hcmp", default="inline",
                    choices=["inline", "overlap", "auto"],
                    help="executor partition for the drafted engine "
                         "(core/hcmp/executors.py): inline = fused "
                         "draft+verify on one executor; overlap = "
                         "disaggregated DraftExecutor/VerifyExecutor with "
                         "draft(t+1) overlapping commit(t) — a replay "
                         "additionally re-runs the stream on an inline "
                         "twin and exits non-zero on any token mismatch "
                         "or leaked page (the CI gate); auto = ARCA times "
                         "both partitions and picks the faster "
                         "(ghidorah only)")
    args = ap.parse_args()
    # ---- argument validation: fail fast with a clear error, never hang
    # or crash layers deeper --------------------------------------------
    if args.tokens < 1:
        ap.error("--tokens must be >= 1")
    if args.batch < 1:
        ap.error("--batch must be >= 1")
    if args.chunk < 1:
        ap.error("--chunk must be >= 1")
    if args.prompt_len < 2:
        ap.error("--prompt-len must be >= 2 (one context token must "
                 "survive the next-token shift)")
    if args.arrivals == "poisson":
        if args.rate <= 0:
            ap.error("--rate must be > 0 (poisson inter-arrivals are "
                     "1/rate)")
        if args.requests < 1:
            ap.error("--requests must be >= 1")
    if args.prefill_chunk < 0:
        ap.error("--prefill-chunk must be >= 0 (0 disables chunked "
                 "prefill)")
    if args.age_limit < 0:
        ap.error("--age-limit must be >= 0 (0 disables aging)")
    if args.paged and args.page_size < 1:
        ap.error("--page-size must be >= 1")
    if args.pool_pages < 0:
        ap.error("--pool-pages must be >= 0 (0 = dense-equivalent pool)")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.deadline_s is not None and args.deadline_s <= 0:
        ap.error("--deadline-s must be > 0")
    if not 0.0 <= args.cancel_rate <= 1.0:
        ap.error("--cancel-rate must be in [0, 1]")
    if args.queue_limit < 1:
        ap.error("--queue-limit must be >= 1")
    if args.spec_width and args.mode != "ghidorah":
        ap.error("--spec-width is a ghidorah option (sequential decoding "
                 "has no verification width)")
    if args.hcmp != "inline" and args.mode != "ghidorah":
        ap.error("--hcmp overlap/auto is a ghidorah option (sequential "
                 "decoding has no draft source to disaggregate)")
    if args.kv_dtype == "int8" and not args.paged:
        ap.error("--kv-dtype int8 quantizes the PAGED pool (per-page "
                 "scales live on the page axis) — add --paged")
    if args.tree_kernel != "dense":
        if not args.paged:
            ap.error("--tree-kernel sparse/auto splits the PAGED verify "
                     "path — add --paged")
        if args.mode != "ghidorah":
            ap.error("--tree-kernel sparse/auto is a ghidorah option "
                     "(sequential decoding has no verification tree)")
    if _fault_tolerant(args) and (args.arrivals != "poisson"
                                  or args.sched != "continuous"):
        ap.error("--replicas/--deadline-s/--cancel-rate/--inject-faults "
                 "need --arrivals poisson --sched continuous (the async "
                 "plane serves an arrival stream)")
    paged_kw = dict(paged=args.paged, page_size=args.page_size,
                    pool_pages=args.pool_pages or None,
                    kv_dtype=None if args.kv_dtype == "fp32"
                    else args.kv_dtype)
    if args.hcmp != "inline":
        # must run BEFORE the first jax computation: the second host
        # device can only be requested while the backend is uninitialized
        from repro.core.hcmp.executors import ensure_host_devices
        ndev = ensure_host_devices(2)
        note = "" if ndev >= 2 else \
            " (single device: overlap degrades to a serial schedule)"
        print(f"[serve] hcmp {args.hcmp}: {ndev} host device(s){note}")
        # overlap-capable engine; "auto" measures and may switch back
        paged_kw["hcmp"] = "overlap"

    cfg = get_config(args.arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    if args.ckpt:
        params = checkpoint.restore(args.ckpt, params)

    data = MarkovDataset(cfg.vocab_size, seed=1)
    toks = data.sample(args.batch, args.prompt_len, seed=7)[:, :-1]
    batch = {"tokens": toks.astype(np.int32)}

    if args.mode == "sequential":
        # prompt + budget slots; the sequential driver writes at most
        # prompt + (tokens - 1) entries before every row is done
        max_len = args.prompt_len + args.tokens
        eng = BatchEngine(model, params, max_len=max_len, chunk=args.chunk,
                          **paged_kw)
        if args.arrivals != "none":
            if _fault_tolerant(args):
                _replay_async(args, data, _once_then(
                    eng, lambda: BatchEngine(model, params, max_len=max_len,
                                             chunk=args.chunk, **paged_kw)))
            else:
                _replay(eng, args, data, cfg)
            return
        t0 = time.perf_counter()
        out, stats = eng.generate(batch, args.tokens)
        dt = time.perf_counter() - t0
        n_out = stats["emitted_total"]       # real tokens, not EOS padding
        print(f"[serve] sequential: {n_out} tokens "
              f"({args.batch} seq x chunk {args.chunk}) in {dt:.2f}s "
              f"({n_out / dt:.1f} tok/s)")
        return

    heads = init_medusa(cfg, jax.random.PRNGKey(args.seed + 1))
    if args.heads_ckpt:
        heads = checkpoint.restore(args.heads_ckpt, heads)
    accs = T.default_accs(cfg.medusa_heads, cfg.medusa_top_k)
    if args.tree_kernel != "dense":
        # the split verify path is kernel-only: pin the pallas backend so
        # "sparse" (and auto's sparse arm) runs the real split page walk +
        # block-masked tree kernel, not the fused ref fallback
        paged_kw["backend"] = "pallas"
        if args.tree_kernel == "sparse":
            paged_kw["tree_kernel"] = "sparse"
        print(f"[serve] tree kernel {args.tree_kernel}: pallas backend")
    auto = args.spec_width == "auto"
    if args.spec_width and not auto:
        args.width = int(args.spec_width)
    if auto:
        # measured ARCA + runtime-adaptive speculation: profile the
        # compiled per-width steps on THIS machine, start at the measured
        # argmax, and let the scheduler re-decide at chunk boundaries
        if args.arrivals == "none" or args.sched != "continuous":
            ap.error("--spec-width auto needs --arrivals poisson "
                     "--sched continuous")
        widths = (1, 2, 4, 8, 16)
        specs = {w: T.candidate_spec(accs, w) for w in widths}
        # size the ring for the DEEPEST candidate: a runtime switch must
        # never outgrow a resident row's capacity
        max_len = args.prompt_len + args.tokens + max(
            s.max_depth for s in specs.values())
        eng = SpeculativeEngine(model, heads, params, specs[max(widths)],
                                max_len=max_len, chunk=args.chunk,
                                **paged_kw)
        time_fn = arca.profile_engine(eng, widths, accs=accs,
                                      batch=args.batch,
                                      prompt_len=args.prompt_len,
                                      tree_kernels=("dense", "sparse")
                                      if args.tree_kernel == "auto"
                                      else None)
        strategies = arca.choose_strategy(cfg, accs, ctx=args.prompt_len,
                                          time_fn=time_fn, widths=widths)
        start = arca.best(strategies)
        print(f"[serve] measured ARCA: start width={start.width} "
              f"(E[AL]={start.acceptance:.2f}, "
              f"step {start.step_time * 1e3:.2f} ms)")
        eng.set_strategy(start.tree)
        if args.tree_kernel == "auto":
            # choose_strategy stamped the measured kernel winner on each
            # Strategy the same way it stamped the partition
            print(f"[serve] tree kernel: {start.tree_kernel} "
                  f"(measured winner for width {start.width})")
            eng.set_tree_kernel(start.tree_kernel)
        if args.hcmp != "inline":
            # profile_engine timed BOTH partitions (the engine was built
            # overlap-capable), so choose_strategy stamped the measured
            # winner on each Strategy; "auto" follows it, "overlap" pins
            part = "overlap" if args.hcmp == "overlap" else start.hcmp
            print(f"[serve] hcmp partition: {part} "
                  f"(measured winner for width {start.width}: "
                  f"{start.hcmp})")
            eng.set_hcmp(part)

        def build_auto():
            e = SpeculativeEngine(model, heads, params, specs[max(widths)],
                                  max_len=max_len, chunk=args.chunk,
                                  **paged_kw)
            e.set_strategy(start.tree)
            if args.tree_kernel == "auto":
                e.set_tree_kernel(eng.tree_kernel)
            if args.hcmp != "inline":
                e.set_hcmp(eng.hcmp)
            return e

        if _fault_tolerant(args):
            _replay_async(args, data, _once_then(eng, build_auto),
                          adaptive=strategies)
        else:
            results, _ = _replay(eng, args, data, cfg, adaptive=strategies)
            if args.hcmp == "overlap":
                def build_inline():
                    e = SpeculativeEngine(model, heads, params,
                                          specs[max(widths)],
                                          max_len=max_len, chunk=args.chunk,
                                          **{**paged_kw, "hcmp": "inline"})
                    e.set_strategy(start.tree)
                    return e
                _hcmp_gate(args, data, eng, results, build_inline,
                           adaptive=strategies)
        return
    if args.width:
        spec = T.build_tree(accs, args.width)
    else:
        strat = arca.best(arca.choose_strategy(cfg, accs, ctx=args.prompt_len))
        spec = strat.tree
        print(f"[serve] ARCA chose width={strat.width} "
              f"(E[AL]={strat.acceptance:.2f})")
    # one speculative step past the budget can commit up to max_depth
    # tokens, so size the ring for the worst-case overshoot — the old
    # ``+ 8`` slack was smaller than the overshoot and the ring wrapped
    max_len = args.prompt_len + args.tokens + spec.max_depth
    eng = SpeculativeEngine(model, heads, params, spec, max_len=max_len,
                            chunk=args.chunk, **paged_kw)
    if args.hcmp == "auto" or args.tree_kernel == "auto":
        # measure the partition / verify kernel for THIS shape on THIS
        # machine: time the compiled step under each candidate layout at
        # the serving batch and keep the faster (same decision path
        # --spec-width auto takes through choose_strategy's Strategy
        # hcmp/tree_kernel stamps)
        modes = {"auto": ("inline", "overlap"), "overlap": ("overlap",),
                 "inline": ("inline",)}[args.hcmp]
        tks = ("dense", "sparse") if args.tree_kernel == "auto" \
            else (args.tree_kernel,)
        tf = arca.profile_engine(eng, (spec.width,), accs=accs,
                                 batch=args.batch,
                                 prompt_len=args.prompt_len,
                                 hcmp_modes=modes, tree_kernels=tks)
        key = (spec.width, spec.max_depth, spec.n_paths, args.batch)
        if args.hcmp == "auto":
            part = tf.partition_for(spec)
            print(f"[serve] measured partition: {part} "
                  f"(inline {tf.times[key + ('inline',)] * 1e3:.2f} ms, "
                  f"overlap {tf.times[key + ('overlap',)] * 1e3:.2f} ms "
                  f"per step)")
            eng.set_hcmp(part)
        if args.tree_kernel == "auto":
            tk = tf.kernel_for(spec)
            mode = tf.partition_for(spec)
            print(f"[serve] measured tree kernel: {tk} (dense "
                  f"{tf.times[key + (mode, 'dense')] * 1e3:.2f} ms, sparse "
                  f"{tf.times[key + (mode, 'sparse')] * 1e3:.2f} ms "
                  f"per step)")
            eng.set_tree_kernel(tk)
    if args.arrivals != "none":
        if _fault_tolerant(args):
            _replay_async(args, data, _once_then(
                eng, lambda: SpeculativeEngine(model, heads, params, spec,
                                               max_len=max_len,
                                               chunk=args.chunk,
                                               **paged_kw)))
        else:
            results, _ = _replay(eng, args, data, cfg)
            if args.hcmp == "overlap":
                _hcmp_gate(args, data, eng, results,
                           lambda: SpeculativeEngine(
                               model, heads, params, spec, max_len=max_len,
                               chunk=args.chunk,
                               **{**paged_kw, "hcmp": "inline"}))
        return
    t0 = time.perf_counter()
    out, stats = eng.generate(batch, args.tokens)        # full batch: B >= 1
    dt = time.perf_counter() - t0
    n_out = stats["emitted_total"]           # real tokens, not EOS padding
    print(f"[serve] ghidorah: {n_out} tokens "
          f"({args.batch} seq x chunk {args.chunk}) in {dt:.2f}s "
          f"({n_out / dt:.1f} tok/s), "
          f"acceptance length {stats['acceptance_length']:.2f} "
          f"over {stats['steps']} seq-steps")
    if args.hcmp == "overlap":
        # fixed-batch parity gate: the overlapped schedule must emit the
        # exact token stream of the fused inline engine
        ref = SpeculativeEngine(model, heads, params, spec, max_len=max_len,
                                chunk=args.chunk,
                                **{**paged_kw, "hcmp": "inline"})
        ref_out, _ = ref.generate(batch, args.tokens)
        hs = eng.hcmp_stats or {}
        ok = np.array_equal(np.asarray(out), np.asarray(ref_out))
        print(f"[serve] hcmp overlap gate: parity "
              f"{'OK' if ok else 'FAIL'}; predraft hits "
              f"{hs.get('predraft_hits', 0)} / discards "
              f"{hs.get('predraft_discards', 0)} over "
              f"{hs.get('chunks', 0)} chunks on "
              f"{hs.get('devices', 1)} device(s)")
        if not ok:
            raise SystemExit("[serve] HCMP OVERLAP VIOLATION: overlapped "
                             "draft/verify diverged from the inline "
                             "engine on the fixed batch")


if __name__ == "__main__":
    main()
