"""Serving launcher: batched Ghidorah speculative serving or batched
sequential serving on the local device(s), with the device-resident chunked
decode loop (one host sync per ``--chunk`` steps).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b-smoke \
      --mode ghidorah --width 8 --tokens 64 --batch 4 --chunk 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import arca
from repro.core.speculative import tree as T
from repro.core.speculative.medusa import init_medusa
from repro.data.pipeline import MarkovDataset
from repro.models.api import get_model
from repro.runtime.engine import BatchEngine, SpeculativeEngine
from repro.training import checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b-smoke")
    ap.add_argument("--mode", default="ghidorah",
                    choices=["ghidorah", "sequential"])
    ap.add_argument("--width", type=int, default=0,
                    help="verification width (0 = let ARCA choose)")
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--chunk", type=int, default=8,
                    help="device-resident steps per host sync")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--heads-ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    if args.ckpt:
        params = checkpoint.restore(args.ckpt, params)

    data = MarkovDataset(cfg.vocab_size, seed=1)
    toks = data.sample(args.batch, args.prompt_len, seed=7)[:, :-1]
    batch = {"tokens": toks.astype(np.int32)}
    max_len = args.prompt_len + args.tokens + 8

    if args.mode == "sequential":
        eng = BatchEngine(model, params, max_len=max_len, chunk=args.chunk)
        t0 = time.perf_counter()
        out, stats = eng.generate(batch, args.tokens)
        dt = time.perf_counter() - t0
        print(f"[serve] sequential: {out.shape[1]} tokens/seq x {args.batch} "
              f"in {dt:.2f}s ({out.size / dt:.1f} tok/s)")
        return

    heads = init_medusa(cfg, jax.random.PRNGKey(args.seed + 1))
    if args.heads_ckpt:
        heads = checkpoint.restore(args.heads_ckpt, heads)
    accs = T.default_accs(cfg.medusa_heads, cfg.medusa_top_k)
    if args.width:
        spec = T.build_tree(accs, args.width)
    else:
        strat = arca.best(arca.choose_strategy(cfg, accs, ctx=args.prompt_len))
        spec = strat.tree
        print(f"[serve] ARCA chose width={strat.width} "
              f"(E[AL]={strat.acceptance:.2f})")
    eng = SpeculativeEngine(model, heads, params, spec, max_len=max_len,
                            chunk=args.chunk)
    t0 = time.perf_counter()
    out, stats = eng.generate(batch, args.tokens)        # full batch: B >= 1
    dt = time.perf_counter() - t0
    n_out = out.size
    print(f"[serve] ghidorah: {n_out} tokens "
          f"({args.batch} seq x chunk {args.chunk}) in {dt:.2f}s "
          f"({n_out / dt:.1f} tok/s), "
          f"acceptance length {stats['acceptance_length']:.2f} "
          f"over {stats['steps']} seq-steps")


if __name__ == "__main__":
    main()
