"""Production mesh construction (TPU v5e pod targets).

Defined as functions (NOT module-level constants) so importing never touches
jax device state.  Hardware constants for the roofline live here too.
"""
from __future__ import annotations

import jax

# TPU v5e per-chip constants (roofline terms, EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(n_model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n // n_model, n_model), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
