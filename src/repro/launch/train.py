"""Training launcher (CPU-runnable smoke scale; same code lowers on the pod
via launch/dryrun.py for the train_4k shape).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b-smoke \
      --steps 50 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import MarkovDataset
from repro.models.api import get_model
from repro.training import checkpoint
from repro.training.optimizer import adamw_init
from repro.training.train import train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b-smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    data = MarkovDataset(cfg.vocab_size, seed=1)

    # params/opt_state are a carry (rebound from the outputs every step):
    # donate them so AdamW updates in place instead of double-buffering
    # the full parameter + moment memory
    step = jax.jit(lambda p, o, b: train_step(cfg, model, p, o, b, lr=args.lr),
                   donate_argnums=(0, 1))
    t0 = time.perf_counter()
    for i, batch in enumerate(data.batches(args.batch, args.seq, args.steps)):
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        if cfg.frontend == "audio":
            batch["frame_embeds"] = jax.numpy.zeros(
                (args.batch, cfg.encoder_seq_len, cfg.d_model), cfg.dtype)
        params, opt, metrics = step(params, opt, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"[train] step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"({(i+1)/(time.perf_counter()-t0):.2f} it/s)", flush=True)
    if args.save:
        checkpoint.save(args.save, params)
        print(f"[train] saved {args.save}")


if __name__ == "__main__":
    main()
