"""Qwen3-32B [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    sliding_window=8192,   # beyond-paper long-context decode variant (long_500k)
    fsdp=True,             # 64 GB bf16 weights: shard on data axis too
)
