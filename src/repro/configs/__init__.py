"""Config registry: ``get_config(arch_id)`` / ``list_archs()``."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, InputShape, INPUT_SHAPES  # noqa: F401

# arch-id -> module name (arch ids use dashes; modules use underscores)
_ARCHS = [
    "qwen3-32b",
    "stablelm-3b",
    "qwen3-moe-30b-a3b",
    "zamba2-7b",
    "qwen2-0.5b",
    "llava-next-mistral-7b",
    "qwen3-moe-235b-a22b",
    "seamless-m4t-medium",
    "xlstm-125m",
    "glm4-9b",
    # the paper's own model (Vicuna-7B, LLaMA architecture)
    "vicuna-7b",
]


def list_archs(include_paper_model: bool = True):
    return list(_ARCHS) if include_paper_model else [a for a in _ARCHS if a != "vicuna-7b"]


def get_config(arch_id: str) -> ModelConfig:
    if arch_id.endswith("-smoke"):
        return get_config(arch_id[: -len("-smoke")]).reduced()
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {_ARCHS}")
    mod = importlib.import_module("repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.CONFIG
