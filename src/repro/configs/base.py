"""Model configuration system.

One ``ModelConfig`` dataclass covers every architecture family in the
assigned pool (dense / MoE / SSM / hybrid / enc-dec / VLM / audio).  Each
``src/repro/configs/<arch>.py`` exports ``CONFIG`` (the exact assigned full
config) built from this dataclass; ``ModelConfig.reduced()`` derives the
CPU-runnable smoke variant (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


# Block kinds for heterogeneous stacks (hybrid / xLSTM).
ATTN = "attn"
MAMBA2 = "mamba2"
SLSTM = "slstm"
MLSTM = "mlstm"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # --- identity -----------------------------------------------------
    name: str
    arch_type: str                      # dense | moe | hybrid | ssm | vlm | audio
    source: str = ""                    # citation (hf:... / arXiv:...)

    # --- transformer core ----------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: Optional[int] = None      # defaults to d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    qk_norm: bool = False               # RMSNorm on per-head q/k (qwen3)
    qkv_bias: bool = False              # linear bias on qkv (qwen2)
    rope_theta: float = 10000.0
    rmsnorm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- MoE -------------------------------------------------------------
    num_experts: int = 0                # 0 => dense MLP
    experts_per_token: int = 0          # top-k
    router_aux_coef: float = 0.01       # load-balance loss coefficient

    # --- SSM / hybrid ------------------------------------------------------
    ssm_state: int = 0                  # Mamba2 state dim N
    ssm_expand: int = 2                 # Mamba2 expansion factor
    ssm_conv: int = 4                   # depthwise conv width
    ssm_heads: int = 0                  # Mamba2 heads (derived if 0)
    # Per-layer block kinds; None => all-attention dense stack.
    block_pattern: Optional[Tuple[str, ...]] = None
    shared_attention_every: int = 0     # zamba2: one shared attn block reused
                                        # every k layers (0 = off)

    # --- encoder-decoder (audio) -----------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0            # fixed encoder memory length (frames)

    # --- modality frontend stub (the one allowed carve-out) --------------
    frontend: Optional[str] = None      # "vision" | "audio" | None
    num_frontend_tokens: int = 0        # patch/frame embeddings per sample

    # --- long-context ------------------------------------------------------
    sliding_window: int = 0             # 0 = full attention; >0 = window size
                                        # used for the long_500k decode shape

    # --- speculative decoding (Ghidorah) ----------------------------------
    medusa_heads: int = 4               # number of drafting heads
    medusa_top_k: int = 10              # candidates kept per head

    # --- distribution -------------------------------------------------------
    fsdp: bool = False                  # additionally shard weights on "data"
    remat: bool = False                 # activation checkpointing in training
    unroll_layers: bool = False         # python-loop layers instead of scan
                                        # (dry-run cost-correction lowers)
    mlstm_chunked: bool = True          # chunked-parallel mLSTM prefill
                                        # (False = per-step scan baseline;
                                        # EXPERIMENTS §Perf hillclimb B)
    mamba_chunked: bool = True          # chunked SSD Mamba2 prefill
                                        # (False = time-scan baseline;
                                        # EXPERIMENTS §Perf iteration F)

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: heads {self.num_heads} not divisible by kv {self.num_kv_heads}")
        if self.num_experts:
            assert 0 < self.experts_per_token <= self.num_experts

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so lm_head/embed column-shard evenly (multiple of
        4096 for full-size configs, 128 for smoke configs)."""
        mult = 128 if self.vocab_size < 4096 else 4096
        return ((self.vocab_size + mult - 1) // mult) * mult

    @property
    def is_recurrent(self) -> bool:
        """True if any block carries recurrent (non-KV-cache) state."""
        if self.block_pattern is None:
            return False
        return any(k in (MAMBA2, SLSTM, MLSTM) for k in self.block_pattern)

    @property
    def is_pure_recurrent(self) -> bool:
        if self.block_pattern is None:
            return False
        return all(k in (MAMBA2, SLSTM, MLSTM) for k in self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """long_500k eligibility: recurrent state or sliding-window attention."""
        return self.is_pure_recurrent or self.sliding_window > 0 or (
            self.is_recurrent and self.sliding_window > 0)

    def blocks(self) -> Tuple[str, ...]:
        if self.block_pattern is not None:
            return self.block_pattern
        return tuple([ATTN] * self.num_layers)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS=6ND)."""
        d, hd = self.d_model, self.head_dim
        n = 0
        n += self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        for kind in self.blocks():
            if kind == ATTN:
                if self.shared_attention_every:
                    continue  # counted once below
                n += self._attn_params()
                n += self._mlp_params()
            elif kind == MAMBA2:
                # Mamba2 blocks are standalone (no per-block MLP); d_ff belongs
                # to the shared attention block in hybrid stacks (zamba2).
                n += self._mamba_params()
            elif kind in (SLSTM, MLSTM):
                n += self._xlstm_params(kind)
            n += 2 * d                                 # norms
        if self.shared_attention_every:
            n += self._attn_params() + self._mlp_params()
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder additionally cross-attn
            enc = self.num_encoder_layers * (self._attn_params() + self._mlp_params() + 2 * self.d_model)
            cross = self.num_layers * self._attn_params()
            n += enc + cross
        return n

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        b = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _mlp_params(self) -> int:
        d = self.d_model
        if self.num_experts:
            # gated MLP per expert + router
            return self.num_experts * 3 * d * self.d_ff + d * self.num_experts
        return 3 * d * self.d_ff                       # SwiGLU: gate, up, down

    def _mamba_params(self) -> int:
        d = self.d_model
        di = self.ssm_expand * d
        nh = self.ssm_heads or max(di // 64, 1)
        # in_proj -> [z, x, B, C, dt], conv, A, D, norm, out_proj
        in_p = d * (2 * di + 2 * self.ssm_state + nh)
        conv = self.ssm_conv * (di + 2 * self.ssm_state)
        return in_p + conv + 2 * nh + di + di * d

    def _xlstm_params(self, kind: str) -> int:
        d = self.d_model
        if kind == MLSTM:
            di = 2 * d
            return d * 2 * di + 3 * di * (di // max(self.num_heads, 1)) + di * d + 2 * di
        # sLSTM: 4 gates recurrent + input
        return 8 * d * d + 4 * d + 2 * d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        moe_total = len([k for k in self.blocks() if k == ATTN]) * self.num_experts * 3 * self.d_model * self.d_ff
        moe_active = len([k for k in self.blocks() if k == ATTN]) * self.experts_per_token * 3 * self.d_model * self.d_ff
        return full - moe_total + moe_active

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (2 layers, d<=512, <=4 experts)."""
        pattern = None
        if self.block_pattern is not None:
            # keep the family's block mix, truncated to 2 layers
            uniq = []
            for k in self.block_pattern:
                if k not in uniq:
                    uniq.append(k)
            pattern = tuple((uniq * 2)[:2])
        kv = min(self.num_kv_heads, 2)
        heads = 4 if 4 % max(kv, 1) == 0 else kv * 2
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=256,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=64,
            d_ff=512 if self.d_ff else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2) if self.num_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=0,
            block_pattern=pattern,
            shared_attention_every=2 if self.shared_attention_every else 0,
            num_encoder_layers=2 if self.is_encoder_decoder else 0,
            encoder_seq_len=16 if self.is_encoder_decoder else 0,
            num_frontend_tokens=16 if self.frontend else 0,
            sliding_window=64 if self.sliding_window else 0,
            medusa_heads=4,
            medusa_top_k=4,
            fsdp=False,
            remat=False,
            dtype="float32",
        )


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",  524_288,    1, "decode"),
}
