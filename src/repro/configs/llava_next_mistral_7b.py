"""LLaVA-NeXT-Mistral-7B [vlm] — anyres tiling; vision tower STUBBED
(input_specs provides pre-projected patch embeddings). [hf:llava-hf/llava-v1.6-mistral-7b-hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    sliding_window=4096,            # Mistral's native sliding window
    frontend="vision",
    # anyres: base 576 patches + 4 tiles x 576 = 2880 image tokens
    num_frontend_tokens=2880,
)
