"""Vicuna-7B [dense] — the paper's evaluation model (LLaMA-7B architecture,
Medusa 5-head version). [hf:lmsys/vicuna-7b-v1.3 / arXiv:2302.13971]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="vicuna-7b",
    arch_type="dense",
    source="hf:lmsys/vicuna-7b-v1.3",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    rope_theta=10000.0,
    sliding_window=8192,
    medusa_heads=5,               # Medusa offers a 5-head Vicuna-7B (paper §IV-A)
)
