"""xLSTM-125M [ssm] — sLSTM + mLSTM blocks (7:1-style mix). [arXiv:2405.04517]"""
from repro.configs.base import MLSTM, SLSTM, ModelConfig

# sLSTM at positions 3 and 9 (paper's sparse placement), mLSTM elsewhere.
_PATTERN = tuple(SLSTM if i in (3, 9) else MLSTM for i in range(12))

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    source="arXiv:2405.04517",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,                        # xLSTM blocks embed their own projections
    vocab_size=50304,
    block_pattern=_PATTERN,
)
