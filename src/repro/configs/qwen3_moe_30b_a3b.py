"""Qwen3-30B-A3B [moe] — 128 experts top-8, GQA kv=4. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                  # per-expert intermediate size
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    experts_per_token=8,
    sliding_window=8192,
    fsdp=True,
)
