"""Zamba2-7B [hybrid] — Mamba2 backbone + shared attention block. [arXiv:2411.15242]"""
from repro.configs.base import MAMBA2, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    source="arXiv:2411.15242",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,                     # MLP of the shared attention block
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    block_pattern=tuple([MAMBA2] * 81),
    shared_attention_every=6,       # one weight-shared attn block every 6 layers
    sliding_window=8192,            # shared-attn blocks windowed for long_500k
)
