"""StableLM-3B [dense] — MHA (kv=heads). [hf:stabilityai/stablelm-2-1_6b family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    arch_type="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    qkv_bias=True,
    rope_theta=10000.0,
    sliding_window=8192,
)
