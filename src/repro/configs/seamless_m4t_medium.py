"""SeamlessM4T-medium [audio] — enc-dec; mel+conv frontend STUBBED
(input_specs provides frame embeddings for the encoder). [arXiv:2308.11596]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    source="arXiv:2308.11596",
    num_layers=12,                 # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    is_encoder_decoder=True,
    num_encoder_layers=12,
    encoder_seq_len=4096,          # stubbed audio-frame embeddings
    frontend="audio",
    num_frontend_tokens=4096,
    sliding_window=8192,           # decoder self-attn window for long_500k
)
