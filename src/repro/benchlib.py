"""Shared constants for the benchmark harness."""

# Paper Table I, MBPP row (widths 4..64) — used as measured-AL input when
# predicting Fig. 9 (the paper's headline speedup is quoted on MBPP).
PAPER_MBPP_AL = [2.54, 2.89, 3.27, 3.55, 3.74]
