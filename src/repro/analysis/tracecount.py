"""R7 dynamic counterpart — compile-count audit of the serving hot path.

The static R7 rule proves what it can from the AST; this module measures
the rest: it runs a PINNED engine + scheduler smoke (fixed model config,
fixed request set, arrivals all at t=0, no deadlines — fully
deterministic) under ``jax_log_compiles`` and counts how many times each
NAMED engine jit actually compiled, then diffs the per-function counts
against the committed ``compile_budget.json``.  Any silent retrace — a
cache-key regression from a dtype flip, a fresh static arg, a
weak-type mismatch — fails the audit with the offending function named.

Only repro-owned buckets (the named defs the engine hands to ``jax.jit``:
``chunk_scan``, ``prefill_extend``, ``admit_row``, ...) are budgeted;
jax-internal helper compiles vary across jax versions and are ignored, so
the committed budget is stable anywhere the smoke runs.

Re-baselining (after an INTENTIONAL compile-behavior change, e.g. a new
chunk width in the smoke): ``python -m repro.analysis.tracecount --write``
regenerates ``compile_budget.json``; commit the diff together with the
change that explains it.
"""
from __future__ import annotations

import argparse
import json
import logging
import re
from pathlib import Path
from typing import Dict, Optional, Sequence

# the engine's named jit targets (see DecodeEngine.__init__ and the
# _chunk_fn/_extend_fn/_prefill_paged_fn memos — every target is a named
# def precisely so this audit can bucket it)
BUCKETS = (
    "prefill_full",        # whole-batch dense prefill (generate path)
    "prefill_prompt",      # prompt-sized dense prefill (paged generate)
    "prefill_paged",       # fused prefill+paginate (per pool size)
    "prefill_extend",      # chunked-prefill piece (per piece width)
    "admit_row",           # fused dense admission
    "admit_paged",         # fused paged admission
    "insert_paged",        # paged row splice (bootstrap)
    "chunk_scan",          # the K-step decode chunk (per K)
    "_insert_row",         # dense row splice (bootstrap)
    "_reset_state_rows",   # batched row reset
    # hcmp overlap executors (core/hcmp/executors.py): the disaggregated
    # schedule replaces chunk_scan with three named jits — the verify
    # front half and cache commit on the verify device, the Medusa draft
    # on the draft device
    "verify_front",        # tree verify + accept walk (verify executor)
    "draft_step",          # Medusa draft + tree expansion (draft executor)
    "commit_step",         # KV commit of the accepted chain (donates cache)
)

BUDGET_PATH = Path(__file__).resolve().parent / "compile_budget.json"

# ``jax_log_compiles`` emits on two loggers depending on jax version:
# "Finished tracing + transforming <name> for pjit" (jax._src.dispatch)
# and/or "Compiling <name> with global shapes" (jax._src.interpreters.pxla)
_TRACE_RE = re.compile(
    r"Finished tracing \+ transforming (\S+) for (?:p?jit|pmap)")
_XLA_RE = re.compile(r"Compiling (\S+) with global shapes")
_LOGGERS = ("jax._src.dispatch", "jax._src.interpreters.pxla")


class CompileCounter(logging.Handler):
    """Counts ``jax_log_compiles`` records per traced-function name.
    Trace and XLA-compile records are counted separately; ``counts``
    prefers the trace stream (it also sees cache-key misses that reuse a
    compiled executable) and falls back to the compile stream on jax
    versions that only emit the latter."""

    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.traces: Dict[str, int] = {}
        self.compiles: Dict[str, int] = {}

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        m = _TRACE_RE.search(msg)
        if m:
            self.traces[m.group(1)] = self.traces.get(m.group(1), 0) + 1
            return
        m = _XLA_RE.search(msg)
        if m:
            self.compiles[m.group(1)] = \
                self.compiles.get(m.group(1), 0) + 1

    @property
    def counts(self) -> Dict[str, int]:
        return self.traces if self.traces else self.compiles


class count_compiles:
    """Context manager: jax compile events -> per-name counts."""

    def __init__(self):
        self.counter = CompileCounter()
        self._loggers = [logging.getLogger(n) for n in _LOGGERS]
        self._saved = []

    def __enter__(self):
        import jax
        jax.config.update("jax_log_compiles", True)
        for lg in self._loggers:
            self._saved.append((lg.level, lg.propagate))
            lg.addHandler(self.counter)
            lg.setLevel(logging.DEBUG)
            lg.propagate = False             # keep CI logs readable
        return self.counter

    def __exit__(self, *exc):
        import jax
        jax.config.update("jax_log_compiles", False)
        for lg, (level, prop) in zip(self._loggers, self._saved):
            lg.removeHandler(self.counter)
            lg.setLevel(level)
            lg.propagate = prop
        self._saved = []
        return False


def run_smoke() -> Dict[str, int]:
    """The pinned workload: one paged scheduler stream (admission,
    chunked prefill, abort, eviction, re-admission) plus one dense
    ``generate`` call.  Deterministic by construction — every arrival is
    t=0 and nothing consults the clock — so compile counts are exact."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models.api import get_model
    from repro.runtime.engine import BatchEngine
    from repro.runtime.scheduler import ContinuousScheduler, Request

    cfg = get_config("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)

    def req(rid, prompt_len, n_tokens):
        toks = rng.integers(0, cfg.vocab_size, size=prompt_len)
        return Request(req_id=rid, tokens=np.asarray(toks, np.int32),
                       n_tokens=n_tokens)

    with count_compiles() as counter:
        # paged stepping stream: mirrors the modelcheck default bound
        eng = BatchEngine(model, params, max_len=64, chunk=2, paged=True,
                          page_size=4, pool_pages=5)
        sched = ContinuousScheduler(eng, batch=2, chunk=2,
                                    prefill_chunk=2)
        sched.start([], eos=None)
        sched.submit(req(1, 3, 2))
        sched.submit(req(3, 2, 2))
        sched.boundary()
        sched.boundary()
        sched.submit(req(2, 5, 3))          # chunked prefill (5 > 2)
        sched.submit(req(4, 3, 2))
        sched.abort(4)
        for _ in range(6):
            sched.boundary()
        sched.finish()
        # dense + paged generate paths (reservation-table prefill)
        dense = BatchEngine(model, params, max_len=32, chunk=2)
        prompts = np.asarray(
            rng.integers(0, cfg.vocab_size, size=(2, 4)), np.int32)
        dense.generate({"tokens": prompts}, 3)
        eng.generate({"tokens": prompts}, 3)
        # quantized int8 pool: same slot protocol, quantize-on-write +
        # fused-dequant page walk — its admission/step/reset jits must
        # compile once each, like the fp32 paged stream above
        eng8 = BatchEngine(model, params, max_len=64, chunk=2, paged=True,
                           page_size=4, pool_pages=5, kv_dtype="int8")
        sched8 = ContinuousScheduler(eng8, batch=2, chunk=2)
        sched8.start([], eos=None)
        sched8.submit(req(5, 3, 2))
        sched8.submit(req(6, 2, 2))
        for _ in range(4):
            sched8.boundary()
        sched8.finish()
        eng8.generate({"tokens": prompts}, 3)
        # hcmp overlap: the disaggregated draft/verify schedule — each
        # executor jit must compile exactly once (single-device fallback
        # traces the same three functions, so this segment is stable no
        # matter how many host devices the process was started with)
        from repro.core.speculative import tree as T
        from repro.core.speculative.medusa import init_medusa
        from repro.runtime.engine import SpeculativeEngine
        heads = init_medusa(cfg, jax.random.PRNGKey(1))
        accs = T.default_accs(cfg.medusa_heads, cfg.medusa_top_k)
        seng = SpeculativeEngine(model, heads, params, T.build_tree(accs, 4),
                                 max_len=32, chunk=2, hcmp="overlap")
        seng.generate({"tokens": prompts}, 3)
    return {name: counter.counts.get(name, 0) for name in BUCKETS}


def diff_counts(observed: Dict[str, int],
                budget: Dict[str, int]) -> Dict[str, str]:
    """Per-function drift description; empty means the audit passes."""
    out: Dict[str, str] = {}
    for name in sorted(set(observed) | set(budget)):
        got, want = observed.get(name, 0), budget.get(name, 0)
        if got == want:
            continue
        if got > want:
            out[name] = (f"{name}: {got} compiles, budget {want} "
                         f"(+{got - want} SILENT RETRACE)")
        else:
            out[name] = (f"{name}: {got} compiles, budget {want} "
                         f"({want - got} fewer — re-baseline if the "
                         f"workload changed)")
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.tracecount",
        description="Run the pinned engine+scheduler smoke under a "
                    "compile counter and diff per-function counts "
                    "against compile_budget.json.")
    ap.add_argument("--budget", type=Path, default=BUDGET_PATH,
                    help="budget file (default: the committed one)")
    ap.add_argument("--write", action="store_true",
                    help="re-baseline: write the observed counts")
    args = ap.parse_args(argv)
    observed = run_smoke()
    width = max(len(n) for n in BUCKETS)
    for name in BUCKETS:
        print(f"tracecount: {name:<{width}} {observed[name]}")
    if args.write:
        args.budget.write_text(json.dumps(observed, indent=2,
                                          sort_keys=True) + "\n")
        print(f"tracecount: wrote {args.budget}")
        return 0
    if not args.budget.exists():
        print(f"tracecount: FAIL — no budget at {args.budget} "
              f"(run with --write to create it)")
        return 1
    budget = json.loads(args.budget.read_text())
    drift = diff_counts(observed, budget)
    if drift:
        for msg in drift.values():
            print(f"tracecount: DRIFT {msg}")
        return 1
    print("tracecount: OK — every compile is budgeted")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
