"""R3 — host-sync discipline in the decode hot path.

The stack's performance contract is ONE host sync per chunk boundary
(`np.asarray` on the chunk's token block).  Any extra
``block_until_ready`` / ``np.asarray`` inside the hot functions
(``generate`` / ``boundary`` / ``sched_step`` / ``sched_emitted`` in
``runtime/``) serializes host and device and erodes the measured
speedups silently.  Wall-clock ``time.time()`` in measured intervals is
flagged everywhere (it is not monotonic; NTP steps corrupt latency
numbers) — suppress only where an absolute timestamp is intended.

Benchmark and test files are allowlisted for the sync checks: a
benchmark's ``block_until_ready`` IS the measurement.  The intended
boundary syncs in runtime code carry inline ``# reprolint: disable=R3``
suppressions — making the budgeted sync sites grep-able is the point.
"""
from __future__ import annotations

import ast
from pathlib import PurePath
from typing import List

from repro.analysis.core import Finding, Project, SourceFile, register_rule
from repro.analysis.callgraph import dotted

_HOT_FUNCS = {"generate", "boundary", "sched_step", "sched_emitted",
              "step_chunk"}
_NP_SYNC = {"asarray", "array", "copyto", "ascontiguousarray", "copy"}


def _allowlisted(rel: str) -> bool:
    parts = PurePath(rel).parts
    name = parts[-1] if parts else rel
    return bool(set(parts[:-1]) & {"tests", "benchmarks"}) or \
        name.startswith(("test_", "bench_")) or name.endswith("_bench.py")


def _numpy_alias(f: SourceFile) -> set:
    out = set()
    for node in ast.walk(f.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.split(".")[0] == "numpy":
                    out.add(a.asname or "numpy")
    return out


@register_rule(
    "R3",
    "host-sync discipline: no block_until_ready/np.asarray/implicit "
    "array bool in hot paths; time.perf_counter for measured intervals")
def rule_hostsync(project: Project) -> List[Finding]:
    out: List[Finding] = []

    def add(rel, line, msg):
        out.append(Finding(path=rel, line=line, rule="R3", message=msg))

    for f in project.files:
        allow = _allowlisted(f.rel)
        np_names = _numpy_alias(f)
        in_runtime = "runtime" in PurePath(f.rel).parts
        # -- time.time() anywhere (except allowlisted files) --------------
        if not allow:
            for node in ast.walk(f.tree):
                if isinstance(node, ast.Call) and \
                        dotted(node.func) == "time.time":
                    add(f.rel, node.lineno,
                        "wall-clock time.time() feeds a measured interval "
                        "— use time.perf_counter() (suppress if an "
                        "absolute timestamp is intended)")
                if isinstance(node, ast.Call) and \
                        dotted(node.func) is not None and \
                        dotted(node.func).endswith("block_until_ready"):
                    add(f.rel, node.lineno,
                        "block_until_ready() stalls the dispatch pipeline "
                        "— outside benchmarks the chunk boundary sync is "
                        "the only budgeted stall")
        # -- hot-function sync checks -------------------------------------
        if allow or not in_runtime:
            continue
        for node in ast.walk(f.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if node.name not in _HOT_FUNCS:
                continue
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    d = dotted(sub.func)
                    if d and d.split(".")[0] in np_names and \
                            d.split(".")[-1] in _NP_SYNC and sub.args and \
                            not isinstance(sub.args[0],
                                           (ast.List, ast.Tuple,
                                            ast.Constant, ast.ListComp,
                                            ast.GeneratorExp)):
                        add(f.rel, sub.lineno,
                            f"host sync `{d}(...)` in hot path "
                            f"`{node.name}` — one sync per chunk boundary "
                            f"is the budget (suppress if this IS the "
                            f"boundary sync)")
                if isinstance(sub, (ast.If, ast.While)):
                    for t in ast.walk(sub.test):
                        if isinstance(t, ast.Call):
                            td = dotted(t.func)
                            if td and td.split(".")[0] in ("jnp",) or \
                                    (td and td.startswith("jax.numpy")):
                                add(f.rel, sub.lineno,
                                    f"implicit device-array __bool__ in "
                                    f"hot path `{node.name}` blocks on "
                                    f"the device")
                                break
    return out
