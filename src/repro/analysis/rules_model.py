"""R9 — boundary-protocol model checking of the scheduler stepping API.

Two layers:

1. **Static protocol-order conformance** on any ``scheduler.py`` that
   implements the stepping protocol (a ``ContinuousScheduler`` class with
   ``boundary``/``fail_all``/``submit``/``abort``): ``boundary()`` must run
   the abort sweep (``self._apply_aborts``) BEFORE any admission
   (``policy.pick``) — freed pages must be reusable by a same-boundary
   admission, never the reverse — and ``fail_all()`` must drain
   ``self._pending``, or a post-crash boundary would admit onto a dead
   replica.

2. **Bounded exhaustive model check** (``repro.analysis.modelcheck``): the
   host model of the protocol is explored over every interleaving of
   ``submit``/``abort``/``boundary``/crash for the documented default bound
   (3 requests, pool pressure, chunked prefill, crash at every reachable
   state).  Any invariant violation (page conservation, exactly-once typed
   terminals, release-before-admission, no admission after ``fail_all``)
   becomes a finding carrying its minimal counterexample trace.  The
   exploration runs once per process and is skipped entirely when the
   project does not contain the protocol implementation.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis import modelcheck
from repro.analysis.core import Finding, Project, register_rule

_PROTOCOL_METHODS = {"boundary", "fail_all", "submit", "abort"}

# the exploration is project-independent (it checks the protocol model
# against its invariants), so one run per process serves every caller
_EXPLORED: Optional[modelcheck.ExploreResult] = None


def _protocol_class(tree) -> Optional[ast.ClassDef]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef) \
                and node.name == "ContinuousScheduler":
            methods = {n.name for n in node.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))}
            if _PROTOCOL_METHODS <= methods:
                return node
    return None


def _methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body if isinstance(n, ast.FunctionDef)}


def _self_calls(fn: ast.FunctionDef, attr: str) -> List[ast.Call]:
    """Document-ordered ``self.<attr>(...)`` / ``<x>.<attr>(...)`` calls."""
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == attr:
            out.append(node)
    return sorted(out, key=lambda n: (n.lineno, n.col_offset))


def _drains_pending(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Attribute) and t.attr == "_pending" \
                        and isinstance(node.value, (ast.List, ast.Tuple)) \
                        and not node.value.elts:
                    return True
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "clear" \
                and isinstance(node.func.value, ast.Attribute) \
                and node.func.value.attr == "_pending":
            return True
    return False


def _static_findings(rel: str, cls: ast.ClassDef) -> List[Finding]:
    out: List[Finding] = []
    m = _methods(cls)
    boundary, fail_all = m["boundary"], m["fail_all"]
    sweeps = _self_calls(boundary, "_apply_aborts")
    picks = _self_calls(boundary, "pick")
    if not sweeps:
        out.append(Finding(
            path=rel, line=boundary.lineno, rule="R9",
            message="boundary() never runs the abort sweep "
                    "(no _apply_aborts call) — cancellations and "
                    "deadline expiries can never take effect"))
    elif picks and (picks[0].lineno, picks[0].col_offset) < \
            (sweeps[0].lineno, sweeps[0].col_offset):
        out.append(Finding(
            path=rel, line=boundary.lineno, rule="R9",
            message="boundary() admits (policy.pick) BEFORE the abort "
                    "sweep — an aborted row's pages are released too "
                    "late for a same-boundary admission to reuse them "
                    "(release-before-admission protocol order)"))
    if not _drains_pending(fail_all):
        out.append(Finding(
            path=rel, line=fail_all.lineno, rule="R9",
            message="fail_all() does not drain self._pending — a "
                    "boundary after the crash would admit queued "
                    "requests onto a dead replica"))
    return out


def _model_findings(rel: str, cls: ast.ClassDef) -> List[Finding]:
    global _EXPLORED
    if _EXPLORED is None:
        _EXPLORED = modelcheck.explore(
            modelcheck.DEFAULT_REQUESTS, modelcheck.DEFAULT_CONFIG,
            max_seconds=60.0)
    m = _methods(cls)
    anchor = m["boundary"].lineno
    out: List[Finding] = []
    if not _EXPLORED.complete:
        out.append(Finding(
            path=rel, line=anchor, rule="R9",
            message="model check did not finish inside its wall-clock "
                    "cap — the documented interleaving bound is "
                    "unverified"))
    for path, msg in _EXPLORED.violations[:10]:
        out.append(Finding(
            path=rel, line=anchor, rule="R9",
            message=f"model check: {msg} "
                    f"[trace: {modelcheck.render_trace(path)}]"))
    return out


@register_rule(
    "R9",
    "boundary-protocol model checker: static release-before-admission / "
    "queue-drain conformance on the scheduler, plus bounded exhaustive "
    "interleaving exploration of the protocol model (pages, terminals, "
    "ordering, crash safety)")
def rule_model(project: Project) -> List[Finding]:
    out: List[Finding] = []
    hits: List[Tuple[str, ast.ClassDef]] = []
    for f in project.files:
        if not f.rel.endswith("scheduler.py"):
            continue
        cls = _protocol_class(f.tree)
        if cls is not None:
            hits.append((f.rel, cls))
    for rel, cls in hits:
        out.extend(_static_findings(rel, cls))
    # the exploration is about the protocol itself: run it once, anchored
    # at the (unique) implementation when the project carries one
    if len(hits) == 1:
        out.extend(_model_findings(*hits[0]))
    return out
