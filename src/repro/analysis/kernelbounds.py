"""R8 engine — concrete-evaluation bounds/coverage verification of the
Pallas ``BlockSpec`` index maps in ``kernels/tree_attention.py`` and
``kernels/sparse_tree.py``.

``BlockSpec`` index maps are *pure Python* lambdas: they can be compiled
and executed without jax, over every point of the concrete grid, for a
matrix of representative shape configs.  For each (wrapper, config) this
module proves:

* **bounds** — every in/out block index is a well-formed tuple of the
  right arity with ``0 <= idx[d]`` and
  ``idx[d]*block[d] + block[d] <= operand_shape[d]`` at *every* grid
  point (the DMA engine fetches the block whether or not the kernel
  branch reads it, so a clamp bug is a real OOB fetch);
* **coverage** — the out_specs tile the output exactly once: block
  shape divides the output shape, every tile is produced, distinct grid
  points that revisit one tile form a contiguous run in lexicographic
  grid order (the sequential minor-most axis on TPU — a non-contiguous
  revisit would clobber the online-softmax accumulator);
* **page domain** (paged wrapper) — the table-walk can only address
  pages reserved in that sequence's block-table row or the trailing
  trash page ``P - 1``, never another sequence's pages via an
  unclamped ``-1``.

The wrapper's shape arithmetic (``bs``/``pad``/``nblocks``/the table
pre-clamp) is mirrored here per wrapper name; an index map that uses a
name the harness doesn't model, or a pallas wrapper with no config
entry, is itself a finding — the harness must grow with the kernels.

Verified domain: ``S >= 1`` (dense) and ``max_pages >= 1`` (paged) —
matching what the engines can construct (a KV cache always has at
least one slot / one logical page).

Everything here is stdlib-only so the lint CI job runs without jax.
"""
from __future__ import annotations

import ast
import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.callgraph import dotted


# --------------------------------------------------------------------------
# tiny eval environment: index maps call jnp.minimum/maximum and index
# the scalar-prefetch table; on concrete ints both are plain Python
# --------------------------------------------------------------------------
class _JnpShim:
    @staticmethod
    def minimum(a, b):
        return min(a, b)

    @staticmethod
    def maximum(a, b):
        return max(a, b)

    @staticmethod
    def where(c, a, b):
        return a if c else b


class _Table:
    """Scalar-prefetch block table: supports ``t[b, i]``."""

    def __init__(self, rows: Sequence[Sequence[int]]):
        self.rows = [list(r) for r in rows]

    def __getitem__(self, key):
        b, i = key
        return self.rows[b][i]


@dataclasses.dataclass
class Config:
    """One concrete shape configuration for a wrapper."""
    desc: str
    env: Dict[str, int]                 # wrapper-derived scalars
    operands: List[Tuple[int, ...]]     # shapes, same order as in_specs
    table: Optional[List[List[int]]] = None   # raw block table (-1 free)
    pool_operands: Tuple[int, ...] = ()       # in_spec indices into pool


@dataclasses.dataclass
class KernelSpec:
    """Extracted pallas_call structure of one wrapper."""
    name: str
    line: int
    grid: ast.expr
    in_specs: List[Tuple[ast.expr, ast.expr, int]]   # (shape, map, line)
    out_spec: Tuple[ast.expr, ast.expr, int]
    out_shape: ast.expr
    n_prefetch: int


# --------------------------------------------------------------------------
# config matrix — dense + paged + sparse, page-size / W / depth sweeps
# --------------------------------------------------------------------------
def _dense_cfg(B, W, Hq, Hkv, hd, S, block_s) -> Config:
    G = Hq // Hkv
    bs = min(block_s, max(S, 1))
    pad = (-S) % bs
    nblocks = (S + pad) // bs
    Sp = S + pad
    env = dict(B=B, W=W, Hq=Hq, Hkv=Hkv, hd=hd, S=S, G=G, bs=bs,
               pad=pad, nblocks=nblocks, block_s=block_s)
    ops = [(B, Hkv, G * W, hd), (B, Sp, Hkv, hd), (B, Sp, Hkv, hd),
           (B, W, Hkv, hd), (B, W, Hkv, hd), (B, Sp), (B, W), (B, W),
           (W, W)]
    return Config(
        desc=f"dense B={B} W={W} Hq={Hq} Hkv={Hkv} hd={hd} S={S} "
             f"block_s={block_s} (bs={bs} pad={pad} nblocks={nblocks})",
        env=env, operands=ops)


def _paged_cfg(B, W, Hq, Hkv, hd, ps, P, tables) -> Config:
    # operand order mirrors the wrapper: q, pool_k, pool_v, scale_k,
    # scale_v, k_new, v_new, key_pos, q_pos, lo, tree_mask.  The (P, Hkv)
    # dequant scales walk the SAME table-driven index map as the pools, so
    # they join the page-domain check (a scale fetched from another
    # sequence's page would dequantize with the wrong amax).
    G = Hq // Hkv
    maxp = len(tables[0])
    env = dict(B=B, W=W, Hq=Hq, Hkv=Hkv, hd=hd, G=G, P=P, ps=ps,
               maxp=maxp)
    ops = [(B, Hkv, G * W, hd), (P, ps, Hkv, hd), (P, ps, Hkv, hd),
           (P, Hkv), (P, Hkv),
           (B, W, Hkv, hd), (B, W, Hkv, hd), (B, maxp * ps), (B, W),
           (B, W), (W, W)]
    reserved = [sum(1 for v in row if v >= 0) for row in tables]
    return Config(
        desc=f"paged B={B} W={W} Hq={Hq} Hkv={Hkv} hd={hd} ps={ps} "
             f"pages={P} maxp={maxp} reserved={reserved}",
        env=env, operands=ops, table=tables, pool_operands=(1, 2, 3, 4))


def _paged_cache_cfg(B, W, Hq, Hkv, hd, ps, P, tables) -> Config:
    """``paged_cache_attention`` (split verify path): the paged walk minus
    the tree operands — q, pool_k, pool_v, scale_k, scale_v, key_pos,
    q_pos, lo — with a (B, Hkv, maxp) grid (no trailing tree block)."""
    G = Hq // Hkv
    maxp = len(tables[0])
    env = dict(B=B, W=W, Hq=Hq, Hkv=Hkv, hd=hd, G=G, P=P, ps=ps,
               maxp=maxp)
    ops = [(B, Hkv, G * W, hd), (P, ps, Hkv, hd), (P, ps, Hkv, hd),
           (P, Hkv), (P, Hkv), (B, maxp * ps), (B, W), (B, W)]
    reserved = [sum(1 for v in row if v >= 0) for row in tables]
    return Config(
        desc=f"paged-cache B={B} W={W} Hq={Hq} Hkv={Hkv} hd={hd} ps={ps} "
             f"pages={P} maxp={maxp} reserved={reserved}",
        env=env, operands=ops, table=tables, pool_operands=(1, 2, 3, 4))


def _sparse_cfg(B, W, Hq, Hkv, hd) -> Config:
    G = Hq // Hkv
    env = dict(B=B, W=W, Hq=Hq, Hkv=Hkv, hd=hd, G=G)
    ops = [(B, Hkv, G * W, hd), (B, W, Hkv, hd), (B, W, Hkv, hd),
           (W, W)]
    return Config(desc=f"sparse B={B} W={W} Hq={Hq} Hkv={Hkv} hd={hd}",
                  env=env, operands=ops)


CONFIGS: Dict[str, List[Config]] = {
    "tree_attention": [
        _dense_cfg(2, 4, 4, 2, 8, 16, 8),      # exact block multiple
        _dense_cfg(1, 2, 2, 1, 4, 5, 4),       # padded tail (pad=3)
        _dense_cfg(3, 4, 8, 4, 16, 3, 512),    # S < block_s (bs=S)
        _dense_cfg(2, 8, 8, 2, 8, 64, 16),     # deep tree, 4 KV blocks
        _dense_cfg(1, 4, 4, 4, 8, 1, 512),     # single-slot cache
    ],
    "paged_tree_attention": [
        _paged_cfg(2, 4, 4, 2, 8, 8, 6,
                   [[0, 1, 2, -1], [3, -1, -1, -1]]),
        _paged_cfg(1, 2, 2, 1, 4, 16, 3, [[-1, -1]]),   # 0 reserved
        _paged_cfg(3, 4, 8, 4, 16, 8, 9,
                   [[0, 1, 2, 3, 4, 5], [6, 7, -1, -1, -1, -1],
                    [-1] * 6]),                          # full/partial/0
        _paged_cfg(2, 8, 8, 8, 8, 16, 4, [[0], [2]]),    # maxp=1 edge
    ],
    "paged_cache_attention": [
        _paged_cache_cfg(2, 4, 4, 2, 8, 8, 6,
                         [[0, 1, 2, -1], [3, -1, -1, -1]]),
        _paged_cache_cfg(1, 2, 2, 1, 4, 16, 3, [[-1, -1]]),
        _paged_cache_cfg(3, 4, 8, 4, 16, 8, 9,
                         [[0, 1, 2, 3, 4, 5], [6, 7, -1, -1, -1, -1],
                          [-1] * 6]),
        _paged_cache_cfg(2, 8, 8, 8, 8, 16, 4, [[0], [2]]),
    ],
    "sparse_tree_attention": [
        _sparse_cfg(2, 4, 4, 2, 8),
        _sparse_cfg(1, 2, 2, 2, 4),
        _sparse_cfg(3, 8, 8, 4, 16),
    ],
    # the W x W tree half of the split verify path: same operands as
    # sparse_tree_attention, packed-(hd + 2) partials output
    "sparse_tree_attention_partial": [
        _sparse_cfg(2, 4, 4, 2, 8),
        _sparse_cfg(1, 2, 2, 2, 4),
        _sparse_cfg(3, 8, 8, 4, 16),
    ],
}


# --------------------------------------------------------------------------
# extraction
# --------------------------------------------------------------------------
def _local_value(fn_node, name: str) -> Optional[ast.expr]:
    """Last ``name = <expr>`` assignment in the wrapper's own body."""
    found = None
    for n in ast.walk(fn_node):
        if isinstance(n, ast.Assign):
            for t in n.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    found = n.value
    return found


def _deref(fn_node, expr) -> Optional[ast.expr]:
    if isinstance(expr, ast.Name):
        return _local_value(fn_node, expr.id)
    return expr


def _blockspec_parts(call: ast.Call) -> Optional[Tuple[ast.expr, ast.expr]]:
    d = dotted(call.func)
    if d is None or d.split(".")[-1] != "BlockSpec":
        return None
    shape = call.args[0] if len(call.args) > 0 else None
    imap = call.args[1] if len(call.args) > 1 else None
    for k in call.keywords:
        if k.arg in ("block_shape",):
            shape = k.value
        elif k.arg in ("index_map",):
            imap = k.value
    if shape is None or imap is None:
        return None
    return shape, imap


def extract_kernel_spec(fn_node) -> Tuple[Optional[KernelSpec], List[str]]:
    """Parse the wrapper's pallas_call into a KernelSpec (or reasons)."""
    errors: List[str] = []
    pc = None
    for n in ast.walk(fn_node):
        if isinstance(n, ast.Call) and (dotted(n.func) or "").endswith(
                "pallas_call"):
            pc = n
    if pc is None:
        return None, ["no pallas_call found"]
    kw = {k.arg: k.value for k in pc.keywords}
    grid = _deref(fn_node, kw.get("grid"))
    in_specs = _deref(fn_node, kw.get("in_specs"))
    out_spec = _deref(fn_node, kw.get("out_specs"))
    out_shape = _deref(fn_node, kw.get("out_shape"))
    n_prefetch = 0
    gs = _deref(fn_node, kw.get("grid_spec"))
    if gs is not None:
        if not (isinstance(gs, ast.Call) and (dotted(gs.func) or "")
                .endswith("PrefetchScalarGridSpec")):
            return None, ["grid_spec is not a PrefetchScalarGridSpec call"]
        gkw = {k.arg: k.value for k in gs.keywords}
        grid = _deref(fn_node, gkw.get("grid"))
        in_specs = _deref(fn_node, gkw.get("in_specs"))
        out_spec = _deref(fn_node, gkw.get("out_specs"))
        np_ = gkw.get("num_scalar_prefetch")
        if isinstance(np_, ast.Constant) and isinstance(np_.value, int):
            n_prefetch = np_.value
        else:
            errors.append("num_scalar_prefetch is not an int literal")
    if grid is None:
        errors.append("no grid expression")
    if not isinstance(in_specs, ast.List):
        errors.append("in_specs is not a literal list of BlockSpecs")
    if out_shape is not None and isinstance(out_shape, ast.Call) and \
            (dotted(out_shape.func) or "").endswith("ShapeDtypeStruct"):
        out_shape = out_shape.args[0] if out_shape.args else None
    if out_shape is None:
        errors.append("no out_shape ShapeDtypeStruct")
    parsed_in: List[Tuple[ast.expr, ast.expr, int]] = []
    if isinstance(in_specs, ast.List):
        for e in in_specs.elts:
            parts = _blockspec_parts(e) if isinstance(e, ast.Call) else None
            if parts is None:
                errors.append(f"in_spec at line {e.lineno} is not a "
                              f"BlockSpec(shape, index_map) call")
            else:
                parsed_in.append((parts[0], parts[1], e.lineno))
    parsed_out = None
    if isinstance(out_spec, ast.Call):
        parts = _blockspec_parts(out_spec)
        if parts is not None:
            parsed_out = (parts[0], parts[1], out_spec.lineno)
    if parsed_out is None:
        errors.append("out_specs is not a BlockSpec(shape, index_map) call")
    if errors:
        return None, errors
    return KernelSpec(name=fn_node.name, line=fn_node.lineno, grid=grid,
                      in_specs=parsed_in, out_spec=parsed_out,
                      out_shape=out_shape, n_prefetch=n_prefetch), []


# --------------------------------------------------------------------------
# verification
# --------------------------------------------------------------------------
def _evaluate(expr, env: Dict) -> object:
    node = ast.Expression(body=expr)
    ast.fix_missing_locations(node)
    code = compile(node, "<kernelbounds>", "eval")
    genv = {"__builtins__": {}, "jnp": _JnpShim}
    genv.update(env)
    return eval(code, genv)          # noqa: S307 — our own parsed source


def _as_tuple(v) -> Tuple:
    return tuple(v) if isinstance(v, tuple) else (v,)


def check_spec(spec: KernelSpec, cfg: Config) -> List[Tuple[int, str]]:
    """All violations of one config against one extracted spec."""
    errs: List[Tuple[int, str]] = []

    def ev(expr, line, what):
        try:
            return _evaluate(expr, cfg.env)
        except NameError as e:
            errs.append((line, f"`{spec.name}` [{cfg.desc}]: {what} uses "
                         f"a name the bounds harness does not model "
                         f"({e}) — extend repro/analysis/kernelbounds.py"))
        except Exception as e:                      # noqa: BLE001
            errs.append((line, f"`{spec.name}` [{cfg.desc}]: {what} "
                         f"failed to evaluate: {e!r}"))
        return None

    grid = ev(spec.grid, spec.line, "grid")
    if grid is None:
        return errs
    grid = _as_tuple(grid)
    if not all(isinstance(g, int) and g >= 1 for g in grid):
        errs.append((spec.line, f"`{spec.name}` [{cfg.desc}]: grid "
                     f"evaluated to {grid!r}, expected positive ints"))
        return errs
    if len(cfg.operands) != len(spec.in_specs):
        errs.append((spec.line,
                     f"`{spec.name}` [{cfg.desc}]: {len(spec.in_specs)} "
                     f"in_specs but the harness models "
                     f"{len(cfg.operands)} operands — extend "
                     f"repro/analysis/kernelbounds.py"))
        return errs
    extra: Tuple = ()
    allowed = None
    if spec.n_prefetch:
        if spec.n_prefetch != 1 or cfg.table is None:
            errs.append((spec.line, f"`{spec.name}` [{cfg.desc}]: "
                         f"num_scalar_prefetch={spec.n_prefetch} not "
                         f"modelled (harness supports exactly one "
                         f"block table)"))
            return errs
        P = cfg.env["P"]
        clamped = [[P - 1 if v < 0 else v for v in row]
                   for row in cfg.table]
        extra = (_Table(clamped),)
        allowed = [{v for v in row if v >= 0} | {P - 1}
                   for row in cfg.table]

    points = list(itertools.product(*(range(g) for g in grid)))

    def run_spec(shape_e, map_e, line, opshape, what, pool_i=None):
        """Evaluate one BlockSpec over the grid; returns the per-point
        block indices (or None after reporting)."""
        blk = ev(shape_e, line, f"{what} block shape")
        imap = ev(map_e, line, f"{what} index map")
        if blk is None or imap is None:
            return None
        blk = _as_tuple(blk)
        if len(blk) != len(opshape):
            errs.append((line, f"`{spec.name}` [{cfg.desc}]: {what} "
                         f"block shape {blk} has rank {len(blk)} but "
                         f"the operand is rank {len(opshape)} "
                         f"{opshape}"))
            return None
        if not callable(imap):
            errs.append((line, f"`{spec.name}` [{cfg.desc}]: {what} "
                         f"index map is not callable"))
            return None
        out = []
        for pt in points:
            try:
                idx = _as_tuple(imap(*pt, *extra))
            except Exception as e:                  # noqa: BLE001
                errs.append((line, f"`{spec.name}` [{cfg.desc}]: {what} "
                             f"index map raised at grid point {pt}: "
                             f"{e!r}"))
                return None
            if len(idx) != len(blk):
                errs.append((line, f"`{spec.name}` [{cfg.desc}]: {what} "
                             f"index map returned {len(idx)} indices "
                             f"for a rank-{len(blk)} block at grid "
                             f"point {pt}"))
                return None
            for d, (i, b, s) in enumerate(zip(idx, blk, opshape)):
                if i < 0 or i * b + b > s:
                    errs.append((line, f"`{spec.name}` [{cfg.desc}]: "
                                 f"{what} block index {idx} at grid "
                                 f"point {pt} is out of bounds in dim "
                                 f"{d} (block {b} x index {i} vs "
                                 f"operand extent {s})"))
                    return None
            if pool_i is not None and allowed is not None:
                b_row = pt[0]
                if idx[0] not in allowed[b_row]:
                    errs.append((line, f"`{spec.name}` [{cfg.desc}]: "
                                 f"{what} addresses physical page "
                                 f"{idx[0]} at grid point {pt}, which "
                                 f"is neither reserved for sequence "
                                 f"{b_row} nor the trash page — the "
                                 f"table walk escapes its page set"))
                    return None
            out.append(idx)
        return out

    for i, (shape_e, map_e, line) in enumerate(spec.in_specs):
        run_spec(shape_e, map_e, line, cfg.operands[i],
                 f"in_spec[{i}]",
                 pool_i=i if i in cfg.pool_operands else None)

    out_shape = ev(spec.out_shape, spec.out_spec[2], "out_shape")
    if out_shape is None:
        return errs
    out_shape = _as_tuple(out_shape)
    shape_e, map_e, line = spec.out_spec
    idxs = run_spec(shape_e, map_e, line, out_shape, "out_spec")
    if idxs is None:
        return errs
    blk = _as_tuple(_evaluate(shape_e, cfg.env))
    bad_div = [d for d in range(len(blk)) if out_shape[d] % blk[d]]
    if bad_div:
        errs.append((line, f"`{spec.name}` [{cfg.desc}]: out block "
                     f"{blk} does not divide output shape {out_shape} "
                     f"in dims {bad_div} — tiles cannot partition the "
                     f"output"))
        return errs
    visits: Dict[Tuple, List[int]] = {}
    for n, idx in enumerate(idxs):
        visits.setdefault(idx, []).append(n)
    want = 1
    for d in range(len(blk)):
        want *= out_shape[d] // blk[d]
    if len(visits) != want:
        errs.append((line, f"`{spec.name}` [{cfg.desc}]: out_specs "
                     f"produce {len(visits)} distinct tiles but the "
                     f"output has {want} — coverage is not exactly-once"
                     f" (missing or duplicated tiles)"))
    for idx, pos in visits.items():
        if max(pos) - min(pos) + 1 != len(pos):
            errs.append((line, f"`{spec.name}` [{cfg.desc}]: output "
                         f"tile {idx} is revisited non-contiguously in "
                         f"grid order (visit steps {pos}) — on TPU "
                         f"only a contiguous minor-axis run may "
                         f"revisit a tile (accumulator semantics)"))
            break
    return errs


def verify_tree(tree: ast.Module) -> List[Tuple[int, str]]:
    """All R8 violations in one kernel module's AST."""
    errs: List[Tuple[int, str]] = []
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        has_pc = any(isinstance(n, ast.Call) and
                     (dotted(n.func) or "").endswith("pallas_call")
                     for n in ast.walk(node))
        if not has_pc:
            continue
        cfgs = CONFIGS.get(node.name)
        if cfgs is None:
            errs.append((node.lineno,
                         f"pallas wrapper `{node.name}` has no "
                         f"bounds-verification config — add a shape "
                         f"matrix entry in "
                         f"repro/analysis/kernelbounds.py"))
            continue
        spec, reasons = extract_kernel_spec(node)
        if spec is None:
            for r in reasons:
                errs.append((node.lineno,
                             f"cannot extract pallas_call structure of "
                             f"`{node.name}` for bounds verification: "
                             f"{r}"))
            continue
        for cfg in cfgs:
            errs.extend(check_spec(spec, cfg))
    return errs
