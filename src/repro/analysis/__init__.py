"""reprolint: AST-based static analysis enforcing this stack's invariants.

Six PRs of growth piled up correctness invariants that were enforced only
by convention: the decode hot path must stay device-resident (one host
sync per chunk), jitted state carries must be donated (the paged pool is
updated in place, never copied), traced code must be pure, pytrees passed
as jit arguments must be registered completely, the async server's shared
state must stay behind its lock, and every engine must implement the full
scheduler slot protocol.  Any of these can rot silently — a forgotten
``donate_argnums`` doubles the pool's memory without failing a single
test — so this package machine-checks them.

Run it with::

    PYTHONPATH=src python -m repro.analysis.lint src/

Rules (see ``src/repro/analysis/README.md`` for the full story):

* **R1 jit-purity** — no host side effects (``time.*``, ``print``,
  ``random``, ``np.*``-on-tracer, ``.item()`` / scalar coercions,
  mutable default args) inside functions reachable from jit roots
  (``jax.jit``, ``lax.scan``/``while_loop``/``fori_loop`` bodies).
* **R2 donation discipline** — a jit threading a cache/pool/state carry
  must declare ``donate_argnums``, and a donated name must not be read
  after the jitted call in the enclosing scope.
* **R3 host-sync discipline** — ``block_until_ready``, ``np.asarray``
  in the chunk-loop/boundary hot paths, and wall-clock ``time.time()``
  in measured intervals (use ``time.perf_counter()``).
* **R4 lock discipline** — attributes mutated under ``self._lock`` are
  never touched off-lock, and worker-thread-owned objects (the
  scheduler/engine behind ``AsyncEngineServer``) are never reached from
  event-loop methods.
* **R5 pytree completeness** — registered pytree classes flatten every
  field; dataclasses built inside traced code must be registered.
* **R6 slot-protocol conformance** — engines exposing any ``sched_*``
  method implement the full protocol the scheduler calls, cross-checked
  against the declared ``SchedulableEngine`` Protocol.

The implementation is stdlib-only (``ast`` + ``tokenize``): it imports
nothing from the repo under analysis and needs no third-party deps.
"""
from repro.analysis.core import Finding, lint_paths, load_baseline  # noqa: F401
