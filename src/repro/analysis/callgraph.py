"""Project index + jit-root call-graph for reprolint.

Builds, from the parsed ``Project``:

* a per-module symbol table (module-level functions, classes + methods,
  nested functions, import aliases);
* the set of **jit roots** — every callable handed to a tracing
  entry point (``jax.jit``, ``lax.scan`` / ``while_loop`` / ``fori_loop``
  / ``cond`` / ``map`` bodies, ``jax.checkpoint`` / ``grad`` /
  ``value_and_grad`` / ``vmap`` / ``pmap``) plus every def decorated with
  ``@jax.jit`` / ``@partial(jax.jit, ...)``;
* a conservative reachability walk from those roots: bare-name calls
  resolve through the lexical scope chain (enclosing defs -> module ->
  imports, cross-module), ``self.method()`` resolves through the
  enclosing class and its statically-known bases, ``module.fn()``
  resolves through import aliases.  Attribute calls on dynamic objects
  (``model.decode``) stay unresolved — polymorphic dispatch is out of
  scope, which keeps the walk noise-free.

The same index records every ``jax.jit(...)`` site with its resolved
target and donation kwargs for R2, and exposes the reached-function set
for R1/R5.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Project, SourceFile

# callables whose function-valued arguments are traced: name (last
# attribute segment) -> indices of callable args ("*" = every arg)
TRACE_ARG_POS: Dict[str, Tuple] = {
    "jit": (0,),
    "scan": (0,),
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "cond": (1, 2),
    "switch": ("*",),
    "map": (0,),
    "checkpoint": (0,),
    "remat": (0,),
    "grad": (0,),
    "value_and_grad": (0,),
    "vmap": (0,),
    "pmap": (0,),
}
# module-ish prefixes we accept for the names above (plain `jit(f)` with
# `from jax import jit` is resolved through import aliases instead)
_JAX_PREFIXES = {"jax", "lax", "jax.lax", "jax.tree_util", "functools"}


def dotted(node: ast.AST) -> Optional[str]:
    """'jax.lax.scan' for nested Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class FuncInfo:
    node: ast.AST                       # FunctionDef/AsyncFunctionDef/Lambda
    file: SourceFile
    qualname: str
    parent: object                      # FuncInfo | ClassInfo | ModuleInfo
    cls: Optional["ClassInfo"] = None   # enclosing class (for self.x())
    locals: Dict[str, "FuncInfo"] = dataclasses.field(default_factory=dict)

    @property
    def params(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in a.posonlyargs + a.args]

    @property
    def line(self) -> int:
        return self.node.lineno


@dataclasses.dataclass
class ClassInfo:
    node: ast.ClassDef
    file: SourceFile
    name: str
    module: "ModuleInfo"
    methods: Dict[str, FuncInfo] = dataclasses.field(default_factory=dict)
    base_names: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ModuleInfo:
    file: SourceFile
    name: str                                        # dotted module path
    funcs: Dict[str, FuncInfo] = dataclasses.field(default_factory=dict)
    classes: Dict[str, ClassInfo] = dataclasses.field(default_factory=dict)
    imports: Dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class JitSite:
    """One ``jax.jit(target, ...)`` call (or @jit-decorated def)."""
    call: Optional[ast.Call]            # None for decorated defs
    file: SourceFile
    scope: object                       # FuncInfo | ClassInfo | ModuleInfo
    target: Optional[FuncInfo]          # resolved jitted callable
    donate: Tuple[int, ...]             # declared donate_argnums
    has_donate: bool
    assigned_to: Optional[str]          # "name" or "self.attr" when known
    line: int


def _module_name(rel: str) -> str:
    name = rel[:-3] if rel.endswith(".py") else rel
    return name.replace("/", ".").replace("\\", ".")


class Index:
    """Symbol tables + jit roots + reachability for one Project."""

    def __init__(self, project: Project):
        self.project = project
        self.modules: Dict[str, ModuleInfo] = {}
        self.jit_sites: List[JitSite] = []
        self._trace_sites: List[Tuple[ast.Call, object, SourceFile]] = []
        self._decorated_roots: List[FuncInfo] = []
        for f in project.files:
            self._index_file(f)
        self._resolve_jit_sites()

    # ---- indexing --------------------------------------------------------
    def _index_file(self, f: SourceFile) -> None:
        mod = ModuleInfo(file=f, name=_module_name(f.rel))
        self.modules[mod.name] = mod
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    mod.imports[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    mod.imports[a.asname or a.name] = \
                        f"{node.module}.{a.name}"
        self._index_body(f.tree.body, mod, mod, None, f)

    def _index_body(self, body, scope, mod: ModuleInfo,
                    cls: Optional[ClassInfo], f: SourceFile) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = node.name if scope is mod else \
                    f"{getattr(scope, 'qualname', getattr(scope, 'name', ''))}." \
                    f"{node.name}"
                fi = FuncInfo(node=node, file=f, qualname=qual,
                              parent=scope, cls=cls)
                if isinstance(scope, ModuleInfo):
                    mod.funcs[node.name] = fi
                elif isinstance(scope, ClassInfo):
                    scope.methods[node.name] = fi
                    fi.cls = scope
                else:
                    scope.locals[node.name] = fi
                if self._has_jit_decorator(node, mod):
                    self._decorated_roots.append(fi)
                    self.jit_sites.append(JitSite(
                        call=None, file=f, scope=scope, target=fi,
                        donate=self._decorator_donate(node),
                        has_donate=self._decorator_has_donate(node),
                        assigned_to=node.name, line=node.lineno))
                self._index_body(node.body, fi, mod, fi.cls, f)
            elif isinstance(node, ast.ClassDef):
                ci = ClassInfo(node=node, file=f, name=node.name, module=mod)
                ci.base_names = [dotted(b) or "" for b in node.bases]
                mod.classes[node.name] = ci
                self._index_body(node.body, ci, mod, ci, f)
            else:
                # non-def statements: record trace-entry calls and pick up
                # defs nested inside if/for/while/with/try blocks (the
                # engine builds its chunk fns inside `if K not in ...:`)
                self._scan_stmt(node, scope, mod, cls, f)

    def _scan_stmt(self, node, scope, mod: ModuleInfo,
                   cls: Optional[ClassInfo], f: SourceFile) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                self._index_body([child], scope, mod, cls, f)
                continue
            if isinstance(child, ast.Call):
                if self._trace_entry_name(child, scope) is not None:
                    self._trace_sites.append((child, scope, f))
            self._scan_stmt(child, scope, mod, cls, f)

    def _entry_kind(self, func_node, scope) -> Optional[str]:
        """'jit'/'scan'/... when ``func_node`` names a tracing entry."""
        d = dotted(func_node)
        if d is None:
            return None
        # functools.partial(jax.jit, ...) handled by callers directly
        parts = d.split(".")
        last = parts[-1]
        if last not in TRACE_ARG_POS:
            return None
        prefix = ".".join(parts[:-1])
        if prefix in _JAX_PREFIXES or prefix.endswith(".lax"):
            return last
        if not prefix:
            # bare name: accept if imported from jax ('from jax import jit')
            mod = self._module_of(scope)
            tgt = mod.imports.get(last, "") if mod else ""
            if tgt.startswith("jax"):
                return last
        return None

    def _trace_entry_name(self, call: ast.Call, scope) -> Optional[str]:
        kind = self._entry_kind(call.func, scope)
        if kind is not None:
            return kind
        # partial(jax.jit, ...) / functools.partial(jax.jit, ...)
        d = dotted(call.func)
        if d in ("partial", "functools.partial") and call.args:
            inner = self._entry_kind(call.args[0], scope)
            if inner == "jit":
                return "jit"
        return None

    # ---- decorator helpers ----------------------------------------------
    def _jit_decorators(self, node):
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call):
                d = dotted(dec.func)
                if d in ("partial", "functools.partial") and dec.args and \
                        dotted(dec.args[0]) in ("jax.jit", "jit"):
                    yield dec
                elif d in ("jax.jit", "jit"):
                    yield dec
            elif dotted(dec) in ("jax.jit", "jit"):
                yield dec

    def _has_jit_decorator(self, node, mod: ModuleInfo) -> bool:
        return next(self._jit_decorators(node), None) is not None

    def _decorator_donate(self, node) -> Tuple[int, ...]:
        for dec in self._jit_decorators(node):
            if isinstance(dec, ast.Call):
                return _donate_from_kwargs(dec.keywords)
        return ()

    def _decorator_has_donate(self, node) -> bool:
        for dec in self._jit_decorators(node):
            if isinstance(dec, ast.Call) and any(
                    k.arg in ("donate_argnums", "donate_argnames")
                    for k in dec.keywords):
                return True
        return False

    # ---- resolution ------------------------------------------------------
    def _module_of(self, scope) -> Optional[ModuleInfo]:
        while scope is not None and not isinstance(scope, ModuleInfo):
            scope = getattr(scope, "parent", None) or \
                getattr(scope, "module", None)
        return scope

    def _module_by_dotted(self, target: str) -> Optional[ModuleInfo]:
        """Match 'repro.runtime.cache' whether files were rooted at src/
        or at the repo root."""
        if target in self.modules:
            return self.modules[target]
        for name, mod in self.modules.items():
            if name.endswith("." + target) or target.endswith("." + name):
                return mod
        # suffix match on the tail (src/-rooted vs repo-rooted)
        for name, mod in self.modules.items():
            if name.split(".")[-1] == target.split(".")[-1] and \
                    target.split(".")[-2:] == name.split(".")[-2:]:
                return mod
        return None

    def resolve_import(self, mod: ModuleInfo, name: str
                       ) -> Optional[FuncInfo]:
        target = mod.imports.get(name)
        if not target:
            return None
        parts = target.rsplit(".", 1)
        if len(parts) == 2:
            m = self._module_by_dotted(parts[0])
            if m is not None:
                if parts[1] in m.funcs:
                    return m.funcs[parts[1]]
        return None

    def resolve_class(self, mod: ModuleInfo, name: str
                      ) -> Optional[ClassInfo]:
        if name in mod.classes:
            return mod.classes[name]
        target = mod.imports.get(name)
        if target:
            head, _, tail = target.rpartition(".")
            m = self._module_by_dotted(head)
            if m is not None and tail in m.classes:
                return m.classes[tail]
        return None

    def class_methods(self, ci: ClassInfo, *, seen=None
                      ) -> Dict[str, FuncInfo]:
        """Own + inherited methods (statically-resolved bases)."""
        seen = seen if seen is not None else set()
        if ci.name in seen:
            return {}
        seen.add(ci.name)
        out: Dict[str, FuncInfo] = {}
        for base in ci.base_names:
            bci = self.resolve_class(ci.module, base.split(".")[-1])
            if bci is not None:
                out.update(self.class_methods(bci, seen=seen))
        out.update(ci.methods)
        return out

    def resolve_call(self, call: ast.Call, scope) -> Optional[FuncInfo]:
        """Resolve a Call's callee to a project FuncInfo (or None)."""
        fn = call.func
        if isinstance(fn, ast.Name):
            s = scope
            while isinstance(s, FuncInfo):
                if fn.id in s.locals:
                    return s.locals[fn.id]
                s = s.parent
            mod = self._module_of(scope)
            if mod is None:
                return None
            if fn.id in mod.funcs:
                return mod.funcs[fn.id]
            return self.resolve_import(mod, fn.id)
        if isinstance(fn, ast.Attribute):
            base = fn.value
            if isinstance(base, ast.Name) and base.id in ("self", "cls"):
                cls = getattr(scope, "cls", None)
                if cls is not None:
                    return self.class_methods(cls).get(fn.attr)
                return None
            d = dotted(base)
            if d is not None:
                mod = self._module_of(scope)
                if mod is not None:
                    target = mod.imports.get(d)
                    if target:
                        m = self._module_by_dotted(target)
                        if m is not None and fn.attr in m.funcs:
                            return m.funcs[fn.attr]
                # Class.method / Class.staticmethod
                if mod is not None:
                    ci = self.resolve_class(mod, d.split(".")[-1])
                    if ci is not None:
                        return self.class_methods(ci).get(fn.attr)
        return None

    def _callable_arg(self, call: ast.Call, i: int, scope, f: SourceFile
                      ) -> Optional[FuncInfo]:
        if i >= len(call.args):
            return None
        arg = call.args[i]
        if isinstance(arg, ast.Lambda):
            fi = FuncInfo(node=arg, file=f,
                          qualname=f"<lambda L{arg.lineno}>", parent=scope,
                          cls=getattr(scope, "cls", None))
            return fi
        if isinstance(arg, (ast.Name, ast.Attribute)):
            fake = ast.Call(func=arg, args=[], keywords=[])
            ast.copy_location(fake, arg)
            return self.resolve_call(fake, scope)
        if isinstance(arg, ast.Call):
            # jax.checkpoint(lambda p: ...) nested inside value_and_grad
            inner = self._trace_entry_name(arg, scope)
            if inner is not None:
                return None        # its own site records the callable
        return None

    def _resolve_jit_sites(self) -> None:
        for call, scope, f in self._trace_sites:
            kind = self._trace_entry_name(call, scope)
            pos = TRACE_ARG_POS.get(kind, ())
            indices = range(len(call.args)) if pos == ("*",) else pos
            # partial(jax.jit, ...) decorates elsewhere; its callable (if
            # given positionally) is arg 1
            d = dotted(call.func)
            if d in ("partial", "functools.partial"):
                indices = (1,) if len(call.args) > 1 else ()
            for i in indices:
                target = self._callable_arg(call, i, scope, f)
                if kind == "jit":
                    self.jit_sites.append(JitSite(
                        call=call, file=f, scope=scope, target=target,
                        donate=_donate_from_kwargs(call.keywords),
                        has_donate=any(
                            k.arg in ("donate_argnums", "donate_argnames")
                            for k in call.keywords),
                        assigned_to=None, line=call.lineno))
                if target is not None:
                    self._decorated_roots.append(target)

    # ---- reachability ----------------------------------------------------
    def reached_from_jit(self) -> List[FuncInfo]:
        """Every project function reachable from any jit/scan root."""
        roots = list(self._decorated_roots)
        for site in self.jit_sites:
            if site.target is not None:
                roots.append(site.target)
        seen: Set[int] = set()
        out: List[FuncInfo] = []
        work = list(roots)
        while work:
            fi = work.pop()
            key = id(fi.node)
            if key in seen:
                continue
            seen.add(key)
            out.append(fi)
            body = [fi.node.body] if isinstance(fi.node, ast.Lambda) \
                else list(fi.node.body)
            for stmt in body:
                for node in ast.walk(stmt):
                    if not isinstance(node, ast.Call):
                        continue
                    callee = self.resolve_call(node, fi)
                    if callee is not None:
                        work.append(callee)
                    # scan/while/cond bodies nested inside traced code
                    kind = self._trace_entry_name(node, fi)
                    if kind is not None:
                        pos = TRACE_ARG_POS.get(kind, ())
                        idxs = range(len(node.args)) if pos == ("*",) \
                            else pos
                        for i in idxs:
                            t = self._callable_arg(node, i, fi, fi.file)
                            if t is not None:
                                work.append(t)
        return out


def _donate_from_kwargs(keywords) -> Tuple[int, ...]:
    for k in keywords:
        if k.arg == "donate_argnums":
            v = k.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for e in v.elts:
                    if isinstance(e, ast.Constant) and \
                            isinstance(e.value, int):
                        out.append(e.value)
                return tuple(out)
    return ()


def build_index(project: Project) -> Index:
    return Index(project)


def get_index(project: Project) -> Index:
    """One shared Index per Project (rules run over the same parse)."""
    idx = getattr(project, "_reprolint_index", None)
    if idx is None:
        idx = Index(project)
        project._reprolint_index = idx
    return idx
