"""R8 — Pallas BlockSpec bounds / coverage / page-domain verification.

The heavy lifting lives in ``repro.analysis.kernelbounds``: BlockSpec
index maps are pure Python lambdas, so they are extracted from the
kernel modules' ASTs and *executed* over every point of the concrete
grid for a matrix of representative shape configs (dense + paged +
sparse, page-size/W/depth sweeps).  This rule surfaces every violation
as a finding, and cross-checks the kernel wrappers' positional
signatures against their ``*_ref`` oracles in ``kernels/ref.py`` (an
argument-order skew between kernel and oracle makes every
kernel-vs-oracle test vacuously compare garbage).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis import kernelbounds
from repro.analysis.core import Finding, Project, register_rule

_KERNEL_FILES = ("tree_attention.py", "sparse_tree.py")


def _pos_params(node) -> List[str]:
    a = node.args
    return [p.arg for p in a.posonlyargs + a.args]


def _public_defs(tree) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body
            if isinstance(n, ast.FunctionDef)
            and not n.name.startswith("_")}


def _oracle_for(name: str, oracles: Dict[str, ast.FunctionDef]
                ) -> Optional[Tuple[str, ast.FunctionDef]]:
    """`X` -> `X_ref`, else the longest `stem_ref` with `stem` a prefix
    of `X` (``sparse_tree_attention`` -> ``sparse_tree_ref``)."""
    if f"{name}_ref" in oracles:
        return f"{name}_ref", oracles[f"{name}_ref"]
    best = None
    for oname, onode in oracles.items():
        if not oname.endswith("_ref"):
            continue
        stem = oname[:-4]
        if name.startswith(stem) and (
                best is None or len(stem) > len(best[0]) - 4):
            best = (oname, onode)
    return best


@register_rule(
    "R8",
    "kernel bounds verifier: BlockSpec index maps evaluated over the "
    "full concrete grid for a dense/paged shape matrix — in-bounds, "
    "exactly-once output coverage, page-domain containment — plus "
    "kernel-vs-oracle signature cross-check")
def rule_kernelbounds(project: Project) -> List[Finding]:
    out: List[Finding] = []

    kernel_files = [f for f in project.files
                    if f.rel.rsplit("/", 1)[-1] in _KERNEL_FILES]
    for f in kernel_files:
        for line, msg in kernelbounds.verify_tree(f.tree):
            out.append(Finding(path=f.rel, line=line, rule="R8",
                               message=msg))

    ref = project.find("kernels/ref.py")
    if ref is not None:
        oracles = _public_defs(ref.tree)
        for f in kernel_files:
            for name, node in _public_defs(f.tree).items():
                hit = _oracle_for(name, oracles)
                if hit is None:
                    out.append(Finding(
                        path=f.rel, line=node.lineno, rule="R8",
                        message=f"kernel wrapper `{name}` has no *_ref "
                                f"oracle in kernels/ref.py — the "
                                f"kernel-vs-oracle sweep cannot cover "
                                f"it"))
                    continue
                oname, onode = hit
                wp, op = _pos_params(node), _pos_params(onode)
                if wp != op:
                    out.append(Finding(
                        path=f.rel, line=node.lineno, rule="R8",
                        message=f"kernel wrapper `{name}` positional "
                                f"signature {wp} does not match oracle "
                                f"`{oname}` {op} — argument-order skew "
                                f"makes every allclose test compare "
                                f"garbage"))
        ops = project.find("kernels/ops.py")
        if ops is not None:
            for name, node in _public_defs(ops.tree).items():
                if _oracle_for(name, oracles) is None:
                    out.append(Finding(
                        path=ops.rel, line=node.lineno, rule="R8",
                        message=f"public kernel op `{name}` has no "
                                f"*_ref oracle in kernels/ref.py"))
    return out
