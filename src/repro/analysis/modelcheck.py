"""R9 — bounded exhaustive model checking of the scheduler boundary protocol.

``SchedModel`` is a pure-host mirror of ``ContinuousScheduler`` stepping a
paged engine: same boundary phase order (abort sweep -> chunked-prefill
extend -> admissions -> chunk -> flush -> evict), same FIFO-by-(arrival,
req_id) queue, same page arithmetic (``pages_for(prompt + budget +
overshoot)`` capped by ``max_pages`` and the pool), same bootstrap bypass
of ``sched_can_admit`` on the very first admission.  Tokens are modeled as
counts (sequential decode, ``eos=None``): an admission emits 1, a chunk
emits ``min(K, rem)`` per live row with ``K = _pow2_chunk(chunk, max rem)``.

``explore`` drives the model through EVERY interleaving of
``submit``/``abort``/``boundary``/crash (``fail_all``) up to the configured
request set, with the crash injectable at every reachable state, and
memoizes canonical states so the search is exhaustive yet finite.  After
each transition four invariants are checked:

  I1  page conservation  — free + sum(held by resident rows) == n_pages,
      free >= 0, at every step (including mid-crash cleanup);
  I2  exactly-once typed terminals — each request is finalized at most
      once, always with a TERMINAL state, and every quiescent all-terminal
      state accounts for every submitted request;
  I3  release-before-admission — within one boundary, every page release
      from the abort sweep precedes every admission (a same-boundary
      admission may fund itself from just-freed pages, never the reverse);
  I4  no admission after ``fail_all`` — a crashed replica's scheduler
      admits nothing, ever (``fail_all`` must drain the queue).

State-space bound (the documented gate): 3 requests x {all submit orders}
x {abort of any active request} x {crash at every reachable point} x
boundaries to quiescence, deduplicated on canonical state.  The default
configuration (batch=2, pool=5 pages, one chunked-prefill request, pool
pressure forcing deferral) explores the full space in well under a second;
``--max-seconds`` is a hard wall-clock cap — exceeding it fails the run,
because an unfinished exploration proves nothing.

The model is validated against the real ``ContinuousScheduler`` +
``PageAllocator`` in ``tests/test_modelcheck.py`` by replaying identical
action traces on both and comparing terminal states, emission counts and
per-boundary pool occupancy.  Out of model scope (documented): deadlines,
EOS stopping, capacity freezes (configs keep ``need <= min(max_pages,
n_pages)`` so reservations are never partial), aging/priority policies.
"""
from __future__ import annotations

import argparse
import bisect
import dataclasses
import time
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

# lifecycle vocabulary, mirrored from repro.runtime.scheduler (kept local:
# the linter must import without jax on the path)
QUEUED = "QUEUED"
PREFILLING = "PREFILLING"
DECODING = "DECODING"
DONE = "DONE"
CANCELLED = "CANCELLED"
TIMED_OUT = "TIMED_OUT"
FAILED = "FAILED"
TERMINAL_STATES = frozenset({DONE, CANCELLED, TIMED_OUT, FAILED})


def pages_for(n_tokens: int, page_size: int) -> int:
    return -(-int(n_tokens) // int(page_size))


def _pow2_chunk(k_max: int, need: int) -> int:
    """Mirror of ``repro.runtime.engine._pow2_chunk``."""
    k = 1
    while k < need and k < k_max:
        k *= 2
    return min(k, k_max)


class ModelViolation(AssertionError):
    """An invariant (I1-I4) failed during a transition."""


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    batch: int = 2
    chunk: int = 2
    prefill_chunk: int = 2       # C: 0 disables chunked prefill
    page_size: int = 4
    n_pages: int = 5             # pool; tight enough to force deferral
    max_len: int = 64
    overshoot: int = 1           # sequential engine: one chain slot

    @property
    def max_pages(self) -> int:
        return pages_for(self.max_len, self.page_size)


@dataclasses.dataclass(frozen=True)
class ModelRequest:
    req_id: int
    prompt_len: int
    n_tokens: int


class SchedModel:
    """Host model of one scheduler stream over a paged engine."""

    def __init__(self, cfg: ModelConfig, reqs: Sequence[ModelRequest]):
        self.cfg = cfg
        self.reqs: Dict[int, ModelRequest] = {r.req_id: r for r in reqs}
        self.pending: List[int] = []            # sorted by req_id (arrival=0)
        self.slots: List[Optional[dict]] = [None] * cfg.batch
        self.free = cfg.n_pages
        self.results: Dict[int, Tuple[str, int]] = {}   # id -> (state, n)
        self.state_of: Dict[int, str] = {}
        self.aborts: Dict[int, str] = {}
        self.submitted: set = set()
        self.started = False                    # mirrors `_dev is not None`
        self.failed = False                     # fail_all() happened
        self.boundary_events: List[str] = []    # last boundary, for I3/I4

    # ---- canonical state (memoization key for the explorer) -------------
    def snapshot(self) -> tuple:
        return (
            tuple(self.pending),
            tuple(None if s is None else
                  (s["id"], s["out"], s["rem"], s["done"],
                   s["left"], s["pages"]) for s in self.slots),
            self.free,
            tuple(sorted(self.results.items())),
            tuple(sorted(self.state_of.items())),
            tuple(sorted(self.aborts.items())),
            frozenset(self.submitted),
            self.started,
            self.failed,
        )

    @classmethod
    def from_snapshot(cls, cfg: ModelConfig, reqs: Sequence[ModelRequest],
                      snap: tuple) -> "SchedModel":
        m = cls(cfg, reqs)
        (pending, slots, free, results, state_of, aborts,
         submitted, started, failed) = snap
        m.pending = list(pending)
        m.slots = [None if s is None else
                   {"id": s[0], "out": s[1], "rem": s[2], "done": s[3],
                    "left": s[4], "pages": s[5]} for s in slots]
        m.free = free
        m.results = dict(results)
        m.state_of = dict(state_of)
        m.aborts = dict(aborts)
        m.submitted = set(submitted)
        m.started = started
        m.failed = failed
        return m

    # ---- internals ------------------------------------------------------
    def _finalize(self, req_id: int, n_emitted: int, state: str) -> None:
        if req_id in self.results:
            raise ModelViolation(
                f"I2: request {req_id} finalized twice "
                f"(was {self.results[req_id][0]}, now {state})")
        if state not in TERMINAL_STATES:
            raise ModelViolation(
                f"I2: request {req_id} finalized with non-terminal "
                f"state {state!r}")
        self.results[req_id] = (state, n_emitted)
        self.state_of.pop(req_id, None)

    def _release(self, slot: dict, kind: str) -> None:
        self.free += slot["pages"]
        slot["pages"] = 0
        self.boundary_events.append(kind)

    def _need_pages(self, req: ModelRequest) -> int:
        c = self.cfg
        return min(pages_for(req.prompt_len + req.n_tokens + c.overshoot,
                             c.page_size),
                   c.max_pages, c.n_pages)

    def _check_conservation(self) -> None:
        held = sum(s["pages"] for s in self.slots if s is not None)
        if self.free < 0 or self.free + held != self.cfg.n_pages:
            raise ModelViolation(
                f"I1: page conservation broken — free={self.free} "
                f"held={held} pool={self.cfg.n_pages}")

    def _check_boundary_order(self) -> None:
        ev = self.boundary_events
        if self.failed and "admit" in ev:
            raise ModelViolation(
                "I4: admission event inside a boundary after fail_all")
        first_admit = next((i for i, e in enumerate(ev) if e == "admit"),
                           None)
        if first_admit is not None and any(
                e == "abort_release" for e in ev[first_admit:]):
            raise ModelViolation(
                "I3: abort release ordered AFTER an admission within one "
                "boundary")

    # ---- the stepping API -----------------------------------------------
    def submit(self, req_id: int) -> None:
        if req_id in self.state_of or req_id in self.submitted:
            raise ValueError(f"req_id {req_id} already submitted")
        self.submitted.add(req_id)
        self.state_of[req_id] = QUEUED
        bisect.insort(self.pending, req_id)   # arrivals all 0: FIFO == id
        self._check_conservation()

    def abort(self, req_id: int, state: str = CANCELLED) -> None:
        if state not in TERMINAL_STATES:
            raise ValueError(f"not a terminal state: {state!r}")
        if req_id not in self.results:
            self.aborts.setdefault(req_id, state)

    def boundary(self) -> Dict[int, int]:
        """One admit/chunk/evict iteration; returns {req_id: tokens
        flushed this boundary} for trace-equivalence tests."""
        c = self.cfg
        self.boundary_events = []
        flushed: Dict[int, int] = {}
        # ---- abort sweep (releases land BEFORE admissions) --------------
        if self.aborts:
            aborts, self.aborts = self.aborts, {}
            rows = {s["id"]: b for b, s in enumerate(self.slots)
                    if s is not None}
            for req_id, state in aborts.items():
                if req_id in self.results:
                    continue
                if req_id in rows:
                    s = self.slots[rows[req_id]]
                    kept = min(s["out"], self.reqs[req_id].n_tokens)
                    self._finalize(req_id, kept, state)
                    self._release(s, "abort_release")
                    self.slots[rows[req_id]] = None
                elif req_id in self.pending:
                    self.pending.remove(req_id)
                    self._finalize(req_id, 0, state)
        # ---- chunked prefill: one piece per row per boundary ------------
        for s in self.slots:
            if s is None or s["left"] is None:
                continue
            piece = min(c.prefill_chunk, s["left"])
            s["left"] -= piece
            if s["left"] == 0:            # last piece: the row goes live
                s["left"] = None
                s["out"] = 1
                s["done"] = False
                s["rem"] = max(self.reqs[s["id"]].n_tokens - 1, 0)
                self.state_of[s["id"]] = DECODING
        # ---- admissions (FIFO; bootstrap bypasses can_admit) ------------
        for b in range(c.batch):
            if self.slots[b] is not None or not self.pending:
                continue
            req = self.reqs[self.pending[0]]
            need = self._need_pages(req)
            bootstrap = not self.started
            if not bootstrap and self.free < need:
                break                     # pick() returned None: defer
            self.pending.pop(0)
            self.free -= need
            self.started = True
            chunked = bool(c.prefill_chunk) and \
                req.prompt_len > c.prefill_chunk
            if chunked:
                self.slots[b] = {"id": req.req_id, "out": 0, "rem": 0,
                                 "done": True,
                                 "left": req.prompt_len - c.prefill_chunk,
                                 "pages": need}
                self.state_of[req.req_id] = PREFILLING
            else:
                self.slots[b] = {"id": req.req_id, "out": 1,
                                 "rem": max(req.n_tokens - 1, 0),
                                 "done": False, "left": None,
                                 "pages": need}
                self.state_of[req.req_id] = DECODING
            self.boundary_events.append("admit")
        occupied = [b for b in range(c.batch) if self.slots[b] is not None]
        if not occupied:
            self._check_boundary_order()
            self._check_conservation()
            return flushed
        # ---- one chunk over the bank ------------------------------------
        live = [b for b in occupied
                if not self.slots[b]["done"] and self.slots[b]["rem"] > 0]
        if live:
            K = _pow2_chunk(c.chunk,
                            max(self.slots[b]["rem"] for b in live))
            for b in live:
                s = self.slots[b]
                m = min(K, s["rem"])
                s["rem"] -= m
                s["out"] += m
                if s["rem"] <= 0:
                    s["done"] = True
        # ---- flush (model: everything new up to the budget) -------------
        for b in occupied:
            s = self.slots[b]
            if s is None or s["left"] is not None:
                continue
            avail = min(s["out"], self.reqs[s["id"]].n_tokens)
            prev = s.get("flushed", 0)
            if avail > prev:
                flushed[s["id"]] = avail - prev
                s["flushed"] = avail
        # ---- evictions ---------------------------------------------------
        for b in occupied:
            s = self.slots[b]
            if s is None or s["left"] is not None:
                continue
            budget = self.reqs[s["id"]].n_tokens
            if not (s["done"] or s["rem"] <= 0 or s["out"] >= budget):
                continue
            self._finalize(s["id"], min(s["out"], budget), DONE)
            self._release(s, "evict_release")
            self.slots[b] = None
        self._check_boundary_order()
        self._check_conservation()
        return flushed

    def fail_all(self) -> None:
        """Replica-crash cleanup: everything in flight or queued fails."""
        self.failed = True
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            kept = min(s["out"], self.reqs[s["id"]].n_tokens)
            self._finalize(s["id"], kept, FAILED)
            self._release(s, "fail_release")
            self.slots[b] = None
        for req_id in self.pending:
            self._finalize(req_id, 0, FAILED)
        self.pending = []
        self.aborts = {}
        self._check_conservation()

    # ---- quiescence ------------------------------------------------------
    def all_terminal(self) -> bool:
        return (bool(self.submitted)
                and not self.pending and not self.state_of
                and all(s is None for s in self.slots))

    def terminal_problems(self) -> List[str]:
        """I2 completeness + drained pool, checked at quiescent states."""
        out = []
        for req_id in sorted(self.submitted):
            got = self.results.get(req_id)
            if got is None:
                out.append(f"I2: request {req_id} submitted but never "
                           f"finalized")
            elif got[0] not in TERMINAL_STATES:
                out.append(f"I2: request {req_id} ended in non-terminal "
                           f"state {got[0]!r}")
        if self.free != self.cfg.n_pages:
            out.append(f"I1: pool not drained at quiescence — "
                       f"free={self.free} of {self.cfg.n_pages}")
        return out


# --------------------------------------------------------------------------
# exhaustive interleaving explorer
# --------------------------------------------------------------------------
Action = Tuple  # ("submit", id) | ("abort", id) | ("boundary",) | ("crash",)


@dataclasses.dataclass
class ExploreResult:
    states: int
    transitions: int
    violations: List[Tuple[Tuple[Action, ...], str]]
    complete: bool

    @property
    def ok(self) -> bool:
        return self.complete and not self.violations


def _enabled(m: SchedModel, all_ids: Sequence[int]) -> List[Action]:
    acts: List[Action] = [("boundary",)]
    if not m.failed:
        for rid in all_ids:
            if rid not in m.submitted:
                acts.append(("submit", rid))
        for rid in sorted(m.state_of):
            if rid not in m.aborts:
                acts.append(("abort", rid))
        acts.append(("crash",))
    return acts


def _apply(m: SchedModel, act: Action) -> None:
    if act[0] == "submit":
        m.submit(act[1])
    elif act[0] == "abort":
        m.abort(act[1])
    elif act[0] == "boundary":
        m.boundary()
    elif act[0] == "crash":
        m.fail_all()
    else:  # pragma: no cover - explorer bug
        raise ValueError(f"unknown action {act!r}")


def explore(reqs: Sequence[ModelRequest], cfg: ModelConfig,
            max_seconds: Optional[float] = None,
            max_states: int = 2_000_000) -> ExploreResult:
    """DFS over every interleaving of the stepping API (crash injected at
    every reachable state), deduplicated on canonical model state."""
    all_ids = sorted(r.req_id for r in reqs)
    root = SchedModel(cfg, reqs)
    snap0 = root.snapshot()
    visited: set = {snap0}
    stack: List[Tuple[tuple, Tuple[Action, ...]]] = [(snap0, ())]
    violations: List[Tuple[Tuple[Action, ...], str]] = []
    transitions = 0
    complete = True
    deadline = (time.perf_counter() + max_seconds
                if max_seconds is not None else None)
    while stack:
        if deadline is not None and time.perf_counter() > deadline:
            complete = False
            break
        if len(visited) > max_states:
            complete = False
            break
        snap, path = stack.pop()
        m0 = SchedModel.from_snapshot(cfg, reqs, snap)
        for act in _enabled(m0, all_ids):
            m = SchedModel.from_snapshot(cfg, reqs, snap)
            transitions += 1
            try:
                _apply(m, act)
            except ModelViolation as e:
                violations.append((path + (act,), str(e)))
                continue
            if m.all_terminal():
                for msg in m.terminal_problems():
                    violations.append((path + (act,), msg))
            nxt = m.snapshot()
            if nxt not in visited:
                visited.add(nxt)
                stack.append((nxt, path + (act,)))
    return ExploreResult(states=len(visited), transitions=transitions,
                         violations=violations, complete=complete)


# --------------------------------------------------------------------------
# the documented default bound: 3 requests, pool pressure, chunked prefill
# --------------------------------------------------------------------------
DEFAULT_CONFIG = ModelConfig(batch=2, chunk=2, prefill_chunk=2,
                             page_size=4, n_pages=5, max_len=64,
                             overshoot=1)
DEFAULT_REQUESTS = (
    ModelRequest(req_id=1, prompt_len=3, n_tokens=2),   # whole-prompt
    ModelRequest(req_id=2, prompt_len=5, n_tokens=3),   # chunked prefill
    ModelRequest(req_id=3, prompt_len=2, n_tokens=2),   # fits beside #1
)


def render_trace(path: Sequence[Action]) -> str:
    return " -> ".join(
        act[0] if len(act) == 1 else f"{act[0]}({act[1]})" for act in path)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.modelcheck",
        description="Exhaustively model-check the scheduler boundary "
                    "protocol (pages, terminals, ordering, crash safety).")
    ap.add_argument("--max-seconds", type=float, default=120.0,
                    help="wall-clock cap; an unfinished exploration FAILS")
    args = ap.parse_args(argv)
    t0 = time.perf_counter()
    res = explore(DEFAULT_REQUESTS, DEFAULT_CONFIG,
                  max_seconds=args.max_seconds)
    dt = time.perf_counter() - t0
    print(f"modelcheck: {res.states} states, {res.transitions} transitions "
          f"in {dt:.2f}s ({len(DEFAULT_REQUESTS)} requests, batch="
          f"{DEFAULT_CONFIG.batch}, pool={DEFAULT_CONFIG.n_pages} pages, "
          f"crash at every reachable state)")
    if not res.complete:
        print("modelcheck: FAIL — exploration did not finish inside the "
              "wall-clock cap; the bound was NOT verified")
        return 1
    if res.violations:
        for path, msg in res.violations[:20]:
            print(f"modelcheck: VIOLATION {msg}")
            print(f"  trace: {render_trace(path)}")
        more = len(res.violations) - 20
        if more > 0:
            print(f"modelcheck: ... and {more} more")
        return 1
    print("modelcheck: OK — all invariants hold over the full bound")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
