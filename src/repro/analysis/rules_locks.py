"""R4 — lock + thread-ownership discipline for the async serving plane.

PR 6 split the serving stack across two threads: the asyncio event loop
(submit/cancel/health) and a dedicated worker that owns the scheduler.
Two static checks keep that split honest:

**R4a (guarded attributes).**  Any ``self.X`` that is *mutated* inside a
``with self._lock:`` block is lock-guarded by definition; every other
access to it (read or write, any method except ``__init__``) must also
hold the lock.  A guarded counter read off-lock is exactly the torn-read
race that only fires under load.

**R4b (worker ownership).**  A class that spawns
``threading.Thread(target=self._run)`` hands the worker exclusive
ownership of the scheduler: ``self.scheduler`` / ``self.engine`` may be
touched only from methods reachable from ``_run`` (plus ``__init__``
and the spawning method, which run before the thread exists).  Any
module that uses such a class (the router) must not reach through
``.scheduler`` at all — cross-thread audits go through worker-published
snapshots.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.core import Finding, Project, register_rule
from repro.analysis.callgraph import dotted

_MUTATORS = {"append", "appendleft", "extend", "add", "remove", "discard",
             "pop", "popleft", "popitem", "clear", "update", "insert",
             "put", "put_nowait", "setdefault", "sort", "reverse"}
_OWNED_ATTRS = {"scheduler", "engine"}


def _lockish(attr: str) -> bool:
    return "lock" in attr or attr in ("_mu", "_cv", "_cond", "_mutex")


def _with_locks(node) -> bool:
    for item in node.items:
        expr = item.context_expr
        if isinstance(expr, ast.Attribute) and \
                isinstance(expr.value, ast.Name) and \
                expr.value.id == "self" and _lockish(expr.attr):
            return True
    return False


class _Access:
    __slots__ = ("attr", "write", "locked", "line", "method")

    def __init__(self, attr, write, locked, line, method):
        self.attr, self.write = attr, write
        self.locked, self.line, self.method = locked, line, method


def _collect_accesses(cls_node) -> List[_Access]:
    out: List[_Access] = []

    def visit(node, locked: bool, method: str):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            inner = locked or _with_locks(node)
            for item in node.items:
                visit(item.context_expr, locked, method)
            for child in node.body:
                visit(child, inner, method)
            return
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                base = t
                if isinstance(base, ast.Subscript):
                    base = base.value
                if isinstance(base, ast.Attribute) and \
                        isinstance(base.value, ast.Name) and \
                        base.value.id == "self" and not _lockish(base.attr):
                    out.append(_Access(base.attr, True, locked,
                                       base.lineno, method))
            visit(node.value, locked, method)
            if isinstance(node, ast.AugAssign):
                return
            for t in node.targets:
                if isinstance(t, ast.Subscript):
                    visit(t.slice, locked, method)
            return
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in _MUTATORS:
            base = node.func.value
            if isinstance(base, ast.Attribute) and \
                    isinstance(base.value, ast.Name) and \
                    base.value.id == "self" and not _lockish(base.attr):
                out.append(_Access(base.attr, True, locked,
                                   base.lineno, method))
                for a in node.args:
                    visit(a, locked, method)
                for k in node.keywords:
                    visit(k.value, locked, method)
                return
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == "self" and not _lockish(node.attr):
            out.append(_Access(node.attr, False, locked,
                               node.lineno, method))
            return
        for child in ast.iter_child_nodes(node):
            visit(child, locked, method)

    for m in cls_node.body:
        if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for stmt in m.body:
                visit(stmt, False, m.name)
    return out


def _thread_entries(cls_node) -> Set[str]:
    """Names of methods handed to threading.Thread(target=self.X)."""
    entries: Set[str] = set()
    for node in ast.walk(cls_node):
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if d is not None and d.split(".")[-1] == "Thread":
                for kw in node.keywords:
                    if kw.arg == "target" and \
                            isinstance(kw.value, ast.Attribute) and \
                            isinstance(kw.value.value, ast.Name) and \
                            kw.value.value.id == "self":
                        entries.add(kw.value.attr)
    return entries


def _spawning_methods(cls_node) -> Set[str]:
    out: Set[str] = set()
    for m in cls_node.body:
        if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _thread_entries_in(m):
                out.add(m.name)
    return out


def _thread_entries_in(node) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            d = dotted(sub.func)
            if d is not None and d.split(".")[-1] == "Thread":
                return True
    return False


def _worker_closure(cls_node, entries: Set[str]) -> Set[str]:
    """entries + every self-method transitively called from them."""
    methods = {m.name: m for m in cls_node.body
               if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
    reached = set()
    work = [e for e in entries if e in methods]
    while work:
        name = work.pop()
        if name in reached:
            continue
        reached.add(name)
        for sub in ast.walk(methods[name]):
            if isinstance(sub, ast.Call) and \
                    isinstance(sub.func, ast.Attribute) and \
                    isinstance(sub.func.value, ast.Name) and \
                    sub.func.value.id == "self" and \
                    sub.func.attr in methods:
                work.append(sub.func.attr)
    return reached


@register_rule(
    "R4",
    "lock discipline: lock-guarded attributes never touched off-lock; "
    "worker-owned scheduler never reached from the event loop")
def rule_locks(project: Project) -> List[Finding]:
    out: List[Finding] = []
    seen = set()

    def add(rel, line, msg):
        if (rel, line, msg) not in seen:
            seen.add((rel, line, msg))
            out.append(Finding(path=rel, line=line, rule="R4", message=msg))

    threaded_classes: Set[str] = set()
    class_nodes = []            # (file, cls_node)
    for f in project.files:
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ClassDef):
                class_nodes.append((f, node))
                if _thread_entries(node):
                    threaded_classes.add(node.name)

    for f, cls_node in class_nodes:
        accesses = _collect_accesses(cls_node)
        # R4a: guarded = mutated under lock anywhere in the class
        guarded = {a.attr for a in accesses if a.write and a.locked}
        for a in accesses:
            if a.attr in guarded and not a.locked and \
                    a.method != "__init__":
                verb = "written" if a.write else "read"
                add(f.rel, a.line,
                    f"`self.{a.attr}` is lock-guarded (mutated under "
                    f"`self._lock`) but {verb} off-lock in "
                    f"`{cls_node.name}.{a.method}`")
        # R4b: worker-owned attrs only from the worker closure
        entries = _thread_entries(cls_node)
        if entries:
            allowed = _worker_closure(cls_node, entries) | {"__init__"} \
                | _spawning_methods(cls_node)
            for a in accesses:
                if a.attr in _OWNED_ATTRS and a.method not in allowed:
                    add(f.rel, a.line,
                        f"worker-owned `self.{a.attr}` reached from "
                        f"`{cls_node.name}.{a.method}` (event-loop side; "
                        f"only the worker thread may touch it — publish "
                        f"a snapshot instead)")

    # R4b cross-object: modules that use a thread-owning class must not
    # reach through `.scheduler` of another object at all
    for f in project.files:
        uses_threaded = False
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ImportFrom):
                if any(a.name in threaded_classes for a in node.names):
                    uses_threaded = True
        if not uses_threaded:
            continue
        for f2, cls_node in class_nodes:
            if f2 is not f or cls_node.name in threaded_classes:
                continue
            for m in cls_node.body:
                if not isinstance(m, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    continue
                for sub in ast.walk(m):
                    if isinstance(sub, ast.Attribute) and \
                            sub.attr == "scheduler" and \
                            not (isinstance(sub.value, ast.Name)
                                 and sub.value.id == "self"):
                        add(f.rel, sub.lineno,
                            f"`{cls_node.name}.{m.name}` reaches through "
                            f"`.scheduler` of a worker-owned replica — "
                            f"cross-thread audits must use the server's "
                            f"published snapshot")
    return out
